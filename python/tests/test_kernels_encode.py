"""L1 correctness: Pallas mds_encode vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.mds_encode import (
    encode_block_shape,
    mds_encode,
    DEFAULT_BLOCK_M,
    DEFAULT_BLOCK_N,
    DEFAULT_BLOCK_K,
)
from compile.kernels import ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


class TestEncodeFixedShapes:
    @pytest.mark.parametrize(
        "m,k,n", [(8, 8, 8), (16, 8, 32), (256, 128, 256), (96, 64, 32)]
    )
    def test_matches_ref(self, m, k, n):
        g = rand(m * 3 + k, (m, k))
        a = rand(n + 17, (k, n))
        np.testing.assert_allclose(
            mds_encode(g, a), ref.encode_ref(g, a), rtol=1e-4, atol=1e-4
        )

    def test_explicit_blocks(self):
        g = rand(1, (64, 96))
        a = rand(2, (96, 48))
        got = mds_encode(g, a, block_m=32, block_n=16, block_k=24)
        np.testing.assert_allclose(got, ref.encode_ref(g, a), rtol=1e-4, atol=1e-4)

    def test_systematic_prefix_is_identity_copy(self):
        # G = [I; P]: the first k coded rows must equal A exactly (up to
        # f32 accumulation order).
        k, n = 32, 16
        p = rand(3, (16, k))
        g = jnp.concatenate([jnp.eye(k), p], axis=0)
        a = rand(4, (k, n))
        coded = mds_encode(g, a)
        np.testing.assert_allclose(coded[:k], a, rtol=1e-5, atol=1e-5)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError, match="shape mismatch"):
            mds_encode(jnp.zeros((8, 8)), jnp.zeros((16, 8)))

    def test_bad_block_raises(self):
        with pytest.raises(ValueError, match="must divide"):
            mds_encode(jnp.zeros((8, 8)), jnp.zeros((8, 12)),
                       block_m=8, block_n=8, block_k=8)


class TestEncodeHypothesis:
    @settings(max_examples=20, deadline=None)
    @given(
        nm=st.integers(1, 3), nk=st.integers(1, 3), nn=st.integers(1, 3),
        bm=st.sampled_from([8, 16]), bk=st.sampled_from([8, 16]),
        bn=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_shape_sweep(self, nm, nk, nn, bm, bk, bn, seed):
        m, k, n = nm * bm, nk * bk, nn * bn
        g = rand(seed, (m, k))
        a = rand(seed ^ 0x5555, (k, n))
        got = mds_encode(g, a, block_m=bm, block_n=bn, block_k=bk)
        np.testing.assert_allclose(got, ref.encode_ref(g, a), rtol=1e-4, atol=1e-4)


class TestEncodeBlockHelper:
    @settings(max_examples=40, deadline=None)
    @given(m=st.integers(1, 500), k=st.integers(1, 300), n=st.integers(1, 500))
    def test_divides_and_capped(self, m, k, n):
        bm, bn, bk = encode_block_shape(m, k, n)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0
        assert bm <= DEFAULT_BLOCK_M and bn <= DEFAULT_BLOCK_N and bk <= DEFAULT_BLOCK_K
