"""AOT path: lowering produces valid HLO text that matches jit numerics.

The rust runtime's only contract with python is the HLO text + manifest;
these tests pin that contract.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


class TestLowering:
    def test_matvec_hlo_text(self):
        text = aot.lower_matvec(8, 16, 1)
        assert "ENTRY" in text and "HloModule" in text
        # return_tuple=True → root is a tuple (rust unwraps with to_tuple1)
        assert "tuple" in text

    def test_encode_hlo_text(self):
        text = aot.lower_encode(16, 8, 8)
        assert "ENTRY" in text and "HloModule" in text

    def test_native_matvec_hlo_text(self):
        text = aot.lower_matvec(8, 16, 1, native=True)
        assert "ENTRY" in text
        # the native twin must not contain the pallas interpret machinery
        assert "while" not in text.lower() or len(text) < 20000

    def test_hlo_matches_jit_numerics(self):
        """Executing the lowered computation via xla_client reproduces the
        jitted function — the same check the rust side performs."""
        rows, cols = 16, 32
        a = jax.random.normal(jax.random.PRNGKey(0), (rows, cols))
        x = jax.random.normal(jax.random.PRNGKey(1), (cols, 1))
        want = model.worker_matvec(a, x)[0]
        got = jax.jit(model.worker_matvec)(a, x)[0]
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestManifest:
    def test_build_small(self, tmp_path, monkeypatch):
        monkeypatch.setattr(aot, "MATVEC_SHAPES", [(8, 16, 1)])
        monkeypatch.setattr(aot, "NATIVE_MATVEC_SHAPES", [(8, 16, 1)])
        monkeypatch.setattr(aot, "ENCODE_SHAPES", [(16, 8, 8)])
        manifest = aot.build(str(tmp_path))
        assert len(manifest["artifacts"]) == 3
        with open(tmp_path / "manifest.json") as f:
            loaded = json.load(f)
        assert loaded == manifest
        for e in loaded["artifacts"]:
            p = tmp_path / e["path"]
            assert p.exists() and p.stat().st_size > 0
            kinds = {"matvec", "matvec_native", "encode"}
            assert e["kind"] in kinds

    def test_manifest_buckets_sorted_usable(self):
        """Bucket table invariants the rust runtime relies on: every matvec
        bucket's rows/cols are multiples of 8, batch ≥ 1."""
        for rows, cols, batch in aot.MATVEC_SHAPES:
            assert rows % 8 == 0 and cols % 8 == 0 and batch >= 1
        for coded, rows, cols in aot.ENCODE_SHAPES:
            assert coded > rows and rows % 8 == 0 and cols % 8 == 0
