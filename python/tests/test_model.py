"""L2 correctness: padding wrapper, systematic generator, full pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


class TestPaddedMatvec:
    @pytest.mark.parametrize("rows,cols", [(1, 1), (7, 13), (100, 37), (129, 257)])
    def test_ragged_shapes(self, rows, cols):
        a = rand(rows * 1000 + cols, (rows, cols))
        x = rand(42, (cols, 1))
        got = model.padded_matvec(a, x)
        np.testing.assert_allclose(got, ref.matvec_ref(a, x), rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(rows=st.integers(1, 200), cols=st.integers(1, 200),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_ragged(self, rows, cols, seed):
        a = rand(seed, (rows, cols))
        x = rand(seed + 1, (cols, 1))
        got = model.padded_matvec(a, x)
        np.testing.assert_allclose(got, ref.matvec_ref(a, x), rtol=1e-4, atol=1e-4)

    def test_pad_to(self):
        assert model.pad_to(1, 8) == 8
        assert model.pad_to(8, 8) == 8
        assert model.pad_to(9, 8) == 16


class TestSystematicGenerator:
    def test_shape_and_identity_prefix(self):
        g = model.systematic_generator(jax.random.PRNGKey(0), 48, 32)
        assert g.shape == (48, 32)
        np.testing.assert_array_equal(g[:32], jnp.eye(32))

    def test_any_subset_invertible(self):
        key = jax.random.PRNGKey(1)
        g = model.systematic_generator(key, 24, 16)
        for seed in range(5):
            idx = jax.random.permutation(jax.random.PRNGKey(seed), 24)[:16]
            sub = g[jnp.sort(idx)]
            # well-conditioned enough to solve
            assert float(jnp.linalg.cond(sub)) < 1e6

    def test_rejects_insufficient_rows(self):
        with pytest.raises(ValueError):
            model.systematic_generator(jax.random.PRNGKey(0), 8, 16)


class TestPipeline:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_recover_from_random_subset(self, seed):
        """encode → worker compute → any-L subset → decode == A x."""
        key = jax.random.PRNGKey(seed)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        L, S, Lt = 24, 16, 40
        a = jax.random.normal(k1, (L, S))
        x = jax.random.normal(k2, (S, 1))
        g = model.systematic_generator(k3, Lt, L)
        received = jnp.sort(jax.random.permutation(k4, Lt)[:L])
        z = model.pipeline_reference(g, a, x, received)
        np.testing.assert_allclose(z, a @ x, rtol=1e-3, atol=1e-3)

    def test_systematic_fast_path(self):
        """If the first L rows arrive, decode is the identity solve."""
        key = jax.random.PRNGKey(7)
        a = jax.random.normal(key, (16, 8))
        x = jax.random.normal(jax.random.PRNGKey(8), (8, 1))
        g = model.systematic_generator(jax.random.PRNGKey(9), 24, 16)
        z = model.pipeline_reference(g, a, x, jnp.arange(16))
        np.testing.assert_allclose(z, a @ x, rtol=1e-4, atol=1e-4)

    def test_pallas_kernels_in_pipeline(self):
        """Same pipeline but with the actual Pallas kernels (block-friendly
        shapes), proving L1∘L2 compose end-to-end."""
        kA, kx, kg, kp = jax.random.split(jax.random.PRNGKey(3), 4)
        L, S, Lt = 32, 16, 48
        a = jax.random.normal(kA, (L, S))
        x = jax.random.normal(kx, (S, 1))
        g = model.systematic_generator(kg, Lt, L)
        coded = model.master_encode(g, a)[0]
        y = model.worker_matvec(coded, x)[0]
        received = jnp.sort(jax.random.permutation(kp, Lt)[:L])
        z = ref.decode_ref(g[received], y[received])
        np.testing.assert_allclose(z, a @ x, rtol=1e-3, atol=1e-3)
