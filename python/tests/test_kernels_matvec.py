"""L1 correctness: Pallas coded_matvec vs pure-jnp oracle.

This is the core build-time correctness signal: the rust runtime executes
exactly what these kernels lower to.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.coded_matvec import (
    DEFAULT_BLOCK_COLS,
    DEFAULT_BLOCK_ROWS,
    coded_matvec,
    matvec_block_shape,
    vmem_bytes,
)
from compile.kernels import ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


class TestMatvecFixedShapes:
    @pytest.mark.parametrize(
        "rows,cols,batch",
        [(8, 8, 1), (16, 32, 1), (128, 256, 1), (64, 64, 4), (256, 128, 8)],
    )
    def test_matches_ref(self, rows, cols, batch):
        a = rand(rows * 7 + cols, (rows, cols))
        x = rand(batch + 13, (cols, batch))
        got = coded_matvec(a, x)
        want = ref.matvec_ref(a, x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_single_block(self):
        a = rand(1, (8, 8))
        x = rand(2, (8, 1))
        np.testing.assert_allclose(
            coded_matvec(a, x, block_rows=8, block_cols=8),
            ref.matvec_ref(a, x), rtol=1e-5, atol=1e-6,
        )

    def test_many_k_blocks_accumulate(self):
        # k is the sequential grid axis; accumulation across 16 k-steps.
        a = rand(3, (8, 128))
        x = rand(4, (128, 1))
        got = coded_matvec(a, x, block_rows=8, block_cols=8)
        np.testing.assert_allclose(got, ref.matvec_ref(a, x), rtol=1e-5, atol=1e-5)

    def test_explicit_blocks(self):
        a = rand(5, (64, 96))
        x = rand(6, (96, 2))
        got = coded_matvec(a, x, block_rows=16, block_cols=32)
        np.testing.assert_allclose(got, ref.matvec_ref(a, x), rtol=1e-5, atol=1e-5)

    def test_bf16_inputs_f32_accumulate(self):
        a = rand(7, (32, 64), jnp.bfloat16)
        x = rand(8, (64, 1), jnp.bfloat16)
        got = coded_matvec(a, x)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(got, ref.matvec_ref(a, x), rtol=2e-2, atol=2e-2)

    def test_zero_matrix(self):
        a = jnp.zeros((16, 16))
        x = rand(9, (16, 1))
        assert float(jnp.abs(coded_matvec(a, x)).max()) == 0.0

    def test_identity_matrix(self):
        x = rand(10, (32, 1))
        got = coded_matvec(jnp.eye(32), x)
        np.testing.assert_allclose(got, x, rtol=1e-6, atol=1e-6)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError, match="shape mismatch"):
            coded_matvec(jnp.zeros((8, 8)), jnp.zeros((16, 1)))

    def test_bad_block_raises(self):
        with pytest.raises(ValueError, match="must divide"):
            coded_matvec(jnp.zeros((8, 12)), jnp.zeros((12, 1)),
                         block_rows=8, block_cols=8)


class TestMatvecHypothesis:
    @settings(max_examples=25, deadline=None)
    @given(
        nr=st.integers(1, 4), nk=st.integers(1, 4),
        br=st.sampled_from([8, 16, 32]), bc=st.sampled_from([8, 16, 32]),
        batch=st.sampled_from([1, 2, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_shape_sweep(self, nr, nk, br, bc, batch, seed):
        rows, cols = nr * br, nk * bc
        a = rand(seed, (rows, cols))
        x = rand(seed ^ 0xABCDEF, (cols, batch))
        got = coded_matvec(a, x, block_rows=br, block_cols=bc)
        np.testing.assert_allclose(got, ref.matvec_ref(a, x), rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_dtype_sweep(self, dtype, seed):
        a = rand(seed, (32, 32), dtype)
        x = rand(seed + 1, (32, 1), dtype)
        tol = 1e-4 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            coded_matvec(a, x), ref.matvec_ref(a, x), rtol=tol, atol=tol
        )


class TestBlockShapeHelper:
    @settings(max_examples=50, deadline=None)
    @given(rows=st.integers(1, 600), cols=st.integers(1, 600))
    def test_divides_and_capped(self, rows, cols):
        br, bc = matvec_block_shape(rows, cols)
        assert rows % br == 0 and cols % bc == 0
        assert br <= DEFAULT_BLOCK_ROWS and bc <= DEFAULT_BLOCK_COLS

    def test_exact_defaults(self):
        assert matvec_block_shape(1024, 512) == (
            DEFAULT_BLOCK_ROWS, DEFAULT_BLOCK_COLS)

    def test_vmem_budget(self):
        # Default tiles stay far below the 16 MiB VMEM budget.
        assert vmem_bytes(DEFAULT_BLOCK_ROWS, DEFAULT_BLOCK_COLS, 8) < 16 * 2**20 / 8
