"""AOT lowering: JAX (L2) + Pallas (L1) → HLO text artifacts for rust (L3).

Run once at build time (``make artifacts``); the rust runtime loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file``, compiles on
the CPU PJRT client, and executes — python never appears on the request
path.

Interchange format is HLO **text**, never ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Each artifact is a fixed-shape entry point; ``manifest.json`` describes the
bucket table the rust runtime pads ragged loads into.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


# ---------------------------------------------------------------------------
# Artifact table
# ---------------------------------------------------------------------------
# matvec buckets: worker loads l_{m,n} are padded up to the next `rows`
# bucket; `cols` is the (padded) task width S_m. batch=8 serves the
# iterated mat-vec of Remark 2 (and feeds the MXU, DESIGN.md §Hardware-
# Adaptation). encode buckets: coded_rows is the padded L̃_m.
MATVEC_SHAPES = [
    # (rows, cols, batch)
    (128, 256, 1),
    (128, 512, 1),
    (256, 512, 1),
    (512, 512, 1),
    (1024, 512, 1),
    (256, 512, 8),
]
NATIVE_MATVEC_SHAPES = [
    (512, 512, 1),  # ablation twin for §Perf
]
ENCODE_SHAPES = [
    # (coded_rows, rows, cols)
    (256, 128, 256),
    (2048, 1024, 512),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_matvec(rows: int, cols: int, batch: int, native: bool = False) -> str:
    fn = model.worker_matvec_native if native else model.worker_matvec
    a = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    x = jax.ShapeDtypeStruct((cols, batch), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(a, x))


def lower_encode(coded_rows: int, rows: int, cols: int) -> str:
    g = jax.ShapeDtypeStruct((coded_rows, rows), jnp.float32)
    a = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    return to_hlo_text(jax.jit(model.master_encode).lower(g, a))


def build(outdir: str) -> dict:
    os.makedirs(outdir, exist_ok=True)
    entries = []

    def emit(name: str, text: str, **meta) -> None:
        path = f"{name}.hlo.txt"
        with open(os.path.join(outdir, path), "w") as f:
            f.write(text)
        entries.append({"name": name, "path": path, **meta})
        print(f"  {name}: {len(text)} chars")

    for rows, cols, batch in MATVEC_SHAPES:
        emit(
            f"matvec_r{rows}_c{cols}_b{batch}",
            lower_matvec(rows, cols, batch),
            kind="matvec", rows=rows, cols=cols, batch=batch,
        )
    for rows, cols, batch in NATIVE_MATVEC_SHAPES:
        emit(
            f"matvec_native_r{rows}_c{cols}_b{batch}",
            lower_matvec(rows, cols, batch, native=True),
            kind="matvec_native", rows=rows, cols=cols, batch=batch,
        )
    for coded_rows, rows, cols in ENCODE_SHAPES:
        emit(
            f"encode_m{coded_rows}_k{rows}_c{cols}",
            lower_encode(coded_rows, rows, cols),
            kind="encode", coded_rows=coded_rows, rows=rows, cols=cols,
        )

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(entries)} artifacts + manifest.json to {outdir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory (or a single .hlo.txt path for "
                         "the legacy Makefile target)")
    args = ap.parse_args()
    out = args.out
    # Accept both `--out dir` and the Makefile's `--out ../artifacts/...txt`.
    outdir = os.path.dirname(out) if out.endswith(".hlo.txt") else out
    build(outdir or ".")


if __name__ == "__main__":
    main()
