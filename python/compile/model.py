"""L2: the paper's compute graph in JAX, calling the L1 Pallas kernels.

The paper's "model" is MDS-coded distributed matrix–vector multiplication
(§II): master m encodes ``Ã_m = G_m A_m`` row-wise, ships row-blocks to
workers, each worker computes ``Ã_{m,n} x_m``, and the master recovers
``A_m x_m`` from any ``L_m`` coded inner products.

This module defines the jittable entry points that ``aot.py`` lowers to HLO
text for the rust runtime:

* :func:`worker_matvec` — per-worker coded mat-vec (calls the Pallas kernel);
* :func:`master_encode` — master-side MDS encode (calls the Pallas kernel);
* :func:`worker_matvec_native` — identical graph without the Pallas kernel,
  exported as an ablation artifact (§Perf: pallas-vs-XLA-native).

Generator matrices are *inputs* (never baked into artifacts), so the rust
coordinator is free to draw them from its own PRNG. Shapes are static per
artifact; the rust runtime pads ragged worker loads up to the next bucket
(zero rows / zero columns do not perturb the products).

Python here is build-time only: nothing in this package is imported on the
rust request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.coded_matvec import coded_matvec, matvec_block_shape
from compile.kernels.mds_encode import mds_encode
from compile.kernels import ref


# ---------------------------------------------------------------------------
# AOT entry points (static shapes, lowered by aot.py)
# ---------------------------------------------------------------------------

def worker_matvec(a: jnp.ndarray, x: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Per-worker compute: ``(Ã_{m,n} @ x_m,)`` via the Pallas kernel."""
    return (coded_matvec(a, x),)


def worker_matvec_native(a: jnp.ndarray, x: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Ablation twin of :func:`worker_matvec` using plain XLA dot."""
    return (ref.matvec_ref(a, x),)


def master_encode(g: jnp.ndarray, a: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Master-side encode: ``(G_m @ A_m,)`` via the Pallas kernel."""
    return (mds_encode(g, a),)


# ---------------------------------------------------------------------------
# Padding helpers (shared by tests and by shape planning in aot.py)
# ---------------------------------------------------------------------------

def pad_to(n: int, multiple: int) -> int:
    """Round ``n`` up to a multiple of ``multiple``."""
    return ((n + multiple - 1) // multiple) * multiple


def padded_matvec(a: jnp.ndarray, x: jnp.ndarray, multiple: int = 8) -> jnp.ndarray:
    """Run the Pallas mat-vec on arbitrary shapes by zero-padding.

    Mirrors what the rust runtime does when a worker load does not match an
    artifact bucket exactly: rows and cols are padded with zeros, the
    product of the padded region is zero, and the pad rows are sliced off.
    """
    rows, cols = a.shape
    pr, pc = pad_to(rows, multiple), pad_to(cols, multiple)
    a_p = jnp.pad(a, ((0, pr - rows), (0, pc - cols)))
    x_p = jnp.pad(x, ((0, pc - cols), (0, 0)))
    br, bc = matvec_block_shape(pr, pc)
    y = coded_matvec(a_p, x_p, block_rows=br, block_cols=bc)
    return y[:rows]


# ---------------------------------------------------------------------------
# Systematic MDS generator + full-pipeline reference (tests only)
# ---------------------------------------------------------------------------

def systematic_generator(key: jax.Array, coded_rows: int, rows: int) -> jnp.ndarray:
    """Systematic real-valued MDS generator ``G = [I; P]``.

    ``P`` is i.i.d. Gaussian scaled by 1/sqrt(rows); any ``rows`` rows of
    ``G`` are invertible with probability 1 (tested, and re-implemented in
    rust ``coding::mds`` for the run-time path).
    """
    if coded_rows < rows:
        raise ValueError(f"coded_rows {coded_rows} < rows {rows}")
    parity = jax.random.normal(key, (coded_rows - rows, rows)) / jnp.sqrt(rows)
    return jnp.concatenate([jnp.eye(rows), parity], axis=0)


def pipeline_reference(
    g: jnp.ndarray,
    a: jnp.ndarray,
    x: jnp.ndarray,
    received: jnp.ndarray,
) -> jnp.ndarray:
    """End-to-end oracle: encode → compute → receive subset → decode.

    ``received``: (rows,) int32 indices of the coded rows that arrived
    first. Returns the recovered ``A x``. Used by python tests to validate
    the whole coding path that rust executes at run time.
    """
    coded = ref.encode_ref(g, a)
    y = ref.matvec_ref(coded, x)
    return ref.decode_ref(g[received], y[received])
