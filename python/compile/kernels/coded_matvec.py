"""L1 Pallas kernel: tiled coded mat-vec ``y = Ã_{m,n} @ x_m``.

This is the per-worker compute hot spot of the paper: each worker receives a
coded row-block ``Ã_{m,n} ∈ R^{l×S}`` and the model vector ``x_m``, and
returns the ``l`` inner products.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA-ish framing of
"one threadblock per row chunk" becomes a Pallas grid over (row-blocks ×
k-blocks) with an f32 VMEM accumulator; ``x`` is widened to a (cols, batch)
panel so the contraction feeds the MXU rather than degenerating to a VPU
reduction. The k axis is the innermost (sequential) grid dimension, so each
A-tile is streamed HBM→VMEM exactly once.

The kernel MUST run with ``interpret=True``: real TPU lowering emits a
Mosaic custom-call that the CPU PJRT client used by the rust runtime cannot
execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile shape: 128 rows feeds an MXU-sized systolic pass; 256-wide
# k-tiles keep (A-tile + x-tile + acc) well under a VMEM budget:
#   128*256*4B (A) + 256*8*4B (x) + 128*8*4B (acc) ≈ 139 KiB per step.
DEFAULT_BLOCK_ROWS = 128
DEFAULT_BLOCK_COLS = 256


def _matvec_kernel(a_ref, x_ref, o_ref):
    """Grid point (i, k): o[i] += A[i, k] @ x[k]; k is sequential."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        x_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def matvec_block_shape(rows: int, cols: int) -> tuple[int, int]:
    """Largest default-capped block shape that divides (rows, cols).

    Keeps the kernel applicable to ragged worker loads: the L2 wrapper pads
    to multiples of 8 and this picks divisor tiles ≤ the defaults.
    """

    def best(dim: int, cap: int) -> int:
        b = 1
        for cand in range(1, min(dim, cap) + 1):
            if dim % cand == 0:
                b = cand
        return b

    return best(rows, DEFAULT_BLOCK_ROWS), best(cols, DEFAULT_BLOCK_COLS)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_cols", "interpret")
)
def coded_matvec(
    a: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_rows: int | None = None,
    block_cols: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Compute ``a @ x`` with the tiled Pallas kernel.

    ``a``: (rows, cols); ``x``: (cols, batch). Block sizes must divide the
    corresponding dims (use :func:`matvec_block_shape` / the L2 padding
    wrapper). Returns (rows, batch) f32.
    """
    rows, cols = a.shape
    cols_x, batch = x.shape
    if cols != cols_x:
        raise ValueError(f"shape mismatch: a is {a.shape}, x is {x.shape}")
    if block_rows is None or block_cols is None:
        br, bc = matvec_block_shape(rows, cols)
        block_rows = block_rows or br
        block_cols = block_cols or bc
    if rows % block_rows or cols % block_cols:
        raise ValueError(
            f"block ({block_rows},{block_cols}) must divide shape ({rows},{cols})"
        )

    grid = (rows // block_rows, cols // block_cols)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_cols), lambda i, k: (i, k)),
            pl.BlockSpec((block_cols, batch), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, batch), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, batch), jnp.float32),
        interpret=interpret,
    )(a, x)


def vmem_bytes(block_rows: int, block_cols: int, batch: int, itemsize: int = 4) -> int:
    """Estimated VMEM residency of one grid step (A-tile + x-tile + acc).

    Used by the §Perf notes in EXPERIMENTS.md to pick block shapes; also
    asserted against the 16 MiB budget in tests.
    """
    a_tile = block_rows * block_cols * itemsize
    x_tile = block_cols * batch * itemsize
    acc = block_rows * batch * 4  # accumulator is always f32
    return a_tile + x_tile + acc
