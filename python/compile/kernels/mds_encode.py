"""L1 Pallas kernel: tiled MDS encode ``Ã = G @ A``.

The master-side hot spot: row-wise MDS encoding of the data matrix is a
dense matmul by the (coded_rows × rows) generator matrix. This runs once
per task at dispatch time but over the full matrix, so it is tiled the same
way as the worker mat-vec — 3-D grid (i, j, k) with k innermost/sequential
and an f32 VMEM accumulator tile.

interpret=True for the same CPU-PJRT reason as ``coded_matvec``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 128  # coded-row tile
DEFAULT_BLOCK_N = 128  # data-column tile
DEFAULT_BLOCK_K = 128  # original-row (contraction) tile


def _encode_kernel(g_ref, a_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        g_ref[...].astype(jnp.float32),
        a_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def encode_block_shape(coded_rows: int, rows: int, cols: int) -> tuple[int, int, int]:
    """Largest default-capped divisor tiles for (coded_rows, cols, rows)."""

    def best(dim: int, cap: int) -> int:
        b = 1
        for cand in range(1, min(dim, cap) + 1):
            if dim % cand == 0:
                b = cand
        return b

    return (
        best(coded_rows, DEFAULT_BLOCK_M),
        best(cols, DEFAULT_BLOCK_N),
        best(rows, DEFAULT_BLOCK_K),
    )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def mds_encode(
    g: jnp.ndarray,
    a: jnp.ndarray,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Compute ``g @ a`` with the tiled Pallas kernel.

    ``g``: (coded_rows, rows) generator; ``a``: (rows, cols) data.
    Returns (coded_rows, cols) f32.
    """
    coded_rows, rows = g.shape
    rows_a, cols = a.shape
    if rows != rows_a:
        raise ValueError(f"shape mismatch: g is {g.shape}, a is {a.shape}")
    if block_m is None or block_n is None or block_k is None:
        bm, bn, bk = encode_block_shape(coded_rows, rows, cols)
        block_m = block_m or bm
        block_n = block_n or bn
        block_k = block_k or bk
    if coded_rows % block_m or cols % block_n or rows % block_k:
        raise ValueError(
            f"blocks ({block_m},{block_n},{block_k}) must divide "
            f"({coded_rows},{cols},{rows})"
        )

    grid = (coded_rows // block_m, cols // block_n, rows // block_k)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((coded_rows, cols), jnp.float32),
        interpret=interpret,
    )(g, a)
