"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has an entry here computing the same
function with plain ``jnp`` ops. pytest (``python/tests``) asserts
``allclose`` between kernel and oracle across randomized shapes and dtypes
(hypothesis). These references are also what the L2 model falls back to for
shapes that do not fit a kernel's tiling constraints.
"""

from __future__ import annotations

import jax.numpy as jnp


def matvec_ref(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Coded mat-vec oracle: ``y = A @ x``.

    ``a``: (rows, cols) coded sub-matrix ``Ã_{m,n}``;
    ``x``: (cols, batch) stacked model vectors (batch=1 for the paper's
    single mat-vec; >1 for the iterated / Remark-2 variant).
    Accumulation is always f32, matching the kernel.
    """
    return jnp.dot(
        a.astype(jnp.float32), x.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def encode_ref(g: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """MDS encode oracle: ``Ã = G @ A``.

    ``g``: (coded_rows, rows) generator matrix; ``a``: (rows, cols) data.
    """
    return jnp.dot(
        g.astype(jnp.float32), a.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def decode_ref(g_sub: jnp.ndarray, y_sub: jnp.ndarray) -> jnp.ndarray:
    """Decode oracle: recover ``z = A x`` from any ``L`` coded products.

    ``g_sub``: (L, L) rows of G corresponding to received coded rows;
    ``y_sub``: (L, batch) received inner products. Solves ``G_S z = y_S``.

    Note: the production decoder lives in rust (``coding::gauss``) because
    jax lowers ``linalg.solve`` to a LAPACK custom-call the PJRT text-HLO
    path cannot execute; this oracle is used in python tests only.
    """
    return jnp.linalg.solve(g_sub.astype(jnp.float32), y_sub.astype(jnp.float32))
