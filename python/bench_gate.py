#!/usr/bin/env python3
"""Bench-regression smoke gate for the Monte-Carlo kernel.

Parses ``BENCH_engine.json`` (written by ``cargo bench --bench engine``
or the ``perf_smoke`` test) and fails if kernel v2 falls below the
legacy kernel measured in the SAME run. Relative comparison only — both
kernels saw identical machine load, so no absolute thresholds and no
cross-run flakiness.

Per scenario tag:

* HARD (``small``, ``large``, ``ec2`` — the shifted-exponential
  kernels): ``<tag>/v2-trial-major`` trials/s must be >=
  ``<tag>/legacy`` (within a small jitter allowance), and
  ``<tag>/v3-chunked`` must be >= ``<tag>/v2-blocked`` under the same
  allowance. A hard tag that carries ``v2-blocked`` but no
  ``v3-chunked`` row fails too — the v3 trajectory must not silently
  drop out of the record.
* INFO (every other tag, e.g. the per-delay-family ``fam-*`` rows and
  any future additions): the same ratios are printed but never fail
  the build — the gate tolerates new keys so the record can grow
  without breaking CI.
* INFO: ``<tag>/v2-blocked`` vs trial-major and ``<tag>/v3-zigg`` vs
  chunked are reported; both are different-bits fast paths whose win
  varies with link count and scenario, so they warn rather than fail.
* SERVE (``BENCH_serve.json``, written by ``cargo bench --bench
  serve``): while only one of ``serve/wheel`` / ``serve/heap`` exists
  the row is informational; once BOTH data points exist the wheel must
  hold the line against the heap oracle (jobs/s, same jitter band) —
  the event-core refactor must never serve slower than what it
  replaced.

Usage: python3 bench_gate.py [BENCH_engine.json [BENCH_serve.json ...]]

Multiple record files merge into one throughput table; the default
single-argument (or no-argument) invocation behaves exactly as before.
"""

import json
import os
import sys

# One-sided jitter allowance on the HARD compare — a 5% noise band, NOT
# an exact v2 >= legacy comparison: CI runners schedule noisily even
# back-to-back, so requiring ratio >= 1.0 was flake-prone on shared
# runners; a true regression shows up far below 1.0. Override with
# BENCH_GATE_JITTER for stricter/looser local runs.
JITTER = float(os.environ.get("BENCH_GATE_JITTER", "0.95"))

# Tags whose v2-vs-legacy ratio gates the build. Everything else is
# reported informationally (new keys must never break the gate).
HARD_TAGS = ("small", "large", "ec2")


def main() -> int:
    paths = sys.argv[1:] if len(sys.argv) > 1 else ["BENCH_engine.json"]
    tput = {}
    for path in paths:
        try:
            with open(path, encoding="utf-8") as f:
                doc = json.load(f)
        except OSError as e:
            print(f"bench gate: cannot read {path}: {e}", file=sys.stderr)
            return 2
        for row in doc.get("results", []):
            name, ips = row.get("name"), row.get("items_per_sec")
            if name and isinstance(ips, (int, float)) and ips > 0:
                tput[name] = float(ips)

    tags = sorted({n.split("/", 1)[0] for n in tput if "/" in n})
    hard_pairs = 0
    failures = []
    for tag in tags:
        legacy = tput.get(f"{tag}/legacy")
        v2 = tput.get(f"{tag}/v2-trial-major")
        blocked = tput.get(f"{tag}/v2-blocked")
        if legacy is None or v2 is None:
            continue
        hard = tag in HARD_TAGS
        if hard:
            hard_pairs += 1
        ratio = v2 / legacy
        if hard:
            verdict = "OK" if ratio >= JITTER else "REGRESSION"
        else:
            verdict = "INFO"
        print(f"{tag:<12} legacy {legacy:>12.0f} trials/s   "
              f"v2 {v2:>12.0f} trials/s   x{ratio:.2f}  [{verdict}]")
        if hard and ratio < JITTER:
            failures.append(f"{tag}: v2-trial-major is {ratio:.2f}x legacy")
        if blocked is not None:
            bratio = blocked / v2
            note = "" if bratio >= 1.0 else "  (blocked slower than trial-major — investigate)"
            print(f"{'':<12} blocked {blocked:>11.0f} trials/s   "
                  f"x{bratio:.2f} vs trial-major{note}")

        # Kernel v3: chunked must hold the line against v2-blocked on
        # the hard tags (same run, same machine load).
        chunked = tput.get(f"{tag}/v3-chunked")
        zigg = tput.get(f"{tag}/v3-zigg")
        if blocked is not None and chunked is None and hard:
            failures.append(f"{tag}: record has v2-blocked but no v3-chunked row")
        if blocked is not None and chunked is not None:
            cratio = chunked / blocked
            if hard:
                cverdict = "OK" if cratio >= JITTER else "REGRESSION"
            else:
                cverdict = "INFO"
            print(f"{'':<12} chunked {chunked:>11.0f} trials/s   "
                  f"x{cratio:.2f} vs blocked  [{cverdict}]")
            if hard and cratio < JITTER:
                failures.append(f"{tag}: v3-chunked is {cratio:.2f}x v2-blocked")
        if zigg is not None and chunked is not None:
            zratio = zigg / chunked
            note = "" if zratio >= 1.0 else "  (ziggurat slower than inverse transform here)"
            print(f"{'':<12} zigg    {zigg:>11.0f} trials/s   "
                  f"x{zratio:.2f} vs chunked{note}")

    # Serving event core: wheel vs heap jobs/s. One data point prints
    # informationally; both present hard-gates the wheel (same run, same
    # machine load — the refactor must not serve slower than the heap it
    # replaced).
    wheel = tput.get("serve/wheel")
    heap = tput.get("serve/heap")
    if wheel is not None and heap is not None:
        hard_pairs += 1
        sratio = wheel / heap
        sverdict = "OK" if sratio >= JITTER else "REGRESSION"
        print(f"{'serve':<12} heap   {heap:>12.0f} jobs/s   "
              f"wheel {wheel:>12.0f} jobs/s   x{sratio:.2f}  [{sverdict}]")
        if sratio < JITTER:
            failures.append(f"serve: wheel is {sratio:.2f}x heap")
    elif wheel is not None or heap is not None:
        which = "wheel" if wheel is not None else "heap"
        only = wheel if wheel is not None else heap
        print(f"{'serve':<12} {which:<6} {only:>12.0f} jobs/s   [INFO]  "
              "(one data point; gate arms once both wheel and heap exist)")

    if hard_pairs == 0:
        print("bench gate: no hard legacy/v2 pairs found in the record",
              file=sys.stderr)
        return 2
    if failures:
        print("\nbench gate FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print(f"\nbench gate passed ({hard_pairs} hard scenario pair(s)).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
