//! Heavy-tail scenario gallery: one planner, four delay families.
//!
//! ```bash
//! cargo run --release --example heavy_tail
//! ```
//!
//! Every worker link keeps the SAME fitted mean delay (`a + 1/u`), but
//! the realized per-row distribution is swapped through the delay-model
//! layer: the paper's shifted exponential, a heavy Weibull tail, a
//! power-law Pareto tail, a burst-throttling bimodal mixture, and a
//! trace-driven empirical family packaged by `traces::package_trace`.
//! The plan is held fixed (Theorem-1 loads on dedicated Alg.-1
//! assignment — distribution-free, so mean-matched families plan
//! identically), which isolates how tail weight alone moves the mean,
//! p95 and p99 completion delay relative to the planner's estimate.

use coded_coop::assign::ValueModel;
use coded_coop::config::{CommModel, Scenario, Transform};
use coded_coop::model::dist::FamilyKind;
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};
use coded_coop::sim::{self, McOptions};
use coded_coop::traces::package_trace;
use coded_coop::util::rng::Rng;
use coded_coop::util::table::Table;

fn main() {
    let base = || Scenario::small_scale(7, 2.0, CommModel::Stochastic);
    let spec = PlanSpec {
        policy: Policy::DediIter,
        values: ValueModel::Markov,
        loads: LoadMethod::Markov,
    };
    let mc = McOptions {
        trials: 60_000,
        seed: 7,
        keep_samples: true,
        threads: 0,
        ziggurat: false,
    };

    // A synthetic "measured" trace: shifted-exp base with a 4% population
    // of 15× throttled rows — the kind of lump a real fleet shows.
    let mut rng = Rng::new(99);
    let samples: Vec<f64> = (0..5_000)
        .map(|_| (0.25 + rng.exp(4.0)) * if rng.f64() < 0.04 { 15.0 } else { 1.0 })
        .collect();
    let (trace, fitted) = package_trace("synthetic-fleet", samples).expect("fit");
    println!(
        "trace fit: a = {:.3} ms, u = {:.3} /ms, KS = {:.3} (heavy tail ⇒ poor fit)\n",
        fitted.a, fitted.u, fitted.ks
    );

    let gallery: Vec<(&str, Option<FamilyKind>)> = vec![
        ("shifted-exp (paper)", None),
        ("weibull k=0.6", Some(FamilyKind::Weibull { shape: 0.6 })),
        ("pareto α=2.2", Some(FamilyKind::Pareto { alpha: 2.2 })),
        (
            "bimodal 5% × 10×",
            Some(FamilyKind::Bimodal {
                prob: 0.05,
                slow: 10.0,
            }),
        ),
        ("trace-driven", None), // handled specially below
    ];

    let mut table = Table::new(&[
        "delay family",
        "t* est (ms)",
        "mean (ms)",
        "p95 (ms)",
        "p99 (ms)",
    ]);
    for (label, kind) in gallery {
        let s = if label == "trace-driven" {
            let mut s = base();
            let id = s.add_trace(trace.clone());
            s.transformed(&[Transform::Family(FamilyKind::Trace { id })])
        } else {
            match kind {
                Some(k) => base().transformed(&[Transform::Family(k)]),
                None => base(),
            }
        };
        let p = plan::build(&s, &spec);
        let r = sim::run(&s, &p, &mc);
        let mean = r.system.mean();
        let t_est = p.t_est();
        let ecdf = r.into_system_ecdf().expect("samples kept");
        table.row(&[
            label.to_string(),
            format!("{t_est:.1}"),
            format!("{mean:.1}"),
            format!("{:.1}", ecdf.inverse(0.95)),
            format!("{:.1}", ecdf.inverse(0.99)),
        ]);
    }
    println!("{}", table.render());
    println!(
        "Reading: the Markov plan only sees first moments, and all families\n\
         are mean-matched — so the plan is identical across rows (the trace\n\
         row re-plans on the trace's own mean). Heavier tails leave the mean\n\
         almost untouched but stretch p95/p99; coding redundancy absorbs part\n\
         of it, and the gap to t* is the price of tail-blind planning."
    );
}
