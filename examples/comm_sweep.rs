//! Communication-rate sweep (Fig. 6 as an application study).
//!
//! ```bash
//! cargo run --release --example comm_sweep
//! ```
//!
//! Sweeps γ/u finer than the paper's five points and reports both the
//! delay and the local-offload behavior — the knob an operator would turn
//! when sizing the network between masters and the worker pool.

use coded_coop::assign::ValueModel;
use coded_coop::config::{CommModel, Scenario};
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};
use coded_coop::sim::{self, McOptions};
use coded_coop::util::table::Table;

fn main() {
    let ratios = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0];
    let mc = McOptions {
        trials: 20_000,
        seed: 3,
        keep_samples: false,
        threads: 0,
        ziggurat: false,
    };

    let mut table = Table::new(&[
        "γ/u",
        "Dedi delay (ms)",
        "Frac delay (ms)",
        "Uncoded delay (ms)",
        "local-load share",
        "offloaded rows",
    ]);
    for ratio in ratios {
        let s = Scenario::large_scale(2022, ratio, CommModel::Stochastic);
        let dedi = PlanSpec {
            policy: Policy::DediIter,
            values: ValueModel::Markov,
            loads: LoadMethod::Markov,
        };
        let frac = PlanSpec {
            policy: Policy::Frac,
            ..dedi
        };
        let unc = PlanSpec {
            policy: Policy::UncodedUniform,
            ..dedi
        };
        let p_dedi = plan::build(&s, &dedi);
        let p_frac = plan::build(&s, &frac);
        let p_unc = plan::build(&s, &unc);
        let r_dedi = sim::run(&s, &p_dedi, &mc);
        let r_frac = sim::run(&s, &p_frac, &mc);
        let r_unc = sim::run(&s, &p_unc, &mc);

        // How much of each master's load stays local vs is shipped out.
        let (mut local, mut total) = (0.0, 0.0);
        for mp in &p_dedi.masters {
            for e in &mp.entries {
                if e.node == 0 {
                    local += e.load;
                }
                total += e.load;
            }
        }
        table.row(&[
            format!("{ratio}"),
            format!("{:.1}", r_dedi.system.mean()),
            format!("{:.1}", r_frac.system.mean()),
            format!("{:.1}", r_unc.system.mean()),
            format!("{:.3}", local / total),
            format!("{:.0}", total - local),
        ]);
    }
    println!("{}", table.render());
    println!(
        "Reading (paper Fig. 6): slow links (γ/u ≤ 1) push work back to the\n\
         masters — the benchmarks cannot adapt; once links are ~4× faster\n\
         than compute, nearly everything is offloaded and the delay floors."
    );
}
