//! Remark 2 (iterated mat-vec): distributed power iteration over a coded
//! matrix — the paper's ML-training motivation.
//!
//! ```bash
//! make artifacts && cargo run --release --example iterated_training
//! ```
//!
//! `A` is MDS-encoded and shipped to the workers ONCE; then every
//! iteration only the updated model vector `x_t` moves, so worker
//! assignment / load allocation use the computation-dominant case
//! (Theorem 2), exactly as Remark 2 prescribes. Each iteration the
//! master collects any `L` coded products of `Ã·x_t` (delays sampled per
//! eq. 2, stragglers re-drawn every iteration), decodes `A·x_t`, and
//! performs the power-iteration update `x ← normalize(A x)`. Converges
//! to the dominant eigenvector — verified against an uncoded in-process
//! power iteration on the same matrix.

use coded_coop::alloc::comp_dominant::{self, CompParams};
use coded_coop::coding::{Matrix, MdsCode};
use coded_coop::config::{AShift, CommModel, Scenario};
use coded_coop::coordinator::round_loads;
use coded_coop::model::dist::LinkDelay;
use coded_coop::runtime::{default_artifact_dir, RuntimeService};
use coded_coop::util::rng::Rng;
use coded_coop::util::table::Table;

fn main() -> anyhow::Result<()> {
    let n = 512usize; // A is n×n, symmetric
    let iters = 12usize;
    let mut rng = Rng::new(99);

    // Symmetric matrix with a planted dominant eigenvector.
    let mut a = vec![0.0f32; n * n];
    let planted: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let norm: f32 = planted.iter().map(|v| v * v).sum::<f32>().sqrt();
    for i in 0..n {
        for j in 0..=i {
            let noise = rng.normal() as f32 * 0.05;
            let v = 4.0 * planted[i] * planted[j] / (norm * norm) + noise;
            a[i * n + j] = v;
            a[j * n + i] = v;
        }
    }

    // Remark 2: computation-dominant planning (comm of x is negligible).
    let scenario = Scenario::random(
        "iterated",
        1,
        6,
        n as f64,
        AShift::Range(0.01, 0.06),
        2.0,
        CommModel::CompDominant,
        99,
    );
    let nodes: Vec<CompParams> = (0..=scenario.n_workers())
        .map(|node| {
            let p = scenario.link(0, node);
            CompParams { a: p.a, u: p.u }
        })
        .collect();
    let alloc = comp_dominant::allocate(&nodes, n as f64);
    let loads = round_loads(&alloc.loads, n);
    let l_coded: usize = loads.iter().sum();
    println!(
        "plan (Theorem 2, comp-dominant): {} nodes, overhead {:.2}×, t* = {:.2} ms/iter",
        loads.len(),
        l_coded as f64 / n as f64,
        alloc.t_star
    );

    // Encode ONCE through the PJRT Pallas artifact (data shipped once).
    let service = RuntimeService::start(&default_artifact_dir())?;
    let h = service.handle();
    let code = MdsCode::new(n, l_coded, &mut rng);
    let g32: Vec<f32> = code.generator().data().iter().map(|&v| v as f32).collect();
    let coded = h.encode(g32, l_coded, n, a.clone(), n)?;

    // Per-node coded blocks (row ranges).
    let mut blocks = Vec::new();
    let mut start = 0usize;
    for &l in &loads {
        blocks.push((start, l));
        start += l;
    }

    // Power iteration with per-iteration straggler sampling + decode.
    let mut x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut x_direct = x.clone();
    let mut table = Table::new(&[
        "iter", "virtual delay (ms)", "rows used", "cos(coded, direct)",
    ]);
    for it in 0..iters {
        // Sample each node's completion time for this iteration (eq. 2).
        let mut arrivals: Vec<(f64, usize)> = blocks
            .iter()
            .enumerate()
            .map(|(i, &(_, l))| {
                let p = scenario.link(0, i);
                (LinkDelay::new(&p, l as f64, 1.0, 1.0).sample(&mut rng), i)
            })
            .collect();
        arrivals.sort_by(|u, v| u.0.partial_cmp(&v.0).unwrap());

        // Collect coded products from the fastest nodes until L arrive —
        // the real mat-vec runs through the PJRT Pallas artifact.
        let mut received: Vec<(usize, f64)> = Vec::with_capacity(n);
        let mut delay = 0.0;
        for &(t, node) in &arrivals {
            if received.len() >= n {
                break;
            }
            let (s0, l) = blocks[node];
            let block = coded[s0 * n..(s0 + l) * n].to_vec();
            let y = h.matvec(block, l, n, x.clone(), 1)?;
            for (off, &v) in y.iter().enumerate() {
                received.push((s0 + off, v as f64));
            }
            delay = t;
        }
        let z = code
            .decode(&received)
            .expect("any L coded rows decode");

        // Power-iteration updates (coded and direct twins).
        let nz: f64 = z.iter().map(|v| v * v).sum::<f64>().sqrt();
        for (xi, &zi) in x.iter_mut().zip(&z) {
            *xi = (zi / nz) as f32;
        }
        let zd = Matrix::from_vec(n, n, a.iter().map(|&v| v as f64).collect())
            .matvec(&x_direct.iter().map(|&v| v as f64).collect::<Vec<_>>());
        let nd: f64 = zd.iter().map(|v| v * v).sum::<f64>().sqrt();
        for (xi, &zi) in x_direct.iter_mut().zip(&zd) {
            *xi = (zi / nd) as f32;
        }

        let cos: f64 = x
            .iter()
            .zip(&x_direct)
            .map(|(&u, &v)| u as f64 * v as f64)
            .sum::<f64>()
            .abs();
        table.row(&[
            format!("{}", it + 1),
            format!("{delay:.2}"),
            format!("{}", received.len()),
            format!("{cos:.6}"),
        ]);
    }
    println!("{}", table.render());

    // Coded training tracked the direct iteration to f32 accuracy.
    let cos_final: f64 = x
        .iter()
        .zip(&x_direct)
        .map(|(&u, &v)| u as f64 * v as f64)
        .sum::<f64>()
        .abs();
    anyhow::ensure!(cos_final > 0.999, "coded iteration diverged: {cos_final}");
    println!(
        "converged: coded and direct power iterations agree (|cos| = {cos_final:.6});\n\
         A was shipped once, only x moved per iteration (Remark 2). OK"
    );
    Ok(())
}
