//! End-to-end driver: the full three-layer system on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_coded_matmul
//! ```
//!
//! Proves every layer composes (EXPERIMENTS.md §E2E):
//!  1. **L2/L1 artifacts** — `make artifacts` lowered the Pallas coded
//!     mat-vec + MDS encode kernels to HLO text;
//!  2. **runtime** — the PJRT service compiles them once and serves all
//!     worker threads;
//!  3. **planner** — the paper's assignment + load-allocation algorithms
//!     plan a 2-master × 8-worker deployment of real 1024×512 matrices;
//!  4. **coordinator** — masters encode (PJRT), dispatch over
//!     delay-injected channels, workers execute the mat-vec artifact,
//!     masters decode from the FIRST `L_m` arrivals and cancel the rest;
//!  5. **verification** — recovered `A_m x_m` is checked against the
//!     direct product;
//!  6. **measurement** — real per-call PJRT mat-vec wallclock is traced
//!     and fitted with the same shifted-exponential pipeline as Fig. 7.

use coded_coop::assign::ValueModel;
use coded_coop::cli::print_report;
use coded_coop::config::{AShift, CommModel, Scenario};
use coded_coop::coordinator::{self, Backend, CoordinatorConfig};
use coded_coop::plan::{LoadMethod, PlanSpec, Policy};
use coded_coop::runtime::{default_artifact_dir, RuntimeService};
use coded_coop::traces::fit::fit_shifted_exp;

fn main() -> anyhow::Result<()> {
    let rows = 1024usize;
    let cols = 512usize;

    println!("== e2e coded matmul: 2 masters × 8 workers, A ∈ R^{rows}×{cols} ==\n");
    let service = RuntimeService::start(&default_artifact_dir())?;

    let scenario = Scenario::random(
        "e2e",
        2,
        8,
        rows as f64,
        AShift::Range(0.01, 0.05),
        2.0,
        CommModel::Stochastic,
        42,
    );

    for (policy, loads) in [
        (Policy::UncodedUniform, LoadMethod::Markov),
        (Policy::DediIter, LoadMethod::Sca),
        (Policy::Frac, LoadMethod::Sca),
    ] {
        let cfg = CoordinatorConfig {
            scenario: scenario.clone(),
            spec: PlanSpec {
                policy,
                values: ValueModel::Markov,
                loads,
            },
            cols,
            time_scale: 1e-3, // real-time ms: lets cancellation propagate visibly
            backend: Backend::Pjrt(service.handle()),
            seed: 42,
            verify: true,
        };
        let report = coordinator::run(&cfg)?;
        print_report(&report);
        anyhow::ensure!(
            report.all_verified(1e-2),
            "recovered products did not match the direct computation"
        );
        println!(
            "compute wall {:.1} ms across workers; {:.0}% of dispatched rows saved by cancellation\n",
            report.compute_wall_ms(),
            100.0 * report.saved_fraction()
        );
        // Structured export for dashboards / regression diffing.
        std::fs::create_dir_all("results")?;
        let path = format!(
            "results/e2e_{}.json",
            report.label.to_lowercase().replace([' ', ',', '+'], "_")
        );
        std::fs::write(&path, report.to_json().to_string_pretty())?;
        println!("report saved to {path}\n");
    }

    let (compiles, executions) = service.handle().stats()?;
    println!("runtime: {compiles} artifact compiles, {executions} executions\n");

    // Real-measurement leg of Fig. 7: trace actual PJRT mat-vec wallclock
    // on two "instance types" (big vs small bucket) and fit.
    println!("-- real PJRT mat-vec delay traces (Fig. 7 pipeline on real data) --");
    for (name, r, c) in [("bucket-512x512", 512, 512), ("bucket-128x256", 128, 256)] {
        let trace = service.handle().measure_matvec(r, c, 60, false)?;
        let fit = fit_shifted_exp(&trace)?;
        println!(
            "{name}: n={} fit a={:.3} ms, u={:.3} /ms, KS={:.3}",
            trace.len(),
            fit.a,
            fit.u,
            fit.ks
        );
    }
    println!("\ne2e OK");
    Ok(())
}
