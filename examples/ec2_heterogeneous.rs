//! EC2-heterogeneity study (the Fig. 7–8 workload as an application).
//!
//! ```bash
//! cargo run --release --example ec2_heterogeneous
//! ```
//!
//! Sweeps the worker mix from all-t2.micro to half-c5.large and shows how
//! the paper's algorithms exploit heterogeneity, under both the fitted
//! delay model and the measured-trace stand-in (burst throttling).

use coded_coop::assign::ValueModel;
use coded_coop::config::Scenario;
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};
use coded_coop::sim::{self, McOptions};
use coded_coop::traces::ec2::{C5_LARGE, T2_MICRO};
use coded_coop::util::table::Table;

fn main() {
    println!(
        "instance profiles: {} (a={} ms, u={} /ms), {} (a={} ms, u={} /ms)\n",
        T2_MICRO.name, T2_MICRO.a, T2_MICRO.u, C5_LARGE.name, C5_LARGE.a, C5_LARGE.u
    );

    let mc = McOptions {
        trials: 30_000,
        seed: 11,
        keep_samples: false,
        threads: 0,
        ziggurat: false,
    };
    let specs = [
        (Policy::UncodedUniform, LoadMethod::Exact),
        (Policy::CodedUniform, LoadMethod::Exact),
        (Policy::DediIter, LoadMethod::Exact),
        (Policy::Frac, LoadMethod::Exact),
    ];

    for stragglers in [false, true] {
        println!(
            "== {} ==",
            if stragglers {
                "measured-trace stand-in (t2 burst throttling)"
            } else {
                "fitted shifted-exponential model"
            }
        );
        let mut table = Table::new(&[
            "worker mix (t2/c5)",
            "Uncoded",
            "Coded [5]",
            "Dedi, iter",
            "Frac",
        ]);
        for (n_t2, n_c5) in [(50, 0), (45, 5), (40, 10), (25, 25)] {
            let s = Scenario::ec2(n_t2, n_c5, stragglers);
            let mut cells = vec![format!("{n_t2}/{n_c5}")];
            for (policy, loads) in specs {
                let spec = PlanSpec {
                    policy,
                    values: ValueModel::Exact,
                    loads,
                };
                let p = plan::build(&s, &spec);
                let r = sim::run(&s, &p, &mc);
                cells.push(format!("{:.0} ms", r.system.mean()));
            }
            table.row(&cells);
        }
        println!("{}", table.render());
    }
    println!(
        "Reading: faster c5.large workers shrink every scheme's delay, but\n\
         the proposed assignment algorithms convert heterogeneity into the\n\
         largest gains; under the straggler tail the uncoded scheme collapses\n\
         (it must wait for every throttled t2 worker) — the paper's 82%."
    );
}
