//! Quickstart: plan and evaluate the paper's algorithms on the
//! small-scale scenario (§V: 2 masters, 5 workers, γ = 2u).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the full public API surface: scenario → plan (assignment +
//! load allocation) → Monte-Carlo delay evaluation, for every policy.

use coded_coop::assign::ValueModel;
use coded_coop::config::{CommModel, Scenario};
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};
use coded_coop::sim::{self, McOptions};
use coded_coop::util::table::Table;

fn main() {
    // 1. A scenario: M masters, N shared heterogeneous workers, per-link
    //    (γ, a, u) delay parameters. Builders reproduce the paper's §V
    //    settings; Scenario::from_file loads custom JSON configs.
    let scenario = Scenario::small_scale(2022, 2.0, CommModel::Stochastic);
    println!("scenario: {}\n", scenario.name);

    // 2. Plans: worker assignment + resource allocation + load allocation.
    let specs = [
        (Policy::UncodedUniform, LoadMethod::Markov),
        (Policy::CodedUniform, LoadMethod::Markov),
        (Policy::DediSimple, LoadMethod::Markov),
        (Policy::DediIter, LoadMethod::Markov),
        (Policy::DediIter, LoadMethod::Sca),
        (Policy::Frac, LoadMethod::Markov),
        (Policy::Frac, LoadMethod::Sca),
        (Policy::FracOptimal, LoadMethod::Sca),
    ];

    let mc = McOptions {
        trials: 50_000,
        seed: 7,
        keep_samples: true,
        threads: 0,
        ziggurat: false,
    };

    let mut table = Table::new(&[
        "algorithm",
        "mean delay (ms)",
        "ρ=0.95 delay (ms)",
        "planner t* (ms)",
        "coding overhead",
    ]);
    for (policy, loads) in specs {
        let spec = PlanSpec {
            policy,
            values: ValueModel::Markov,
            loads,
        };
        let p = plan::build(&scenario, &spec);
        let r = sim::run(&scenario, &p, &mc);
        let mean_ms = r.system.mean();
        // Consuming ECDF: the sample vector moves, no copy.
        let rho95 = r.into_system_ecdf().unwrap().inverse(0.95);
        let overhead = p
            .masters
            .iter()
            .map(|m| m.total_load() / m.l_rows)
            .fold(0.0f64, f64::max);
        table.row(&[
            p.label.clone(),
            format!("{mean_ms:.1}"),
            format!("{rho95:.1}"),
            format!("{:.1}", p.t_est()),
            format!("{overhead:.2}×"),
        ]);
    }
    println!("{}", table.render());
    println!(
        "Monte-Carlo: {} trials per algorithm; see `coded-coop figure all`\n\
         for the full §V reproduction and EXPERIMENTS.md for recorded runs.",
        mc.trials
    );
}
