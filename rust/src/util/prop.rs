//! Tiny property-testing harness (no `proptest` offline).
//!
//! A property is a closure over a [`Gen`]; [`check`] runs it for `cases`
//! random cases and, on failure, re-runs with progressively smaller "size"
//! to report the smallest failing size (a lightweight shrink), plus the
//! seed needed to replay the case deterministically.
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath link flags)
//! use coded_coop::util::prop::{check, Config};
//! check(Config::default().cases(64), "abs is nonneg", |g| {
//!     let x = g.f64_range(-1e6, 1e6);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use super::rng::Rng;

/// Case generator handed to properties: wraps an [`Rng`] plus a size hint.
pub struct Gen {
    rng: Rng,
    /// Size hint in `(0, 1]`; shrinking re-runs with smaller sizes so
    /// generators that scale with `size()` produce smaller cases.
    size: f64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn size(&self) -> f64 {
        self.size
    }

    /// Integer in `[lo, hi]`, scaled down when shrinking.
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.size).ceil() as usize;
        lo + self.rng.index(span.max(1).min(hi - lo + 1))
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, lo + (hi - lo) * self.size)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of values from a element generator.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Seed from env for reproducing CI failures: PROP_SEED=<u64>.
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0DE_C0DE);
        Self { cases: 100, seed }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` for `cfg.cases` random cases; panic with a replayable report
/// on the first failure.
pub fn check<F>(cfg: Config, name: &str, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let run = |size: f64| {
            let mut g = Gen {
                rng: Rng::new(case_seed),
                size,
            };
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)))
        };
        if let Err(payload) = run(1.0) {
            // Shrink: retry the same seed at smaller sizes, keep the
            // smallest size that still fails.
            let mut failing_size = 1.0;
            for &size in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                if run(size).is_err() {
                    failing_size = size;
                }
            }
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} \
                 (replay: PROP_SEED={} size={failing_size}): {msg}",
                cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::default().cases(50), "sum commutative", |g| {
            let a = g.f64_range(-1e3, 1e3);
            let b = g.f64_range(-1e3, 1e3);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check(Config::default().cases(5), "always fails", |g| {
            let x = g.f64_range(0.0, 1.0);
            assert!(x < 0.0, "x={x}");
        });
    }

    #[test]
    fn usize_range_bounds() {
        check(Config::default().cases(200), "usize_range in bounds", |g| {
            let lo = g.rng().index(10);
            let hi = lo + g.rng().index(100);
            let x = g.usize_range(lo, hi);
            assert!(x >= lo && x <= hi, "{lo} ≤ {x} ≤ {hi}");
        });
    }

    #[test]
    fn vec_generator_len() {
        check(Config::default().cases(20), "vec length", |g| {
            let v = g.vec(17, |g| g.bool());
            assert_eq!(v.len(), 17);
        });
    }
}
