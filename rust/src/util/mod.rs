//! Offline-environment substrates.
//!
//! The build environment has no network access and only the crates vendored
//! for the `xla` PJRT bridge, so everything a well-maintained project would
//! normally pull from crates.io (`rand`, `serde`, `criterion`, `proptest`,
//! `clap`) is implemented here in-tree (DESIGN.md §Substitutions).

pub mod rng;
pub mod stats;
pub mod lambert;
pub mod json;
pub mod prop;
pub mod benchkit;
pub mod table;
