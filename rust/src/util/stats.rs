//! Descriptive statistics, quantiles and empirical CDFs for the
//! Monte-Carlo engine and the figure harness.

/// Running summary over a stream of samples (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean += d * other.n as f64 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Sample variance (n−1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Γ(x) for positive real `x` via the Lanczos approximation (g = 7,
/// 9 terms; relative error < 1e-13 on the positive axis). Used by the
/// delay-model layer to moment-match the Weibull family
/// (`E[scale·E^{1/k}] = scale·Γ(1 + 1/k)`).
pub fn gamma_fn(x: f64) -> f64 {
    const LANCZOS: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_59,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    assert!(x.is_finite() && x > 0.0, "gamma_fn needs x > 0, got {x}");
    if x < 0.5 {
        // Reflection Γ(x)·Γ(1−x) = π/sin(πx); one level deep only.
        let pi = std::f64::consts::PI;
        return pi / ((pi * x).sin() * gamma_fn(1.0 - x));
    }
    let z = x - 1.0;
    let mut acc = LANCZOS[0];
    for (i, &c) in LANCZOS.iter().enumerate().skip(1) {
        acc += c / (z + i as f64);
    }
    let t = z + 7.5;
    (2.0 * std::f64::consts::PI).sqrt() * t.powf(z + 0.5) * (-t).exp() * acc
}

/// Linear-interpolated quantile of an UNSORTED sample set (`None` when
/// empty) — sorts a copy NaN-safely. The single implementation behind
/// every tail readout (serving p99, sweep exports).
pub fn percentile(xs: &[f64], q: f64) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    Some(quantile_sorted(&v, q))
}

/// Linear-interpolated quantile of a **sorted** slice, `q ∈ [0,1]`.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty slice");
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Empirical CDF over a finite sample.
#[derive(Clone, Debug)]
pub struct Ecdf {
    sorted: Vec<f64>,
    /// Cached at construction: `Ecdf::mean` sits on the planner's θ
    /// path for trace-driven delay families, which may be evaluated
    /// thousands of times per plan (grid searches, balancing loops) —
    /// it must not re-sum the trace per call.
    mean: f64,
}

impl Ecdf {
    /// Panicking constructor for internal callers whose samples are
    /// correct by construction (Monte-Carlo outputs: finite or `+∞` for
    /// infeasible trials, never empty). External data — config / trace
    /// JSON, user-supplied series — must go through [`Ecdf::try_new`],
    /// which returns a graceful error instead.
    pub fn new(samples: Vec<f64>) -> Self {
        Self::try_new(samples).expect("Ecdf::new: invalid sample set")
    }

    /// Fallible constructor: rejects empty inputs and NaN samples (NaN
    /// has no place in an order statistic; `±∞` is allowed — an
    /// infeasible Monte-Carlo trial legitimately contributes `+∞` to a
    /// delay ECDF). This is the checked path for anything arriving from
    /// JSON or other external sources.
    pub fn try_new(mut samples: Vec<f64>) -> anyhow::Result<Self> {
        anyhow::ensure!(!samples.is_empty(), "Ecdf needs at least one sample");
        anyhow::ensure!(
            !samples.iter().any(|x| x.is_nan()),
            "Ecdf samples must not be NaN"
        );
        // total_cmp: a NaN that slips past the guard in a release build
        // degrades to a deterministic sort position instead of a panic
        // mid-sort (`partial_cmp(..).unwrap()` was the old behavior).
        samples.sort_by(f64::total_cmp);
        let mean = mean(&samples);
        Ok(Self {
            sorted: samples,
            mean,
        })
    }

    /// Borrowing constructor for callers that only hold `&[f64]` (e.g.
    /// `McResults::system_ecdf` on a shared result). Still one copy —
    /// the sorted vector must be owned; callers done with their samples
    /// should move them into [`Ecdf::new`] instead (zero copies), as
    /// `McResults::into_system_ecdf` and the figure CDF panels do.
    pub fn from_slice(samples: &[f64]) -> Self {
        Self::new(samples.to_vec())
    }

    /// Kolmogorov–Smirnov-style sup distance `sup_t |F(t) − G(t)|`
    /// between two ECDFs (used by the blocked-sampling
    /// distribution-equivalence tests).
    pub fn sup_distance(&self, other: &Ecdf) -> f64 {
        let mut d = 0.0f64;
        for &t in self.sorted.iter().chain(&other.sorted) {
            d = d.max((self.eval(t) - other.eval(t)).abs());
        }
        d
    }

    /// `P[X ≤ t]`.
    pub fn eval(&self, t: f64) -> f64 {
        // partition_point = number of samples ≤ t
        let cnt = self.sorted.partition_point(|&x| x <= t);
        cnt as f64 / self.sorted.len() as f64
    }

    /// Smallest `t` with `P[X ≤ t] ≥ p` — the ρ_s readout of Fig. 5.
    pub fn inverse(&self, p: f64) -> f64 {
        quantile_sorted(&self.sorted, p)
    }

    /// Generalized inverse `F̂⁻¹(p) = inf{x : F̂(x) ≥ p}` — the exact
    /// step-function inverse, unlike [`Ecdf::inverse`] which
    /// interpolates between order statistics for plot readouts.
    ///
    /// This is the inverse-transform sampler of the trace-driven delay
    /// family: with `U ~ Uniform[0, 1)`, `quantile(U)` redraws exactly
    /// the empirical distribution (each stored sample with probability
    /// `1/n`), so a resampled ECDF converges to this one in sup
    /// distance (property-tested below).
    pub fn quantile(&self, p: f64) -> f64 {
        let n = self.sorted.len();
        if p <= 0.0 {
            return self.sorted[0];
        }
        if p >= 1.0 {
            return self.sorted[n - 1];
        }
        // F̂(sorted[i]) = (i+1)/n ⇒ the smallest index with F̂ ≥ p is
        // ⌈p·n⌉ − 1.
        let i = (p * n as f64).ceil() as usize;
        self.sorted[i.saturating_sub(1).min(n - 1)]
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// The underlying samples in sorted order (trace serialization and
    /// diagnostics; the original insertion order is not retained).
    pub fn sorted_samples(&self) -> &[f64] {
        &self.sorted
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Evenly-spaced `(t, F(t))` series for plotting/JSON export.
    pub fn series(&self, points: usize) -> Vec<(f64, f64)> {
        let lo = self.sorted[0];
        let hi = *self.sorted.last().unwrap();
        (0..points)
            .map(|i| {
                let t = lo + (hi - lo) * i as f64 / (points - 1).max(1) as f64;
                (t, self.eval(t))
            })
            .collect()
    }
}

/// Fixed-width histogram (used for delay-distribution exports).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    width: f64,
    counts: Vec<u64>,
    total: u64,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self {
            lo,
            width: (hi - lo) / bins as f64,
            counts: vec![0; bins],
            total: 0,
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.total += 1;
        if x < self.lo {
            self.underflow += 1;
        } else {
            let idx = ((x - self.lo) / self.width) as usize;
            if idx >= self.counts.len() {
                self.overflow += 1;
            } else {
                self.counts[idx] += 1;
            }
        }
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }
    pub fn total(&self) -> u64 {
        self.total
    }
    pub fn overflow(&self) -> u64 {
        self.overflow
    }
    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    /// Bin centers with normalized frequency.
    pub fn density(&self) -> Vec<(f64, f64)> {
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                (
                    self.lo + (i as f64 + 0.5) * self.width,
                    c as f64 / (self.total.max(1) as f64 * self.width),
                )
            })
            .collect()
    }
}

/// Default compactor capacity for [`QuantileSketch`] — the serving
/// layer's per-stream tail accumulator. 256 keeps the worst-case rank
/// error (see [`QuantileSketch::error_bound`]) under ~0.1 at a million
/// samples while storing at most a few kilobytes per stream.
pub const SKETCH_CAPACITY: usize = 256;

/// Bounded-memory streaming quantile sketch (GK-style guarantees via a
/// deterministic Munro–Paterson compactor hierarchy).
///
/// Level `ℓ` holds samples of weight `2^ℓ`; when a level reaches the
/// capacity `k`, it is sorted and every other element (alternating
/// parity between compactions) is promoted to level `ℓ+1` with doubled
/// weight. Total weight is conserved exactly, so `count()` is exact.
///
/// **Rank-error bound.** A compaction at level `ℓ` perturbs the rank of
/// any threshold by at most `2^ℓ`, and at most `n / (⌊k/2⌋·2^ℓ)`
/// compactions happen at level `ℓ` over `n` inserts, so the total rank
/// error is at most `n·L/⌊k/2⌋` where `L = ⌈log₂(n/k)⌉` is the number
/// of populated levels above 0. [`QuantileSketch::error_bound`] returns
/// that `ε = L/⌊k/2⌋`; `quantile(q)` is then guaranteed to land within
/// rank `q·n ± ε·n` of the exact order statistic (the deterministic
/// parity alternation cancels errors pairwise, so observed error is
/// typically ~1/k — property-tested against the exact [`percentile`]
/// oracle below).
///
/// **Memory.** At most `k` items per populated level, i.e.
/// `O(k·log(n/k))` floats total — constant for any practical `n`, vs.
/// the `O(n)` of exact percentile accumulation.
///
/// `merge` is weight-exact and order-insensitive up to the documented
/// bound: merging appends per level then re-compacts, so any merge tree
/// over the same streams obeys the same error bound (property-tested).
#[derive(Clone, Debug)]
pub struct QuantileSketch {
    k: usize,
    /// `levels[ℓ]` holds items of weight `2^ℓ`, unsorted between
    /// compactions.
    levels: Vec<Vec<f64>>,
    /// Per-level compaction parity: which of each sorted pair survives.
    /// Alternating deterministically cancels rank error pairwise and
    /// keeps the sketch reproducible run to run.
    parity: Vec<bool>,
    n: u64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new(SKETCH_CAPACITY)
    }
}

impl QuantileSketch {
    /// `k` is the per-level compactor capacity (clamped to ≥ 8 and
    /// rounded up to even so pairs always form).
    pub fn new(k: usize) -> Self {
        let k = k.max(8) + (k % 2);
        Self {
            k,
            levels: vec![Vec::new()],
            parity: vec![false],
            n: 0,
        }
    }

    /// Insert one sample. NaN is skipped (order statistics are
    /// undefined for it); `±∞` is legitimate (infeasible trials).
    pub fn insert(&mut self, x: f64) {
        if x.is_nan() {
            debug_assert!(false, "QuantileSketch::insert(NaN)");
            return;
        }
        self.levels[0].push(x);
        self.n += 1;
        if self.levels[0].len() >= self.k {
            self.compact(0);
        }
    }

    /// Compact level `l`: sort, leave one element behind on odd counts,
    /// promote every other element of the pairs to level `l+1`.
    fn compact(&mut self, l: usize) {
        if self.levels.len() == l + 1 {
            self.levels.push(Vec::new());
            self.parity.push(false);
        }
        let mut buf = std::mem::take(&mut self.levels[l]);
        buf.sort_by(f64::total_cmp);
        let start = buf.len() % 2;
        let offset = self.parity[l] as usize;
        self.parity[l] = !self.parity[l];
        let mut i = start + offset;
        while i < buf.len() {
            self.levels[l + 1].push(buf[i]);
            i += 2;
        }
        if start == 1 {
            self.levels[l].push(buf[0]);
        }
        if self.levels[l + 1].len() >= self.k {
            self.compact(l + 1);
        }
    }

    /// Merge `other` into `self` (weight-exact; both sketches keep
    /// their documented error bound afterwards).
    pub fn merge(&mut self, other: &QuantileSketch) {
        for (l, buf) in other.levels.iter().enumerate() {
            while self.levels.len() <= l {
                self.levels.push(Vec::new());
                self.parity.push(false);
            }
            self.levels[l].extend_from_slice(buf);
        }
        self.n += other.n;
        for l in 0..self.levels.len() {
            while self.levels[l].len() >= self.k {
                self.compact(l);
            }
        }
    }

    /// Approximate `q`-quantile (`None` when empty): the smallest
    /// stored value whose cumulative weight reaches `⌈q·n⌉`, i.e. a
    /// generalized-inverse readout like [`Ecdf::quantile`], accurate to
    /// the documented rank error.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.n == 0 {
            return None;
        }
        let mut items: Vec<(f64, u64)> = Vec::with_capacity(self.stored());
        for (l, buf) in self.levels.iter().enumerate() {
            let w = 1u64 << l;
            items.extend(buf.iter().map(|&x| (x, w)));
        }
        items.sort_by(|a, b| a.0.total_cmp(&b.0));
        let target = ((q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (x, w) in &items {
            cum += w;
            if cum >= target {
                return Some(*x);
            }
        }
        items.last().map(|(x, _)| *x)
    }

    /// Exact number of inserted samples (weight is conserved).
    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Items currently stored — the O(k·log(n/k)) memory witness.
    pub fn stored(&self) -> usize {
        self.levels.iter().map(Vec::len).sum()
    }

    /// The documented worst-case rank error `ε` (fraction of `n`):
    /// `quantile(q)` lands within rank `q·n ± ε·n` of exact.
    pub fn error_bound(&self) -> f64 {
        let levels_above_zero = self.levels.len().saturating_sub(1);
        levels_above_zero as f64 / (self.k / 2) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_direct_computation() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn summary_merge_equals_sequential() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut whole = Summary::new();
        for i in 0..100 {
            let x = (i as f64).sin() * 5.0;
            if i % 2 == 0 {
                a.push(x);
            } else {
                b.push(x);
            }
            whole.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.var() - whole.var()).abs() < 1e-10);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Summary::new();
        a.push(1.0);
        a.push(2.0);
        let before = (a.count(), a.mean(), a.var());
        a.merge(&Summary::new());
        assert_eq!(before, (a.count(), a.mean(), a.var()));
        let mut e = Summary::new();
        e.merge(&a);
        assert_eq!(e.count(), 2);
    }

    #[test]
    fn quantiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((quantile_sorted(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile_sorted(&xs, 1.0) - 100.0).abs() < 1e-12);
        assert!((quantile_sorted(&xs, 0.5) - 50.5).abs() < 1e-12);
        // The unsorted wrapper sorts a copy and agrees.
        let shuffled: Vec<f64> = (1..=100).rev().map(|i| i as f64).collect();
        assert_eq!(percentile(&shuffled, 0.5), Some(quantile_sorted(&xs, 0.5)));
        assert_eq!(percentile(&[], 0.99), None);
        assert_eq!(percentile(&[3.0, f64::INFINITY], 1.0), Some(f64::INFINITY));
    }

    #[test]
    fn ecdf_eval_and_inverse() {
        let e = Ecdf::new(vec![3.0, 1.0, 2.0, 4.0]);
        assert_eq!(e.eval(0.5), 0.0);
        assert_eq!(e.eval(1.0), 0.25);
        assert_eq!(e.eval(2.5), 0.5);
        assert_eq!(e.eval(4.0), 1.0);
        assert!(e.inverse(0.95) <= 4.0 && e.inverse(0.95) >= 3.0);
    }

    #[test]
    fn ecdf_inverse_is_generalized_inverse() {
        let e = Ecdf::new((1..=1000).map(|i| i as f64).collect());
        for &p in &[0.1, 0.5, 0.9, 0.95, 0.99] {
            let t = e.inverse(p);
            assert!(e.eval(t) >= p - 1e-9, "p={p} t={t} F={}", e.eval(t));
        }
    }

    #[test]
    fn ecdf_try_new_rejects_bad_inputs_gracefully() {
        // Empty and NaN inputs are typed errors, not panics — these are
        // reachable from config/trace JSON through external callers.
        assert!(Ecdf::try_new(vec![]).is_err());
        assert!(Ecdf::try_new(vec![1.0, f64::NAN, 2.0]).is_err());
        // +∞ is a legitimate delay sample (infeasible MC trials).
        let e = Ecdf::try_new(vec![1.0, f64::INFINITY]).unwrap();
        assert_eq!(e.len(), 2);
        assert_eq!(e.eval(1.0), 0.5);
        assert!(e.mean().is_infinite());
        // The checked and panicking constructors agree on valid input.
        let a = Ecdf::try_new(vec![3.0, 1.0, 2.0]).unwrap();
        let b = Ecdf::new(vec![3.0, 1.0, 2.0]);
        assert_eq!(a.sorted_samples(), b.sorted_samples());
    }

    #[test]
    fn ecdf_from_slice_matches_new() {
        let v = vec![3.0, 1.0, 2.0, 4.0];
        let a = Ecdf::from_slice(&v);
        let b = Ecdf::new(v);
        for &t in &[0.5, 1.0, 2.5, 4.0, 9.0] {
            assert_eq!(a.eval(t), b.eval(t));
        }
    }

    #[test]
    fn ecdf_quantile_is_step_function_inverse() {
        let e = Ecdf::new(vec![1.0, 2.0, 3.0, 4.0]);
        // Edge quantiles clamp to the extreme order statistics.
        assert_eq!(e.quantile(0.0), 1.0);
        assert_eq!(e.quantile(-0.5), 1.0);
        assert_eq!(e.quantile(1.0), 4.0);
        assert_eq!(e.quantile(2.0), 4.0);
        // inf{x : F(x) ≥ p}: F(1) = 0.25, F(2) = 0.5, …
        assert_eq!(e.quantile(0.25), 1.0);
        assert_eq!(e.quantile(0.26), 2.0);
        assert_eq!(e.quantile(0.5), 2.0);
        assert_eq!(e.quantile(0.75), 3.0);
        assert_eq!(e.quantile(0.76), 4.0);
        // Tiny but positive p still lands on the minimum.
        assert_eq!(e.quantile(1e-300), 1.0);
    }

    #[test]
    fn ecdf_quantile_properties() {
        use crate::util::prop::{check, Config};
        use crate::util::rng::Rng;
        check(
            Config::default().cases(40),
            "Ecdf::quantile monotone + galois + resample round-trip",
            |g| {
                let n = g.usize_range(2, 200);
                let samples = g.vec(n, |g| g.f64_range(-5.0, 50.0));
                let e = Ecdf::new(samples);
                // Monotone in p.
                let mut prev = f64::NEG_INFINITY;
                for i in 0..=100 {
                    let q = e.quantile(i as f64 / 100.0);
                    assert!(q >= prev, "quantile not monotone at p={}", i as f64 / 100.0);
                    prev = q;
                }
                // Galois pair: quantile(F(x)) ≤ x and F(quantile(p)) ≥ p.
                for i in 0..n {
                    let x = e.sorted[i];
                    assert!(e.quantile(e.eval(x)) <= x);
                }
                for &p in &[0.01, 0.3, 0.5, 0.77, 0.99] {
                    assert!(e.eval(e.quantile(p)) >= p);
                }
                // Inverse-transform resampling reproduces the ECDF.
                let mut rng = Rng::new(g.rng().next_u64());
                let redraw: Vec<f64> = (0..20_000).map(|_| e.quantile(rng.f64())).collect();
                let d = e.sup_distance(&Ecdf::new(redraw));
                // Two-sided KS scale at n = 20 000 is ~0.01; 0.03 is ≈ 4σ.
                assert!(d < 0.03, "resample sup distance {d}");
            },
        );
    }

    #[test]
    fn gamma_fn_reference_values() {
        let cases = [
            (1.0, 1.0),
            (2.0, 1.0),
            (3.0, 2.0),
            (5.0, 24.0),
            (0.5, std::f64::consts::PI.sqrt()),
            (1.5, 0.886_226_925_452_758),
            (2.5, 1.329_340_388_179_137),
            // Γ(8/3) = (10/9)·Γ(2/3) — a 1 + 1/k point for Weibull k = 0.6
            (8.0 / 3.0, 1.504_575_488_251_556),
        ];
        for (x, want) in cases {
            let got = gamma_fn(x);
            assert!(
                (got - want).abs() / want < 1e-10,
                "Γ({x}) = {got}, want {want}"
            );
        }
        // Recurrence Γ(x+1) = x·Γ(x) across the implementation's branches.
        for &x in &[0.2, 0.45, 0.7, 1.3, 3.7, 9.2] {
            let lhs = gamma_fn(x + 1.0);
            let rhs = x * gamma_fn(x);
            assert!((lhs - rhs).abs() / rhs.abs() < 1e-11, "recurrence at {x}");
        }
    }

    #[test]
    fn ecdf_sup_distance_basics() {
        let a = Ecdf::new((1..=100).map(|i| i as f64).collect());
        let b = Ecdf::new((1..=100).map(|i| i as f64).collect());
        assert_eq!(a.sup_distance(&b), 0.0);
        // Shift by half the support: distance is large and symmetric.
        let c = Ecdf::new((51..=150).map(|i| i as f64).collect());
        let d = a.sup_distance(&c);
        assert!((d - 0.5).abs() < 0.02, "sup distance {d}");
        assert_eq!(d, c.sup_distance(&a));
    }

    #[test]
    fn ecdf_series_monotone() {
        let e = Ecdf::new((0..500).map(|i| (i as f64 * 0.37).fract()).collect());
        let s = e.series(50);
        assert_eq!(s.len(), 50);
        assert!(s.windows(2).all(|w| w[1].1 >= w[0].1));
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..100 {
            h.push(i as f64 / 10.0); // 0.0 .. 9.9
        }
        assert_eq!(h.total(), 100);
        assert_eq!(h.overflow(), 0);
        assert_eq!(h.underflow(), 0);
        assert!(h.counts().iter().all(|&c| c == 10));
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
    }

    #[test]
    fn histogram_density_integrates_to_one() {
        let mut h = Histogram::new(0.0, 1.0, 20);
        for i in 0..1000 {
            h.push((i as f64 + 0.5) / 1000.0);
        }
        let integral: f64 = h.density().iter().map(|(_, d)| d * 0.05).sum();
        assert!((integral - 1.0).abs() < 1e-9);
    }

    /// Rank distance between the sketch's answer at `q` and the exact
    /// order statistics: 0 when the value sits inside the exact rank
    /// interval `[#<v, #≤v]` around target rank `⌈q·n⌉`.
    fn rank_error(exact: &[f64], v: f64, q: f64) -> u64 {
        let n = exact.len() as u64;
        let below = exact.iter().filter(|&&x| x < v).count() as u64;
        let upto = exact.iter().filter(|&&x| x <= v).count() as u64;
        let target = ((q * n as f64).ceil() as u64).clamp(1, n);
        if target < below {
            below - target
        } else {
            target.saturating_sub(upto)
        }
    }

    #[test]
    fn sketch_small_streams_are_exact() {
        let mut s = QuantileSketch::new(64);
        assert_eq!(s.quantile(0.5), None);
        for x in [5.0, 1.0, 3.0, 2.0, 4.0] {
            s.insert(x);
        }
        // Below capacity nothing compacts: generalized-inverse exact.
        assert_eq!(s.count(), 5);
        assert_eq!(s.quantile(0.0), Some(1.0));
        assert_eq!(s.quantile(0.5), Some(3.0));
        assert_eq!(s.quantile(1.0), Some(5.0));
        assert_eq!(s.error_bound(), 0.0);
        // NaN is skipped, ∞ is kept.
        s.insert(f64::NAN);
        assert_eq!(s.count(), 5);
        s.insert(f64::INFINITY);
        assert_eq!(s.quantile(1.0), Some(f64::INFINITY));
    }

    #[test]
    fn sketch_memory_is_bounded_and_count_exact() {
        let mut s = QuantileSketch::new(32);
        for i in 0..100_000u64 {
            s.insert((i as f64 * 0.7919).fract());
        }
        assert_eq!(s.count(), 100_000);
        // k items per populated level, L ≈ log2(n/k) levels.
        let levels = (100_000f64 / 32.0).log2().ceil() as usize + 2;
        assert!(
            s.stored() <= 32 * levels,
            "stored {} exceeds {}",
            s.stored(),
            32 * levels
        );
        assert!(s.error_bound() < 1.0);
    }

    #[test]
    fn sketch_rank_error_within_documented_bound() {
        use crate::util::prop::{check, Config};
        check(
            Config::default().cases(12),
            "QuantileSketch rank error ≤ documented bound (uniform/heavy-tail/sorted)",
            |g| {
                let n = g.usize_range(500, 8_000);
                let shape = g.usize_range(0, 3);
                let xs: Vec<f64> = (0..n)
                    .map(|i| match shape {
                        // Uniform noise.
                        0 => g.f64_range(0.0, 1_000.0),
                        // Heavy tail: Pareto-ish 1/U².
                        1 => {
                            let u = g.f64_range(1e-4, 1.0);
                            1.0 / (u * u)
                        }
                        // Adversarial: exactly sorted ascending input.
                        _ => i as f64,
                    })
                    .collect();
                let mut s = QuantileSketch::new(128);
                for &x in &xs {
                    s.insert(x);
                }
                assert_eq!(s.count(), n as u64);
                let allowed = (s.error_bound() * n as f64).ceil() as u64 + 1;
                for &q in &[0.1, 0.5, 0.9, 0.99, 1.0] {
                    let v = s.quantile(q).unwrap();
                    let err = rank_error(&xs, v, q);
                    assert!(
                        err <= allowed,
                        "shape {shape} n {n} q {q}: rank error {err} > {allowed}"
                    );
                    // The sketch never invents values: every readout is
                    // one of the inserted samples, so the exact
                    // percentile oracle brackets it at the bound's edge.
                    assert!(xs.iter().any(|&x| x == v), "readout {v} not a sample");
                    let lo = percentile(&xs, (q - s.error_bound()).max(0.0) * 0.9).unwrap();
                    let hi = percentile(&xs, 1.0).unwrap();
                    assert!(v >= lo && v <= hi, "shape {shape} q {q}: {v} ∉ [{lo}, {hi}]");
                }
            },
        );
    }

    #[test]
    fn sketch_merge_is_weight_exact_and_order_insensitive() {
        use crate::util::prop::{check, Config};
        check(
            Config::default().cases(10),
            "QuantileSketch merge associativity within bound",
            |g| {
                let n = g.usize_range(300, 3_000);
                let xs: Vec<f64> = (0..3 * n).map(|_| g.f64_range(-10.0, 10.0)).collect();
                let chunk = |r: std::ops::Range<usize>| {
                    let mut s = QuantileSketch::new(128);
                    for &x in &xs[r] {
                        s.insert(x);
                    }
                    s
                };
                let (a, b, c) = (chunk(0..n), chunk(n..2 * n), chunk(2 * n..3 * n));
                // (a ∪ b) ∪ c
                let mut left = a.clone();
                left.merge(&b);
                left.merge(&c);
                // a ∪ (b ∪ c)
                let mut right = b.clone();
                right.merge(&c);
                let mut right_full = a.clone();
                right_full.merge(&right);
                assert_eq!(left.count(), 3 * n as u64);
                assert_eq!(right_full.count(), 3 * n as u64);
                for s in [&left, &right_full] {
                    let allowed = (s.error_bound() * (3 * n) as f64).ceil() as u64 + 1;
                    for &q in &[0.5, 0.9, 0.99] {
                        let v = s.quantile(q).unwrap();
                        let err = rank_error(&xs, v, q);
                        assert!(err <= allowed, "q {q}: rank error {err} > {allowed}");
                    }
                }
            },
        );
    }
}
