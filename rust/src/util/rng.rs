//! Deterministic PRNG + samplers (no `rand` crate offline).
//!
//! [`Rng`] is xoshiro256++ seeded through SplitMix64 — the standard
//! recommendation of Blackman & Vigna; passes BigCrush, 2^128 jump-free
//! substreams are obtained by re-seeding with distinct seeds. All samplers
//! used by the delay model live here so the Monte-Carlo engine and the
//! coordinator share one implementation.

/// SplitMix64: used for seeding and as a cheap stateless mixer.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ PRNG with the sampler surface the crate needs.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the last Box–Muller draw.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-thread / per-trial RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1]` — safe as an argument to `ln`.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire rejection-free bias is overkill
    /// here; 64→128 multiply keeps bias < 2⁻⁶⁴).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Exponential with given rate (mean `1/rate`).
    #[inline]
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0, "exp rate must be positive, got {rate}");
        -self.f64_open().ln() / rate
    }

    /// Shifted exponential: `shift + Exp(rate)`.
    #[inline]
    pub fn shifted_exp(&mut self, shift: f64, rate: f64) -> f64 {
        shift + self.exp(rate)
    }

    /// Fill `out` with uniforms in `[0, 1)` — the batched form of
    /// [`Rng::f64`], bit-identical to calling it `out.len()` times.
    #[inline]
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        for x in out.iter_mut() {
            *x = self.f64();
        }
    }

    /// Fill `out` with `Exp(rate)` draws — the batched form of
    /// [`Rng::exp`], bit-identical to calling it `out.len()` times from
    /// the same generator state.
    ///
    /// The point of the batch is shape, not different math: the
    /// (inherently serial) generator pass and the `ln` transform pass are
    /// split into two tight loops over the column, so the blocked
    /// Monte-Carlo kernel keeps the RNG state hot and hands the compiler
    /// a straight-line transform loop.
    pub fn fill_exp(&mut self, rate: f64, out: &mut [f64]) {
        debug_assert!(rate > 0.0, "exp rate must be positive, got {rate}");
        for x in out.iter_mut() {
            // f64_open(): uniform in (0, 1], safe under ln.
            *x = 1.0 - self.f64();
        }
        for x in out.iter_mut() {
            *x = -x.ln() / rate;
        }
    }

    /// Standard normal via polar Box–Muller (cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.index(i + 1));
        }
    }

    /// Random `k`-subset of `0..n` (partial Fisher–Yates), sorted.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "subset: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.f64();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var {var}");
    }

    #[test]
    fn exponential_moments() {
        let mut r = Rng::new(3);
        let rate = 2.5;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 5e-3, "mean {mean}");
    }

    #[test]
    fn shifted_exp_min_respects_shift() {
        let mut r = Rng::new(4);
        let min = (0..50_000)
            .map(|_| r.shifted_exp(1.5, 3.0))
            .fold(f64::INFINITY, f64::min);
        assert!(min >= 1.5);
        assert!(min < 1.51, "min {min} should be close to shift");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn index_bounds_and_coverage() {
        let mut r = Rng::new(6);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.index(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn subset_properties() {
        let mut r = Rng::new(8);
        for _ in 0..100 {
            let s = r.subset(20, 8);
            assert_eq!(s.len(), 8);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn fill_samplers_bit_identical_to_sequential_draws() {
        // The SoA engine's blocked mode relies on this contract: a column
        // fill consumes the generator exactly like the scalar calls.
        let mut a = Rng::new(77);
        let mut b = Rng::new(77);
        let mut col = [0.0f64; 64];
        a.fill_exp(2.5, &mut col);
        for (i, &x) in col.iter().enumerate() {
            assert_eq!(x, b.exp(2.5), "exp draw {i}");
        }
        let mut u = [0.0f64; 32];
        a.fill_f64(&mut u);
        for (i, &x) in u.iter().enumerate() {
            assert_eq!(x, b.f64(), "uniform draw {i}");
        }
        // And the streams stay in lockstep afterwards.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_streams_are_independent_looking() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
