//! Deterministic PRNG + samplers (no `rand` crate offline).
//!
//! [`Rng`] is xoshiro256++ seeded through SplitMix64 — the standard
//! recommendation of Blackman & Vigna; passes BigCrush, 2^128 jump-free
//! substreams are obtained by re-seeding with distinct seeds. All samplers
//! used by the delay model live here so the Monte-Carlo engine and the
//! coordinator share one implementation.

use std::sync::OnceLock;

/// SplitMix64: used for seeding and as a cheap stateless mixer.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Chunk width for the batched fill samplers ([`Rng::fill_f64`],
/// [`Rng::fill_exp`]): 8 f64 lanes, one AVX-512 register or two
/// AVX2 / NEON registers, chosen so the fixed-width transform loops
/// lower to full vectors on every mainstream target.
pub const FILL_LANES: usize = 8;

/// Rightmost layer boundary of the 256-layer exponential ziggurat
/// (Marsaglia & Tsang 2000): `x` such that the 256 equal-area layers
/// plus the tail beyond `x` tile the area under `e^{-x}`.
const ZIG_R: f64 = 7.697_117_470_131_487;

/// Precomputed ziggurat layer boundaries and density values.
///
/// `x[0] = V · e^R` is the *fictitious* base-layer width (so the
/// common accept test `u · x[i] < x[i+1]` selects the rectangular part
/// of the base layer with the right probability); `x[1] = R`; the
/// remaining boundaries follow the equal-area recurrence
/// `x[i] = -ln(e^{-x[i-1]} + V / x[i-1])`, ending at `x[256] = 0`.
/// `f[i] = e^{-x[i]}` caches the density at each boundary for the
/// wedge test.
struct ZigTables {
    x: [f64; 257],
    f: [f64; 257],
}

fn zig_tables() -> &'static ZigTables {
    static ZIG: OnceLock<ZigTables> = OnceLock::new();
    ZIG.get_or_init(|| {
        // Common layer area: base rectangle [0, R] × e^{-R} plus the
        // tail mass ∫_R^∞ e^{-x} dx = e^{-R}, i.e. V = e^{-R}(R + 1).
        // Deriving V from R here keeps the tables self-consistent to
        // machine precision.
        let v = (-ZIG_R).exp() * (ZIG_R + 1.0);
        let mut x = [0.0f64; 257];
        let mut f = [0.0f64; 257];
        x[0] = v * ZIG_R.exp();
        x[1] = ZIG_R;
        for i in 2..256 {
            x[i] = -((-x[i - 1]).exp() + v / x[i - 1]).ln();
        }
        x[256] = 0.0;
        for i in 0..257 {
            f[i] = (-x[i]).exp();
        }
        ZigTables { x, f }
    })
}

/// xoshiro256++ PRNG with the sampler surface the crate needs.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the last Box–Muller draw.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-thread / per-trial RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1]` — safe as an argument to `ln`.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire rejection-free bias is overkill
    /// here; 64→128 multiply keeps bias < 2⁻⁶⁴).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Exponential with given rate (mean `1/rate`).
    #[inline]
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0, "exp rate must be positive, got {rate}");
        -self.f64_open().ln() / rate
    }

    /// Shifted exponential: `shift + Exp(rate)`.
    #[inline]
    pub fn shifted_exp(&mut self, shift: f64, rate: f64) -> f64 {
        shift + self.exp(rate)
    }

    /// Fill `out` with uniforms in `[0, 1)` — the batched form of
    /// [`Rng::f64`], bit-identical to calling it `out.len()` times.
    ///
    /// Kernel v3 shape: the column is walked in [`FILL_LANES`]-wide
    /// chunks — a serial generator pass into a fixed-width bit array,
    /// then a straight-line fixed-width transform loop the
    /// autovectorizer can lower to SIMD lanes (no `std::simd`, stable
    /// Rust only). Per-element arithmetic and draw order are unchanged,
    /// so the bit contract survives the chunking.
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        let mut chunks = out.chunks_exact_mut(FILL_LANES);
        for chunk in &mut chunks {
            let mut bits = [0u64; FILL_LANES];
            for b in bits.iter_mut() {
                *b = self.next_u64();
            }
            for (x, &b) in chunk.iter_mut().zip(bits.iter()) {
                *x = (b >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            }
        }
        for x in chunks.into_remainder() {
            *x = self.f64();
        }
    }

    /// Fill `out` with `Exp(rate)` draws — the batched form of
    /// [`Rng::exp`], bit-identical to calling it `out.len()` times from
    /// the same generator state.
    ///
    /// The point of the batch is shape, not different math: per
    /// [`FILL_LANES`]-wide chunk, the (inherently serial) generator pass
    /// lands in a fixed-width array, then the uniform and `ln`
    /// transforms run as straight-line fixed-width loops (the `ln` calls
    /// stay scalar libm calls, but the surrounding arithmetic
    /// vectorizes and the RNG state stays hot).
    ///
    /// `1 − u` with `u = f64() ∈ [0, 1)` is uniform on `(0, 1]` —
    /// strictly positive, so it is safe as an argument to `ln`.
    pub fn fill_exp(&mut self, rate: f64, out: &mut [f64]) {
        debug_assert!(rate > 0.0, "exp rate must be positive, got {rate}");
        let mut chunks = out.chunks_exact_mut(FILL_LANES);
        for chunk in &mut chunks {
            let mut bits = [0u64; FILL_LANES];
            for b in bits.iter_mut() {
                *b = self.next_u64();
            }
            let mut open = [0.0f64; FILL_LANES];
            for (o, &b) in open.iter_mut().zip(bits.iter()) {
                *o = 1.0 - (b >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            }
            for (x, &o) in chunk.iter_mut().zip(open.iter()) {
                *x = -o.ln() / rate;
            }
        }
        for x in chunks.into_remainder() {
            let o = 1.0 - self.f64();
            *x = -o.ln() / rate;
        }
    }

    /// One `Exp(rate)` draw via the 256-layer ziggurat — a rejection
    /// sampler that replaces the `ln` per draw with a table lookup and
    /// one compare on the ~98.9% fast path. **Different-bits mode**:
    /// rejection consumes a variable number of generator words per
    /// draw, so ziggurat draws are *distribution-equal* to
    /// [`Rng::exp`], never bit-equal (the inverse transform stays the
    /// bit-exact default everywhere).
    #[inline]
    pub fn exp_zig(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0, "exp rate must be positive, got {rate}");
        self.exp_zig_unit() / rate
    }

    /// Fill `out` with ziggurat `Exp(rate)` draws (the batched form of
    /// [`Rng::exp_zig`]; same different-bits contract).
    pub fn fill_exp_zig(&mut self, rate: f64, out: &mut [f64]) {
        debug_assert!(rate > 0.0, "exp rate must be positive, got {rate}");
        let inv_rate = 1.0 / rate;
        for x in out.iter_mut() {
            *x = self.exp_zig_unit() * inv_rate;
        }
    }

    /// Unit-rate exponential via Marsaglia–Tsang layers. One generator
    /// word feeds both the layer index (low 8 bits) and the 53-bit
    /// uniform (bits 11..64) — the bit ranges do not overlap.
    #[inline]
    fn exp_zig_unit(&mut self) -> f64 {
        let t = zig_tables();
        loop {
            let bits = self.next_u64();
            let i = (bits & 0xFF) as usize;
            let u = (bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            let x = u * t.x[i];
            if x < t.x[i + 1] {
                // Strictly inside the next layer's width: under the
                // curve for every layer, and for the base layer (i = 0)
                // this is exactly the rectangular part.
                return x;
            }
            if i == 0 {
                // Base-layer tail: memorylessness gives R + Exp(1).
                return ZIG_R - self.f64_open().ln();
            }
            // Wedge between x[i+1] and x[i]: accept iff the uniform
            // height lands below the density.
            let u2 = self.f64();
            if t.f[i] + u2 * (t.f[i + 1] - t.f[i]) < (-x).exp() {
                return x;
            }
        }
    }

    /// Standard normal via polar Box–Muller (cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.index(i + 1));
        }
    }

    /// Random `k`-subset of `0..n` (partial Fisher–Yates), sorted.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "subset: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.f64();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var {var}");
    }

    #[test]
    fn exponential_moments() {
        let mut r = Rng::new(3);
        let rate = 2.5;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 5e-3, "mean {mean}");
    }

    #[test]
    fn shifted_exp_min_respects_shift() {
        let mut r = Rng::new(4);
        let min = (0..50_000)
            .map(|_| r.shifted_exp(1.5, 3.0))
            .fold(f64::INFINITY, f64::min);
        assert!(min >= 1.5);
        assert!(min < 1.51, "min {min} should be close to shift");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn index_bounds_and_coverage() {
        let mut r = Rng::new(6);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.index(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn subset_properties() {
        let mut r = Rng::new(8);
        for _ in 0..100 {
            let s = r.subset(20, 8);
            assert_eq!(s.len(), 8);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn fill_samplers_bit_identical_to_sequential_draws() {
        // The SoA engine's blocked mode relies on this contract: a column
        // fill consumes the generator exactly like the scalar calls.
        let mut a = Rng::new(77);
        let mut b = Rng::new(77);
        let mut col = [0.0f64; 64];
        a.fill_exp(2.5, &mut col);
        for (i, &x) in col.iter().enumerate() {
            assert_eq!(x, b.exp(2.5), "exp draw {i}");
        }
        let mut u = [0.0f64; 32];
        a.fill_f64(&mut u);
        for (i, &x) in u.iter().enumerate() {
            assert_eq!(x, b.f64(), "uniform draw {i}");
        }
        // And the streams stay in lockstep afterwards.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn chunked_fills_bit_identical_across_lengths() {
        // The v3 chunked fills must preserve the bit contract at every
        // length — full chunks, the scalar remainder, and the empty and
        // sub-lane edge cases (lengths straddling multiples of
        // FILL_LANES = 8).
        for &len in &[0usize, 1, 7, 8, 9, 31, 63, 64, 65, 257] {
            let mut a = Rng::new(1000 + len as u64);
            let mut b = a.clone();
            let mut col = vec![0.0f64; len];
            a.fill_f64(&mut col);
            for (i, &x) in col.iter().enumerate() {
                assert_eq!(x, b.f64(), "fill_f64 len {len} draw {i}");
            }
            a.fill_exp(1.7, &mut col);
            for (i, &x) in col.iter().enumerate() {
                assert_eq!(x, b.exp(1.7), "fill_exp len {len} draw {i}");
            }
            // Streams stay in lockstep afterwards.
            assert_eq!(a.next_u64(), b.next_u64(), "len {len}");
        }
    }

    #[test]
    fn zig_tables_are_consistent() {
        let t = zig_tables();
        // Boundaries decrease strictly from the fictitious base width
        // down to zero; densities increase to f(0) = 1.
        assert_eq!(t.x[1], ZIG_R);
        assert_eq!(t.x[256], 0.0);
        assert_eq!(t.f[256], 1.0);
        for i in 1..256 {
            assert!(t.x[i] > t.x[i + 1], "x not decreasing at {i}");
            assert!(t.f[i] < t.f[i + 1], "f not increasing at {i}");
        }
        // The fictitious base width exceeds R (it encodes the tail mass).
        assert!(t.x[0] > t.x[1]);
        // Equal-area check on an interior layer: the recurrence was
        // built from V, so layer 100's area must reproduce it.
        let v = (-ZIG_R).exp() * (ZIG_R + 1.0);
        let area = t.x[100] * (t.f[101] - t.f[100]);
        assert!((area - v).abs() < 1e-12, "layer area {area} vs V {v}");
    }

    #[test]
    fn ziggurat_draws_are_positive_and_finite() {
        let mut r = Rng::new(11);
        for _ in 0..100_000 {
            let x = r.exp_zig(0.8);
            assert!(x.is_finite() && x > 0.0, "bad draw {x}");
        }
    }

    #[test]
    fn ziggurat_matches_exponential_cdf() {
        // Moment + KS-style pin of the ziggurat sampler against the
        // Exp(rate) law, on the in-tree prop harness: random rates,
        // 40k draws each, mean within 6σ, variance within 10%, and the
        // ECDF sup-distance under 0.015 (≈ 2.2× the 99.9% KS quantile
        // at n = 40_000 — loose enough to be flake-free, tight enough
        // to catch any table or accept-test error).
        crate::util::prop::check(
            crate::util::prop::Config::default().cases(4),
            "ziggurat_matches_exponential_cdf",
            |g| {
                let rate = g.f64_range(0.2, 5.0);
                let n = 40_000usize;
                let mut xs = vec![0.0f64; n];
                g.rng().fill_exp_zig(rate, &mut xs);
                let mean = xs.iter().sum::<f64>() / n as f64;
                let var =
                    xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
                let true_mean = 1.0 / rate;
                let true_var = true_mean * true_mean;
                // Mean of n iid Exp(rate) has sd = (1/rate)/sqrt(n).
                let sd = true_mean / (n as f64).sqrt();
                assert!(
                    (mean - true_mean).abs() < 6.0 * sd,
                    "rate {rate}: mean {mean} vs {true_mean}"
                );
                assert!(
                    (var - true_var).abs() < 0.1 * true_var,
                    "rate {rate}: var {var} vs {true_var}"
                );
                xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let mut sup = 0.0f64;
                for (i, &x) in xs.iter().enumerate() {
                    let cdf = 1.0 - (-rate * x).exp();
                    let lo = i as f64 / n as f64;
                    let hi = (i + 1) as f64 / n as f64;
                    sup = sup.max((cdf - lo).abs()).max((cdf - hi).abs());
                }
                assert!(sup < 0.015, "rate {rate}: KS distance {sup}");
            },
        );
    }

    #[test]
    fn fork_streams_are_independent_looking() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
