//! Plain-text table rendering for the figure harness.
//!
//! The figure binaries print the same rows/series the paper reports; this
//! keeps the formatting in one place (fixed-width, markdown-compatible).

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_fmt(&mut self, label: &str, values: &[f64], prec: usize) -> &mut Self {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| format!("{v:.prec$}")));
        self.row(&cells)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", cell, w = widths[c]));
            }
            line
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new(&["algo", "delay (s)"]);
        t.row(&["uncoded".into(), "3.10".into()]);
        t.row_fmt("coded", &[0.957], 3);
        let s = t.render();
        assert!(s.contains("| algo "));
        assert!(s.contains("| coded "));
        assert!(s.contains("0.957"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        Table::new(&["a", "b"]).row(&["only-one".into()]);
    }
}
