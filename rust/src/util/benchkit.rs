//! Micro-benchmark harness (no `criterion` offline).
//!
//! Warmup + timed iterations with mean/σ/p50/p99 reporting, a stable text
//! format for `cargo bench`, and a `black_box` to keep the optimizer
//! honest. Used by `rust/benches/*.rs` (harness = false) and the §Perf
//! pass in EXPERIMENTS.md.

use std::time::{Duration, Instant};

use super::json::Json;

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub std: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|items| items / self.mean.as_secs_f64())
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>9.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>9.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:>9.2} item/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} ±{:>10}  p50 {:>10}  p99 {:>10}  [{} iters]{}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.std),
            fmt_dur(self.p50),
            fmt_dur(self.p99),
            self.iters,
            tp
        )
    }

    /// Structured record for `BENCH_*.json` perf-trajectory files.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set("iters", Json::Num(self.iters as f64));
        j.set("mean_ns", Json::Num(self.mean.as_nanos() as f64));
        j.set("std_ns", Json::Num(self.std.as_nanos() as f64));
        j.set("p50_ns", Json::Num(self.p50.as_nanos() as f64));
        j.set("p99_ns", Json::Num(self.p99.as_nanos() as f64));
        j.set("min_ns", Json::Num(self.min.as_nanos() as f64));
        j.set("max_ns", Json::Num(self.max.as_nanos() as f64));
        if let Some(t) = self.throughput() {
            j.set("items_per_sec", Json::Num(t));
        }
        j
    }
}

/// Write a `BENCH_<bench>.json` perf record — one document per bench
/// binary, a `results` array of [`BenchResult::to_json`] rows. These
/// files seed the perf trajectory across PRs (DESIGN.md §Perf).
pub fn write_json(path: &str, bench: &str, results: &[BenchResult]) -> std::io::Result<()> {
    let mut j = Json::obj();
    j.set("bench", Json::Str(bench.to_string()));
    j.set(
        "results",
        Json::Arr(results.iter().map(BenchResult::to_json).collect()),
    );
    std::fs::write(path, j.to_string_pretty())
}

/// True when `BENCH_QUICK` is set (to anything but `""`/`"0"`): benches
/// shrink their measurement windows for CI smoke runs. One definition so
/// every bench binary agrees on the env contract.
pub fn quick_mode() -> bool {
    std::env::var("BENCH_QUICK").is_ok_and(|v| v != "0" && !v.is_empty())
}

/// Absolute path of a perf-trajectory record at the **repo root**
/// (`BENCH_*.json` live one level above the crate, next to ROADMAP.md),
/// independent of the caller's working directory.
pub fn repo_root_record(file: &str) -> String {
    format!("{}/../{file}", env!("CARGO_MANIFEST_DIR"))
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark builder.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    max_iters: usize,
    items_per_iter: Option<f64>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            max_iters: 10_000,
            items_per_iter: None,
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn measure_time(mut self, d: Duration) -> Self {
        self.measure = d;
        self
    }

    pub fn max_iters(mut self, n: usize) -> Self {
        self.max_iters = n;
        self
    }

    /// Declare iteration throughput (e.g. trials per run call).
    pub fn items(mut self, n: f64) -> Self {
        self.items_per_iter = Some(n);
        self
    }

    /// Run `f` repeatedly, return timing statistics.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0usize;
        while start.elapsed() < self.warmup && warm_iters < self.max_iters {
            black_box(f());
            warm_iters += 1;
        }

        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.len() < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            // Function slower than the budget: take exactly one sample.
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }

        let mut sorted = samples.clone();
        sorted.sort();
        let n = samples.len();
        let mean_ns =
            samples.iter().map(|d| d.as_nanos() as f64).sum::<f64>() / n as f64;
        let var_ns = samples
            .iter()
            .map(|d| {
                let x = d.as_nanos() as f64 - mean_ns;
                x * x
            })
            .sum::<f64>()
            / n.max(2) as f64;
        let pick = |q: f64| sorted[((n - 1) as f64 * q) as usize];

        BenchResult {
            name: name.to_string(),
            iters: n,
            mean: Duration::from_nanos(mean_ns as u64),
            std: Duration::from_nanos(var_ns.sqrt() as u64),
            p50: pick(0.50),
            p99: pick(0.99),
            min: sorted[0],
            max: sorted[n - 1],
            items_per_iter: self.items_per_iter,
        }
    }
}

/// Group header printer for bench binaries.
pub fn group(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let r = Bench::new()
            .warmup(Duration::from_millis(1))
            .measure_time(Duration::from_millis(20))
            .run("noop-ish", || black_box(3u64.wrapping_mul(7)));
        assert!(r.iters >= 1);
        assert!(r.mean <= r.max);
        assert!(r.min <= r.p50 && r.p50 <= r.p99);
        assert!(r.report().contains("noop-ish"));
    }

    #[test]
    fn throughput_math() {
        let r = Bench::new()
            .warmup(Duration::from_millis(1))
            .measure_time(Duration::from_millis(10))
            .items(1000.0)
            .run("tp", || {
                std::thread::sleep(Duration::from_micros(100));
            });
        let tp = r.throughput().unwrap();
        // 1000 items / ~100µs ⇒ ~10M items/s, allow wide margin
        assert!(tp > 1e5 && tp < 1e8, "tp={tp}");
    }

    #[test]
    fn bench_json_record_parses_back() {
        let r = Bench::new()
            .warmup(Duration::from_millis(1))
            .measure_time(Duration::from_millis(5))
            .items(10.0)
            .run("json-probe", || black_box(1u64 + 1));
        let dir = std::env::temp_dir().join("coded_coop_benchkit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        write_json(path.to_str().unwrap(), "test", &[r]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = super::super::json::parse(&text).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("test"));
        let rows = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].get("mean_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert!(rows[0].get("items_per_sec").is_some());
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }
}
