//! Lambert W function, lower branch `W₋₁`.
//!
//! Theorem 2 of the paper defines the optimal computation-dominant load via
//! `φ = (−W₋₁(−e^{−u·a−1}) − 1)/u`. `W₋₁(x)` is real for `x ∈ [−1/e, 0)`
//! with `W₋₁(x) ≤ −1` and `W₋₁(x)·e^{W₋₁(x)} = x`.
//!
//! Implementation: branch-point series / log-log asymptote as the initial
//! guess, then Halley iterations (cubic convergence); ~4 iterations reach
//! `|w·e^w − x| < 1e−14·|x|` across the domain.

/// Machine value of `−1/e`.
pub const NEG_INV_E: f64 = -0.36787944117144233;

/// Lower branch `W₋₁(x)` for `x ∈ [−1/e, 0)`.
///
/// Returns `None` outside the domain. At the branch point `x = −1/e`
/// returns exactly `−1`.
pub fn lambert_wm1(x: f64) -> Option<f64> {
    if !(x < 0.0) || x < NEG_INV_E - 1e-12 {
        return None;
    }
    if (x - NEG_INV_E).abs() < 1e-16 {
        return Some(-1.0);
    }

    // Initial guess.
    let mut w = if x > -0.27 {
        // Asymptotic for x → 0⁻: W₋₁ ≈ ln(−x) − ln(−ln(−x)).
        let l1 = (-x).ln();
        let l2 = (-l1).ln();
        l1 - l2
    } else {
        // Branch-point series with p = −sqrt(2(1 + e·x)) (negative root
        // selects the lower branch): W = −1 + p − p²/3 + 11/72·p³ …
        let p = -(2.0 * (1.0 + std::f64::consts::E * x)).max(0.0).sqrt();
        -1.0 + p - p * p / 3.0 + 11.0 / 72.0 * p * p * p
    };

    // Halley iterations on f(w) = w·e^w − x.
    for _ in 0..50 {
        let ew = w.exp();
        let f = w * ew - x;
        let wp1 = w + 1.0;
        let denom = ew * wp1 - (w + 2.0) * f / (2.0 * wp1);
        let step = f / denom;
        w -= step;
        if step.abs() <= 1e-15 * (1.0 + w.abs()) {
            break;
        }
    }
    // Guard: lower branch must satisfy w ≤ −1.
    if w > -1.0 {
        w = -1.0;
    }
    Some(w)
}

/// The paper's `φ(a, u) = (−W₋₁(−e^{−u·a−1}) − 1)/u` (Theorem 2).
///
/// `a` is the per-row shift, `u` the per-row rate of the shifted
/// exponential computation delay; both must be positive. `φ` is the
/// optimal per-row time budget `t*/l*` for that node.
pub fn phi(a: f64, u: f64) -> f64 {
    assert!(a > 0.0 && u > 0.0, "phi requires a>0, u>0 (a={a}, u={u})");
    let arg = -(-u * a - 1.0).exp();
    // arg ∈ (−1/e, 0) strictly because u·a > 0, so W₋₁ exists.
    let w = lambert_wm1(arg).expect("phi: argument left W₋₁ domain");
    (-w - 1.0) / u
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_w_exp_w() {
        // Spread of domain points, log-spaced toward 0⁻ and near −1/e.
        let xs = [
            -0.367879, -0.36, -0.3, -0.2, -0.1, -0.05, -0.01, -1e-3, -1e-6,
            -1e-12,
        ];
        for &x in &xs {
            let w = lambert_wm1(x).unwrap();
            assert!(w <= -1.0 + 1e-9, "w={w} must be ≤ −1 at x={x}");
            let back = w * w.exp();
            assert!(
                (back - x).abs() <= 1e-12 * x.abs().max(1e-300),
                "x={x} w={w} back={back}"
            );
        }
    }

    #[test]
    fn branch_point_exact() {
        assert_eq!(lambert_wm1(NEG_INV_E), Some(-1.0));
    }

    #[test]
    fn out_of_domain() {
        assert_eq!(lambert_wm1(0.0), None);
        assert_eq!(lambert_wm1(0.5), None);
        assert_eq!(lambert_wm1(-0.4), None);
        assert_eq!(lambert_wm1(f64::NAN), None);
    }

    #[test]
    fn known_value() {
        // W₋₁(−0.2) ≈ −2.5426413577735264 (reference: scipy.special.lambertw)
        let w = lambert_wm1(-0.2).unwrap();
        assert!((w - (-2.5426413577735264)).abs() < 1e-12, "w={w}");
        // W₋₁(−0.1) ≈ −3.577152063957297
        let w = lambert_wm1(-0.1).unwrap();
        assert!((w - (-3.577152063957297)).abs() < 1e-12, "w={w}");
    }

    #[test]
    fn monotone_decreasing_on_domain() {
        // W₋₁ decreases from −1 (at −1/e) to −∞ (at 0⁻).
        let mut prev = -1.0;
        for i in 1..=100 {
            let x = NEG_INV_E * (1.0 - i as f64 / 101.0);
            let w = lambert_wm1(x).unwrap();
            assert!(w <= prev + 1e-12, "not monotone at x={x}");
            prev = w;
        }
    }

    #[test]
    fn phi_satisfies_theorem2_stationarity() {
        // φ solves (1 + u·φ·u_inv…) — directly: with w = −(1+uφ),
        // (1 + uφ) e^{−(1+uφ)} = e^{−u a − 1}, i.e. the KKT stationarity
        // (36) of the paper. Check the defining identity.
        for &(a, u) in &[(0.2, 5.0), (1.36, 0.735), (0.05, 20.0), (0.5, 2.0)] {
            let f = phi(a, u);
            assert!(f > 0.0);
            let lhs = (1.0 + u * f) * (-(1.0 + u * f)).exp();
            let rhs = (-u * a - 1.0).exp();
            assert!((lhs - rhs).abs() < 1e-12, "a={a} u={u} φ={f}");
        }
    }

    #[test]
    fn phi_exceeds_shift() {
        // The optimal per-row budget must exceed the deterministic per-row
        // shift a (t* > a·l*).
        for &(a, u) in &[(0.2, 5.0), (0.3, 3.3), (1.36, 4.976)] {
            assert!(phi(a, u) > a, "phi({a},{u}) = {} ≤ a", phi(a, u));
        }
    }
}
