//! Minimal JSON parser/serializer (no `serde` offline).
//!
//! Covers the full JSON grammar needed by the config system, the artifact
//! manifest and result export: objects, arrays, strings with escapes,
//! numbers, booleans, null. Object key order is preserved (Vec of pairs) so
//! exported results are diff-stable.

use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ----- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a key in an object (panics on non-objects).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = value;
                } else {
                    pairs.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_pairs(xs: &[(f64, f64)]) -> Json {
        Json::Arr(
            xs.iter()
                .map(|&(a, b)| Json::Arr(vec![Json::Num(a), Json::Num(b)]))
                .collect(),
        )
    }

    // ----- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Path accessor: `j.at(&["workers", "0", "gamma"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(_) => cur.get(p)?,
                Json::Arr(v) => v.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    // ----- serialize -------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no Inf/NaN; serialize as null (documented).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if indent.is_some() {
                            out.push(' ');
                        }
                    }
                    item.write(out, None); // arrays inline
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                let inner = indent.map(|i| i + 2);
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = inner {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind));
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, inner);
                }
                if let (Some(ind), false) = (indent, pairs.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.at(&["a", "2", "b"]).unwrap().as_str(), Some("c"));
        assert_eq!(j.get("d"), Some(&Json::Null));
        assert_eq!(j.at(&["a", "0"]).unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = parse(r#""a\n\t\"\\ A ü""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"\\ A ü"));
    }

    #[test]
    fn parse_errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name": "fig4", "trials": 100000, "series": [[0.5, 1.25], [1, 2]], "ok": true, "none": null}"#;
        let j = parse(src).unwrap();
        let out = j.to_string_pretty();
        assert_eq!(parse(&out).unwrap(), j);
    }

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"version": 1, "artifacts": [{"name": "matvec_r128_c256_b1", "path": "matvec_r128_c256_b1.hlo.txt", "kind": "matvec", "rows": 128, "cols": 256, "batch": 1}]}"#;
        let j = parse(src).unwrap();
        let a = j.at(&["artifacts", "0"]).unwrap();
        assert_eq!(a.get("rows").unwrap().as_usize(), Some(128));
        assert_eq!(parse(&j.to_string_pretty()).unwrap(), j);
    }

    #[test]
    fn set_and_builders() {
        let mut o = Json::obj();
        o.set("x", Json::Num(1.0)).set("y", Json::from_f64_slice(&[1.0, 2.0]));
        o.set("x", Json::Num(3.0)); // overwrite
        assert_eq!(o.get("x").unwrap().as_f64(), Some(3.0));
        assert_eq!(o.get("y").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn non_finite_serializes_null() {
        let j = Json::Num(f64::INFINITY);
        assert_eq!(j.to_string_pretty(), "null");
    }

    #[test]
    fn as_usize_rejects_fraction_and_negative() {
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
    }
}
