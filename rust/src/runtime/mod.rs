//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the CPU PJRT client — the L3↔L2 bridge.
//!
//! * [`pjrt`] — single-threaded owner of the `xla` client: manifest,
//!   executable cache, bucket-padding execute for mat-vec and encode.
//! * [`service`] — the `xla` wrapper types hold raw pointers and are not
//!   `Send`/`Sync`, so [`pjrt::Runtime`] lives on one dedicated thread;
//!   [`service::RuntimeHandle`] is the cloneable, thread-safe façade the
//!   coordinator's workers call into.
//!
//! Interchange contract (see `/opt/xla-example/README.md`): HLO **text** +
//! `manifest.json`, compiled once per artifact (cached), executed with
//! f32 literals. Python never runs here.

pub mod pjrt;
pub mod service;
pub mod xla;

pub use pjrt::{ArtifactKind, ArtifactSpec, Manifest, Runtime};
pub use service::{RuntimeHandle, RuntimeService};

/// Default artifact directory: `$CODED_COOP_ARTIFACTS` or
/// `<repo>/artifacts`.
pub fn default_artifact_dir() -> String {
    std::env::var("CODED_COOP_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}

/// Whether the AOT artifact manifest is present in the default directory.
///
/// Tests that exercise the artifact path call this and skip (rather than
/// fail) when `make artifacts` has not been run — the artifact pipeline
/// needs the Python L1/L2 toolchain, which CI for the Rust crate does not
/// assume.
pub fn artifacts_available() -> bool {
    Manifest::load(&default_artifact_dir()).is_ok()
}
