//! Thread-safe façade over the single-threaded PJRT [`Runtime`].
//!
//! The `xla` wrapper types hold raw pointers and are neither `Send` nor
//! `Sync`, so the runtime is constructed and driven on one dedicated
//! service thread; [`RuntimeHandle`] (cheaply cloneable) marshals requests
//! over an mpsc channel and blocks on a reply channel. The coordinator's
//! worker threads each hold a handle.

use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use super::pjrt::Runtime;

enum Request {
    Matvec {
        a: Vec<f32>,
        rows: usize,
        cols: usize,
        x: Vec<f32>,
        batch: usize,
        reply: Sender<anyhow::Result<Vec<f32>>>,
    },
    Encode {
        g: Vec<f32>,
        coded: usize,
        rows: usize,
        a: Vec<f32>,
        cols: usize,
        reply: Sender<anyhow::Result<Vec<f32>>>,
    },
    Measure {
        rows: usize,
        cols: usize,
        n: usize,
        native: bool,
        reply: Sender<anyhow::Result<Vec<f64>>>,
    },
    Stats {
        reply: Sender<(usize, usize)>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the runtime service.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Request>,
}

impl RuntimeHandle {
    pub fn matvec(
        &self,
        a: Vec<f32>,
        rows: usize,
        cols: usize,
        x: Vec<f32>,
        batch: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Matvec {
                a,
                rows,
                cols,
                x,
                batch,
                reply,
            })
            .map_err(|_| anyhow::anyhow!("runtime service is down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("runtime service dropped reply"))?
    }

    pub fn encode(
        &self,
        g: Vec<f32>,
        coded: usize,
        rows: usize,
        a: Vec<f32>,
        cols: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Encode {
                g,
                coded,
                rows,
                a,
                cols,
                reply,
            })
            .map_err(|_| anyhow::anyhow!("runtime service is down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("runtime service dropped reply"))?
    }

    pub fn measure_matvec(
        &self,
        rows: usize,
        cols: usize,
        n: usize,
        native: bool,
    ) -> anyhow::Result<Vec<f64>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Measure {
                rows,
                cols,
                n,
                native,
                reply,
            })
            .map_err(|_| anyhow::anyhow!("runtime service is down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("runtime service dropped reply"))?
    }

    /// `(compiles, executions)` so far.
    pub fn stats(&self) -> anyhow::Result<(usize, usize)> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| anyhow::anyhow!("runtime service is down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("runtime service dropped reply"))
    }
}

/// Owns the service thread; dropping (or calling [`shutdown`]) stops it.
pub struct RuntimeService {
    tx: Sender<Request>,
    join: Option<JoinHandle<()>>,
}

impl RuntimeService {
    /// Start the service. The runtime (and PJRT client) is constructed on
    /// the service thread itself, so no `Send` bound is needed.
    pub fn start(artifact_dir: &str) -> anyhow::Result<Self> {
        let dir = artifact_dir.to_string();
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || {
                let mut rt = match Runtime::new(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Matvec {
                            a,
                            rows,
                            cols,
                            x,
                            batch,
                            reply,
                        } => {
                            let _ = reply.send(rt.matvec(&a, rows, cols, &x, batch));
                        }
                        Request::Encode {
                            g,
                            coded,
                            rows,
                            a,
                            cols,
                            reply,
                        } => {
                            let _ = reply.send(rt.encode(&g, coded, rows, &a, cols));
                        }
                        Request::Measure {
                            rows,
                            cols,
                            n,
                            native,
                            reply,
                        } => {
                            let _ = reply.send(rt.measure_matvec(rows, cols, n, native));
                        }
                        Request::Stats { reply } => {
                            let _ = reply.send((rt.compiles, rt.executions));
                        }
                        Request::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("runtime thread died during startup"))??;
        Ok(Self {
            tx,
            join: Some(join),
        })
    }

    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle {
            tx: self.tx.clone(),
        }
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_roundtrip_multithreaded() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let svc = RuntimeService::start(&crate::runtime::default_artifact_dir())
            .expect("manifest present but runtime failed to start");
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let h = svc.handle();
                std::thread::spawn(move || {
                    let a = vec![(i + 1) as f32; 8 * 256];
                    let x = vec![1.0f32; 256];
                    let y = h.matvec(a, 8, 256, x, 1).unwrap();
                    assert_eq!(y.len(), 8);
                    // each row = 256 * (i+1)
                    assert!((y[0] - 256.0 * (i + 1) as f32).abs() < 1e-2);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let (compiles, execs) = svc.handle().stats().unwrap();
        assert_eq!(compiles, 1, "one bucket, one compile");
        assert_eq!(execs, 4);
    }

    #[test]
    fn bad_artifact_dir_fails_cleanly() {
        assert!(RuntimeService::start("/nonexistent/artifacts").is_err());
    }
}
