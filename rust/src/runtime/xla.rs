//! Offline stand-in for the `xla` (PJRT) binding crate.
//!
//! The production build links the real PJRT CPU client through the `xla`
//! crate; that native binding is unavailable in this offline environment,
//! so the exact API surface [`super::pjrt`] uses is provided here with a
//! pure-Rust executor. The artifacts this runtime "compiles" are the AOT
//! mat-vec / encode HLO programs from `python/compile/aot.py` — both are
//! a single `dot(lhs, rhs)` over f32 operands, so the stub executes the
//! equivalent row-major matmul natively. Contracts preserved:
//!
//! * compiling requires the HLO text artifact to exist and be non-empty
//!   (missing artifacts fail exactly like the real client);
//! * `execute` takes 2-D f32 literals `(m × k)` and `(k × n)` and returns
//!   the `(m × n)` product wrapped in a 1-tuple (aot.py lowers with
//!   `return_tuple=True`);
//! * shapes are validated and mismatches surface as `Err`, not panics.
//!
//! Swapping the real `xla` crate back in is a one-line change in
//! `pjrt.rs` (`use super::xla` → `use xla`).

use std::borrow::Borrow;

/// An f32 literal with a shape, optionally a tuple of literals.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal over a slice.
    pub fn vec1(xs: &[f32]) -> Literal {
        Literal {
            data: xs.to_vec(),
            dims: vec![xs.len() as i64],
            tuple: None,
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> anyhow::Result<Literal> {
        let want: i64 = dims.iter().product();
        anyhow::ensure!(
            want >= 0 && want as usize == self.data.len(),
            "reshape {:?} incompatible with {} elements",
            dims,
            self.data.len()
        );
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
            tuple: None,
        })
    }

    /// Unwrap a 1-tuple literal (AOT artifacts return tuples).
    pub fn to_tuple1(&self) -> anyhow::Result<Literal> {
        match &self.tuple {
            Some(items) if items.len() == 1 => Ok(items[0].clone()),
            Some(items) => anyhow::bail!("expected 1-tuple, got {}-tuple", items.len()),
            None => anyhow::bail!("literal is not a tuple"),
        }
    }

    /// Copy out as a flat vector.
    pub fn to_vec<T: Element>(&self) -> anyhow::Result<Vec<T>> {
        anyhow::ensure!(self.tuple.is_none(), "cannot to_vec a tuple literal");
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

/// Element types extractable from a literal (f32 only — all artifacts
/// are f32).
pub trait Element {
    fn from_f32(v: f32) -> Self;
}

impl Element for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// Parsed HLO module (the stub validates existence, not content).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text_len: usize,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> anyhow::Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read HLO artifact {path}: {e}"))?;
        anyhow::ensure!(!text.trim().is_empty(), "HLO artifact {path} is empty");
        Ok(HloModuleProto {
            text_len: text.len(),
        })
    }
}

/// Computation handle built from an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer holding an execution output.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> anyhow::Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable: the stub evaluates `dot(lhs, rhs)`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> anyhow::Result<Vec<Vec<PjRtBuffer>>> {
        anyhow::ensure!(args.len() == 2, "artifact expects 2 operands");
        let a = args[0].borrow();
        let b = args[1].borrow();
        anyhow::ensure!(
            a.dims.len() == 2 && b.dims.len() == 2,
            "operands must be rank-2, got {:?} and {:?}",
            a.dims,
            b.dims
        );
        let (m, k) = (a.dims[0] as usize, a.dims[1] as usize);
        let (k2, n) = (b.dims[0] as usize, b.dims[1] as usize);
        anyhow::ensure!(
            k == k2,
            "contraction mismatch: ({m} × {k}) · ({k2} × {n})"
        );
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = a.data[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        let result = Literal {
            data: out,
            dims: vec![m as i64, n as i64],
            tuple: None,
        };
        let tuple = Literal {
            data: Vec::new(),
            dims: Vec::new(),
            tuple: Some(vec![result]),
        };
        Ok(vec![vec![PjRtBuffer { literal: tuple }]])
    }
}

/// CPU client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> anyhow::Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> anyhow::Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 4]).is_err());
    }

    #[test]
    fn execute_is_matmul_in_a_tuple() {
        let a = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let x = Literal::vec1(&[1.0, 1.0]).reshape(&[2, 1]).unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&XlaComputation).unwrap();
        let out = exe.execute::<Literal>(&[a, x]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        let y = out.to_tuple1().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(y, vec![3.0, 7.0]);
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let a = Literal::vec1(&[1.0; 6]).reshape(&[2, 3]).unwrap();
        let b = Literal::vec1(&[1.0; 4]).reshape(&[2, 2]).unwrap();
        let exe = PjRtLoadedExecutable;
        assert!(exe.execute::<Literal>(&[a, b]).is_err());
    }

    #[test]
    fn missing_artifact_fails() {
        assert!(HloModuleProto::from_text_file("/no/such/artifact.hlo.txt").is_err());
    }
}
