//! Single-threaded PJRT runtime: manifest, executable cache, padded
//! execution of the mat-vec / encode artifacts.

use std::collections::HashMap;
use std::time::Instant;

use super::xla;
use crate::util::json::{self, Json};

/// Artifact role.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Worker compute `y = Ã_{m,n} x` (Pallas kernel).
    Matvec,
    /// XLA-native ablation twin of `Matvec`.
    MatvecNative,
    /// Master-side `Ã = G A` (Pallas kernel).
    Encode,
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: String,
    pub kind: ArtifactKind,
    /// Matvec: row bucket; Encode: original-row bucket.
    pub rows: usize,
    pub cols: usize,
    /// Matvec only.
    pub batch: usize,
    /// Encode only.
    pub coded_rows: usize,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: String,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(format!("{dir}/manifest.json"))
            .map_err(|e| {
                anyhow::anyhow!(
                    "cannot read {dir}/manifest.json ({e}); run `make artifacts` first"
                )
            })?;
        let j = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?;
        let get_usize = |e: &Json, k: &str| e.get(k).and_then(Json::as_usize).unwrap_or(0);
        let artifacts = arts
            .iter()
            .map(|e| {
                let kind = match e.get("kind").and_then(Json::as_str) {
                    Some("matvec") => ArtifactKind::Matvec,
                    Some("matvec_native") => ArtifactKind::MatvecNative,
                    Some("encode") => ArtifactKind::Encode,
                    other => anyhow::bail!("unknown artifact kind {other:?}"),
                };
                Ok(ArtifactSpec {
                    name: e
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow::anyhow!("artifact missing name"))?
                        .to_string(),
                    path: e
                        .get("path")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow::anyhow!("artifact missing path"))?
                        .to_string(),
                    kind,
                    rows: get_usize(e, "rows"),
                    cols: get_usize(e, "cols"),
                    batch: get_usize(e, "batch"),
                    coded_rows: get_usize(e, "coded_rows"),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(!artifacts.is_empty(), "manifest has no artifacts");
        Ok(Self {
            dir: dir.to_string(),
            artifacts,
        })
    }

    /// Smallest matvec bucket with `rows ≥ r`, `cols ≥ c`, `batch == b`.
    pub fn matvec_bucket(&self, r: usize, c: usize, b: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Matvec && a.rows >= r && a.cols >= c && a.batch == b
            })
            .min_by_key(|a| (a.rows, a.cols))
    }

    /// Smallest encode bucket covering `(coded, rows, cols)`.
    pub fn encode_bucket(
        &self,
        coded: usize,
        rows: usize,
        cols: usize,
    ) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Encode
                    && a.coded_rows >= coded
                    && a.rows >= rows
                    && a.cols >= cols
            })
            .min_by_key(|a| (a.coded_rows, a.rows, a.cols))
    }
}

/// The runtime proper. NOT `Send`: construct and use on one thread (see
/// [`super::service`] for the multi-threaded façade).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Compiles performed (for cache-behavior tests/metrics).
    pub compiles: usize,
    /// Executions performed.
    pub executions: usize,
}

impl Runtime {
    pub fn new(artifact_dir: &str) -> anyhow::Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu()?,
            manifest: Manifest::load(artifact_dir)?,
            cache: HashMap::new(),
            compiles: 0,
            executions: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&mut self, name: &str) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self
                .manifest
                .artifacts
                .iter()
                .find(|a| a.name == name)
                .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?;
            let path = format!("{}/{}", self.manifest.dir, spec.path);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.compiles += 1;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute a 2-input artifact and return the flat f32 output.
    fn exec2(
        &mut self,
        name: &str,
        a: &[f32],
        a_dims: [usize; 2],
        b: &[f32],
        b_dims: [usize; 2],
    ) -> anyhow::Result<Vec<f32>> {
        let la = xla::Literal::vec1(a).reshape(&[a_dims[0] as i64, a_dims[1] as i64])?;
        let lb = xla::Literal::vec1(b).reshape(&[b_dims[0] as i64, b_dims[1] as i64])?;
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        self.executions += 1;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    /// `y = A·x` through the Pallas mat-vec artifact.
    ///
    /// `a`: row-major `(rows × cols)`; `x`: `(cols × batch)`. Ragged
    /// shapes are zero-padded up to the chosen bucket (zero rows/cols do
    /// not change the products).
    pub fn matvec(
        &mut self,
        a: &[f32],
        rows: usize,
        cols: usize,
        x: &[f32],
        batch: usize,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(a.len() == rows * cols, "a has wrong length");
        anyhow::ensure!(x.len() == cols * batch, "x has wrong length");
        let spec = self
            .manifest
            .matvec_bucket(rows, cols, batch)
            .ok_or_else(|| {
                anyhow::anyhow!("no matvec bucket covers ({rows}, {cols}, b={batch})")
            })?;
        let (br, bc) = (spec.rows, spec.cols);
        let name = spec.name.clone();
        let a_pad = pad2(a, rows, cols, br, bc);
        let x_pad = pad2(x, cols, batch, bc, batch);
        let out = self.exec2(&name, &a_pad, [br, bc], &x_pad, [bc, batch])?;
        // Output (br × batch) row-major: the first `rows` rows are ours.
        Ok(out[..rows * batch].to_vec())
    }

    /// Ablation twin: same mat-vec through the XLA-native artifact.
    pub fn matvec_native(
        &mut self,
        a: &[f32],
        rows: usize,
        cols: usize,
        x: &[f32],
        batch: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let spec = self
            .manifest
            .artifacts
            .iter()
            .find(|s| {
                s.kind == ArtifactKind::MatvecNative
                    && s.rows >= rows
                    && s.cols >= cols
                    && s.batch == batch
            })
            .ok_or_else(|| anyhow::anyhow!("no native matvec bucket"))?;
        let (br, bc) = (spec.rows, spec.cols);
        let name = spec.name.clone();
        let a_pad = pad2(a, rows, cols, br, bc);
        let x_pad = pad2(x, cols, batch, bc, batch);
        let out = self.exec2(&name, &a_pad, [br, bc], &x_pad, [bc, batch])?;
        Ok(out[..rows * batch].to_vec())
    }

    /// `Ã = G·A` through the Pallas encode artifact.
    pub fn encode(
        &mut self,
        g: &[f32],
        coded: usize,
        rows: usize,
        a: &[f32],
        cols: usize,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(g.len() == coded * rows, "g has wrong length");
        anyhow::ensure!(a.len() == rows * cols, "a has wrong length");
        let spec = self
            .manifest
            .encode_bucket(coded, rows, cols)
            .ok_or_else(|| {
                anyhow::anyhow!("no encode bucket covers ({coded}, {rows}, {cols})")
            })?;
        let (bm, bk, bc) = (spec.coded_rows, spec.rows, spec.cols);
        let name = spec.name.clone();
        let g_pad = pad2(g, coded, rows, bm, bk);
        let a_pad = pad2(a, rows, cols, bk, bc);
        let out = self.exec2(&name, &g_pad, [bm, bk], &a_pad, [bk, bc])?;
        // Slice the top-left (coded × cols) block out of (bm × bc).
        let mut res = Vec::with_capacity(coded * cols);
        for r in 0..coded {
            res.extend_from_slice(&out[r * bc..r * bc + cols]);
        }
        Ok(res)
    }

    /// Measure `n` repeated mat-vec executions (per-call wallclock, ms) —
    /// the real-measurement path for the Fig. 7 pipeline.
    pub fn measure_matvec(
        &mut self,
        rows: usize,
        cols: usize,
        n: usize,
        native: bool,
    ) -> anyhow::Result<Vec<f64>> {
        let a = vec![1.0f32; rows * cols];
        let x = vec![1.0f32; cols];
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let t0 = Instant::now();
            if native {
                self.matvec_native(&a, rows, cols, &x, 1)?;
            } else {
                self.matvec(&a, rows, cols, &x, 1)?;
            }
            out.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        Ok(out)
    }
}

/// Zero-pad a row-major `(r × c)` buffer into `(pr × pc)`.
fn pad2(src: &[f32], r: usize, c: usize, pr: usize, pc: usize) -> Vec<f32> {
    debug_assert!(pr >= r && pc >= c);
    if pr == r && pc == c {
        return src.to_vec();
    }
    let mut out = vec![0.0f32; pr * pc];
    for i in 0..r {
        out[i * pc..i * pc + c].copy_from_slice(&src[i * c..(i + 1) * c]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// `None` (⇒ the test skips) when `make artifacts` has not been run.
    fn runtime() -> Option<Runtime> {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(
            Runtime::new(&crate::runtime::default_artifact_dir())
                .expect("manifest present but runtime failed to start"),
        )
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn naive_matmul(a: &[f32], r: usize, k: usize, b: &[f32], c: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for kk in 0..k {
                let av = a[i * k + kk];
                for j in 0..c {
                    out[i * c + j] += av * b[kk * c + j];
                }
            }
        }
        out
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!(
                (g - w).abs() <= tol * (1.0 + w.abs()),
                "idx {i}: {g} vs {w}"
            );
        }
    }

    #[test]
    fn manifest_loads_and_has_buckets() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&crate::runtime::default_artifact_dir()).unwrap();
        assert!(m.matvec_bucket(100, 256, 1).is_some());
        assert!(m.matvec_bucket(1000, 512, 1).is_some());
        assert!(m.encode_bucket(2000, 1000, 512).is_some());
        assert!(m.matvec_bucket(100_000, 512, 1).is_none());
    }

    #[test]
    fn matvec_exact_bucket_matches_naive() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::new(1);
        let (r, c) = (128, 256);
        let a = rand_vec(&mut rng, r * c);
        let x = rand_vec(&mut rng, c);
        let got = rt.matvec(&a, r, c, &x, 1).unwrap();
        let want = naive_matmul(&a, r, c, &x, 1);
        assert_close(&got, &want, 1e-4);
    }

    #[test]
    fn matvec_ragged_shape_padded() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::new(2);
        let (r, c) = (100, 200); // not a bucket: pads to (128, 256)
        let a = rand_vec(&mut rng, r * c);
        let x = rand_vec(&mut rng, c);
        let got = rt.matvec(&a, r, c, &x, 1).unwrap();
        let want = naive_matmul(&a, r, c, &x, 1);
        assert_close(&got, &want, 1e-4);
    }

    #[test]
    fn matvec_batched() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::new(3);
        let (r, c, b) = (200, 500, 8);
        let a = rand_vec(&mut rng, r * c);
        let x = rand_vec(&mut rng, c * b);
        let got = rt.matvec(&a, r, c, &x, b).unwrap();
        let want = naive_matmul(&a, r, c, &x, b);
        assert_close(&got, &want, 1e-4);
    }

    #[test]
    fn encode_matches_naive() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::new(4);
        let (coded, rows, cols) = (200, 100, 250);
        let g = rand_vec(&mut rng, coded * rows);
        let a = rand_vec(&mut rng, rows * cols);
        let got = rt.encode(&g, coded, rows, &a, cols).unwrap();
        let want = naive_matmul(&g, coded, rows, &a, cols);
        assert_close(&got, &want, 1e-4);
    }

    #[test]
    fn pallas_and_native_twins_agree() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::new(5);
        let (r, c) = (512, 512);
        let a = rand_vec(&mut rng, r * c);
        let x = rand_vec(&mut rng, c);
        let p = rt.matvec(&a, r, c, &x, 1).unwrap();
        let n = rt.matvec_native(&a, r, c, &x, 1).unwrap();
        assert_close(&p, &n, 1e-4);
    }

    #[test]
    fn executable_cache_compiles_once() {
        let Some(mut rt) = runtime() else { return };
        let a = vec![1.0f32; 128 * 256];
        let x = vec![1.0f32; 256];
        rt.matvec(&a, 128, 256, &x, 1).unwrap();
        rt.matvec(&a, 128, 256, &x, 1).unwrap();
        rt.matvec(&a, 128, 256, &x, 1).unwrap();
        assert_eq!(rt.compiles, 1, "same bucket must compile once");
        assert_eq!(rt.executions, 3);
    }

    #[test]
    fn measure_returns_positive_timings() {
        let Some(mut rt) = runtime() else { return };
        let ts = rt.measure_matvec(128, 256, 5, false).unwrap();
        assert_eq!(ts.len(), 5);
        assert!(ts.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn pad2_behavior() {
        let src = [1.0, 2.0, 3.0, 4.0]; // 2x2
        let out = pad2(&src, 2, 2, 3, 4);
        assert_eq!(
            out,
            vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]
        );
    }
}
