//! Shifted-exponential fitting (Fig. 7's "fit the data" step).
//!
//! MLE for `X = a + Exp(u)` from i.i.d. samples:
//! `â = min(x_i)` (boundary MLE), `û = 1/(mean(x_i) − â)`.
//! `E[min] = a + 1/(n·u)` — the raw min over-estimates the shift; we apply
//! the standard unbiasing `â = min − (mean − min)/(n−1)` (since
//! `E[mean − min] = (n−1)/(n·u)`), which matters only for small traces but
//! keeps the estimator consistent.
//!
//! Fitting is fallible — short or constant traces have no
//! shifted-exponential MLE — and sweep-driven pipelines fit thousands of
//! traces unattended, so [`fit_shifted_exp`] returns a typed
//! [`FitError`] instead of panicking.

use std::fmt;

use crate::model::dist::ShiftedExp;

/// Why a trace could not be fitted. Typed (not a string) so sweep
/// pipelines can branch on the cause — e.g. skip degenerate cells but
/// fail loudly on non-finite data.
#[derive(Clone, Debug, PartialEq)]
pub enum FitError {
    /// Fewer than two samples: the MLE needs min AND mean information.
    TooFewSamples { n: usize },
    /// All samples equal: `û = 1/(mean − â)` has no finite solution.
    DegenerateTrace { value: f64 },
    /// A sample was NaN/∞ — upstream measurement corruption.
    NonFinite,
}

impl fmt::Display for FitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FitError::TooFewSamples { n } => {
                write!(f, "need ≥2 samples to fit a shifted exponential, got {n}")
            }
            FitError::DegenerateTrace { value } => {
                write!(f, "degenerate trace: all samples equal ({value})")
            }
            FitError::NonFinite => write!(f, "trace contains non-finite samples"),
        }
    }
}

impl std::error::Error for FitError {}

/// A fitted shifted exponential with fit diagnostics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FittedShiftedExp {
    pub a: f64,
    pub u: f64,
    /// Kolmogorov–Smirnov statistic of the fit (sup |F̂ − F|).
    pub ks: f64,
    pub n: usize,
}

impl FittedShiftedExp {
    pub fn dist(&self) -> ShiftedExp {
        ShiftedExp::new(self.a, self.u)
    }
}

/// Fit a shifted exponential to a delay trace. Errors (never panics) on
/// traces with fewer than two samples, non-finite samples, or all
/// samples equal.
pub fn fit_shifted_exp(samples: &[f64]) -> Result<FittedShiftedExp, FitError> {
    if samples.len() < 2 {
        return Err(FitError::TooFewSamples { n: samples.len() });
    }
    if samples.iter().any(|x| !x.is_finite()) {
        return Err(FitError::NonFinite);
    }
    let n = samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean = samples.iter().sum::<f64>() / n;
    if mean <= min {
        return Err(FitError::DegenerateTrace { value: min });
    }
    // Bias-corrected shift and the matching rate.
    let a = min - (mean - min) / (n - 1.0);
    let u = 1.0 / (mean - a);

    // KS statistic against the fitted CDF.
    let mut sorted = samples.to_vec();
    // total_cmp: the NonFinite guard above already rejects NaN, but the
    // sort itself must never be the thing that panics on a bad trace.
    sorted.sort_by(f64::total_cmp);
    let fitted = ShiftedExp::new(a.max(0.0), u);
    let mut ks = 0.0f64;
    for (i, &x) in sorted.iter().enumerate() {
        let f = fitted.cdf(x);
        let hi = (i + 1) as f64 / n;
        let lo = i as f64 / n;
        ks = ks.max((f - lo).abs()).max((hi - f).abs());
    }

    Ok(FittedShiftedExp {
        a,
        u,
        ks,
        n: samples.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::ec2::{C5_LARGE, T2_MICRO};
    use crate::util::rng::Rng;

    #[test]
    fn recovers_t2_micro_parameters() {
        let mut rng = Rng::new(42);
        let trace = T2_MICRO.sample_trace(200_000, &mut rng);
        let fit = fit_shifted_exp(&trace).unwrap();
        assert!(
            (fit.a - T2_MICRO.a).abs() / T2_MICRO.a < 0.01,
            "a: {} vs {}",
            fit.a,
            T2_MICRO.a
        );
        assert!(
            (fit.u - T2_MICRO.u).abs() / T2_MICRO.u < 0.02,
            "u: {} vs {}",
            fit.u,
            T2_MICRO.u
        );
        // A correct parametric fit has small KS distance.
        assert!(fit.ks < 0.01, "ks={}", fit.ks);
    }

    #[test]
    fn recovers_c5_large_parameters() {
        let mut rng = Rng::new(43);
        let trace = C5_LARGE.sample_trace(200_000, &mut rng);
        let fit = fit_shifted_exp(&trace).unwrap();
        assert!((fit.a - C5_LARGE.a).abs() / C5_LARGE.a < 0.01);
        assert!((fit.u - C5_LARGE.u).abs() / C5_LARGE.u < 0.02);
    }

    #[test]
    fn ks_detects_wrong_model() {
        // Uniform[0,1] data is a bad shifted-exp fit: KS should be large
        // relative to the correct-model case.
        let mut rng = Rng::new(44);
        let unif: Vec<f64> = (0..50_000).map(|_| rng.f64()).collect();
        let fit = fit_shifted_exp(&unif).unwrap();
        assert!(fit.ks > 0.05, "ks={} unexpectedly small", fit.ks);
    }

    #[test]
    fn typed_errors_instead_of_panics() {
        assert_eq!(
            fit_shifted_exp(&[1.0]),
            Err(FitError::TooFewSamples { n: 1 })
        );
        assert_eq!(fit_shifted_exp(&[]), Err(FitError::TooFewSamples { n: 0 }));
        assert_eq!(
            fit_shifted_exp(&[2.5, 2.5, 2.5]),
            Err(FitError::DegenerateTrace { value: 2.5 })
        );
        assert_eq!(
            fit_shifted_exp(&[1.0, f64::NAN, 2.0]),
            Err(FitError::NonFinite)
        );
        assert_eq!(
            fit_shifted_exp(&[1.0, f64::INFINITY]),
            Err(FitError::NonFinite)
        );
        // Display strings name the cause for humans.
        let msg = FitError::DegenerateTrace { value: 2.5 }.to_string();
        assert!(msg.contains("degenerate"), "{msg}");
        // And the error type flows through anyhow (`?` in callers).
        fn through_anyhow(xs: &[f64]) -> anyhow::Result<f64> {
            Ok(fit_shifted_exp(xs)?.a)
        }
        assert!(through_anyhow(&[0.5]).is_err());
        assert!(through_anyhow(&[0.5, 1.5, 2.0]).is_ok());
    }

    #[test]
    fn fitted_errors_are_partialeq_not_strings() {
        // Sweep pipelines branch on the variant, not the message.
        match fit_shifted_exp(&[3.0]) {
            Err(FitError::TooFewSamples { n }) => assert_eq!(n, 1),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn small_sample_bias_correction_helps() {
        // With n=20, raw min underestimates `a`; the corrected estimator
        // should not be systematically below the true shift.
        let mut rng = Rng::new(45);
        let mut sum_a = 0.0;
        let reps = 3000;
        for _ in 0..reps {
            let trace = T2_MICRO.sample_trace(20, &mut rng);
            sum_a += fit_shifted_exp(&trace).unwrap().a;
        }
        let avg_a = sum_a / reps as f64;
        assert!(
            (avg_a - T2_MICRO.a).abs() < 0.01,
            "bias-corrected â averages {avg_a}, want ≈{}",
            T2_MICRO.a
        );
    }
}
