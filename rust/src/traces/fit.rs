//! Shifted-exponential fitting (Fig. 7's "fit the data" step).
//!
//! MLE for `X = a + Exp(u)` from i.i.d. samples:
//! `â = min(x_i)` (boundary MLE), `û = 1/(mean(x_i) − â)`.
//! `E[min] = a + 1/(n·u)` — the raw min over-estimates the shift; we apply
//! the standard unbiasing `â = min − (mean − min)/(n−1)` (since
//! `E[mean − min] = (n−1)/(n·u)`), which matters only for small traces but
//! keeps the estimator consistent.

use crate::model::dist::ShiftedExp;

/// A fitted shifted exponential with fit diagnostics.
#[derive(Clone, Copy, Debug)]
pub struct FittedShiftedExp {
    pub a: f64,
    pub u: f64,
    /// Kolmogorov–Smirnov statistic of the fit (sup |F̂ − F|).
    pub ks: f64,
    pub n: usize,
}

impl FittedShiftedExp {
    pub fn dist(&self) -> ShiftedExp {
        ShiftedExp::new(self.a, self.u)
    }
}

/// Fit a shifted exponential to a delay trace. Panics on fewer than two
/// samples or a degenerate (constant) trace.
pub fn fit_shifted_exp(samples: &[f64]) -> FittedShiftedExp {
    assert!(samples.len() >= 2, "need ≥2 samples to fit");
    let n = samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean = samples.iter().sum::<f64>() / n;
    assert!(
        mean > min,
        "degenerate trace: all samples equal ({min})"
    );
    // Bias-corrected shift and the matching rate.
    let a = min - (mean - min) / (n - 1.0);
    let u = 1.0 / (mean - a);

    // KS statistic against the fitted CDF.
    let mut sorted = samples.to_vec();
    sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let fitted = ShiftedExp::new(a.max(0.0), u);
    let mut ks = 0.0f64;
    for (i, &x) in sorted.iter().enumerate() {
        let f = fitted.cdf(x);
        let hi = (i + 1) as f64 / n;
        let lo = i as f64 / n;
        ks = ks.max((f - lo).abs()).max((hi - f).abs());
    }

    FittedShiftedExp {
        a,
        u,
        ks,
        n: samples.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::ec2::{C5_LARGE, T2_MICRO};
    use crate::util::rng::Rng;

    #[test]
    fn recovers_t2_micro_parameters() {
        let mut rng = Rng::new(42);
        let trace = T2_MICRO.sample_trace(200_000, &mut rng);
        let fit = fit_shifted_exp(&trace);
        assert!(
            (fit.a - T2_MICRO.a).abs() / T2_MICRO.a < 0.01,
            "a: {} vs {}",
            fit.a,
            T2_MICRO.a
        );
        assert!(
            (fit.u - T2_MICRO.u).abs() / T2_MICRO.u < 0.02,
            "u: {} vs {}",
            fit.u,
            T2_MICRO.u
        );
        // A correct parametric fit has small KS distance.
        assert!(fit.ks < 0.01, "ks={}", fit.ks);
    }

    #[test]
    fn recovers_c5_large_parameters() {
        let mut rng = Rng::new(43);
        let trace = C5_LARGE.sample_trace(200_000, &mut rng);
        let fit = fit_shifted_exp(&trace);
        assert!((fit.a - C5_LARGE.a).abs() / C5_LARGE.a < 0.01);
        assert!((fit.u - C5_LARGE.u).abs() / C5_LARGE.u < 0.02);
    }

    #[test]
    fn ks_detects_wrong_model() {
        // Uniform[0,1] data is a bad shifted-exp fit: KS should be large
        // relative to the correct-model case.
        let mut rng = Rng::new(44);
        let unif: Vec<f64> = (0..50_000).map(|_| rng.f64()).collect();
        let fit = fit_shifted_exp(&unif);
        assert!(fit.ks > 0.05, "ks={} unexpectedly small", fit.ks);
    }

    #[test]
    #[should_panic(expected = "need ≥2")]
    fn rejects_tiny_traces() {
        fit_shifted_exp(&[1.0]);
    }

    #[test]
    fn small_sample_bias_correction_helps() {
        // With n=20, raw min underestimates `a`; the corrected estimator
        // should not be systematically below the true shift.
        let mut rng = Rng::new(45);
        let mut sum_a = 0.0;
        let reps = 3000;
        for _ in 0..reps {
            let trace = T2_MICRO.sample_trace(20, &mut rng);
            sum_a += fit_shifted_exp(&trace).a;
        }
        let avg_a = sum_a / reps as f64;
        assert!(
            (avg_a - T2_MICRO.a).abs() < 0.01,
            "bias-corrected â averages {avg_a}, want ≈{}",
            T2_MICRO.a
        );
    }
}
