//! EC2-style instance profiles.
//!
//! The paper samples the computation delay of a 10⁶-dimension float
//! dot-product 10⁶ times on two EC2 instance types and fits shifted
//! exponentials (§V-C). We cannot run on EC2; instead each profile is a
//! delay *source* with the paper's fitted parameters, and the fitting
//! pipeline itself ([`super::fit`]) is reproduced so Fig. 7 regenerates
//! end-to-end: sample → fit → compare CDFs.
//!
//! Units: per-coded-row delay in ms — `a` is the shift, `u` the rate, so a
//! load of `l` rows takes `a·l + Exp(u/l)` (eq. 2 with k = 1).

use crate::model::dist::ShiftedExp;
use crate::util::rng::Rng;

/// A worker hardware profile with shifted-exponential per-row compute.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct InstanceType {
    pub name: &'static str,
    /// Shift of the per-row computation delay (ms).
    pub a: f64,
    /// Rate of the per-row computation delay (1/ms).
    pub u: f64,
}

/// Paper fit for Amazon EC2 t2.micro: a = 1.36 ms, u = 4.976 ms⁻¹.
pub const T2_MICRO: InstanceType = InstanceType {
    name: "t2.micro",
    a: 1.36,
    u: 4.976,
};

/// Paper fit for Amazon EC2 c5.large: a = 0.97 ms, u = 19.29 ms⁻¹.
pub const C5_LARGE: InstanceType = InstanceType {
    name: "c5.large",
    a: 0.97,
    u: 19.29,
};

/// t2.micro burst-throttling mixture `(prob, slowdown)`: t2 instances are
/// burstable — once CPU credits are exhausted, baseline performance is a
/// small fraction of burst. Real measured traces therefore carry a heavy
/// straggler tail that the fitted shifted exponential misses; this
/// mixture restores it for the Fig. 8 simulation (c5 is fixed-performance
/// and gets none). See DESIGN.md §Substitutions.
pub const T2_MICRO_THROTTLE: (f64, f64) = (0.02, 20.0);

impl InstanceType {
    /// The per-row delay distribution (eq. 2 with l = k = 1).
    pub fn per_row(&self) -> ShiftedExp {
        ShiftedExp::new(self.a, self.u)
    }

    /// Sample `n` per-row computation delays — the stand-in for the
    /// paper's EC2 measurement campaign.
    pub fn sample_trace(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        let d = self.per_row();
        (0..n).map(|_| d.sample(rng)).collect()
    }

    /// Mean per-row delay `a + 1/u`.
    pub fn mean(&self) -> f64 {
        self.a + 1.0 / self.u
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_parameters() {
        assert_eq!(T2_MICRO.a, 1.36);
        assert_eq!(T2_MICRO.u, 4.976);
        assert_eq!(C5_LARGE.a, 0.97);
        assert_eq!(C5_LARGE.u, 19.29);
        // c5.large is strictly faster in both shift and rate.
        assert!(C5_LARGE.mean() < T2_MICRO.mean());
    }

    #[test]
    fn trace_respects_shift_and_mean() {
        let mut rng = Rng::new(11);
        let trace = T2_MICRO.sample_trace(100_000, &mut rng);
        let min = trace.iter().cloned().fold(f64::INFINITY, f64::min);
        let mean = trace.iter().sum::<f64>() / trace.len() as f64;
        assert!(min >= T2_MICRO.a);
        assert!((mean - T2_MICRO.mean()).abs() / T2_MICRO.mean() < 0.01);
    }
}
