//! Delay-trace substrate replacing the paper's Amazon EC2 measurements
//! (§V-C, Figs. 7–8). See DESIGN.md §Substitutions.

pub mod ec2;
pub mod fit;

pub use ec2::{InstanceType, C5_LARGE, T2_MICRO};
pub use fit::{fit_shifted_exp, FittedShiftedExp};
