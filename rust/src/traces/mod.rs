//! Delay-trace substrate replacing the paper's Amazon EC2 measurements
//! (§V-C, Figs. 7–8). See DESIGN.md §Substitutions.

pub mod ec2;
pub mod fit;

use crate::model::dist::TraceDist;

pub use ec2::{InstanceType, C5_LARGE, T2_MICRO};
pub use fit::{fit_shifted_exp, FitError, FittedShiftedExp};

/// Package a measured per-row delay trace for the delay-model layer:
/// the raw empirical distribution (register with
/// [`crate::config::Scenario::add_trace`] and select with
/// [`crate::model::dist::FamilyKind::Trace`] to sample it verbatim via
/// ECDF inverse transform) plus its shifted-exponential fit (the
/// `(a, u)` surrogate the closed-form allocators keep planning with).
/// One call turns a measurement campaign into everything a scenario
/// needs.
pub fn package_trace(
    name: &str,
    samples: Vec<f64>,
) -> anyhow::Result<(TraceDist, FittedShiftedExp)> {
    let fitted = fit_shifted_exp(&samples)?;
    let dist = TraceDist::from_samples(name, samples)?;
    Ok((dist, fitted))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn package_trace_yields_sampler_and_surrogate() {
        let mut rng = Rng::new(21);
        let samples = T2_MICRO.sample_trace(50_000, &mut rng);
        let (dist, fitted) = package_trace("t2", samples).unwrap();
        // The empirical mean and the fit's mean agree (the shifted-exp
        // MLE preserves the sample mean exactly: a + 1/u = mean).
        let fit_mean = fitted.a + 1.0 / fitted.u;
        assert!((dist.mean() - fit_mean).abs() / fit_mean < 1e-9);
        // Degenerate traces error through the typed path.
        assert!(package_trace("bad", vec![1.0, 1.0]).is_err());
    }
}
