//! Per-worker circuit breaker: closed → open (exponential backoff) →
//! half-open probe → closed again.
//!
//! The coordinator asks [`CircuitBreaker::allow`] before dispatching
//! (or re-queueing) anything to a worker. A healthy worker's breaker is
//! `Closed` and always allows. Each detected failure
//! ([`CircuitBreaker::on_failure`]) trips it `Open` for
//! `base_ms · 2^(failures-1)` (capped at `cap_ms`); while `Open`,
//! nothing is dispatched. Once the backoff elapses the next `allow`
//! admits exactly ONE probe (`HalfOpen`): the probe's outcome either
//! closes the breaker ([`CircuitBreaker::on_success`], resetting the
//! failure count) or re-opens it with doubled backoff. All clocks are
//! caller-supplied `now_ms` so the machine is deterministic under test
//! and usable in both wall time (dispatch) and virtual time (serve
//! synthesis).

/// Breaker state, exposed for event logging.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

/// One worker's breaker.
#[derive(Clone, Debug)]
pub struct CircuitBreaker {
    state: BreakerState,
    /// Consecutive failures since the last success (drives backoff).
    failures: u32,
    /// While `Open`: when the next probe may go out.
    open_until_ms: f64,
    /// While `HalfOpen`: has the single probe been admitted?
    probe_out: bool,
    base_ms: f64,
    cap_ms: f64,
}

impl CircuitBreaker {
    pub fn new(base_ms: f64, cap_ms: f64) -> Self {
        Self {
            state: BreakerState::Closed,
            failures: 0,
            open_until_ms: 0.0,
            probe_out: false,
            base_ms: base_ms.max(1e-9),
            cap_ms: cap_ms.max(base_ms.max(1e-9)),
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// The backoff the NEXT failure would impose (monotone in the
    /// failure count, capped).
    pub fn backoff_ms(&self) -> f64 {
        let exp = self.failures.saturating_sub(1).min(52);
        (self.base_ms * (1u64 << exp) as f64).min(self.cap_ms)
    }

    /// May work be dispatched to this worker at `now_ms`? `Open`
    /// transitions to `HalfOpen` once the backoff has elapsed, and
    /// `HalfOpen` admits exactly one probe until resolved.
    pub fn allow(&mut self, now_ms: f64) -> bool {
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                if now_ms < self.open_until_ms {
                    false
                } else {
                    self.state = BreakerState::HalfOpen;
                    self.probe_out = true;
                    true
                }
            }
            BreakerState::HalfOpen => {
                if self.probe_out {
                    false
                } else {
                    self.probe_out = true;
                    true
                }
            }
        }
    }

    /// Record a detected failure (missed beats, stall, disconnect, or a
    /// failed probe): trip `Open` with exponentially grown backoff.
    pub fn on_failure(&mut self, now_ms: f64) {
        self.failures = self.failures.saturating_add(1);
        self.state = BreakerState::Open;
        self.probe_out = false;
        self.open_until_ms = now_ms + self.backoff_ms();
    }

    /// Record a successful probe (or healthy traffic): close and reset.
    pub fn on_success(&mut self) {
        self.state = BreakerState::Closed;
        self.failures = 0;
        self.probe_out = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn closed_allows_open_blocks_halfopen_probes() {
        let mut b = CircuitBreaker::new(100.0, 1000.0);
        assert!(b.allow(0.0));
        b.on_failure(0.0);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow(50.0));
        // Backoff elapsed: exactly one probe.
        assert!(b.allow(100.0));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.allow(100.0));
        b.on_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow(101.0));
    }

    /// Drive a breaker with a random event sequence and check the three
    /// contract properties from the issue: never dispatch while open,
    /// half-open admits exactly one probe per open→half-open episode,
    /// and backoff is monotone nondecreasing (and capped) without an
    /// intervening success.
    #[test]
    fn prop_breaker_contract() {
        check(Config::default().cases(200), "breaker_contract", |g| {
            let base = g.f64_range(1.0, 50.0);
            let cap = base * g.f64_range(1.0, 64.0);
            let mut b = CircuitBreaker::new(base, cap);
            let mut now = 0.0f64;
            let mut probes_this_episode = 0usize;
            let mut last_backoff = 0.0f64;
            let mut since_success = 0u32;
            for _ in 0..g.usize_range(1, 60) {
                now += g.f64_range(0.0, 3.0 * cap);
                match g.usize_range(0, 2) {
                    0 => {
                        let state_before = b.state();
                        let allowed = b.allow(now);
                        match (state_before, b.state()) {
                            (BreakerState::Open, BreakerState::Open) => {
                                assert!(!allowed, "dispatched to an open breaker");
                            }
                            (_, BreakerState::HalfOpen) => {
                                if allowed {
                                    probes_this_episode += 1;
                                }
                                assert!(
                                    probes_this_episode <= 1,
                                    "half-open admitted {probes_this_episode} probes"
                                );
                            }
                            _ => {}
                        }
                    }
                    1 => {
                        b.on_failure(now);
                        probes_this_episode = 0;
                        since_success += 1;
                        let bo = b.backoff_ms();
                        if since_success > 1 {
                            assert!(
                                bo >= last_backoff - 1e-9,
                                "backoff shrank without a success: {last_backoff} -> {bo}"
                            );
                        }
                        assert!(bo <= cap + 1e-9, "backoff {bo} exceeds cap {cap}");
                        last_backoff = bo;
                    }
                    _ => {
                        b.on_success();
                        probes_this_episode = 0;
                        since_success = 0;
                        last_backoff = 0.0;
                    }
                }
            }
        });
    }

    /// Backoff sequence under repeated failures: doubles from base,
    /// saturates at the cap, resets after a success.
    #[test]
    fn backoff_doubles_and_caps() {
        let mut b = CircuitBreaker::new(100.0, 700.0);
        let mut seen = Vec::new();
        for _ in 0..5 {
            b.on_failure(0.0);
            seen.push(b.backoff_ms());
        }
        assert_eq!(seen, vec![100.0, 200.0, 400.0, 700.0, 700.0]);
        b.on_success();
        b.on_failure(0.0);
        assert_eq!(b.backoff_ms(), 100.0);
    }
}
