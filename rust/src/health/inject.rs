//! Fault injection: the [`FaultPlan`] DSL and its per-worker resolution.
//!
//! A fault plan is a comma-separated list of specs in the CLI syntax
//!
//! ```text
//! <kind>:<target>@<param>[x<factor>]
//!
//! crash:w3@50%          sever the connection after 50% of the queue
//! gray:w2@0%            beats stay alive, compute goes dead at 0%
//! spike:w1@25%x40       +40 ms wall latency per sub-task from 25% on
//! slow:w4@40%x30        slow-start: +30 ms per sub-task UNTIL 40% done
//! flaky:all@7           every 7th sub-task compute fails (Backend::Flaky)
//! ```
//!
//! `wN` is the 1-based worker queue (matching the planner's worker node
//! ids; local master queues sit past the workers and are addressable
//! too); `all` targets every queue. Percent params are fractions of the
//! worker's own queue in execution (deadline) order, so `@50%` means
//! "after half of its sub-tasks ran" regardless of queue length.
//!
//! The plan travels as a string: the coordinator passes `--fault <plan>`
//! to auto-spawned worker processes ([`std::fmt::Display`] round-trips
//! the parse), and each worker resolves its own slice with
//! [`FaultPlan::for_worker`] once the Hello handshake tells it its wid.
//! Injection is symmetric across transports — the thread dispatcher
//! resolves the same [`WorkerFaults`] for its in-process workers.

use std::fmt;

/// What goes wrong.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Dead silence: stop executing and sever the connection (TCP) or
    /// return early (thread transport) — a process crash as seen from
    /// the coordinator.
    Crash,
    /// Gray failure: heartbeats keep flowing but compute never finishes
    /// another sub-task. The worker parks until its tasks are cancelled
    /// (the coordinator's recovery path shuts it down on detection).
    Gray,
    /// Latency spike: every sub-task from the trigger point on is
    /// published `extra_ms` wall milliseconds late.
    Spike { extra_ms: f64 },
    /// Slow-start rejoin: sub-tasks BEFORE the trigger point are
    /// `extra_ms` late, then the worker runs at full speed.
    SlowStart { extra_ms: f64 },
    /// The legacy `--flaky N` backend: a deterministic ~1/N of sub-task
    /// computes fail (stragglers the MDS redundancy must absorb).
    Flaky { every: usize },
    /// Connection drop: sever the socket at the trigger point but KEEP
    /// computing — the resumable counterpart of [`FaultKind::Crash`].
    /// On a resumable session the worker parks its unsent results for a
    /// later `Resume` replay; thread transport treats it like a crash
    /// (there is no connection to drop).
    Drop,
}

/// One injected fault: a kind, a target queue and a trigger point.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    /// 0-based worker queue index; `None` = every queue.
    pub worker: Option<usize>,
    pub kind: FaultKind,
    /// Trigger point as a fraction of the target's queue (execution
    /// order); 0 for [`FaultKind::Flaky`] (it has no trigger).
    pub at_frac: f64,
}

/// A set of injected faults, resolvable per worker.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
}

/// Everything one worker needs to misbehave: the plan's specs resolved
/// against its wid and queue length. Indices are positions in the
/// worker's deadline-sorted execution order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerFaults {
    /// Crash before executing this sub-task index.
    pub crash_at: Option<usize>,
    /// Stop computing from this sub-task index on (beats stay alive).
    pub gray_from: Option<usize>,
    /// `(from index, extra wall ms)` — latency spike.
    pub spike: Option<(usize, f64)>,
    /// `(until index, extra wall ms)` — slow-start.
    pub slow: Option<(usize, f64)>,
    /// Swap the compute backend for `Backend::Flaky { every }`.
    pub flaky_every: Option<usize>,
    /// Sever the connection before this sub-task index, keep computing.
    pub drop_at: Option<usize>,
}

impl WorkerFaults {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_none(&self) -> bool {
        *self == Self::default()
    }
}

impl FaultPlan {
    /// The legacy `--flaky N` flag as a fault plan (every queue,
    /// [`FaultKind::Flaky`]). `every == 1` would fail EVERY sub-task:
    /// row absorption needs redundancy headroom — the code only carries
    /// ~β× the required rows, so at least every other compute must
    /// survive for any master to decode.
    pub fn flaky(every: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(
            every >= 2,
            "flaky fault needs a period ≥ 2: failing every sub-task (period 1) \
             leaves no rows for the MDS code to decode from — row absorption \
             needs redundancy headroom"
        );
        Ok(Self {
            specs: vec![FaultSpec {
                worker: None,
                kind: FaultKind::Flaky { every },
                at_frac: 0.0,
            }],
        })
    }

    /// Parse the CLI syntax (`crash:w3@50%,gray:w1@0%`, see module docs).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let mut specs = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            specs.push(parse_spec(part)?);
        }
        anyhow::ensure!(!specs.is_empty(), "empty fault plan '{s}'");
        Ok(Self { specs })
    }

    /// Deterministic plan for a sweep cell: a `rate` fraction of the
    /// `n_workers` fleet gets faulted, cycling through the kinds so a
    /// rate axis exercises crash, gray and latency faults together.
    pub fn synthesize(n_workers: usize, rate: f64, seed: u64) -> Option<Self> {
        let rate = rate.clamp(0.0, 1.0);
        let k = ((rate * n_workers as f64).ceil() as usize).min(n_workers);
        if k == 0 {
            return None;
        }
        // Seed-rotated victim choice: which workers fail varies with the
        // cell seed, the count only with the rate.
        let start = (seed % n_workers as u64) as usize;
        let specs = (0..k)
            .map(|i| {
                let wid = (start + i * (n_workers / k).max(1)) % n_workers;
                let kind = match i % 4 {
                    0 => FaultKind::Crash,
                    1 => FaultKind::Gray,
                    2 => FaultKind::SlowStart { extra_ms: 25.0 },
                    _ => FaultKind::Spike { extra_ms: 25.0 },
                };
                FaultSpec {
                    worker: Some(wid),
                    kind,
                    at_frac: 0.25 + 0.5 * (i % 3) as f64 / 2.0,
                }
            })
            .collect();
        Some(Self { specs })
    }

    /// Resolve this plan for one worker: wid-matched specs with their
    /// trigger fractions mapped onto a queue of `n_tasks` sub-tasks.
    /// Later specs of the same kind win (CLI "last flag wins" spirit).
    pub fn for_worker(&self, wid: usize, n_tasks: usize) -> WorkerFaults {
        let mut f = WorkerFaults::none();
        let idx = |frac: f64| ((frac * n_tasks as f64).round() as usize).min(n_tasks);
        for s in &self.specs {
            if s.worker.is_some_and(|w| w != wid) {
                continue;
            }
            match s.kind {
                FaultKind::Crash => f.crash_at = Some(idx(s.at_frac)),
                FaultKind::Gray => f.gray_from = Some(idx(s.at_frac)),
                FaultKind::Spike { extra_ms } => f.spike = Some((idx(s.at_frac), extra_ms)),
                FaultKind::SlowStart { extra_ms } => f.slow = Some((idx(s.at_frac), extra_ms)),
                FaultKind::Flaky { every } => f.flaky_every = Some(every),
                FaultKind::Drop => f.drop_at = Some(idx(s.at_frac)),
            }
        }
        f
    }

    /// Does any spec target `wid` (or all workers)?
    pub fn targets(&self, wid: usize) -> bool {
        self.specs.iter().any(|s| s.worker.map_or(true, |w| w == wid))
    }
}

fn parse_spec(part: &str) -> anyhow::Result<FaultSpec> {
    let (kind_s, rest) = part
        .split_once(':')
        .ok_or_else(|| anyhow::anyhow!("fault spec '{part}': expected <kind>:<target>@<param>"))?;
    let (target_s, param_s) = rest
        .split_once('@')
        .ok_or_else(|| anyhow::anyhow!("fault spec '{part}': expected <target>@<param>"))?;
    let worker = match target_s {
        "all" => None,
        w => {
            let n: usize = w
                .strip_prefix('w')
                .and_then(|d| d.parse().ok())
                .ok_or_else(|| {
                    anyhow::anyhow!("fault target '{w}': expected wN (1-based) or 'all'")
                })?;
            anyhow::ensure!(n >= 1, "fault target 'w0': worker queues are 1-based");
            Some(n - 1)
        }
    };
    // `@P%` (queue fraction) with an optional `xF` factor, or a bare
    // integer (the flaky period).
    let (param_s, factor) = match param_s.split_once('x') {
        Some((p, f)) => (
            p,
            Some(f.parse::<f64>().map_err(|_| {
                anyhow::anyhow!("fault spec '{part}': factor '{f}' is not a number")
            })?),
        ),
        None => (param_s, None),
    };
    if let Some(f) = factor {
        anyhow::ensure!(
            f.is_finite() && f >= 0.0,
            "fault spec '{part}': factor must be finite and ≥ 0"
        );
    }
    let frac = |p: &str| -> anyhow::Result<f64> {
        let pct: f64 = p
            .strip_suffix('%')
            .and_then(|d| d.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("fault spec '{part}': expected a percent like 50%"))?;
        anyhow::ensure!(
            (0.0..=100.0).contains(&pct),
            "fault spec '{part}': percent {pct} outside [0, 100]"
        );
        Ok(pct / 100.0)
    };
    let default_extra = 25.0;
    let (kind, at_frac) = match kind_s {
        "crash" => (FaultKind::Crash, frac(param_s)?),
        "gray" => (FaultKind::Gray, frac(param_s)?),
        "spike" => (
            FaultKind::Spike {
                extra_ms: factor.unwrap_or(default_extra),
            },
            frac(param_s)?,
        ),
        "slow" => (
            FaultKind::SlowStart {
                extra_ms: factor.unwrap_or(default_extra),
            },
            frac(param_s)?,
        ),
        "flaky" => {
            let every: usize = param_s.parse().map_err(|_| {
                anyhow::anyhow!("fault spec '{part}': flaky period must be an integer")
            })?;
            // Shares FaultPlan::flaky's rationale (redundancy headroom).
            let _ = FaultPlan::flaky(every)?;
            (FaultKind::Flaky { every }, 0.0)
        }
        "drop" => (FaultKind::Drop, frac(param_s)?),
        other => anyhow::bail!(
            "unknown fault kind '{other}' (known: crash, gray, spike, slow, flaky, drop)"
        ),
    };
    Ok(FaultSpec {
        worker,
        kind,
        at_frac,
    })
}

impl fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let target = match self.worker {
            None => "all".to_string(),
            Some(w) => format!("w{}", w + 1),
        };
        let pct = (self.at_frac * 100.0).round() as u64;
        match self.kind {
            FaultKind::Crash => write!(f, "crash:{target}@{pct}%"),
            FaultKind::Gray => write!(f, "gray:{target}@{pct}%"),
            FaultKind::Spike { extra_ms } => write!(f, "spike:{target}@{pct}%x{extra_ms}"),
            FaultKind::SlowStart { extra_ms } => write!(f, "slow:{target}@{pct}%x{extra_ms}"),
            FaultKind::Flaky { every } => write!(f, "flaky:{target}@{every}"),
            FaultKind::Drop => write!(f, "drop:{target}@{pct}%"),
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, s) in self.specs.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{s}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_round_trip() {
        for s in [
            "crash:w3@50%",
            "gray:w2@0%",
            "spike:w1@25%x40",
            "slow:w4@40%x30",
            "flaky:all@7",
            "drop:w2@50%",
            "crash:w1@50%,gray:w2@0%,flaky:all@5,drop:w3@25%",
        ] {
            let p = FaultPlan::parse(s).unwrap();
            let rendered = p.to_string();
            let back = FaultPlan::parse(&rendered).unwrap();
            assert_eq!(p, back, "{s} -> {rendered}");
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "boom:w1@50%",
            "crash:w0@50%",
            "crash:x1@50%",
            "crash:w1@150%",
            "crash:w1",
            "spike:w1@10%xnope",
            "flaky:all@1",
            "flaky:all@7%",
            "drop:w1@7",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn flaky_needs_redundancy_headroom() {
        let err = FaultPlan::flaky(1).unwrap_err().to_string();
        assert!(
            err.contains("redundancy headroom"),
            "message must explain WHY ≥ 2: {err}"
        );
        assert!(FaultPlan::flaky(2).is_ok());
    }

    #[test]
    fn for_worker_resolves_fractions_and_targets() {
        let p = FaultPlan::parse("crash:w3@50%,spike:all@25%x40").unwrap();
        let w2 = p.for_worker(2, 4); // w3 == wid 2
        assert_eq!(w2.crash_at, Some(2));
        assert_eq!(w2.spike, Some((1, 40.0)));
        let w0 = p.for_worker(0, 4);
        assert_eq!(w0.crash_at, None);
        assert_eq!(w0.spike, Some((1, 40.0)));
        assert!(p.targets(0) && p.targets(2));

        let d = FaultPlan::parse("drop:w1@50%").unwrap().for_worker(0, 4);
        assert_eq!(d.drop_at, Some(2));
        assert!(FaultPlan::parse("drop:w1@50%")
            .unwrap()
            .for_worker(1, 4)
            .drop_at
            .is_none());

        let f = FaultPlan::flaky(7).unwrap().for_worker(5, 10);
        assert_eq!(f.flaky_every, Some(7));
        assert!(!f.is_none());
        assert!(WorkerFaults::none().is_none());
    }

    #[test]
    fn synthesize_scales_with_rate() {
        assert!(FaultPlan::synthesize(8, 0.0, 1).is_none());
        let half = FaultPlan::synthesize(8, 0.5, 1).unwrap();
        assert_eq!(half.specs.len(), 4);
        let all = FaultPlan::synthesize(8, 1.0, 9).unwrap();
        assert_eq!(all.specs.len(), 8);
        // Distinct victims.
        let mut wids: Vec<_> = all.specs.iter().map(|s| s.worker.unwrap()).collect();
        wids.sort_unstable();
        wids.dedup();
        assert_eq!(wids.len(), 8);
        // Rate > 1 clamps.
        assert_eq!(FaultPlan::synthesize(4, 7.0, 0).unwrap().specs.len(), 4);
    }
}
