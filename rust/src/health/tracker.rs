//! Coordinator-side health scoring from heartbeat + result streams.
//!
//! The tracker keeps, per worker session: when the last beat arrived,
//! when progress (rows done) last advanced, the queue depth the worker
//! reported, and an EWMA of its self-reported per-task latency. From
//! those it renders a [`Verdict`]:
//!
//! - **MissedBeats** — no beat for `miss_beats · beat_ms` (a crash:
//!   silence on the wire). The reader thread usually sees the EOF
//!   first, but missed beats catch the half-open-socket case where the
//!   OS never delivers one.
//! - **Stalled** — gray failure: beats keep arriving but the worker's
//!   earliest pending sub-task is `stall_ms` past the wall-clock
//!   deadline it should have published by, AND rows-done hasn't moved
//!   since. The deadline guard is what separates a gray worker from a
//!   healthy one legitimately sleeping toward a far-future virtual
//!   deadline.
//! - **LatencySpike** — the worker's reported last-task latency exceeds
//!   `spike_factor ×` its own EWMA for `spike_beats` consecutive beats.
//!   A degraded-but-alive worker; callers may throttle or exclude it.
//!
//! Detection thresholds trade detection time against false positives —
//! they affect *performance*, never correctness: a false positive just
//! re-queues rows that redundancy would have covered anyway.

/// Tunables for the whole health layer (tracker + breaker + beats).
#[derive(Clone, Debug)]
pub struct HealthConfig {
    /// Heartbeat cadence the coordinator asks workers for (wall ms);
    /// ≤ 0 disables recurring beats.
    pub beat_ms: f64,
    /// Verdict `MissedBeats` after this many silent beat intervals.
    pub miss_beats: u32,
    /// Verdict `Stalled` when a pending deadline is this many wall ms
    /// overdue with no progress.
    pub stall_ms: f64,
    /// EWMA smoothing for reported latency, in `(0, 1]`.
    pub ewma_alpha: f64,
    /// Latency spike threshold: last ≥ factor × EWMA …
    pub spike_factor: f64,
    /// … for this many consecutive beats.
    pub spike_beats: u32,
    /// Breaker backoff base / cap (wall ms).
    pub breaker_backoff_ms: f64,
    pub breaker_backoff_cap_ms: f64,
    /// Reconnect retries after a transport-level failure (connect or
    /// session resume); `0` disables retrying. See `net::reconnect`.
    pub reconnect_attempts: u32,
    /// First reconnect delay (wall ms); subsequent retries double up to
    /// `breaker_backoff_cap_ms`.
    pub reconnect_base_ms: f64,
    /// Arm health bookkeeping even with no fault plan (detection on
    /// real fleets). Defaults off so a fault-free run stays on the
    /// exact PR-6 code path (the no-op parity criterion).
    pub armed: bool,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self {
            beat_ms: 25.0,
            miss_beats: 4,
            stall_ms: 200.0,
            ewma_alpha: 0.3,
            spike_factor: 4.0,
            spike_beats: 3,
            breaker_backoff_ms: 250.0,
            breaker_backoff_cap_ms: 4000.0,
            reconnect_attempts: 5,
            reconnect_base_ms: 100.0,
            armed: false,
        }
    }
}

impl HealthConfig {
    /// Tightened thresholds for loopback tests (fast detection, wall
    /// clocks in the tens of milliseconds). The reconnect schedule
    /// (20, 40, 80, 160, 320, 640 ms ≈ a 1.26 s window) comfortably
    /// spans a worker-process restart in CI.
    pub fn fast() -> Self {
        Self {
            beat_ms: 10.0,
            miss_beats: 3,
            stall_ms: 60.0,
            reconnect_attempts: 6,
            reconnect_base_ms: 20.0,
            ..Self::default()
        }
    }

    /// Is the health layer active for this run?
    pub fn active(&self, fault_present: bool) -> bool {
        self.armed || fault_present
    }
}

/// The tracker's judgement of one worker at a point in time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Verdict {
    Healthy,
    /// `n` beat intervals of silence.
    MissedBeats(u32),
    /// Progress-free and `behind_ms` past an expected publish deadline.
    Stalled { behind_ms: f64 },
    /// Reported latency is `ratio ×` the worker's EWMA.
    LatencySpike { ratio: f64 },
}

impl Verdict {
    pub fn is_sick(&self) -> bool {
        !matches!(self, Verdict::Healthy)
    }
}

#[derive(Clone, Debug)]
struct WorkerState {
    last_beat_ms: f64,
    last_progress_ms: f64,
    rows_done: u64,
    queue_depth: u32,
    ewma_latency_ms: f64,
    spike_streak: u32,
    last_ratio: f64,
}

/// Health state for a fleet of worker sessions, indexed by session id.
#[derive(Clone, Debug, Default)]
pub struct HealthTracker {
    cfg_beat_ms: f64,
    cfg: TrackerKnobs,
    states: Vec<Option<WorkerState>>,
}

#[derive(Clone, Debug, Default)]
struct TrackerKnobs {
    miss_beats: u32,
    stall_ms: f64,
    ewma_alpha: f64,
    spike_factor: f64,
    spike_beats: u32,
}

impl HealthTracker {
    pub fn new(cfg: &HealthConfig) -> Self {
        Self {
            cfg_beat_ms: cfg.beat_ms.max(1e-9),
            cfg: TrackerKnobs {
                miss_beats: cfg.miss_beats.max(1),
                stall_ms: cfg.stall_ms.max(0.0),
                ewma_alpha: cfg.ewma_alpha.clamp(1e-6, 1.0),
                spike_factor: cfg.spike_factor.max(1.0),
                spike_beats: cfg.spike_beats.max(1),
            },
            states: Vec::new(),
        }
    }

    fn state_mut(&mut self, sid: usize, now_ms: f64) -> &mut WorkerState {
        if self.states.len() <= sid {
            self.states.resize(sid + 1, None);
        }
        self.states[sid].get_or_insert_with(|| WorkerState {
            last_beat_ms: now_ms,
            last_progress_ms: now_ms,
            rows_done: 0,
            queue_depth: 0,
            ewma_latency_ms: 0.0,
            spike_streak: 0,
            last_ratio: 1.0,
        })
    }

    /// Register a session so silence counts from `now_ms` even before
    /// its first beat.
    pub fn on_connect(&mut self, sid: usize, now_ms: f64) {
        self.state_mut(sid, now_ms);
    }

    /// Consume one heartbeat.
    pub fn on_beat(
        &mut self,
        sid: usize,
        now_ms: f64,
        rows_done: u64,
        queue_depth: u32,
        last_latency_ms: f64,
    ) {
        let alpha = self.cfg.ewma_alpha;
        let factor = self.cfg.spike_factor;
        let s = self.state_mut(sid, now_ms);
        s.last_beat_ms = now_ms;
        if rows_done > s.rows_done {
            s.rows_done = rows_done;
            s.last_progress_ms = now_ms;
        }
        s.queue_depth = queue_depth;
        if last_latency_ms > 0.0 && last_latency_ms.is_finite() {
            if s.ewma_latency_ms <= 0.0 {
                s.ewma_latency_ms = last_latency_ms;
                s.last_ratio = 1.0;
                s.spike_streak = 0;
            } else {
                let ratio = last_latency_ms / s.ewma_latency_ms;
                s.last_ratio = ratio;
                if ratio >= factor {
                    s.spike_streak += 1;
                } else {
                    s.spike_streak = 0;
                }
                s.ewma_latency_ms =
                    alpha * last_latency_ms + (1.0 - alpha) * s.ewma_latency_ms;
            }
        }
    }

    /// A result arrived on the data path — that is progress too (beats
    /// may lag the results bus).
    pub fn on_result(&mut self, sid: usize, now_ms: f64, rows: u64) {
        let s = self.state_mut(sid, now_ms);
        s.rows_done += rows;
        s.last_progress_ms = now_ms;
        s.last_beat_ms = s.last_beat_ms.max(now_ms); // data flow proves liveness
    }

    /// The session drained (cleanly or not): stop tracking it.
    pub fn on_drain(&mut self, sid: usize) {
        if let Some(slot) = self.states.get_mut(sid) {
            *slot = None;
        }
    }

    pub fn rows_done(&self, sid: usize) -> u64 {
        self.states
            .get(sid)
            .and_then(|s| s.as_ref())
            .map_or(0, |s| s.rows_done)
    }

    pub fn queue_depth(&self, sid: usize) -> u32 {
        self.states
            .get(sid)
            .and_then(|s| s.as_ref())
            .map_or(0, |s| s.queue_depth)
    }

    pub fn ewma_latency_ms(&self, sid: usize) -> f64 {
        self.states
            .get(sid)
            .and_then(|s| s.as_ref())
            .map_or(0.0, |s| s.ewma_latency_ms)
    }

    /// Judge session `sid` at `now_ms`. `earliest_deadline_ms` is the
    /// wall-clock time by which the worker's earliest still-pending
    /// sub-task should have published (None when nothing is pending —
    /// an idle worker cannot stall).
    pub fn verdict(
        &self,
        sid: usize,
        now_ms: f64,
        earliest_deadline_ms: Option<f64>,
    ) -> Verdict {
        let Some(s) = self.states.get(sid).and_then(|s| s.as_ref()) else {
            return Verdict::Healthy; // drained or never connected
        };
        let silent = now_ms - s.last_beat_ms;
        let miss_after = self.cfg.miss_beats as f64 * self.cfg_beat_ms;
        if silent >= miss_after {
            return Verdict::MissedBeats((silent / self.cfg_beat_ms) as u32);
        }
        if let Some(deadline) = earliest_deadline_ms {
            let behind = now_ms - deadline;
            if behind >= self.cfg.stall_ms && s.last_progress_ms <= deadline {
                return Verdict::Stalled { behind_ms: behind };
            }
        }
        if s.spike_streak >= self.cfg.spike_beats {
            return Verdict::LatencySpike { ratio: s.last_ratio };
        }
        Verdict::Healthy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HealthConfig {
        HealthConfig {
            beat_ms: 10.0,
            miss_beats: 3,
            stall_ms: 50.0,
            ..HealthConfig::default()
        }
    }

    #[test]
    fn silence_becomes_missed_beats() {
        let mut t = HealthTracker::new(&cfg());
        t.on_connect(0, 0.0);
        assert_eq!(t.verdict(0, 20.0, None), Verdict::Healthy);
        match t.verdict(0, 35.0, None) {
            Verdict::MissedBeats(n) => assert!(n >= 3, "n={n}"),
            v => panic!("expected MissedBeats, got {v:?}"),
        }
        // A beat resets the clock.
        t.on_beat(0, 36.0, 1, 4, 2.0);
        assert_eq!(t.verdict(0, 50.0, None), Verdict::Healthy);
    }

    #[test]
    fn gray_failure_is_stall_not_silence() {
        let mut t = HealthTracker::new(&cfg());
        t.on_connect(0, 0.0);
        // Beats keep flowing but rows_done never moves past 2 and the
        // earliest pending deadline (t=40) sails by.
        for i in 1..=12 {
            t.on_beat(0, i as f64 * 10.0, 2, 5, 1.0);
        }
        // Deadline 40, now 120: 80 ms overdue ≥ stall_ms, progress at 10.
        match t.verdict(0, 120.0, Some(40.0)) {
            Verdict::Stalled { behind_ms } => assert!((behind_ms - 80.0).abs() < 1e-9),
            v => panic!("expected Stalled, got {v:?}"),
        }
        // Same silence pattern but the deadline is far in the future:
        // healthy (a worker sleeping toward a virtual deadline).
        assert_eq!(t.verdict(0, 120.0, Some(500.0)), Verdict::Healthy);
        // No pending work at all: healthy.
        assert_eq!(t.verdict(0, 120.0, None), Verdict::Healthy);
    }

    #[test]
    fn progress_defuses_stall() {
        let mut t = HealthTracker::new(&cfg());
        t.on_connect(0, 0.0);
        t.on_beat(0, 10.0, 1, 5, 1.0);
        // Progress after the deadline passed: the worker is slow, not gray.
        t.on_result(0, 95.0, 8);
        assert_eq!(t.verdict(0, 100.0, Some(40.0)), Verdict::Healthy);
        assert_eq!(t.rows_done(0), 1 + 8);
    }

    #[test]
    fn latency_spikes_need_a_streak() {
        let mut t = HealthTracker::new(&cfg());
        t.on_connect(0, 0.0);
        t.on_beat(0, 10.0, 1, 5, 2.0); // seeds EWMA
        t.on_beat(0, 20.0, 2, 5, 2.0);
        t.on_beat(0, 30.0, 3, 5, 40.0); // spike 1: 40/2.0; EWMA -> 13.4
        assert_eq!(t.verdict(0, 31.0, None), Verdict::Healthy);
        t.on_beat(0, 40.0, 4, 5, 60.0); // spike 2: 60/13.4; EWMA -> 27.38
        t.on_beat(0, 50.0, 5, 5, 150.0); // spike 3: 150/27.38
        match t.verdict(0, 51.0, None) {
            Verdict::LatencySpike { ratio } => assert!(ratio >= 4.0, "ratio={ratio}"),
            v => panic!("expected LatencySpike, got {v:?}"),
        }
        // A normal-latency beat breaks the streak.
        t.on_beat(0, 60.0, 6, 5, t.ewma_latency_ms(0) * 0.9);
        assert_eq!(t.verdict(0, 61.0, None), Verdict::Healthy);
        assert_eq!(t.queue_depth(0), 5);
    }

    #[test]
    fn drained_sessions_are_healthy() {
        let mut t = HealthTracker::new(&cfg());
        t.on_connect(0, 0.0);
        t.on_drain(0);
        assert_eq!(t.verdict(0, 1e9, Some(0.0)), Verdict::Healthy);
        assert_eq!(t.rows_done(0), 0);
        // Unknown sid is healthy, not a panic.
        assert_eq!(t.verdict(7, 1e9, None), Verdict::Healthy);
    }

    #[test]
    fn active_gates_on_fault_or_armed() {
        let mut c = HealthConfig::default();
        assert!(!c.active(false));
        assert!(c.active(true));
        c.armed = true;
        assert!(c.active(false));
    }
}
