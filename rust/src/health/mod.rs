//! Observed worker health: heartbeat tracking, fault injection, and
//! circuit-breaker recovery.
//!
//! The paper allocates coded redundancy so that *random* straggling is
//! absorbed by the code itself; this module is the layer after coding —
//! detecting that a worker has actually failed and reacting (exclude,
//! re-queue) instead of merely hoping the redundancy covers the loss.
//! It has three parts plus a serve bridge:
//!
//! - [`inject`] — [`FaultPlan`]: a small DSL describing what to break
//!   (`crash:w3@50%,gray:w2@0%`), resolvable per worker and usable by
//!   both transports. Generalizes the old `--flaky` path.
//! - [`tracker`] — [`HealthTracker`]: consumes recurring `Heartbeat`
//!   frames (rows done, queue depth, last-task latency) and renders
//!   per-worker [`Verdict`]s: missed beats (crash), deadline stalls
//!   (gray failure), latency-spike streaks (degradation).
//! - [`breaker`] — [`CircuitBreaker`]: closed → open (exponential
//!   backoff) → half-open probe; sick workers are excluded from
//!   dispatch and re-queue targeting until a probe succeeds.
//! - [`churn_from_faults`] — compiles a fault plan into the
//!   [`ChurnScript`] vocabulary by simulating detection and breaker
//!   recovery in virtual time, so `serve`'s replanning is driven by the
//!   detector's timeline instead of a hand-written script.
//!
//! Every detection/recovery action is logged as a [`HealthEvent`];
//! coordinator reports carry the log so tests and CI can assert that
//! exclusion and re-queue actually happened.

pub mod breaker;
pub mod inject;
pub mod tracker;

pub use breaker::{BreakerState, CircuitBreaker};
pub use inject::{FaultKind, FaultPlan, FaultSpec, WorkerFaults};
pub use tracker::{HealthConfig, HealthTracker, Verdict};

use crate::serve::churn::{ChurnAction, ChurnEvent, ChurnScript};

/// One detection or recovery action, stamped with wall time since run
/// start (dispatch) or virtual time (serve synthesis).
#[derive(Clone, Debug, PartialEq)]
pub struct HealthEvent {
    pub at_ms: f64,
    /// Worker queue id (0-based, matching dispatch queues).
    pub worker: usize,
    pub kind: HealthEventKind,
}

/// What happened.
#[derive(Clone, Debug, PartialEq)]
pub enum HealthEventKind {
    /// The tracker flagged the worker (detail: verdict description).
    Suspect { why: String },
    /// Its breaker opened with this backoff.
    Open { backoff_ms: f64 },
    /// A half-open probe went out.
    HalfOpen,
    /// A probe succeeded; the breaker closed.
    Closed,
    /// The session dropped with work still pending (reader saw EOF/error).
    Disconnect,
    /// A reconnect/connect retry slot: attempt `attempt` sleeps
    /// `delay_ms` before redialing (see `net::reconnect`).
    Backoff { attempt: u32, delay_ms: f64 },
    /// A dropped session was resumed: the worker replayed its parked
    /// unacked results instead of anyone recomputing them.
    Reconnect,
    /// `rows` coded rows re-queued onto worker `to`.
    Requeue { rows: usize, to: usize },
}

impl HealthEvent {
    /// Stable label for JSON export / CI grepping.
    pub fn kind_label(&self) -> &'static str {
        match self.kind {
            HealthEventKind::Suspect { .. } => "suspect",
            HealthEventKind::Open { .. } => "open",
            HealthEventKind::HalfOpen => "half-open",
            HealthEventKind::Closed => "closed",
            HealthEventKind::Disconnect => "disconnect",
            HealthEventKind::Backoff { .. } => "backoff",
            HealthEventKind::Reconnect => "reconnect",
            HealthEventKind::Requeue { .. } => "requeue",
        }
    }

    /// Human-readable detail for logs and JSON.
    pub fn detail(&self) -> String {
        match &self.kind {
            HealthEventKind::Suspect { why } => why.clone(),
            HealthEventKind::Open { backoff_ms } => format!("backoff {backoff_ms:.0} ms"),
            HealthEventKind::HalfOpen => "probe".into(),
            HealthEventKind::Closed => "recovered".into(),
            HealthEventKind::Disconnect => "session dropped with pending work".into(),
            HealthEventKind::Backoff { attempt, delay_ms } => {
                format!("retry {attempt} in {delay_ms:.0} ms")
            }
            HealthEventKind::Reconnect => "session resumed, parked results replayed".into(),
            HealthEventKind::Requeue { rows, to } => format!("{rows} rows -> worker {to}"),
        }
    }
}

/// Compile a fault plan into churn events by replaying what the health
/// layer would observe and decide, in virtual time over `[0,
/// horizon_ms]`. Trigger fractions map onto the horizon (`@50%` =
/// mid-run). Per spec:
///
/// - **crash** → `Leave` at `t_f + miss_beats · beat_ms` (the silence
///   threshold — detection is never instant).
/// - **gray** → `Leave` at `t_f + stall_ms` (beats keep flowing; the
///   stall detector fires once a deadline is overdue).
/// - **spike** → `Throttle(beat_ms / (beat_ms + extra_ms))` at
///   `t_f + spike_beats · beat_ms` (streak confirmation), no recovery —
///   a degraded worker serves at reduced rate.
/// - **slow** (slow-start rejoin) → the worker is degraded from t = 0:
///   `Throttle` once the streak confirms, then the breaker probes on
///   exponential backoff until a probe lands past `t_f` (the worker has
///   warmed up) and a `Throttle(1.0)` restores it.
/// - **flaky** → no event: compute-level failures are absorbed by the
///   code's redundancy, invisible at fleet granularity.
///
/// Workers outside `1..=n_workers` (local master queues) are skipped —
/// churn only addresses shared workers. Events come out time-sorted.
pub fn churn_from_faults(
    plan: &FaultPlan,
    n_workers: usize,
    horizon_ms: f64,
    cfg: &HealthConfig,
) -> ChurnScript {
    let beat = cfg.beat_ms.max(1e-9);
    let mut events: Vec<ChurnEvent> = Vec::new();
    for spec in &plan.specs {
        // `all` fans out to every shared worker.
        let wids: Vec<usize> = match spec.worker {
            Some(w) if w < n_workers => vec![w],
            Some(_) => continue,
            None => (0..n_workers).collect(),
        };
        let t_f = spec.at_frac.clamp(0.0, 1.0) * horizon_ms;
        for wid in wids {
            let worker = wid + 1; // churn speaks 1-based worker ids
            match spec.kind {
                FaultKind::Crash => events.push(ChurnEvent {
                    at_ms: t_f + cfg.miss_beats as f64 * beat,
                    worker,
                    action: ChurnAction::Leave,
                }),
                FaultKind::Gray => events.push(ChurnEvent {
                    at_ms: t_f + cfg.stall_ms,
                    worker,
                    action: ChurnAction::Leave,
                }),
                FaultKind::Spike { extra_ms } => events.push(ChurnEvent {
                    at_ms: t_f + cfg.spike_beats as f64 * beat,
                    worker,
                    action: ChurnAction::Throttle(beat / (beat + extra_ms.max(0.0))),
                }),
                FaultKind::SlowStart { extra_ms } => {
                    let detect = cfg.spike_beats as f64 * beat;
                    events.push(ChurnEvent {
                        at_ms: detect,
                        worker,
                        action: ChurnAction::Throttle(beat / (beat + extra_ms.max(0.0))),
                    });
                    // Breaker probe loop: failures double the backoff
                    // until a probe lands past the warm-up point.
                    let mut b =
                        CircuitBreaker::new(cfg.breaker_backoff_ms, cfg.breaker_backoff_cap_ms);
                    b.on_failure(detect);
                    let mut t = detect + b.backoff_ms();
                    for _ in 0..64 {
                        if !b.allow(t) {
                            t += b.backoff_ms().max(beat);
                            continue;
                        }
                        if t >= t_f {
                            b.on_success();
                            events.push(ChurnEvent {
                                at_ms: t,
                                worker,
                                action: ChurnAction::Throttle(1.0),
                            });
                            break;
                        }
                        b.on_failure(t);
                        t += b.backoff_ms();
                    }
                }
                FaultKind::Flaky { .. } => {}
                // A dropped connection is detected like a crash (the
                // reader sees the close), but the reconnect layer gets
                // the session back once the backoff schedule lands:
                // Leave at detection, Join after the retry window.
                FaultKind::Drop => {
                    let detect = t_f + cfg.miss_beats as f64 * beat;
                    events.push(ChurnEvent {
                        at_ms: detect,
                        worker,
                        action: ChurnAction::Leave,
                    });
                    let retry_window: f64 = (0..cfg.reconnect_attempts)
                        .map(|a| {
                            (cfg.reconnect_base_ms * 2f64.powi(a.min(52) as i32))
                                .min(cfg.breaker_backoff_cap_ms)
                        })
                        .sum();
                    events.push(ChurnEvent {
                        at_ms: detect + retry_window.max(beat),
                        worker,
                        action: ChurnAction::Join,
                    });
                }
            }
        }
    }
    ChurnScript::from_events(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HealthConfig {
        HealthConfig {
            beat_ms: 10.0,
            miss_beats: 3,
            stall_ms: 50.0,
            spike_beats: 3,
            breaker_backoff_ms: 20.0,
            breaker_backoff_cap_ms: 320.0,
            ..HealthConfig::default()
        }
    }

    #[test]
    fn crash_and_gray_become_delayed_leaves() {
        let plan = FaultPlan::parse("crash:w2@50%,gray:w1@0%").unwrap();
        let sc = churn_from_faults(&plan, 4, 1000.0, &cfg());
        sc.validate(4).unwrap();
        assert_eq!(sc.events.len(), 2);
        // Time-sorted: gray at 0 + 50 first, crash at 500 + 30 second.
        assert_eq!(sc.events[0].worker, 1);
        assert_eq!(sc.events[0].action, ChurnAction::Leave);
        assert!((sc.events[0].at_ms - 50.0).abs() < 1e-9);
        assert_eq!(sc.events[1].worker, 2);
        assert!((sc.events[1].at_ms - 530.0).abs() < 1e-9);
    }

    #[test]
    fn slow_start_throttles_then_recovers_via_probes() {
        let plan = FaultPlan::parse("slow:w1@40%x30").unwrap();
        let sc = churn_from_faults(&plan, 2, 1000.0, &cfg());
        sc.validate(2).unwrap();
        assert!(sc.events.len() >= 2, "throttle + restore: {:?}", sc.events);
        let first = &sc.events[0];
        assert!((first.at_ms - 30.0).abs() < 1e-9, "detect at spike_beats·beat");
        match first.action {
            ChurnAction::Throttle(f) => assert!((f - 10.0 / 40.0).abs() < 1e-9),
            a => panic!("expected Throttle, got {a:?}"),
        }
        let last = sc.events.last().unwrap();
        assert_eq!(last.action, ChurnAction::Throttle(1.0));
        assert!(
            last.at_ms >= 400.0,
            "restore only after the warm-up point: {}",
            last.at_ms
        );
    }

    #[test]
    fn spike_throttles_without_recovery_and_flaky_is_silent() {
        let plan = FaultPlan::parse("spike:w2@25%x40,flaky:all@5").unwrap();
        let sc = churn_from_faults(&plan, 2, 1000.0, &cfg());
        assert_eq!(sc.events.len(), 1);
        assert_eq!(sc.events[0].worker, 2);
        assert_eq!(sc.events[0].action, ChurnAction::Throttle(10.0 / 50.0));
    }

    #[test]
    fn all_target_fans_out_and_locals_are_skipped() {
        let plan = FaultPlan::parse("crash:all@0%").unwrap();
        let sc = churn_from_faults(&plan, 3, 100.0, &cfg());
        assert_eq!(sc.events.len(), 3);
        // A spec naming a queue past the shared fleet (a local master
        // queue) contributes nothing.
        let local = FaultPlan::parse("crash:w9@0%").unwrap();
        assert!(churn_from_faults(&local, 3, 100.0, &cfg()).is_empty());
    }

    #[test]
    fn event_labels_are_stable() {
        let e = HealthEvent {
            at_ms: 1.0,
            worker: 3,
            kind: HealthEventKind::Requeue { rows: 12, to: 1 },
        };
        assert_eq!(e.kind_label(), "requeue");
        assert!(e.detail().contains("12 rows"));
        let open = HealthEvent {
            at_ms: 1.0,
            worker: 3,
            kind: HealthEventKind::Open { backoff_ms: 250.0 },
        };
        assert_eq!(open.kind_label(), "open");
    }
}
