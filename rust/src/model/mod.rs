//! The paper's stochastic delay model (§II-B).
//!
//! * [`params`] — per-link `(γ, a, u)` parameters, resource-scaled expected
//!   unit delays `θ_{m,n}` (eqs. 10 and 24).
//! * [`dist`] — the delay distributions themselves: eqs. (1)–(5) CDFs,
//!   densities where needed, means, and exact samplers used by both the
//!   Monte-Carlo engine and the coordinator's delay injection.

pub mod params;
pub mod dist;

pub use dist::{Exponential, LinkDelay, ShiftedExp};
pub use params::{theta_dedicated, theta_fractional, theta_local, LinkParams};
