//! The paper's stochastic delay model (§II-B) and the pluggable
//! delay-family layer that generalizes it.
//!
//! * [`params`] — per-link `(γ, a, u)` parameters, resource-scaled expected
//!   unit delays `θ_{m,n}` (eqs. 10 and 24), and the per-link
//!   [`FamilyKind`] selector.
//! * [`dist`] — the delay distributions themselves: eqs. (1)–(5) CDFs,
//!   means, quantiles and exact samplers used by both the Monte-Carlo
//!   engine and the coordinator's delay injection, plus the
//!   [`DelayFamily`] abstraction (shifted-exp, Weibull/Pareto heavy
//!   tails, bimodal throttling mixtures, trace-driven empirical).

pub mod params;
pub mod dist;

pub use dist::{DelayFamily, Exponential, FamilyKind, LinkDelay, ShiftedExp, TraceDist};
pub use params::{
    theta_dedicated, theta_fractional, theta_from_comp_mean, theta_local, LinkParams,
};
