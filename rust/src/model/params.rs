//! Per-link delay parameters and expected unit delays θ.
//!
//! A "link" is a (master m, node n) pair. For workers (n ≥ 1) a link has
//! three parameters (§II-B):
//!
//! * `gamma` — rate of the exponential communication delay of ONE coded row
//!   at full bandwidth (eq. 1);
//! * `a`, `u` — shift and rate of the shifted-exponential computation delay
//!   of ONE coded row at full compute (eq. 2).
//!
//! For local processing (n = 0) there is no communication: `gamma = ∞`.
//!
//! θ_{m,n} is the **expected total delay of a unit coded task** and is the
//! only statistic the Markov-approximation algorithms need (Remark 1):
//! dedicated (eq. 10) and fractional (eq. 24) variants below.

use super::dist::FamilyKind;

/// Occasional multiplicative slowdown of the computation legs — models
/// the heavy-tailed stragglers of real measured traces (e.g. t2.micro
/// CPU-credit throttling on EC2) that a fitted shifted exponential cannot
/// produce. The *planner* never sees this (it plans with the fitted
/// parameters, like the paper); only the delay *sampler* applies it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Straggler {
    /// Probability that a sub-task lands on a throttled period.
    pub prob: f64,
    /// Computation slowdown factor while throttled.
    pub slowdown: f64,
}

/// Delay parameters of one (master, node) link. Times are milliseconds
/// throughout (matching §V); rates are 1/ms.
///
/// `(a, u)` are the *fitted* shifted-exponential parameters (eq. 2);
/// [`LinkParams::family`] selects the delay family actually sampled —
/// [`FamilyKind::ShiftedExp`] (the default) samples the fit itself,
/// every other kind a mean-matched or trace-driven alternative (see
/// [`crate::model::dist`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkParams {
    /// Communication rate per coded row at full bandwidth (1/ms);
    /// `f64::INFINITY` for local processing (no communication).
    pub gamma: f64,
    /// Computation shift per coded row (ms).
    pub a: f64,
    /// Computation rate per coded row (1/ms).
    pub u: f64,
    /// Optional heavy-tail mixture applied when *sampling* (not planning).
    pub straggler: Option<Straggler>,
    /// Computation-delay family selector (default: the eq.-2 shifted
    /// exponential). Trace ids resolve against the scenario's table.
    pub family: FamilyKind,
}

impl LinkParams {
    pub fn new(gamma: f64, a: f64, u: f64) -> Self {
        assert!(gamma > 0.0, "gamma must be positive (got {gamma})");
        assert!(a > 0.0, "a must be positive (got {a})");
        assert!(u > 0.0, "u must be positive (got {u})");
        Self {
            gamma,
            a,
            u,
            straggler: None,
            family: FamilyKind::ShiftedExp,
        }
    }

    /// Local-processing parameters (no communication leg).
    pub fn local(a: f64, u: f64) -> Self {
        Self {
            gamma: f64::INFINITY,
            a,
            u,
            straggler: None,
            family: FamilyKind::ShiftedExp,
        }
    }

    /// Attach a heavy-tail straggler mixture (sampling only).
    pub fn with_straggler(mut self, prob: f64, slowdown: f64) -> Self {
        assert!((0.0..=1.0).contains(&prob) && slowdown >= 1.0);
        self.straggler = Some(Straggler { prob, slowdown });
        self
    }

    /// Select the computation-delay family (panics on invalid
    /// parameters; trace ids are validated by the scenario).
    pub fn with_family(mut self, family: FamilyKind) -> Self {
        if !matches!(family, FamilyKind::Trace { .. }) {
            family
                .validate(0)
                .expect("with_family: invalid family parameters");
        }
        self.family = family;
        self
    }

    pub fn is_local(&self) -> bool {
        self.gamma.is_infinite()
    }

    /// Mean TOTAL delay of shipping + computing one coded row with full
    /// resources: `1/γ + 1/u + a` (eq. 10); the 1/γ term vanishes for
    /// local processing.
    pub fn theta(&self) -> f64 {
        theta_dedicated(self)
    }
}

/// θ under dedicated assignment (k = b = 1), eq. (10).
pub fn theta_dedicated(p: &LinkParams) -> f64 {
    let comm = if p.is_local() { 0.0 } else { 1.0 / p.gamma };
    comm + 1.0 / p.u + p.a
}

/// θ for the master's local processing, eq. (10) right.
pub fn theta_local(a0: f64, u0: f64) -> f64 {
    1.0 / u0 + a0
}

/// θ under fractional assignment with compute share `k` and bandwidth
/// share `b`, eq. (24). Returns `∞` when either share is zero.
pub fn theta_fractional(p: &LinkParams, k: f64, b: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&k) && (0.0..=1.0).contains(&b));
    if k <= 0.0 || (!p.is_local() && b <= 0.0) {
        return f64::INFINITY;
    }
    let comm = if p.is_local() { 0.0 } else { 1.0 / (b * p.gamma) };
    comm + 1.0 / (k * p.u) + p.a / k
}

/// θ from an arbitrary per-row computation-delay mean `E[X]` — the
/// family-aware generalization of eq. (24) via Remark 1:
/// `1/(bγ) + E[X]/k`, with the same share guards and zero-share → ∞
/// degradation as [`theta_fractional`]. One home for the moment-based
/// formula so the family path cannot drift from the share/comm
/// conventions. The shifted-exp fast path deliberately does NOT route
/// through here: [`theta_fractional`] keeps the legacy
/// `1/(k·u) + a/k` expression bit-for-bit.
pub fn theta_from_comp_mean(p: &LinkParams, comp_mean: f64, k: f64, b: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&k) && (0.0..=1.0).contains(&b));
    if k <= 0.0 || (!p.is_local() && b <= 0.0) {
        return f64::INFINITY;
    }
    let comm = if p.is_local() { 0.0 } else { 1.0 / (b * p.gamma) };
    comm + comp_mean / k
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theta_dedicated_matches_eq10() {
        let p = LinkParams::new(2.0, 0.25, 4.0);
        // 1/2 + 1/4 + 0.25 = 1.0
        assert!((p.theta() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn theta_local_no_comm_term() {
        let p = LinkParams::local(0.4, 2.5);
        assert!((p.theta() - (0.4 + 0.4)).abs() < 1e-12);
        assert!((theta_local(0.4, 2.5) - p.theta()).abs() < 1e-12);
    }

    #[test]
    fn theta_fractional_scales() {
        let p = LinkParams::new(2.0, 0.2, 5.0);
        let full = theta_fractional(&p, 1.0, 1.0);
        assert!((full - p.theta()).abs() < 1e-12);
        // Half of both resources: comm doubles, comp (rate + shift) doubles.
        let half = theta_fractional(&p, 0.5, 0.5);
        assert!((half - 2.0 * full).abs() < 1e-12);
    }

    #[test]
    fn theta_fractional_zero_share_is_infinite() {
        let p = LinkParams::new(2.0, 0.2, 5.0);
        assert!(theta_fractional(&p, 0.0, 0.5).is_infinite());
        assert!(theta_fractional(&p, 0.5, 0.0).is_infinite());
    }

    #[test]
    fn theta_fractional_local_ignores_bandwidth() {
        let p = LinkParams::local(0.4, 2.0);
        // local: b is irrelevant (b_{m,0}=1 by assumption)
        let t = theta_fractional(&p, 1.0, 0.0);
        assert!((t - p.theta()).abs() < 1e-12);
    }

    #[test]
    fn theta_from_mean_generalizes_eq24() {
        let p = LinkParams::new(2.0, 0.25, 4.0);
        // With E[X] = a + 1/u the moment formula agrees with eq. (24)
        // up to association (the shifted-exp fast path never routes
        // through it, so only value agreement matters here).
        let want = theta_fractional(&p, 0.5, 0.5);
        let got = theta_from_comp_mean(&p, p.a + 1.0 / p.u, 0.5, 0.5);
        assert!((got - want).abs() / want < 1e-12);
        // Same zero-share degradation and local-link conventions.
        assert!(theta_from_comp_mean(&p, 1.0, 0.0, 0.5).is_infinite());
        let local = LinkParams::local(0.4, 2.5);
        assert!((theta_from_comp_mean(&local, 0.8, 1.0, 1.0) - 0.8).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "a must be positive")]
    fn rejects_nonpositive_shift() {
        LinkParams::new(1.0, 0.0, 1.0);
    }
}
