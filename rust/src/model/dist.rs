//! Delay distributions of §II-B: eqs. (1)–(5).
//!
//! [`LinkDelay`] is the load/resource-scaled total delay
//! `T_{m,n} = T^{[tr]} + T^{[cp]}` of one assigned sub-task:
//! `Exp(bγ/l)` communication + deterministic shift `a·l/k` + `Exp(ku/l)`
//! computation — a shifted hypoexponential whose CDF is eq. (3) (distinct
//! rates), eq. (4) (equal rates), or eq. (5) (local: no comm leg).

use super::params::LinkParams;
use crate::util::rng::Rng;

/// Plain exponential distribution (eq. 1 building block).
#[derive(Clone, Copy, Debug)]
pub struct Exponential {
    pub rate: f64,
}

impl Exponential {
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0 && rate.is_finite());
        Self { rate }
    }

    pub fn cdf(&self, t: f64) -> f64 {
        if t <= 0.0 {
            0.0
        } else {
            1.0 - (-self.rate * t).exp()
        }
    }

    pub fn mean(&self) -> f64 {
        1.0 / self.rate
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        rng.exp(self.rate)
    }
}

/// Shifted exponential (eq. 2 building block; also Fig. 7's fitted model).
#[derive(Clone, Copy, Debug)]
pub struct ShiftedExp {
    pub shift: f64,
    pub rate: f64,
}

impl ShiftedExp {
    pub fn new(shift: f64, rate: f64) -> Self {
        assert!(shift >= 0.0 && rate > 0.0 && rate.is_finite());
        Self { shift, rate }
    }

    pub fn cdf(&self, t: f64) -> f64 {
        if t <= self.shift {
            0.0
        } else {
            1.0 - (-self.rate * (t - self.shift)).exp()
        }
    }

    pub fn mean(&self) -> f64 {
        self.shift + 1.0 / self.rate
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        rng.shifted_exp(self.shift, self.rate)
    }
}

/// Total delay of one assigned sub-task (eqs. 3–5).
///
/// Built from link parameters, load `l` (> 0 coded rows), compute share
/// `k`, bandwidth share `b`. Local links ignore `b` and have no comm leg.
#[derive(Clone, Copy, Debug)]
pub struct LinkDelay {
    /// Communication rate `bγ/l`; `∞` for local processing.
    comm_rate: f64,
    /// Deterministic shift `a·l/k`.
    shift: f64,
    /// Computation rate `k·u/l`.
    comp_rate: f64,
    /// Heavy-tail mixture on the computation legs (sampling only; the
    /// CDF below describes the fitted/non-throttled component).
    straggler: Option<super::params::Straggler>,
}

impl LinkDelay {
    pub fn new(p: &LinkParams, l: f64, k: f64, b: f64) -> Self {
        assert!(l > 0.0, "LinkDelay needs positive load, got {l}");
        assert!(k > 0.0 && k <= 1.0, "compute share k={k} out of (0,1]");
        let comm_rate = if p.is_local() {
            f64::INFINITY
        } else {
            assert!(b > 0.0 && b <= 1.0, "bandwidth share b={b} out of (0,1]");
            b * p.gamma / l
        };
        Self {
            comm_rate,
            shift: p.a * l / k,
            comp_rate: k * p.u / l,
            straggler: p.straggler,
        }
    }

    /// Local computation at the master (eq. 5): `k = b = 1`, no comm.
    pub fn local(a0: f64, u0: f64, l: f64) -> Self {
        Self::new(&LinkParams::local(a0, u0), l, 1.0, 1.0)
    }

    pub fn is_local(&self) -> bool {
        self.comm_rate.is_infinite()
    }

    pub fn shift(&self) -> f64 {
        self.shift
    }

    /// Communication rate `bγ/l` (`∞` for local links). Exposed so the
    /// SoA Monte-Carlo kernel can compile link columns without
    /// re-deriving the eq. (3) parameterization.
    pub fn comm_rate(&self) -> f64 {
        self.comm_rate
    }

    /// Computation rate `k·u/l`.
    pub fn comp_rate(&self) -> f64 {
        self.comp_rate
    }

    /// Heavy-tail mixture applied to the computation legs, if any.
    pub fn straggler(&self) -> Option<super::params::Straggler> {
        self.straggler
    }

    /// `E[T] = 1/(bγ/l) + a·l/k + 1/(k·u/l)` — the Markov-inequality
    /// numerator `l·θ` (eqs. 9, 23).
    pub fn mean(&self) -> f64 {
        let comm = if self.is_local() {
            0.0
        } else {
            1.0 / self.comm_rate
        };
        comm + self.shift + 1.0 / self.comp_rate
    }

    /// CDF `P[T ≤ t]`, eqs. (3)/(4)/(5).
    pub fn cdf(&self, t: f64) -> f64 {
        let x = t - self.shift;
        if x <= 0.0 {
            return 0.0;
        }
        if self.is_local() {
            // eq. (5)
            return 1.0 - (-self.comp_rate * x).exp();
        }
        let (l1, l2) = (self.comm_rate, self.comp_rate);
        let rel = (l1 - l2).abs() / l1.max(l2);
        if rel < 1e-9 {
            // eq. (4): equal-rate limit (Erlang-2 with shift)
            let lx = l2 * x;
            1.0 - (1.0 + lx) * (-lx).exp()
        } else {
            // eq. (3)
            1.0 - (l1 * (-l2 * x).exp() - l2 * (-l1 * x).exp()) / (l1 - l2)
        }
    }

    /// Draw one delay: comm + shift + comp (independent legs). With a
    /// straggler mixture attached, the computation legs are stretched by
    /// `slowdown` with probability `prob`.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let comm = if self.is_local() {
            0.0
        } else {
            rng.exp(self.comm_rate)
        };
        let factor = match self.straggler {
            Some(s) if rng.f64() < s.prob => s.slowdown,
            _ => 1.0,
        };
        comm + factor * (self.shift + rng.exp(self.comp_rate))
    }

    /// Decomposed sample `(comm, shift, comp)` — the coordinator injects
    /// the comm leg on the channel and the comp legs at the worker.
    pub fn sample_parts(&self, rng: &mut Rng) -> (f64, f64, f64) {
        let comm = if self.is_local() {
            0.0
        } else {
            rng.exp(self.comm_rate)
        };
        (comm, self.shift, rng.exp(self.comp_rate))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn empirical_cdf(d: &LinkDelay, t: f64, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut cnt = 0usize;
        for _ in 0..n {
            if d.sample(&mut rng) <= t {
                cnt += 1;
            }
        }
        cnt as f64 / n as f64
    }

    #[test]
    fn exponential_cdf_and_mean() {
        let e = Exponential::new(2.0);
        assert_eq!(e.cdf(0.0), 0.0);
        assert!((e.cdf(0.5) - (1.0 - (-1.0f64).exp())).abs() < 1e-12);
        assert!((e.mean() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn shifted_exp_cdf() {
        let s = ShiftedExp::new(1.0, 3.0);
        assert_eq!(s.cdf(0.9), 0.0);
        assert_eq!(s.cdf(1.0), 0.0);
        assert!((s.cdf(2.0) - (1.0 - (-3.0f64).exp())).abs() < 1e-12);
        assert!((s.mean() - (1.0 + 1.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn link_delay_mean_is_l_theta() {
        // E[T] = l·θ(k,b) — the exact identity behind eqs. (9)/(23).
        use crate::model::params::theta_fractional;
        let p = LinkParams::new(2.0, 0.25, 4.0);
        for &(l, k, b) in &[(10.0, 1.0, 1.0), (25.0, 0.5, 0.25), (3.0, 0.1, 0.9)] {
            let d = LinkDelay::new(&p, l, k, b);
            let want = l * theta_fractional(&p, k, b);
            assert!((d.mean() - want).abs() < 1e-9, "l={l} k={k} b={b}");
        }
    }

    #[test]
    fn cdf_zero_before_shift_eq3() {
        let p = LinkParams::new(1.0, 0.5, 2.0);
        let d = LinkDelay::new(&p, 8.0, 0.5, 1.0);
        // shift = 0.5*8/0.5 = 8.0
        assert_eq!(d.shift(), 8.0);
        assert_eq!(d.cdf(7.99), 0.0);
        assert!(d.cdf(8.01) > 0.0);
    }

    #[test]
    fn cdf_matches_eq3_formula_directly() {
        // Hand-evaluate eq. (3) at one point.
        let p = LinkParams::new(3.0, 0.2, 1.0);
        let (l, k, b) = (4.0, 1.0, 1.0);
        let d = LinkDelay::new(&p, l, k, b);
        let t = 3.0;
        let x = t - p.a * l / k;
        let l1 = b * p.gamma / l; // 0.75
        let l2 = k * p.u / l; // 0.25
        let want = 1.0 - (l1 * (-l2 * x).exp() - l2 * (-l1 * x).exp()) / (l1 - l2);
        assert!((d.cdf(t) - want).abs() < 1e-12);
    }

    #[test]
    fn cdf_equal_rate_limit_continuous() {
        // eq. (4) must be the limit of eq. (3) as rates converge.
        let p_eq = LinkParams::new(1.0, 0.1, 1.0);
        let d_eq = LinkDelay::new(&p_eq, 5.0, 1.0, 1.0); // rates equal: 0.2, 0.2
        let p_near = LinkParams::new(1.0 + 1e-7, 0.1, 1.0);
        let d_near = LinkDelay::new(&p_near, 5.0, 1.0, 1.0);
        for &t in &[1.0, 2.0, 5.0, 10.0] {
            assert!(
                (d_eq.cdf(t) - d_near.cdf(t)).abs() < 1e-6,
                "t={t}: {} vs {}",
                d_eq.cdf(t),
                d_near.cdf(t)
            );
        }
    }

    #[test]
    fn cdf_is_monotone_and_bounded() {
        let p = LinkParams::new(2.0, 0.25, 4.0);
        let d = LinkDelay::new(&p, 10.0, 0.7, 0.4);
        let mut prev = 0.0;
        for i in 0..200 {
            let t = i as f64 * 0.5;
            let c = d.cdf(t);
            assert!((0.0..=1.0).contains(&c));
            assert!(c >= prev - 1e-12, "not monotone at t={t}");
            prev = c;
        }
        assert!(prev > 0.99, "CDF should approach 1, got {prev}");
    }

    #[test]
    fn sampler_agrees_with_cdf() {
        let p = LinkParams::new(2.0, 0.25, 4.0);
        let d = LinkDelay::new(&p, 10.0, 1.0, 1.0);
        for &t in &[3.0, 5.0, 8.0, 12.0] {
            let emp = empirical_cdf(&d, t, 100_000, 42);
            let ana = d.cdf(t);
            assert!((emp - ana).abs() < 0.01, "t={t}: emp={emp} ana={ana}");
        }
    }

    #[test]
    fn local_sampler_and_cdf_eq5() {
        let d = LinkDelay::local(0.4, 2.5, 20.0);
        assert!(d.is_local());
        // shift = 0.4*20 = 8, rate = 2.5/20 = 0.125
        assert_eq!(d.cdf(8.0), 0.0);
        let want = 1.0 - (-0.125f64 * 4.0).exp();
        assert!((d.cdf(12.0) - want).abs() < 1e-12);
        let emp = empirical_cdf(&d, 12.0, 100_000, 7);
        assert!((emp - want).abs() < 0.01);
    }

    #[test]
    fn sample_parts_sum_to_sample_distribution() {
        let p = LinkParams::new(1.5, 0.3, 2.0);
        let d = LinkDelay::new(&p, 6.0, 0.5, 0.5);
        let mut rng = Rng::new(9);
        let mut mean = 0.0;
        let n = 100_000;
        for _ in 0..n {
            let (c, s, q) = d.sample_parts(&mut rng);
            assert!(c >= 0.0 && q >= 0.0);
            assert_eq!(s, d.shift());
            mean += c + s + q;
        }
        mean /= n as f64;
        assert!((mean - d.mean()).abs() / d.mean() < 0.02);
    }
}
