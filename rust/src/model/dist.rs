//! Delay distributions of §II-B (eqs. 1–5) and the pluggable
//! **delay-family layer** that generalizes them.
//!
//! The paper models the computation delay of one coded row as a shifted
//! exponential (eq. 2); real clusters are heavier-tailed than a
//! shifted-exp fit admits (arXiv:1810.09992), and streaming analyses
//! cover non-exponential service processes outright (arXiv:2103.01921).
//! [`DelayFamily`] is the per-row computation-delay distribution the
//! whole stack samples through:
//!
//! | family | law of the per-row delay `X` | tail |
//! |---|---|---|
//! | `ShiftedExp` | `shift + Exp(rate)` (eq. 2) | exponential |
//! | `Weibull` | `shift + scale·E^{1/shape}`, `E ~ Exp(1)` | heavy for `shape < 1` |
//! | `Pareto` | `P[X > x] = (scale/x)^alpha` on `[scale, ∞)` | power law |
//! | `Bimodal` | `F·(shift + Exp(rate))`, `F = slow` w.p. `prob` | throttling mixture |
//! | `Empirical` | `scale·F̂⁻¹(U)` over a measured trace ([`Ecdf`]) | whatever was measured |
//!
//! **Scaling law.** Eq. (2) gives a block of `l` rows at compute share
//! `k` the delay `a·l/k + Exp(k·u/l)` — exactly `(l/k)·X` in
//! distribution. That multiplicative law is applied family-generically:
//! [`DelayFamily::scaled`] maps a per-row family to its block-scaled
//! version, so every family plugs into the same kernel.
//!
//! **Selection vs distribution.** [`FamilyKind`] is the `Copy`,
//! JSON-serializable *selector* stored per link
//! ([`LinkParams::family`]); [`FamilyKind::resolve`] lifts it into the
//! concrete [`DelayFamily`] by **mean-matching** the link's fitted
//! `(a, u)` parameters — every parametric family keeps
//! `E[X] = a + 1/u`, so planners that only consume means (Theorem 1,
//! Remark 1) produce identical plans while the realized tail changes.
//! Trace-driven links sample the raw measured distribution instead
//! (`E[X]` = the trace mean, threaded to the planner through the moment
//! interface `DelayFamily::mean`).
//!
//! [`LinkDelay`] remains the load/resource-scaled total delay
//! `T = T^{[tr]} + T^{[cp]}` of one assigned sub-task: `Exp(bγ/l)`
//! communication plus the block-scaled computation family. For
//! shifted-exponential links its CDF is eq. (3)/(4)/(5) in closed form
//! and its compile/sampling arithmetic is bit-for-bit the pre-family
//! kernel's.

use std::sync::Arc;

use super::params::LinkParams;
use crate::util::json::Json;
use crate::util::rng::{Rng, FILL_LANES};
use crate::util::stats::{gamma_fn, Ecdf};

/// Plain exponential distribution (eq. 1 building block).
#[derive(Clone, Copy, Debug)]
pub struct Exponential {
    pub rate: f64,
}

impl Exponential {
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0 && rate.is_finite());
        Self { rate }
    }

    pub fn cdf(&self, t: f64) -> f64 {
        if t <= 0.0 {
            0.0
        } else {
            1.0 - (-self.rate * t).exp()
        }
    }

    pub fn mean(&self) -> f64 {
        1.0 / self.rate
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        rng.exp(self.rate)
    }
}

/// Shifted exponential (eq. 2 building block; also Fig. 7's fitted model).
#[derive(Clone, Copy, Debug)]
pub struct ShiftedExp {
    pub shift: f64,
    pub rate: f64,
}

impl ShiftedExp {
    pub fn new(shift: f64, rate: f64) -> Self {
        assert!(shift >= 0.0 && rate > 0.0 && rate.is_finite());
        Self { shift, rate }
    }

    pub fn cdf(&self, t: f64) -> f64 {
        if t <= self.shift {
            0.0
        } else {
            1.0 - (-self.rate * (t - self.shift)).exp()
        }
    }

    pub fn mean(&self) -> f64 {
        self.shift + 1.0 / self.rate
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        rng.shifted_exp(self.shift, self.rate)
    }
}

// ----------------------------------------------------------------------
// Trace-driven empirical distributions
// ----------------------------------------------------------------------

/// A named empirical per-row delay distribution built from a measured
/// (or synthesized) trace — the sampling source of
/// [`FamilyKind::Trace`]. Scenarios carry a table of these
/// ([`crate::config::Scenario::traces`]); links reference them by index
/// so [`LinkParams`] stays `Copy`.
#[derive(Clone, Debug)]
pub struct TraceDist {
    name: String,
    ecdf: Arc<Ecdf>,
}

impl TraceDist {
    /// Build from raw per-row delay samples (≥ 2 finite, non-negative).
    pub fn from_samples(name: &str, samples: Vec<f64>) -> anyhow::Result<Self> {
        anyhow::ensure!(
            samples.len() >= 2,
            "trace '{name}' needs ≥ 2 samples, got {}",
            samples.len()
        );
        anyhow::ensure!(
            samples.iter().all(|x| x.is_finite() && *x >= 0.0),
            "trace '{name}' has non-finite or negative delay samples"
        );
        Ok(Self {
            name: name.to_string(),
            // Checked path even though the guards above already hold:
            // trace JSON must never reach a panicking constructor.
            ecdf: Arc::new(Ecdf::try_new(samples)?),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn ecdf(&self) -> &Arc<Ecdf> {
        &self.ecdf
    }

    /// Trace mean — the moment the planner consumes for trace-driven
    /// links (`θ` uses this, not the fitted `(a, u)` surrogate).
    pub fn mean(&self) -> f64 {
        self.ecdf.mean()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set("samples", Json::from_f64_slice(self.ecdf.sorted_samples()));
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("trace")
            .to_string();
        let samples = j
            .get("samples")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("trace '{name}' missing 'samples' array"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("trace '{name}': samples must be numbers"))
            })
            .collect::<anyhow::Result<Vec<f64>>>()?;
        Self::from_samples(&name, samples)
    }
}

// ----------------------------------------------------------------------
// Family selector (per-link, Copy, JSON)
// ----------------------------------------------------------------------

/// Per-link delay-family selector: how the fitted `(a, u)` parameters
/// are lifted into a per-row computation-delay distribution. Stored on
/// [`LinkParams`] (default [`FamilyKind::ShiftedExp`] — the paper);
/// resolved against a scenario's trace table by [`FamilyKind::resolve`].
///
/// All parametric kinds are **mean-matched**: the resolved family keeps
/// `E[X] = a + 1/u`, so swapping the family changes the tail, not the
/// planner's first moment.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum FamilyKind {
    /// Eq. (2): `a + Exp(u)` — the paper's model and the default.
    #[default]
    ShiftedExp,
    /// Weibull tail with the given shape (`< 1` = heavier than
    /// exponential), shift `a`, scale chosen so the mean is `a + 1/u`.
    Weibull { shape: f64 },
    /// Pareto (power-law) tail with index `alpha > 1`, scale chosen so
    /// the mean is `a + 1/u`. Heavier than any Weibull; variance is
    /// infinite for `alpha ≤ 2`.
    Pareto { alpha: f64 },
    /// Throttling mixture `F·(a' + Exp(u'))` with `F = slow` w.p.
    /// `prob`; the base is the `(a, u)` shifted-exp rescaled so the
    /// mixture mean stays `a + 1/u` (unlike the sampling-only
    /// [`crate::model::params::Straggler`], which inflates the mean
    /// behind the planner's back by design).
    Bimodal { prob: f64, slow: f64 },
    /// Trace-driven: per-row delays redrawn from scenario trace `id`
    /// via ECDF inverse transform; `(a, u)` become the fitted surrogate
    /// used only by allocators that require a parametric form.
    Trace { id: usize },
}

impl FamilyKind {
    /// JSON/registry name of this kind.
    pub fn name(&self) -> &'static str {
        match self {
            FamilyKind::ShiftedExp => "shifted_exp",
            FamilyKind::Weibull { .. } => "weibull",
            FamilyKind::Pareto { .. } => "pareto",
            FamilyKind::Bimodal { .. } => "bimodal",
            FamilyKind::Trace { .. } => "trace",
        }
    }

    /// Validate the kind's parameters; `n_traces` bounds trace ids.
    pub fn validate(&self, n_traces: usize) -> anyhow::Result<()> {
        match *self {
            FamilyKind::ShiftedExp => {}
            // Lower bound 0.01 keeps Γ(1 + 1/shape) inside f64 range
            // (f64 Γ overflows past ~171): smaller shapes would resolve
            // to scale = 1/∞ = 0 and a silent NaN mean. Tails that
            // extreme are beyond any physical straggler model anyway.
            FamilyKind::Weibull { shape } => anyhow::ensure!(
                shape.is_finite() && shape >= 0.01,
                "weibull shape must be ≥ 0.01 and finite, got {shape}"
            ),
            FamilyKind::Pareto { alpha } => anyhow::ensure!(
                alpha.is_finite() && alpha > 1.0,
                "pareto alpha must be > 1 (finite mean), got {alpha}"
            ),
            FamilyKind::Bimodal { prob, slow } => anyhow::ensure!(
                (0.0..=1.0).contains(&prob) && slow.is_finite() && slow >= 1.0,
                "bimodal mixture needs prob ∈ [0, 1] and slow ≥ 1 (got {prob} × {slow})"
            ),
            FamilyKind::Trace { id } => anyhow::ensure!(
                id < n_traces,
                "trace family references trace {id} but only {n_traces} trace(s) exist"
            ),
        }
        Ok(())
    }

    /// Lift the fitted `(a, u)` link parameters into the concrete
    /// per-row [`DelayFamily`] (mean-matched; see the type docs).
    /// Panics on invalid parameters — call [`FamilyKind::validate`] at
    /// construction/JSON boundaries first.
    pub fn resolve(&self, a: f64, u: f64, traces: &[TraceDist]) -> DelayFamily {
        self.validate(traces.len())
            .expect("FamilyKind validated at the scenario boundary");
        match *self {
            FamilyKind::ShiftedExp => DelayFamily::ShiftedExp { shift: a, rate: u },
            FamilyKind::Weibull { shape } => DelayFamily::Weibull {
                shift: a,
                // E[scale·E^{1/k}] = scale·Γ(1 + 1/k) ≡ 1/u.
                scale: 1.0 / (u * gamma_fn(1.0 + 1.0 / shape)),
                shape,
            },
            FamilyKind::Pareto { alpha } => DelayFamily::Pareto {
                // E[X] = scale·α/(α−1) ≡ a + 1/u.
                scale: (a + 1.0 / u) * (alpha - 1.0) / alpha,
                alpha,
            },
            FamilyKind::Bimodal { prob, slow } => {
                // E[F·(a' + Exp(u'))] = (1 + prob·(slow−1))·(a' + 1/u');
                // rescale the base by c so the mixture mean is a + 1/u.
                let c = 1.0 / (1.0 + prob * (slow - 1.0));
                DelayFamily::Bimodal {
                    shift: c * a,
                    rate: u / c,
                    prob,
                    slow,
                }
            }
            FamilyKind::Trace { id } => DelayFamily::Empirical {
                ecdf: Arc::clone(traces[id].ecdf()),
                scale: 1.0,
            },
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", Json::Str(self.name().into()));
        match *self {
            FamilyKind::ShiftedExp => {}
            FamilyKind::Weibull { shape } => {
                j.set("shape", Json::Num(shape));
            }
            FamilyKind::Pareto { alpha } => {
                j.set("alpha", Json::Num(alpha));
            }
            FamilyKind::Bimodal { prob, slow } => {
                j.set("prob", Json::Num(prob));
                j.set("slow", Json::Num(slow));
            }
            FamilyKind::Trace { id } => {
                j.set("id", Json::Num(id as f64));
            }
        }
        j
    }

    /// Parse a family selector; unknown kinds and malformed parameters
    /// error gracefully (no panics on hand-written JSON).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("delay family missing string 'kind'"))?;
        let num = |k: &str| -> anyhow::Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("{kind} family missing number '{k}'"))
        };
        let fam = match kind {
            "shifted_exp" => FamilyKind::ShiftedExp,
            "weibull" => FamilyKind::Weibull { shape: num("shape")? },
            "pareto" => FamilyKind::Pareto { alpha: num("alpha")? },
            "bimodal" => FamilyKind::Bimodal {
                prob: num("prob")?,
                slow: num("slow")?,
            },
            "trace" => FamilyKind::Trace {
                id: j
                    .get("id")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("trace family missing integer 'id'"))?,
            },
            other => anyhow::bail!(
                "unknown delay family '{other}' (shifted_exp|weibull|pareto|bimodal|trace)"
            ),
        };
        // Trace ids are bounded by the scenario's table, checked there.
        fam.validate(usize::MAX)?;
        Ok(fam)
    }
}

// ----------------------------------------------------------------------
// Resolved delay families
// ----------------------------------------------------------------------

/// A concrete computation-delay distribution with the
/// `sample / cdf / mean / quantile` surface every layer shares — the
/// Monte-Carlo kernel and coordinator draw through [`sample`] /
/// [`fill_block`], the Markov-inequality allocators consume the moment
/// interface ([`mean`]), and the KS property tests pin sampler↔CDF
/// agreement per family.
///
/// Instances are *at some scale*: [`FamilyKind::resolve`] produces the
/// per-row distribution, [`DelayFamily::scaled`] the `(l/k)`-scaled
/// block version (the eq.-2 scaling law, applied family-generically).
///
/// [`sample`]: DelayFamily::sample
/// [`fill_block`]: DelayFamily::fill_block
/// [`mean`]: DelayFamily::mean
#[derive(Clone, Debug)]
pub enum DelayFamily {
    /// `shift + Exp(rate)` — eq. (2). The kernel fast path keeps this
    /// arm in the legacy flat-column layout, bit-for-bit.
    ShiftedExp { shift: f64, rate: f64 },
    /// `shift + scale·E^{1/shape}`, `E ~ Exp(1)`.
    Weibull { shift: f64, scale: f64, shape: f64 },
    /// `P[X > x] = (scale/x)^alpha` on `[scale, ∞)`.
    Pareto { scale: f64, alpha: f64 },
    /// `F·(shift + Exp(rate))` with `F = slow` w.p. `prob`, else 1.
    Bimodal {
        shift: f64,
        rate: f64,
        prob: f64,
        slow: f64,
    },
    /// `scale·F̂⁻¹(U)` — ECDF inverse transform over a trace.
    Empirical { ecdf: Arc<Ecdf>, scale: f64 },
}

impl DelayFamily {
    /// The `(l/k)`-scaled version of this family (eq. 2's scaling law:
    /// a block of `l` rows at share `k` takes `(l/k)·X`).
    ///
    /// Shifted-exp links compiled by [`LinkDelay::new`] do NOT go
    /// through here — they keep the legacy `a·l/k` / `k·u/l`
    /// expressions so the kernel stays bit-for-bit reproducible.
    pub fn scaled(&self, factor: f64) -> DelayFamily {
        assert!(
            factor.is_finite() && factor > 0.0,
            "scale factor must be positive, got {factor}"
        );
        match self {
            DelayFamily::ShiftedExp { shift, rate } => DelayFamily::ShiftedExp {
                shift: shift * factor,
                rate: rate / factor,
            },
            DelayFamily::Weibull {
                shift,
                scale,
                shape,
            } => DelayFamily::Weibull {
                shift: shift * factor,
                scale: scale * factor,
                shape: *shape,
            },
            DelayFamily::Pareto { scale, alpha } => DelayFamily::Pareto {
                scale: scale * factor,
                alpha: *alpha,
            },
            DelayFamily::Bimodal {
                shift,
                rate,
                prob,
                slow,
            } => DelayFamily::Bimodal {
                shift: shift * factor,
                rate: rate / factor,
                prob: *prob,
                slow: *slow,
            },
            DelayFamily::Empirical { ecdf, scale } => DelayFamily::Empirical {
                ecdf: Arc::clone(ecdf),
                scale: scale * factor,
            },
        }
    }

    /// Draw one delay. RNG consumption per family (the contract the
    /// blocked kernel's column fills mirror): shifted-exp / Weibull /
    /// Pareto — one `Exp` draw; bimodal — one uniform then one `Exp`;
    /// empirical — one uniform.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            DelayFamily::ShiftedExp { shift, rate } => shift + rng.exp(*rate),
            DelayFamily::Weibull {
                shift,
                scale,
                shape,
            } => shift + scale * rng.exp(1.0).powf(1.0 / *shape),
            DelayFamily::Pareto { scale, alpha } => scale * (rng.exp(1.0) / alpha).exp(),
            DelayFamily::Bimodal {
                shift,
                rate,
                prob,
                slow,
            } => {
                let f = if rng.f64() < *prob { *slow } else { 1.0 };
                f * (shift + rng.exp(*rate))
            }
            DelayFamily::Empirical { ecdf, scale } => scale * ecdf.quantile(rng.f64()),
        }
    }

    /// Column fill: `col.len()` draws of this family, the vectorized
    /// form of [`DelayFamily::sample`] used by the blocked kernel.
    /// `scratch` must be at least `col.len()` long (only the bimodal
    /// arm uses it, for its mixture uniforms).
    ///
    /// Single-uniform/exponential families fill bit-identically to the
    /// scalar draws (the [`Rng::fill_exp`]/[`Rng::fill_f64`] contract);
    /// the bimodal arm draws its uniform column before its exponential
    /// column, so it is same-distribution/different-bits — exactly the
    /// documented blocked-sampling contract.
    #[inline]
    pub fn fill_block(&self, rng: &mut Rng, col: &mut [f64], scratch: &mut [f64]) {
        self.fill_block_opts(rng, col, scratch, false);
    }

    /// [`DelayFamily::fill_block`] with the kernel-v3 knob: when
    /// `ziggurat` is true, every exponential column is drawn through
    /// [`Rng::fill_exp_zig`] instead of the inverse transform. The
    /// ziggurat consumes a variable number of generator words per draw,
    /// so `ziggurat = true` is *distribution-equal* only — the
    /// bit-parity contract above holds solely for `ziggurat = false`.
    ///
    /// All transform passes are chunked [`FILL_LANES`] wide (fixed-size
    /// array views the autovectorizer can lower to SIMD lanes, plus a
    /// scalar remainder); chunking reorders nothing, so it never
    /// affects which bits are produced.
    pub fn fill_block_opts(
        &self,
        rng: &mut Rng,
        col: &mut [f64],
        scratch: &mut [f64],
        ziggurat: bool,
    ) {
        #[inline]
        fn fill_exp_mode(rng: &mut Rng, rate: f64, col: &mut [f64], ziggurat: bool) {
            if ziggurat {
                rng.fill_exp_zig(rate, col);
            } else {
                rng.fill_exp(rate, col);
            }
        }
        /// Apply `f` element-wise over FILL_LANES-wide array chunks,
        /// then the scalar remainder.
        #[inline]
        fn transform_chunked(col: &mut [f64], f: impl Fn(f64) -> f64) {
            let mut chunks = col.chunks_exact_mut(FILL_LANES);
            for chunk in &mut chunks {
                let lanes: &mut [f64; FILL_LANES] = chunk.try_into().expect("exact chunk");
                for c in lanes.iter_mut() {
                    *c = f(*c);
                }
            }
            for c in chunks.into_remainder() {
                *c = f(*c);
            }
        }
        match self {
            DelayFamily::ShiftedExp { shift, rate } => {
                fill_exp_mode(rng, *rate, col, ziggurat);
                let shift = *shift;
                transform_chunked(col, |c| shift + c);
            }
            DelayFamily::Weibull {
                shift,
                scale,
                shape,
            } => {
                fill_exp_mode(rng, 1.0, col, ziggurat);
                let (shift, scale, inv) = (*shift, *scale, 1.0 / *shape);
                transform_chunked(col, |c| shift + scale * c.powf(inv));
            }
            DelayFamily::Pareto { scale, alpha } => {
                fill_exp_mode(rng, 1.0, col, ziggurat);
                let (scale, alpha) = (*scale, *alpha);
                transform_chunked(col, |c| scale * (c / alpha).exp());
            }
            DelayFamily::Bimodal {
                shift,
                rate,
                prob,
                slow,
            } => {
                let nb = col.len();
                rng.fill_f64(&mut scratch[..nb]);
                fill_exp_mode(rng, *rate, col, ziggurat);
                let (shift, prob, slow) = (*shift, *prob, *slow);
                let mut cc = col.chunks_exact_mut(FILL_LANES);
                let mut uc = scratch[..nb].chunks_exact(FILL_LANES);
                for (chunk, us) in (&mut cc).zip(&mut uc) {
                    let lanes: &mut [f64; FILL_LANES] = chunk.try_into().expect("exact chunk");
                    let ulanes: &[f64; FILL_LANES] = us.try_into().expect("exact chunk");
                    for (c, &u) in lanes.iter_mut().zip(ulanes.iter()) {
                        let f = if u < prob { slow } else { 1.0 };
                        *c = f * (shift + *c);
                    }
                }
                for (c, &u) in cc.into_remainder().iter_mut().zip(uc.remainder().iter()) {
                    let f = if u < prob { slow } else { 1.0 };
                    *c = f * (shift + *c);
                }
            }
            DelayFamily::Empirical { ecdf, scale } => {
                rng.fill_f64(col);
                let scale = *scale;
                // `quantile` walks the trace table — a scalar lookup per
                // element, so the chunking buys nothing here; keep the
                // plain loop.
                for c in col.iter_mut() {
                    *c = scale * ecdf.quantile(*c);
                }
            }
        }
    }

    /// `P[X ≤ x]`.
    pub fn cdf(&self, x: f64) -> f64 {
        match self {
            DelayFamily::ShiftedExp { shift, rate } => {
                if x <= *shift {
                    0.0
                } else {
                    1.0 - (-rate * (x - shift)).exp()
                }
            }
            DelayFamily::Weibull {
                shift,
                scale,
                shape,
            } => {
                if x <= *shift {
                    0.0
                } else {
                    1.0 - (-((x - shift) / scale).powf(*shape)).exp()
                }
            }
            DelayFamily::Pareto { scale, alpha } => {
                if x <= *scale {
                    0.0
                } else {
                    1.0 - (scale / x).powf(*alpha)
                }
            }
            DelayFamily::Bimodal {
                shift,
                rate,
                prob,
                slow,
            } => {
                let se = |y: f64| {
                    if y <= *shift {
                        0.0
                    } else {
                        1.0 - (-rate * (y - shift)).exp()
                    }
                };
                (1.0 - prob) * se(x) + prob * se(x / slow)
            }
            DelayFamily::Empirical { ecdf, scale } => ecdf.eval(x / scale),
        }
    }

    /// `E[X]` — the Markov-inequality moment (Remark 1: the only
    /// statistic Theorem 1 needs). Finite for every constructible
    /// family (Pareto requires `alpha > 1` at validation).
    pub fn mean(&self) -> f64 {
        match self {
            DelayFamily::ShiftedExp { shift, rate } => shift + 1.0 / rate,
            DelayFamily::Weibull {
                shift,
                scale,
                shape,
            } => shift + scale * gamma_fn(1.0 + 1.0 / shape),
            DelayFamily::Pareto { scale, alpha } => scale * alpha / (alpha - 1.0),
            DelayFamily::Bimodal {
                shift,
                rate,
                prob,
                slow,
            } => (1.0 + prob * (slow - 1.0)) * (shift + 1.0 / rate),
            DelayFamily::Empirical { ecdf, scale } => scale * ecdf.mean(),
        }
    }

    /// Generalized inverse `inf{x : F(x) ≥ p}` for `p ∈ [0, 1)`
    /// (`p ≥ 1` returns the supremum of the support: `∞` for the
    /// parametric families, the largest sample for empirical ones).
    pub fn quantile(&self, p: f64) -> f64 {
        assert!((0.0..=1.0).contains(&p), "quantile needs p ∈ [0, 1], got {p}");
        match self {
            DelayFamily::ShiftedExp { shift, rate } => {
                if p >= 1.0 {
                    f64::INFINITY
                } else {
                    shift - (1.0 - p).ln() / rate
                }
            }
            DelayFamily::Weibull {
                shift,
                scale,
                shape,
            } => {
                if p >= 1.0 {
                    f64::INFINITY
                } else {
                    shift + scale * (-(1.0 - p).ln()).powf(1.0 / *shape)
                }
            }
            DelayFamily::Pareto { scale, alpha } => {
                if p >= 1.0 {
                    f64::INFINITY
                } else {
                    scale * (1.0 - p).powf(-1.0 / *alpha)
                }
            }
            DelayFamily::Bimodal { shift, rate, slow, .. } => {
                if p >= 1.0 {
                    return f64::INFINITY;
                }
                // Monotone mixture CDF: bracket + bisect.
                let mut lo = *shift;
                let mut hi = slow * (shift + 1.0 / rate) + 1.0;
                while self.cdf(hi) < p {
                    hi *= 2.0;
                }
                for _ in 0..200 {
                    let mid = 0.5 * (lo + hi);
                    if self.cdf(mid) >= p {
                        hi = mid;
                    } else {
                        lo = mid;
                    }
                    if hi - lo <= 1e-12 * hi.max(1.0) {
                        break;
                    }
                }
                hi
            }
            DelayFamily::Empirical { ecdf, scale } => scale * ecdf.quantile(p),
        }
    }

    /// Infimum of the support (the earliest possible delay).
    pub fn min_support(&self) -> f64 {
        match self {
            DelayFamily::ShiftedExp { shift, .. } => *shift,
            DelayFamily::Weibull { shift, .. } => *shift,
            DelayFamily::Pareto { scale, .. } => *scale,
            DelayFamily::Bimodal { shift, .. } => *shift,
            DelayFamily::Empirical { ecdf, scale } => scale * ecdf.quantile(0.0),
        }
    }
}

// ----------------------------------------------------------------------
// Total link delay
// ----------------------------------------------------------------------

/// Total delay of one assigned sub-task (eqs. 3–5, family-generalized).
///
/// Built from link parameters, load `l` (> 0 coded rows), compute share
/// `k`, bandwidth share `b`. Local links ignore `b` and have no comm
/// leg. The computation leg is a block-scaled [`DelayFamily`];
/// [`LinkDelay::new`] compiles the paper's shifted exponential with the
/// exact legacy arithmetic, [`LinkDelay::with_family`] any other
/// per-row family (use [`crate::config::Scenario::link_delay`] to
/// resolve a link's own family selection).
#[derive(Clone, Debug)]
pub struct LinkDelay {
    /// Communication rate `bγ/l`; `∞` for local processing.
    comm_rate: f64,
    /// Block-scaled computation-delay family.
    comp: DelayFamily,
    /// Heavy-tail mixture on the computation legs (sampling only; the
    /// CDF below describes the fitted/non-throttled component).
    straggler: Option<super::params::Straggler>,
}

impl LinkDelay {
    /// Shifted-exponential compile path (eq. 3 parameterization) — the
    /// pre-family arithmetic, bit-for-bit: `shift = a·l/k`,
    /// `rate = k·u/l`. Ignores `p.family`; family-selecting callers go
    /// through [`crate::config::Scenario::link_delay`].
    pub fn new(p: &LinkParams, l: f64, k: f64, b: f64) -> Self {
        Self {
            comm_rate: Self::comm_rate_of(p, l, k, b),
            comp: DelayFamily::ShiftedExp {
                shift: p.a * l / k,
                rate: k * p.u / l,
            },
            straggler: p.straggler,
        }
    }

    /// Compile a link whose computation leg follows `per_row` (a
    /// [`FamilyKind::resolve`] output): the comm leg is eq. (1) as
    /// always, the computation leg is `(l/k)·X`.
    pub fn with_family(p: &LinkParams, per_row: &DelayFamily, l: f64, k: f64, b: f64) -> Self {
        Self {
            comm_rate: Self::comm_rate_of(p, l, k, b),
            comp: per_row.scaled(l / k),
            straggler: p.straggler,
        }
    }

    fn comm_rate_of(p: &LinkParams, l: f64, k: f64, b: f64) -> f64 {
        assert!(l > 0.0, "LinkDelay needs positive load, got {l}");
        assert!(k > 0.0 && k <= 1.0, "compute share k={k} out of (0,1]");
        if p.is_local() {
            f64::INFINITY
        } else {
            assert!(b > 0.0 && b <= 1.0, "bandwidth share b={b} out of (0,1]");
            b * p.gamma / l
        }
    }

    /// Local computation at the master (eq. 5): `k = b = 1`, no comm.
    pub fn local(a0: f64, u0: f64, l: f64) -> Self {
        Self::new(&LinkParams::local(a0, u0), l, 1.0, 1.0)
    }

    pub fn is_local(&self) -> bool {
        self.comm_rate.is_infinite()
    }

    /// Earliest possible computation delay — for shifted-exponential
    /// links the deterministic shift `a·l/k`, for other families the
    /// infimum of their support.
    pub fn shift(&self) -> f64 {
        self.comp.min_support()
    }

    /// Communication rate `bγ/l` (`∞` for local links). Exposed so the
    /// SoA Monte-Carlo kernel can compile link columns without
    /// re-deriving the eq. (3) parameterization.
    pub fn comm_rate(&self) -> f64 {
        self.comm_rate
    }

    /// Computation rate `k·u/l` — defined for shifted-exponential links
    /// only (the kernel's flat-column arm); panics for other families,
    /// which are compiled from [`LinkDelay::comp`] instead.
    pub fn comp_rate(&self) -> f64 {
        match &self.comp {
            DelayFamily::ShiftedExp { rate, .. } => *rate,
            other => panic!("comp_rate() on a non-shifted-exp link ({other:?})"),
        }
    }

    /// The block-scaled computation-delay family.
    pub fn comp(&self) -> &DelayFamily {
        &self.comp
    }

    /// Heavy-tail mixture applied to the computation legs, if any.
    pub fn straggler(&self) -> Option<super::params::Straggler> {
        self.straggler
    }

    /// `E[T]` — for shifted-exp links
    /// `1/(bγ/l) + a·l/k + 1/(k·u/l)`, the Markov-inequality numerator
    /// `l·θ` (eqs. 9, 23); family-generically `E[comm] + E[comp]`.
    pub fn mean(&self) -> f64 {
        let comm = if self.is_local() {
            0.0
        } else {
            1.0 / self.comm_rate
        };
        comm + self.comp.mean()
    }

    /// CDF `P[T ≤ t]`. Shifted-exp links use the closed forms of
    /// eqs. (3)/(4)/(5); other families use their exact CDF when there
    /// is no comm leg and a numerically-integrated exponential
    /// convolution (composite Simpson) otherwise.
    pub fn cdf(&self, t: f64) -> f64 {
        match &self.comp {
            DelayFamily::ShiftedExp { shift, rate } => {
                let x = t - shift;
                if x <= 0.0 {
                    return 0.0;
                }
                if self.is_local() {
                    // eq. (5)
                    return 1.0 - (-rate * x).exp();
                }
                let (l1, l2) = (self.comm_rate, *rate);
                let rel = (l1 - l2).abs() / l1.max(l2);
                if rel < 1e-9 {
                    // eq. (4): equal-rate limit (Erlang-2 with shift)
                    let lx = l2 * x;
                    1.0 - (1.0 + lx) * (-lx).exp()
                } else {
                    // eq. (3)
                    1.0 - (l1 * (-l2 * x).exp() - l2 * (-l1 * x).exp()) / (l1 - l2)
                }
            }
            fam => {
                if self.is_local() {
                    fam.cdf(t)
                } else {
                    conv_exp_cdf(self.comm_rate, fam, t)
                }
            }
        }
    }

    /// Draw one delay: comm + straggler-scaled computation leg
    /// (independent legs). With a straggler mixture attached, the
    /// computation leg is stretched by `slowdown` with probability
    /// `prob`. RNG order: comm (non-local only), straggler uniform
    /// (attached mixtures only), then the family draw.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let comm = if self.is_local() {
            0.0
        } else {
            rng.exp(self.comm_rate)
        };
        let factor = match self.straggler {
            Some(s) if rng.f64() < s.prob => s.slowdown,
            _ => 1.0,
        };
        comm + factor * self.comp.sample(rng)
    }

    /// Decomposed sample `(comm, deterministic, stochastic)` — the
    /// coordinator injects the comm leg on the channel and the
    /// computation legs at the worker. For shifted-exp links the
    /// deterministic part is the shift `a·l/k` (legacy semantics); for
    /// other families the whole computation draw is stochastic.
    pub fn sample_parts(&self, rng: &mut Rng) -> (f64, f64, f64) {
        let comm = if self.is_local() {
            0.0
        } else {
            rng.exp(self.comm_rate)
        };
        match &self.comp {
            DelayFamily::ShiftedExp { shift, rate } => (comm, *shift, rng.exp(*rate)),
            fam => (comm, 0.0, fam.sample(rng)),
        }
    }
}

/// `P[C + X ≤ t]` for `C ~ Exp(rate)` ⊥ `X ~ fam`, by composite Simpson
/// on `∫ rate·e^{−rate·c}·F_X(t − c) dc`. Used only by the (cold)
/// analytic-CDF path of non-shifted families with a stochastic comm
/// leg.
///
/// The integration domain is truncated to `c ≤ 40/rate` (beyond it the
/// exponential kernel carries `e⁻⁴⁰ ≈ 4·10⁻¹⁸` of mass), so the fixed
/// step count always resolves the kernel — without the truncation a
/// deep-tail query with `rate·t ≫ STEPS` would sample the kernel only
/// at `c = 0` and grossly overshoot. Accuracy stays far below the KS
/// test tolerances that consume this.
fn conv_exp_cdf(rate: f64, fam: &DelayFamily, t: f64) -> f64 {
    if t <= fam.min_support() {
        return 0.0;
    }
    const STEPS: usize = 512; // even
    let c_max = t.min(40.0 / rate);
    let h = c_max / STEPS as f64;
    let f = |c: f64| rate * (-rate * c).exp() * fam.cdf(t - c);
    let mut s = f(0.0) + f(c_max);
    for i in 1..STEPS {
        s += f(i as f64 * h) * if i % 2 == 1 { 4.0 } else { 2.0 };
    }
    (s * h / 3.0).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn empirical_cdf(d: &LinkDelay, t: f64, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut cnt = 0usize;
        for _ in 0..n {
            if d.sample(&mut rng) <= t {
                cnt += 1;
            }
        }
        cnt as f64 / n as f64
    }

    #[test]
    fn exponential_cdf_and_mean() {
        let e = Exponential::new(2.0);
        assert_eq!(e.cdf(0.0), 0.0);
        assert!((e.cdf(0.5) - (1.0 - (-1.0f64).exp())).abs() < 1e-12);
        assert!((e.mean() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn shifted_exp_cdf() {
        let s = ShiftedExp::new(1.0, 3.0);
        assert_eq!(s.cdf(0.9), 0.0);
        assert_eq!(s.cdf(1.0), 0.0);
        assert!((s.cdf(2.0) - (1.0 - (-3.0f64).exp())).abs() < 1e-12);
        assert!((s.mean() - (1.0 + 1.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn link_delay_mean_is_l_theta() {
        // E[T] = l·θ(k,b) — the exact identity behind eqs. (9)/(23).
        use crate::model::params::theta_fractional;
        let p = LinkParams::new(2.0, 0.25, 4.0);
        for &(l, k, b) in &[(10.0, 1.0, 1.0), (25.0, 0.5, 0.25), (3.0, 0.1, 0.9)] {
            let d = LinkDelay::new(&p, l, k, b);
            let want = l * theta_fractional(&p, k, b);
            assert!((d.mean() - want).abs() < 1e-9, "l={l} k={k} b={b}");
        }
    }

    #[test]
    fn cdf_zero_before_shift_eq3() {
        let p = LinkParams::new(1.0, 0.5, 2.0);
        let d = LinkDelay::new(&p, 8.0, 0.5, 1.0);
        // shift = 0.5*8/0.5 = 8.0
        assert_eq!(d.shift(), 8.0);
        assert_eq!(d.cdf(7.99), 0.0);
        assert!(d.cdf(8.01) > 0.0);
    }

    #[test]
    fn cdf_matches_eq3_formula_directly() {
        // Hand-evaluate eq. (3) at one point.
        let p = LinkParams::new(3.0, 0.2, 1.0);
        let (l, k, b) = (4.0, 1.0, 1.0);
        let d = LinkDelay::new(&p, l, k, b);
        let t = 3.0;
        let x = t - p.a * l / k;
        let l1 = b * p.gamma / l; // 0.75
        let l2 = k * p.u / l; // 0.25
        let want = 1.0 - (l1 * (-l2 * x).exp() - l2 * (-l1 * x).exp()) / (l1 - l2);
        assert!((d.cdf(t) - want).abs() < 1e-12);
    }

    #[test]
    fn cdf_equal_rate_limit_continuous() {
        // eq. (4) must be the limit of eq. (3) as rates converge.
        let p_eq = LinkParams::new(1.0, 0.1, 1.0);
        let d_eq = LinkDelay::new(&p_eq, 5.0, 1.0, 1.0); // rates equal: 0.2, 0.2
        let p_near = LinkParams::new(1.0 + 1e-7, 0.1, 1.0);
        let d_near = LinkDelay::new(&p_near, 5.0, 1.0, 1.0);
        for &t in &[1.0, 2.0, 5.0, 10.0] {
            assert!(
                (d_eq.cdf(t) - d_near.cdf(t)).abs() < 1e-6,
                "t={t}: {} vs {}",
                d_eq.cdf(t),
                d_near.cdf(t)
            );
        }
    }

    #[test]
    fn cdf_is_monotone_and_bounded() {
        let p = LinkParams::new(2.0, 0.25, 4.0);
        let d = LinkDelay::new(&p, 10.0, 0.7, 0.4);
        let mut prev = 0.0;
        for i in 0..200 {
            let t = i as f64 * 0.5;
            let c = d.cdf(t);
            assert!((0.0..=1.0).contains(&c));
            assert!(c >= prev - 1e-12, "not monotone at t={t}");
            prev = c;
        }
        assert!(prev > 0.99, "CDF should approach 1, got {prev}");
    }

    #[test]
    fn sampler_agrees_with_cdf() {
        let p = LinkParams::new(2.0, 0.25, 4.0);
        let d = LinkDelay::new(&p, 10.0, 1.0, 1.0);
        for &t in &[3.0, 5.0, 8.0, 12.0] {
            let emp = empirical_cdf(&d, t, 100_000, 42);
            let ana = d.cdf(t);
            assert!((emp - ana).abs() < 0.01, "t={t}: emp={emp} ana={ana}");
        }
    }

    #[test]
    fn local_sampler_and_cdf_eq5() {
        let d = LinkDelay::local(0.4, 2.5, 20.0);
        assert!(d.is_local());
        // shift = 0.4*20 = 8, rate = 2.5/20 = 0.125
        assert_eq!(d.cdf(8.0), 0.0);
        let want = 1.0 - (-0.125f64 * 4.0).exp();
        assert!((d.cdf(12.0) - want).abs() < 1e-12);
        let emp = empirical_cdf(&d, 12.0, 100_000, 7);
        assert!((emp - want).abs() < 0.01);
    }

    #[test]
    fn sample_parts_sum_to_sample_distribution() {
        let p = LinkParams::new(1.5, 0.3, 2.0);
        let d = LinkDelay::new(&p, 6.0, 0.5, 0.5);
        let mut rng = Rng::new(9);
        let mut mean = 0.0;
        let n = 100_000;
        for _ in 0..n {
            let (c, s, q) = d.sample_parts(&mut rng);
            assert!(c >= 0.0 && q >= 0.0);
            assert_eq!(s, d.shift());
            mean += c + s + q;
        }
        mean /= n as f64;
        assert!((mean - d.mean()).abs() / d.mean() < 0.02);
    }

    // ------------------------------------------------------------------
    // Delay-family layer
    // ------------------------------------------------------------------

    /// KS statistic of `n` sampled draws against the analytic CDF.
    fn ks_stat(fam: &DelayFamily, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut xs: Vec<f64> = (0..n).map(|_| fam.sample(&mut rng)).collect();
        xs.sort_by(f64::total_cmp);
        let nn = n as f64;
        let mut ks = 0.0f64;
        for (i, &x) in xs.iter().enumerate() {
            let f = fam.cdf(x);
            ks = ks
                .max((f - i as f64 / nn).abs())
                .max(((i + 1) as f64 / nn - f).abs());
        }
        ks
    }

    fn all_kinds() -> Vec<FamilyKind> {
        vec![
            FamilyKind::ShiftedExp,
            FamilyKind::Weibull { shape: 0.6 },
            FamilyKind::Pareto { alpha: 2.5 },
            FamilyKind::Bimodal {
                prob: 0.1,
                slow: 10.0,
            },
            FamilyKind::Trace { id: 0 },
        ]
    }

    fn toy_traces() -> Vec<TraceDist> {
        // A deliberately lumpy synthetic trace.
        let mut rng = Rng::new(1234);
        let samples: Vec<f64> = (0..200)
            .map(|_| {
                let base = 0.2 + rng.exp(4.0);
                if rng.f64() < 0.05 {
                    base * 12.0
                } else {
                    base
                }
            })
            .collect();
        vec![TraceDist::from_samples("toy", samples).unwrap()]
    }

    #[test]
    fn every_family_sampler_agrees_with_its_cdf() {
        // The per-family KS acceptance test: 40k draws vs analytic CDF.
        // The α = 1e-6 KS critical value at n = 40 000 is ≈ 0.0135.
        let traces = toy_traces();
        for kind in all_kinds() {
            let fam = kind.resolve(0.25, 4.0, &traces);
            let ks = ks_stat(&fam, 40_000, 0xFA11);
            assert!(ks < 0.015, "{}: KS = {ks}", kind.name());
            // And at block scale — the scaling law preserves agreement.
            let scaled = fam.scaled(7.5);
            let ks = ks_stat(&scaled, 40_000, 0xFA12);
            assert!(ks < 0.015, "{} scaled: KS = {ks}", kind.name());
        }
    }

    #[test]
    fn parametric_families_are_mean_matched() {
        // Every non-trace kind must keep E[X] = a + 1/u exactly (the
        // planner-facing moment); the sampled mean must agree too.
        let (a, u) = (0.3, 2.5);
        let want = a + 1.0 / u;
        for kind in all_kinds() {
            if matches!(kind, FamilyKind::Trace { .. }) {
                continue;
            }
            let fam = kind.resolve(a, u, &[]);
            assert!(
                (fam.mean() - want).abs() < 1e-9,
                "{}: analytic mean {} vs {want}",
                kind.name(),
                fam.mean()
            );
            let mut rng = Rng::new(0x4EA2);
            let n = 200_000;
            let emp: f64 = (0..n).map(|_| fam.sample(&mut rng)).sum::<f64>() / n as f64;
            assert!(
                (emp - want).abs() / want < 0.05,
                "{}: sampled mean {emp} vs {want}",
                kind.name()
            );
        }
    }

    #[test]
    fn trace_family_mean_is_trace_mean() {
        let traces = toy_traces();
        let fam = FamilyKind::Trace { id: 0 }.resolve(99.0, 99.0, &traces);
        assert!((fam.mean() - traces[0].mean()).abs() < 1e-12);
        // Fitted surrogate params are ignored by the sampler entirely.
        let mut rng = Rng::new(5);
        let x = fam.sample(&mut rng);
        assert!(x >= 0.0 && x.is_finite());
    }

    #[test]
    fn family_quantile_inverts_cdf() {
        let traces = toy_traces();
        for kind in all_kinds() {
            let fam = kind.resolve(0.25, 4.0, &traces);
            let mut prev = f64::NEG_INFINITY;
            for i in 0..20 {
                let p = i as f64 / 20.0;
                let q = fam.quantile(p);
                assert!(q >= prev, "{}: quantile not monotone", kind.name());
                prev = q;
                // Galois inequality of the generalized inverse.
                assert!(
                    fam.cdf(q) >= p - 1e-9,
                    "{}: F(Q({p})) = {} < {p}",
                    kind.name(),
                    fam.cdf(q)
                );
            }
        }
    }

    #[test]
    fn scaling_law_scales_mean_and_quantiles() {
        let traces = toy_traces();
        for kind in all_kinds() {
            let fam = kind.resolve(0.2, 5.0, &traces);
            let s = fam.scaled(12.5);
            assert!(
                (s.mean() - 12.5 * fam.mean()).abs() / s.mean() < 1e-9,
                "{}: mean does not scale",
                kind.name()
            );
            for &p in &[0.1, 0.5, 0.9] {
                let (q, sq) = (fam.quantile(p), s.quantile(p));
                assert!(
                    (sq - 12.5 * q).abs() / sq.max(1e-12) < 1e-6,
                    "{}: quantile({p}) does not scale: {sq} vs {}",
                    kind.name(),
                    12.5 * q
                );
            }
        }
    }

    #[test]
    fn fill_block_matches_scalar_draws() {
        // Single-draw families fill bit-identically; the bimodal arm
        // reorders its two draw streams (documented), so compare its
        // distribution via means instead.
        let traces = toy_traces();
        for kind in all_kinds() {
            let fam = kind.resolve(0.25, 4.0, &traces);
            let mut a = Rng::new(0xB10C);
            let mut b = Rng::new(0xB10C);
            let mut col = vec![0.0f64; 257];
            let mut scratch = vec![0.0f64; 257];
            fam.fill_block(&mut a, &mut col, &mut scratch);
            if matches!(kind, FamilyKind::Bimodal { .. }) {
                let scalar_mean: f64 =
                    (0..50_000).map(|_| fam.sample(&mut b)).sum::<f64>() / 50_000.0;
                let mut big = vec![0.0f64; 50_000];
                let mut sc = vec![0.0f64; 50_000];
                let mut c = Rng::new(0xB10D);
                fam.fill_block(&mut c, &mut big, &mut sc);
                let block_mean: f64 = big.iter().sum::<f64>() / big.len() as f64;
                assert!(
                    (scalar_mean - block_mean).abs() / scalar_mean < 0.1,
                    "bimodal block vs scalar mean: {block_mean} vs {scalar_mean}"
                );
            } else {
                for (i, &x) in col.iter().enumerate() {
                    assert_eq!(x, fam.sample(&mut b), "{}: draw {i}", kind.name());
                }
                // Generators stay in lockstep afterwards.
                assert_eq!(a.next_u64(), b.next_u64(), "{}", kind.name());
            }
        }
    }

    #[test]
    fn fill_block_bit_parity_across_lengths() {
        // The v3 chunked transform passes must not change a single bit
        // at any column length — full chunks, remainders, sub-lane
        // columns. Single-draw families compare against the scalar
        // sampler; the bimodal arm compares against its documented
        // column order (uniform column, then exponential column).
        let traces = toy_traces();
        for &len in &[1usize, 7, 8, 9, 63, 64, 65, 257] {
            for kind in all_kinds() {
                let fam = kind.resolve(0.25, 4.0, &traces);
                let mut a = Rng::new(0xC0DE + len as u64);
                let mut b = a.clone();
                let mut col = vec![0.0f64; len];
                let mut scratch = vec![0.0f64; len];
                fam.fill_block(&mut a, &mut col, &mut scratch);
                if let DelayFamily::Bimodal {
                    shift,
                    rate,
                    prob,
                    slow,
                } = &fam
                {
                    let us: Vec<f64> = (0..len).map(|_| b.f64()).collect();
                    let es: Vec<f64> = (0..len).map(|_| b.exp(*rate)).collect();
                    for i in 0..len {
                        let f = if us[i] < *prob { *slow } else { 1.0 };
                        assert_eq!(col[i], f * (shift + es[i]), "bimodal len {len} draw {i}");
                    }
                } else {
                    for (i, &x) in col.iter().enumerate() {
                        assert_eq!(
                            x,
                            fam.sample(&mut b),
                            "{}: len {len} draw {i}",
                            kind.name()
                        );
                    }
                }
                assert_eq!(a.next_u64(), b.next_u64(), "{} len {len}", kind.name());
            }
        }
    }

    #[test]
    fn fill_block_ziggurat_is_distribution_equal() {
        // ziggurat = true swaps the exponential columns to the rejection
        // sampler: different bits by construction, same law. Pin the
        // column mean against the family's analytic mean for every arm.
        let traces = toy_traces();
        for kind in all_kinds() {
            let fam = kind.resolve(0.25, 4.0, &traces);
            let n = 50_000usize;
            let mut col = vec![0.0f64; n];
            let mut scratch = vec![0.0f64; n];
            let mut r = Rng::new(0x216);
            fam.fill_block_opts(&mut r, &mut col, &mut scratch, true);
            assert!(
                col.iter().all(|x| x.is_finite() && *x >= 0.0),
                "{}: bad ziggurat draw",
                kind.name()
            );
            let mean = col.iter().sum::<f64>() / n as f64;
            assert!(
                (mean - fam.mean()).abs() / fam.mean() < 0.1,
                "{}: ziggurat mean {mean} vs analytic {}",
                kind.name(),
                fam.mean()
            );
        }
    }

    #[test]
    fn family_link_with_comm_leg_cdf_matches_sampler() {
        // The Simpson-integrated Exp ∗ family convolution must agree
        // with Monte-Carlo across t.
        let p = LinkParams::new(2.0, 0.25, 4.0);
        let per_row = FamilyKind::Weibull { shape: 0.6 }.resolve(p.a, p.u, &[]);
        let d = LinkDelay::with_family(&p, &per_row, 10.0, 1.0, 1.0);
        assert!(!d.is_local());
        for &t in &[3.0, 5.0, 8.0, 15.0] {
            let emp = empirical_cdf(&d, t, 100_000, 77);
            let ana = d.cdf(t);
            assert!((emp - ana).abs() < 0.01, "t={t}: emp={emp} ana={ana}");
        }
        // Monotone + bounded, like every CDF here.
        let mut prev = 0.0;
        for i in 0..120 {
            let c = d.cdf(i as f64 * 0.5);
            assert!((0.0..=1.0).contains(&c) && c >= prev - 1e-9);
            prev = c;
        }
    }

    #[test]
    fn shifted_exp_resolve_reproduces_linkdelay_bits() {
        // The ShiftedExp kind must sample exactly like the legacy
        // compile path (same RNG consumption, same arithmetic).
        let p = LinkParams::new(2.0, 0.25, 4.0);
        let legacy = LinkDelay::new(&p, 10.0, 1.0, 1.0);
        let fam = FamilyKind::ShiftedExp.resolve(p.a, p.u, &[]);
        let via_family = LinkDelay::with_family(&p, &fam, 10.0, 1.0, 1.0);
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        for _ in 0..1000 {
            // k = 1: a·l/k vs (a)·(l/k) agree exactly, so even the
            // scaled() path is bit-equal here.
            assert_eq!(legacy.sample(&mut r1), via_family.sample(&mut r2));
        }
    }

    #[test]
    fn family_kind_validation() {
        assert!(FamilyKind::Weibull { shape: 0.0 }.validate(0).is_err());
        // Shapes below the Γ-overflow bound are rejected, not NaN'd.
        assert!(FamilyKind::Weibull { shape: 0.005 }.validate(0).is_err());
        assert!(FamilyKind::Weibull { shape: f64::NAN }.validate(0).is_err());
        assert!(FamilyKind::Pareto { alpha: 1.0 }.validate(0).is_err());
        assert!(FamilyKind::Pareto { alpha: 0.5 }.validate(0).is_err());
        assert!(FamilyKind::Bimodal {
            prob: 1.5,
            slow: 2.0
        }
        .validate(0)
        .is_err());
        assert!(FamilyKind::Bimodal {
            prob: 0.5,
            slow: 0.5
        }
        .validate(0)
        .is_err());
        assert!(FamilyKind::Trace { id: 0 }.validate(0).is_err());
        assert!(FamilyKind::Trace { id: 0 }.validate(1).is_ok());
        assert!(FamilyKind::Weibull { shape: 0.6 }.validate(0).is_ok());
    }

    #[test]
    fn family_kind_json_roundtrip() {
        for kind in all_kinds() {
            let back = FamilyKind::from_json(&kind.to_json()).unwrap();
            assert_eq!(back, kind);
        }
        assert!(FamilyKind::from_json(&Json::obj()).is_err());
        let bad = crate::util::json::parse(r#"{"kind": "cauchy"}"#).unwrap();
        assert!(FamilyKind::from_json(&bad).is_err());
        let bad = crate::util::json::parse(r#"{"kind": "pareto", "alpha": 0.5}"#).unwrap();
        assert!(FamilyKind::from_json(&bad).is_err());
    }

    #[test]
    fn trace_dist_json_roundtrip_and_validation() {
        let t = TraceDist::from_samples("t2", vec![3.0, 1.0, 2.0, 2.0]).unwrap();
        let back = TraceDist::from_json(&t.to_json()).unwrap();
        assert_eq!(back.name(), "t2");
        assert_eq!(back.mean(), t.mean());
        assert_eq!(back.ecdf().sorted_samples(), t.ecdf().sorted_samples());
        assert!(TraceDist::from_samples("x", vec![1.0]).is_err());
        assert!(TraceDist::from_samples("x", vec![1.0, f64::NAN]).is_err());
        assert!(TraceDist::from_samples("x", vec![1.0, -2.0]).is_err());
    }

    #[test]
    fn empirical_family_redraws_the_trace() {
        // Inverse-transform sampling over the ECDF reproduces the trace
        // distribution (sup distance of a 40k redraw vs the source).
        let traces = toy_traces();
        let fam = FamilyKind::Trace { id: 0 }.resolve(0.0, 1.0, &traces);
        let mut rng = Rng::new(0xECDF);
        let redraw: Vec<f64> = (0..40_000).map(|_| fam.sample(&mut rng)).collect();
        let d = traces[0].ecdf().sup_distance(&Ecdf::new(redraw));
        assert!(d < 0.02, "sup distance {d}");
    }
}
