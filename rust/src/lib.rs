//! # coded-coop
//!
//! Production reproduction of **"Coded Computation across Shared
//! Heterogeneous Workers with Communication Delay"** (Sun, Zhang, Zhao,
//! Zhou, Niu, Gündüz — IEEE Trans. Signal Processing 2022).
//!
//! The crate is the L3 (run-time) layer of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): tiled coded
//!   mat-vec and MDS encode, lowered with `interpret=True`.
//! * **L2** — JAX compute graph (`python/compile/model.py`), AOT-lowered to
//!   HLO text artifacts by `python/compile/aot.py` (build time only).
//! * **L3** — this crate: the paper's worker-assignment / load-allocation /
//!   resource-allocation algorithms, the multi-master coordinator runtime,
//!   the Monte-Carlo delay simulator and the figure-reproduction harness.
//!   Artifacts are executed through the PJRT CPU client ([`runtime`]);
//!   python never runs on the request path.
//!
//! See `DESIGN.md` (repository root) for the full architecture — the
//! trait/registry/executor seams, the module → paper-section table and
//! the documented environment substitutions.
//!
//! ## Crate map
//!
//! | module | contents |
//! |---|---|
//! | [`util`] | offline-environment substrates: PRNG, stats, Lambert W₋₁, JSON, property-test + bench harnesses |
//! | [`model`] | the paper's delay model: eqs. (1)–(5) CDFs, means, samplers |
//! | [`config`] | scenario definitions (§V settings) + JSON config system |
//! | [`coding`] | real-valued systematic MDS code + dense LU solver |
//! | [`alloc`] | load allocation: Thm 1 (Markov), Thm 2 (Lambert), Thm 3 (fractional KKT), Alg. 3 (SCA) |
//! | [`assign`] | worker assignment: Alg. 1 (iterated greedy), Alg. 2 (simple greedy), Alg. 4 (fractional), λ-sweep optimum, uniform benchmarks |
//! | [`policy`] | OPEN strategy API: `Assigner`/`LoadAllocator` traits, string-keyed registry, serializable `PolicySpec` |
//! | [`plan`] | strategy pair → `Plan` (assignment + allocation) pipeline; schema-versioned plan JSON |
//! | [`sim`] | Monte-Carlo completion-delay engine (multi-threaded), incl. time-varying-share capacity profiles |
//! | [`exec`] | unified `Executor` seam over [`sim`] and [`coordinator`]; shared-pool `BatchRunner` for cell grids |
//! | [`serve`] | online serving: job arrivals + worker churn, plan cache with warm-started replanning, per-job sojourn records |
//! | [`experiment`] | declarative sweeps: schema-versioned `SweepSpec` (axes × policies + serving arrivals), figure catalog, batched `run_sweep` |
//! | [`traces`] | EC2-style instance profiles + shifted-exponential fitting (Fig. 7) |
//! | [`figures`] | regenerates every figure of §V (Figs. 2–8) |
//! | [`runtime`] | PJRT bridge: artifact manifest, executable cache, typed execute |
//! | [`coordinator`] | the real multi-master / shared-worker runtime (threads, delay-injected channels, decode, cancellation) |
//! | [`net`] | socket-mode execution: length-prefixed framed codec over `std::net` TCP, wire `Message` enum, worker server, coordinator transport seam |
//! | [`health`] | observed worker health: heartbeat tracker, fault-injection `FaultPlan`, circuit breaker, re-queue events, serve churn synthesis |
//! | [`cli`] | argument parsing + subcommands for the `coded-coop` binary |

pub mod util;
pub mod model;
pub mod config;
pub mod coding;
pub mod alloc;
pub mod assign;
pub mod policy;
pub mod plan;
pub mod sim;
pub mod exec;
pub mod serve;
pub mod experiment;
pub mod traces;
pub mod figures;
pub mod runtime;
pub mod coordinator;
pub mod net;
pub mod health;
pub mod cli;

/// Crate version, surfaced by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
