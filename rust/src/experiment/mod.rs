//! Declarative, batched experiment layer.
//!
//! The paper's whole evaluation (§V, Figs. 2–8) is parameter sweeps:
//! scenario knobs × policy rosters, each cell a Monte-Carlo run. This
//! module turns that shape into data:
//!
//! ```text
//! SweepSpec ──expand()──▶ [Cell] ──plans──▶ [BatchJob] ──BatchRunner──▶ SweepResult
//! ```
//!
//! * [`SweepSpec`] ([`spec`]) — schema-versioned, serializable: a
//!   [`ScenarioSpec`] template, named [`Axis`]es over scenario/plan
//!   parameters and a [`PolicySpec`] roster;
//! * [`catalog`] — every figure/ablation of the paper as a named spec
//!   (`coded-coop sweep export --figure fig6`);
//! * [`run_sweep`] — expands, plans and evaluates the grid on the shared
//!   thread pool of [`crate::exec::BatchRunner`]; per cell the result is
//!   bit-identical to a serial `sim::run` at `cell_streams` threads,
//!   which is what makes the figure rewrites golden-parity testable.
//!
//! Common random numbers (`SweepSpec::crn`, default on — the legacy
//! figure loops shared one MC seed across a roster) make cross-policy
//! deltas variance-reduced; switch off for independent replications.

pub mod catalog;
pub mod spec;

pub use spec::{ArrivalSpec, Axis, Cell, ScenarioSpec, SweepSpec, KNOWN_PARAMS, MAX_CELLS, MAX_SEED};

use crate::exec::{pool, BatchJob, BatchRunner, Outcome};
use crate::health::FaultPlan;
use crate::plan::Plan;
use crate::policy::PolicySpec;
use crate::serve::{self, JobRecord, ServeConfig};
use crate::util::json::Json;
use crate::util::stats::percentile;
use crate::util::table::Table;

/// Execution knobs for [`run_sweep`] (everything statistical lives in the
/// spec so results are reproducible from the JSON alone).
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepOptions {
    /// Worker threads of the shared pool (0 = all cores).
    pub threads: usize,
    /// RNG streams per cell (`McOptions::threads` semantics; 0 = all
    /// cores). Pin it to reproduce a serial `sim::run` split exactly.
    pub cell_streams: usize,
    /// Compile the whole grid into one fused column arena (kernel v3)
    /// instead of one compile per cell. Bit-for-bit the same results for
    /// every sample order; batch sweeps only (ignored by serving specs).
    pub fused: bool,
}

/// One evaluated grid cell.
pub struct CellResult {
    pub index: usize,
    /// `(param, value)` pairs of this grid point, axis order.
    pub axis_values: Vec<(String, f64)>,
    pub policy: PolicySpec,
    /// Plan-load rescale applied (from an `overhead` axis).
    pub overhead: Option<f64>,
    /// The plan the cell actually ran (post-overhead rescale; for
    /// serving cells, the initial-fleet plan).
    pub plan: Plan,
    pub outcome: Outcome,
    /// Per-job records (serving cells only; empty on batch cells). When
    /// the arrival spec set a `record_cap`, only the LAST that many jobs
    /// are retained — the counters below still cover every job.
    pub records: Vec<JobRecord>,
    /// p99 sojourn from the serving layer's bounded-memory sketch
    /// (serving cells; `None` on batch cells, whose tail readout comes
    /// from kept samples).
    pub p99_ms: Option<f64>,
    /// Jobs served (serving cells; 0 on batch cells). Independent of the
    /// record cap.
    pub jobs: usize,
    /// Jobs that starved (`feasible: false`), cap-independent.
    pub starved_jobs: usize,
}

impl CellResult {
    /// Value of one axis parameter at this cell.
    pub fn axis(&self, param: &str) -> Option<f64> {
        self.axis_values
            .iter()
            .find(|(k, _)| k == param)
            .map(|&(_, v)| v)
    }
}

/// All cells of one sweep, in grid order.
pub struct SweepResult {
    pub name: String,
    pub trials: usize,
    pub cells: Vec<CellResult>,
}

impl SweepResult {
    /// Structured export: one record per cell (axes, policy, outcome).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", Json::Num(SweepSpec::SCHEMA as f64));
        j.set("name", Json::Str(self.name.clone()));
        j.set("trials", Json::Num(self.trials as f64));
        j.set(
            "cells",
            Json::Arr(
                self.cells
                    .iter()
                    .map(|c| {
                        let mut o = c.outcome.to_json();
                        let mut ax = Json::obj();
                        for (k, v) in &c.axis_values {
                            ax.set(k, Json::Num(*v));
                        }
                        o.set("axes", ax);
                        o.set("policy", c.policy.to_json());
                        if let Some(b) = c.overhead {
                            o.set("overhead", Json::Num(b));
                        }
                        // Tail readout: serving cells carry a sketch
                        // p99 computed once at cell time; batch cells
                        // fall back to the exact percentile over kept
                        // samples (when any).
                        if let Some(p99) = c.p99_ms.or_else(|| {
                            c.outcome.samples.as_deref().and_then(|xs| percentile(xs, 0.99))
                        }) {
                            o.set("p99_ms", Json::Num(p99));
                        }
                        if c.jobs > 0 {
                            o.set("jobs", Json::Num(c.jobs as f64));
                            o.set("starved_jobs", Json::Num(c.starved_jobs as f64));
                        }
                        o
                    })
                    .collect(),
            ),
        );
        j
    }

    /// Per-cell text table for the CLI.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "cell",
            "axes",
            "policy",
            "mean delay (ms)",
            "±sem",
            "planner t* (ms)",
        ]);
        for c in &self.cells {
            let axes = c
                .axis_values
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" ");
            t.row(&[
                format!("{}", c.index),
                axes,
                c.outcome.label.clone(),
                format!("{:.3}", c.outcome.system.mean()),
                format!("{:.3}", c.outcome.system.sem()),
                format!("{:.3}", c.outcome.t_est_ms),
            ]);
        }
        t
    }
}

/// Expand `spec`, build every cell's plan, and evaluate the whole grid on
/// one shared thread pool. Serving specs (an `arrivals` block present)
/// route to the online serving layer instead — each cell becomes a job
/// stream and the outcome is the sojourn distribution.
pub fn run_sweep(spec: &SweepSpec, opts: &SweepOptions) -> anyhow::Result<SweepResult> {
    if spec.arrivals.is_some() {
        return run_serving_pooled(spec, opts.threads);
    }
    let cells = spec.expand()?;
    let mut jobs = Vec::with_capacity(cells.len());
    for c in &cells {
        let mut plan = c
            .policy
            .build(&c.scenario)
            .map_err(|e| anyhow::anyhow!("cell {}: {e}", c.index))?;
        if let Some(beta) = c.overhead {
            plan = plan.with_overhead(beta);
        }
        jobs.push(BatchJob {
            scenario: c.scenario.clone(),
            plan,
            seed: c.seed,
            trials: spec.trials,
            keep_samples: spec.keep_samples,
            order: spec.sample_order,
            ziggurat: spec.ziggurat,
        });
    }
    let runner = BatchRunner {
        pool_threads: opts.threads,
        cell_streams: opts.cell_streams,
        fused: opts.fused,
    };
    let outcomes = runner.run(&jobs)?;
    let mut results = Vec::with_capacity(cells.len());
    for ((cell, job), outcome) in cells.into_iter().zip(jobs).zip(outcomes) {
        results.push(CellResult {
            index: cell.index,
            axis_values: cell.axis_values,
            policy: cell.policy,
            overhead: cell.overhead,
            plan: job.plan,
            outcome,
            records: Vec::new(),
            p99_ms: None,
            jobs: 0,
            starved_jobs: 0,
        });
    }
    Ok(SweepResult {
        name: spec.name.clone(),
        trials: spec.trials,
        cells: results,
    })
}

/// Run a serving sweep cell-by-cell (sequential and deterministic),
/// invoking `on_cell` as each cell finishes — the CLI streams per-job
/// JSON records through this hook. Every cell's [`Outcome`] summarizes
/// the **sojourn** (arrival → completion) distribution over feasible
/// jobs; starved jobs surface in `records` (`feasible: false`) and the
/// `starved_jobs` export field. `run_sweep` routes serving specs through
/// the pooled variant instead (no callback ⇒ cells may run concurrently).
pub fn run_serving_with<F: FnMut(&CellResult)>(
    spec: &SweepSpec,
    mut on_cell: F,
) -> anyhow::Result<SweepResult> {
    anyhow::ensure!(
        spec.arrivals.is_some(),
        "sweep spec '{}' has no 'arrivals' block (use run_sweep for batch specs)",
        spec.name
    );
    let cells = spec.expand()?;
    let mut results = Vec::with_capacity(cells.len());
    for cell in cells {
        let cr = serve_cell(spec, cell)?;
        on_cell(&cr);
        results.push(cr);
    }
    Ok(SweepResult {
        name: spec.name.clone(),
        trials: spec.trials,
        cells: results,
    })
}

/// Serving-grid execution for [`run_sweep`]: independent, deterministic
/// cells evaluated concurrently on the shared process pool. `threads ==
/// 1` forces a serial run; other explicit widths degrade to the shared
/// pool (values never change — cells are self-contained — only wall
/// time does). Per-cell streaming callers use [`run_serving_with`].
fn run_serving_pooled(spec: &SweepSpec, threads: usize) -> anyhow::Result<SweepResult> {
    anyhow::ensure!(
        spec.arrivals.is_some(),
        "sweep spec '{}' has no 'arrivals' block",
        spec.name
    );
    let cells = spec.expand()?;
    let outs: Vec<anyhow::Result<CellResult>> = if threads == 1 || cells.len() <= 1 {
        cells.into_iter().map(|c| serve_cell(spec, c)).collect()
    } else {
        pool::run_all(
            cells
                .into_iter()
                .map(|cell| {
                    let spec = spec.clone();
                    move || serve_cell(&spec, cell)
                })
                .collect(),
        )
    };
    let mut results = Vec::with_capacity(outs.len());
    for r in outs {
        results.push(r?);
    }
    Ok(SweepResult {
        name: spec.name.clone(),
        trials: spec.trials,
        cells: results,
    })
}

/// Evaluate one serving cell: job stream in, [`CellResult`] out.
fn serve_cell(spec: &SweepSpec, cell: Cell) -> anyhow::Result<CellResult> {
    let arr = cell
        .arrivals
        .clone()
        .expect("serving cells carry an arrival spec");
    let cfg = ServeConfig {
        policy: cell.policy.clone(),
        process: arr.process,
        load_factor: arr.load_factor,
        jobs: arr.jobs,
        script: None,
        // A fault_rate axis swaps the rate-based churn cycle for a
        // health-derived timeline: deterministic per-cell faults, churn
        // events where detection would fire.
        faults: FaultPlan::synthesize(cell.scenario.n_workers(), arr.fault_rate, cell.seed),
        churn_rate: arr.churn_rate,
        churn_downtime: arr.churn_downtime,
        seed: cell.seed,
        use_cache: true,
        warm_start: true,
        queue: Default::default(),
        record_cap: arr.record_cap,
        streams: Default::default(),
    };
    let out = serve::run(&cell.scenario, &cfg)
        .map_err(|e| anyhow::anyhow!("serving cell {}: {e}", cell.index))?;
    let samples = spec.keep_samples.then(|| out.sojourn_samples());
    // Sojourn summaries cover feasible jobs only (one starved job
    // must not poison the mean) — but a summary that saw NO job at
    // all because EVERY job starved would read as a feasible 0 ms
    // cell in the export. Mark that case with an explicit ∞ so
    // `Outcome::to_json` emits null + `"feasible": false`.
    let starved_out = |sm: &crate::util::stats::Summary, had_jobs: bool| {
        let mut sm = sm.clone();
        if had_jobs && sm.count() == 0 {
            sm.push(f64::INFINITY);
        }
        sm
    };
    let per_master: Vec<_> = out
        .per_master
        .iter()
        .enumerate()
        // Traffic detection reads the cap-independent job counters, not
        // the (possibly ring-truncated) records.
        .map(|(m, sm)| starved_out(sm, out.per_master_jobs[m] > 0))
        .collect();
    let system = starved_out(&out.system, out.jobs > 0);
    let cr = CellResult {
        index: cell.index,
        axis_values: cell.axis_values,
        policy: cell.policy,
        overhead: None,
        plan: out.cold_plan.clone(),
        outcome: Outcome {
            label: out.label.clone(),
            executor: "serve".to_string(),
            per_master,
            system,
            t_est_ms: out.t_est_ms,
            samples,
        },
        p99_ms: out.p99_ms(),
        jobs: out.jobs,
        starved_jobs: out.infeasible,
        records: out.records,
    };
    Ok(cr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::CommModel;
    use crate::sim::{self, McOptions};

    fn two_policy_spec() -> SweepSpec {
        SweepSpec {
            trials: 1_000,
            seed: 77,
            ..SweepSpec::new(
                "test-sweep",
                ScenarioSpec::base("small", 3, CommModel::Stochastic),
                vec![
                    PolicySpec::new("uncoded", ValueModel::Markov, "markov"),
                    PolicySpec::new("dedi-iter", ValueModel::Markov, "markov"),
                ],
            )
        }
    }

    #[test]
    fn sweep_cells_match_serial_sim_run() {
        let spec = two_policy_spec();
        let opts = SweepOptions {
            threads: 2,
            cell_streams: 2,
            fused: false,
        };
        let result = run_sweep(&spec, &opts).unwrap();
        assert_eq!(result.cells.len(), 2);
        let s = spec.scenario.build().unwrap();
        for c in &result.cells {
            let direct = sim::run(
                &s,
                &c.plan,
                &McOptions {
                    trials: spec.trials,
                    seed: spec.seed,
                    keep_samples: false,
                    threads: 2,
                    ziggurat: false,
                },
            );
            assert_eq!(c.outcome.system.mean(), direct.system.mean(), "{}", c.index);
        }
    }

    #[test]
    fn overhead_axis_rescales_the_cell_plan() {
        let mut spec = two_policy_spec();
        spec.policies = vec![PolicySpec::new("dedi-iter", ValueModel::Markov, "markov")];
        spec.axes
            .push(Axis::single("overhead", &[1.2, 2.5]));
        let result = run_sweep(&spec, &SweepOptions::default()).unwrap();
        assert_eq!(result.cells.len(), 2);
        for (c, want) in result.cells.iter().zip([1.2, 2.5]) {
            assert_eq!(c.overhead, Some(want));
            assert_eq!(c.axis("overhead"), Some(want));
            for mp in &c.plan.masters {
                assert!((mp.total_load() / mp.l_rows - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn crn_reduces_comparison_variance_vs_independent_seeds() {
        // The point of common random numbers: the paired delta between
        // two policies on the SAME draws has (much) lower variance than
        // with independent streams. Compare the spread of per-shard
        // deltas... cheap proxy: CRN deltas across two repeat runs are
        // identical, independent-seed deltas are not.
        let mut spec = two_policy_spec();
        spec.crn = true;
        let a = run_sweep(&spec, &SweepOptions::default()).unwrap();
        let b = run_sweep(&spec, &SweepOptions::default()).unwrap();
        let delta =
            |r: &SweepResult| r.cells[1].outcome.system.mean() - r.cells[0].outcome.system.mean();
        assert_eq!(delta(&a), delta(&b), "CRN must be reproducible");
        // Under CRN both cells share the delay draws; with independent
        // seeds the cells' sample streams differ.
        spec.crn = false;
        let c = run_sweep(&spec, &SweepOptions::default()).unwrap();
        assert_ne!(
            c.cells[0].outcome.system.mean(),
            a.cells[0].outcome.system.mean(),
            "independent seeds must change the draws"
        );
    }

    #[test]
    fn blocked_sample_order_flows_through_the_sweep() {
        let mut spec = two_policy_spec();
        spec.trials = 2_000;
        spec.sample_order = crate::sim::SampleOrder::Blocked;
        let blocked = run_sweep(&spec, &SweepOptions::default()).unwrap();
        spec.sample_order = crate::sim::SampleOrder::TrialMajor;
        let tm = run_sweep(&spec, &SweepOptions::default()).unwrap();
        for (b, t) in blocked.cells.iter().zip(&tm.cells) {
            // Different bits (the blocked contract) ...
            assert_ne!(b.outcome.system.mean(), t.outcome.system.mean());
            // ... same distribution (loose sanity bound; the tight
            // statistical-equivalence tests live in sim::engine).
            let rel = (b.outcome.system.mean() - t.outcome.system.mean()).abs()
                / t.outcome.system.mean();
            assert!(rel < 0.1, "blocked vs trial-major means diverge: {rel}");
        }
    }

    #[test]
    fn serving_sweep_runs_deterministically_over_the_grid() {
        let mut spec = two_policy_spec();
        spec.keep_samples = true;
        spec.arrivals = Some(ArrivalSpec {
            jobs: 15,
            churn_rate: 0.0,
            ..Default::default()
        });
        spec.axes.push(Axis::single("load_factor", &[0.5, 4.0]));
        let mut streamed = 0usize;
        let a = run_serving_with(&spec, |c| {
            assert_eq!(c.outcome.executor, "serve");
            streamed += c.records.len();
        })
        .unwrap();
        assert_eq!(a.cells.len(), 4);
        // M = 2 masters × 15 jobs per cell.
        assert_eq!(streamed, 4 * 30);
        // run_sweep routes serving specs here automatically.
        let b = run_sweep(&spec, &SweepOptions::default()).unwrap();
        for (x, y) in a.cells.iter().zip(&b.cells) {
            assert_eq!(x.outcome.system.mean(), y.outcome.system.mean());
            assert_eq!(x.records, y.records);
            assert_eq!(x.outcome.samples, y.outcome.samples);
        }
        // Deep overload queues far more than underload (same policy
        // column; queueing delay dominates the draw-order noise).
        for pol in 0..2 {
            let low = &a.cells[pol];
            let high = &a.cells[2 + pol];
            assert!(
                high.outcome.system.mean() >= low.outcome.system.mean(),
                "policy {pol}: 8× overload sojourn below 0.5× underload"
            );
        }
        // Export carries the serving extras.
        let j = a.to_json();
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells[0].get("jobs").and_then(Json::as_usize), Some(30));
        assert_eq!(cells[0].get("starved_jobs").and_then(Json::as_usize), Some(0));
        assert!(cells[0].get("p99_ms").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(
            cells[0].get("executor").and_then(Json::as_str),
            Some("serve")
        );
    }

    #[test]
    fn result_json_exports_cells() {
        let result = run_sweep(&two_policy_spec(), &SweepOptions::default()).unwrap();
        let j = result.to_json();
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        let cells = back.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells[0]
            .get("mean_system_delay_ms")
            .unwrap()
            .as_f64()
            .unwrap()
            .is_finite());
        assert_eq!(
            cells[1].at(&["policy", "policy"]).unwrap().as_str(),
            Some("dedi-iter")
        );
        // table renders one row per cell
        assert_eq!(result.table().n_rows(), 2);
    }
}
