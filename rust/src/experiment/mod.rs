//! Declarative, batched experiment layer.
//!
//! The paper's whole evaluation (§V, Figs. 2–8) is parameter sweeps:
//! scenario knobs × policy rosters, each cell a Monte-Carlo run. This
//! module turns that shape into data:
//!
//! ```text
//! SweepSpec ──expand()──▶ [Cell] ──plans──▶ [BatchJob] ──BatchRunner──▶ SweepResult
//! ```
//!
//! * [`SweepSpec`] ([`spec`]) — schema-versioned, serializable: a
//!   [`ScenarioSpec`] template, named [`Axis`]es over scenario/plan
//!   parameters and a [`PolicySpec`] roster;
//! * [`catalog`] — every figure/ablation of the paper as a named spec
//!   (`coded-coop sweep export --figure fig6`);
//! * [`run_sweep`] — expands, plans and evaluates the grid on the shared
//!   thread pool of [`crate::exec::BatchRunner`]; per cell the result is
//!   bit-identical to a serial `sim::run` at `cell_streams` threads,
//!   which is what makes the figure rewrites golden-parity testable.
//!
//! Common random numbers (`SweepSpec::crn`, default on — the legacy
//! figure loops shared one MC seed across a roster) make cross-policy
//! deltas variance-reduced; switch off for independent replications.

pub mod catalog;
pub mod spec;

pub use spec::{Axis, Cell, ScenarioSpec, SweepSpec, KNOWN_PARAMS, MAX_CELLS, MAX_SEED};

use crate::exec::{BatchJob, BatchRunner, Outcome};
use crate::plan::Plan;
use crate::policy::PolicySpec;
use crate::util::json::Json;
use crate::util::table::Table;

/// Execution knobs for [`run_sweep`] (everything statistical lives in the
/// spec so results are reproducible from the JSON alone).
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepOptions {
    /// Worker threads of the shared pool (0 = all cores).
    pub threads: usize,
    /// RNG streams per cell (`McOptions::threads` semantics; 0 = all
    /// cores). Pin it to reproduce a serial `sim::run` split exactly.
    pub cell_streams: usize,
}

/// One evaluated grid cell.
pub struct CellResult {
    pub index: usize,
    /// `(param, value)` pairs of this grid point, axis order.
    pub axis_values: Vec<(String, f64)>,
    pub policy: PolicySpec,
    /// Plan-load rescale applied (from an `overhead` axis).
    pub overhead: Option<f64>,
    /// The plan the cell actually ran (post-overhead rescale).
    pub plan: Plan,
    pub outcome: Outcome,
}

impl CellResult {
    /// Value of one axis parameter at this cell.
    pub fn axis(&self, param: &str) -> Option<f64> {
        self.axis_values
            .iter()
            .find(|(k, _)| k == param)
            .map(|&(_, v)| v)
    }
}

/// All cells of one sweep, in grid order.
pub struct SweepResult {
    pub name: String,
    pub trials: usize,
    pub cells: Vec<CellResult>,
}

impl SweepResult {
    /// Structured export: one record per cell (axes, policy, outcome).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", Json::Num(SweepSpec::SCHEMA as f64));
        j.set("name", Json::Str(self.name.clone()));
        j.set("trials", Json::Num(self.trials as f64));
        j.set(
            "cells",
            Json::Arr(
                self.cells
                    .iter()
                    .map(|c| {
                        let mut o = c.outcome.to_json();
                        let mut ax = Json::obj();
                        for (k, v) in &c.axis_values {
                            ax.set(k, Json::Num(*v));
                        }
                        o.set("axes", ax);
                        o.set("policy", c.policy.to_json());
                        if let Some(b) = c.overhead {
                            o.set("overhead", Json::Num(b));
                        }
                        o
                    })
                    .collect(),
            ),
        );
        j
    }

    /// Per-cell text table for the CLI.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "cell",
            "axes",
            "policy",
            "mean delay (ms)",
            "±sem",
            "planner t* (ms)",
        ]);
        for c in &self.cells {
            let axes = c
                .axis_values
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" ");
            t.row(&[
                format!("{}", c.index),
                axes,
                c.outcome.label.clone(),
                format!("{:.3}", c.outcome.system.mean()),
                format!("{:.3}", c.outcome.system.sem()),
                format!("{:.3}", c.outcome.t_est_ms),
            ]);
        }
        t
    }
}

/// Expand `spec`, build every cell's plan, and evaluate the whole grid on
/// one shared thread pool.
pub fn run_sweep(spec: &SweepSpec, opts: &SweepOptions) -> anyhow::Result<SweepResult> {
    let cells = spec.expand()?;
    let mut jobs = Vec::with_capacity(cells.len());
    for c in &cells {
        let mut plan = c
            .policy
            .build(&c.scenario)
            .map_err(|e| anyhow::anyhow!("cell {}: {e}", c.index))?;
        if let Some(beta) = c.overhead {
            plan = plan.with_overhead(beta);
        }
        jobs.push(BatchJob {
            scenario: c.scenario.clone(),
            plan,
            seed: c.seed,
            trials: spec.trials,
            keep_samples: spec.keep_samples,
            order: spec.sample_order,
        });
    }
    let runner = BatchRunner {
        pool_threads: opts.threads,
        cell_streams: opts.cell_streams,
    };
    let outcomes = runner.run(&jobs)?;
    let mut results = Vec::with_capacity(cells.len());
    for ((cell, job), outcome) in cells.into_iter().zip(jobs).zip(outcomes) {
        results.push(CellResult {
            index: cell.index,
            axis_values: cell.axis_values,
            policy: cell.policy,
            overhead: cell.overhead,
            plan: job.plan,
            outcome,
        });
    }
    Ok(SweepResult {
        name: spec.name.clone(),
        trials: spec.trials,
        cells: results,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::CommModel;
    use crate::sim::{self, McOptions};

    fn two_policy_spec() -> SweepSpec {
        SweepSpec {
            trials: 1_000,
            seed: 77,
            ..SweepSpec::new(
                "test-sweep",
                ScenarioSpec::base("small", 3, CommModel::Stochastic),
                vec![
                    PolicySpec::new("uncoded", ValueModel::Markov, "markov"),
                    PolicySpec::new("dedi-iter", ValueModel::Markov, "markov"),
                ],
            )
        }
    }

    #[test]
    fn sweep_cells_match_serial_sim_run() {
        let spec = two_policy_spec();
        let opts = SweepOptions {
            threads: 2,
            cell_streams: 2,
        };
        let result = run_sweep(&spec, &opts).unwrap();
        assert_eq!(result.cells.len(), 2);
        let s = spec.scenario.build().unwrap();
        for c in &result.cells {
            let direct = sim::run(
                &s,
                &c.plan,
                &McOptions {
                    trials: spec.trials,
                    seed: spec.seed,
                    keep_samples: false,
                    threads: 2,
                },
            );
            assert_eq!(c.outcome.system.mean(), direct.system.mean(), "{}", c.index);
        }
    }

    #[test]
    fn overhead_axis_rescales_the_cell_plan() {
        let mut spec = two_policy_spec();
        spec.policies = vec![PolicySpec::new("dedi-iter", ValueModel::Markov, "markov")];
        spec.axes
            .push(Axis::single("overhead", &[1.2, 2.5]));
        let result = run_sweep(&spec, &SweepOptions::default()).unwrap();
        assert_eq!(result.cells.len(), 2);
        for (c, want) in result.cells.iter().zip([1.2, 2.5]) {
            assert_eq!(c.overhead, Some(want));
            assert_eq!(c.axis("overhead"), Some(want));
            for mp in &c.plan.masters {
                assert!((mp.total_load() / mp.l_rows - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn crn_reduces_comparison_variance_vs_independent_seeds() {
        // The point of common random numbers: the paired delta between
        // two policies on the SAME draws has (much) lower variance than
        // with independent streams. Compare the spread of per-shard
        // deltas... cheap proxy: CRN deltas across two repeat runs are
        // identical, independent-seed deltas are not.
        let mut spec = two_policy_spec();
        spec.crn = true;
        let a = run_sweep(&spec, &SweepOptions::default()).unwrap();
        let b = run_sweep(&spec, &SweepOptions::default()).unwrap();
        let delta =
            |r: &SweepResult| r.cells[1].outcome.system.mean() - r.cells[0].outcome.system.mean();
        assert_eq!(delta(&a), delta(&b), "CRN must be reproducible");
        // Under CRN both cells share the delay draws; with independent
        // seeds the cells' sample streams differ.
        spec.crn = false;
        let c = run_sweep(&spec, &SweepOptions::default()).unwrap();
        assert_ne!(
            c.cells[0].outcome.system.mean(),
            a.cells[0].outcome.system.mean(),
            "independent seeds must change the draws"
        );
    }

    #[test]
    fn blocked_sample_order_flows_through_the_sweep() {
        let mut spec = two_policy_spec();
        spec.trials = 2_000;
        spec.sample_order = crate::sim::SampleOrder::Blocked;
        let blocked = run_sweep(&spec, &SweepOptions::default()).unwrap();
        spec.sample_order = crate::sim::SampleOrder::TrialMajor;
        let tm = run_sweep(&spec, &SweepOptions::default()).unwrap();
        for (b, t) in blocked.cells.iter().zip(&tm.cells) {
            // Different bits (the blocked contract) ...
            assert_ne!(b.outcome.system.mean(), t.outcome.system.mean());
            // ... same distribution (loose sanity bound; the tight
            // statistical-equivalence tests live in sim::engine).
            let rel = (b.outcome.system.mean() - t.outcome.system.mean()).abs()
                / t.outcome.system.mean();
            assert!(rel < 0.1, "blocked vs trial-major means diverge: {rel}");
        }
    }

    #[test]
    fn result_json_exports_cells() {
        let result = run_sweep(&two_policy_spec(), &SweepOptions::default()).unwrap();
        let j = result.to_json();
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        let cells = back.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells[0]
            .get("mean_system_delay_ms")
            .unwrap()
            .as_f64()
            .unwrap()
            .is_finite());
        assert_eq!(
            cells[1].at(&["policy", "policy"]).unwrap().as_str(),
            Some("dedi-iter")
        );
        // table renders one row per cell
        assert_eq!(result.table().n_rows(), 2);
    }
}
