//! Figure / ablation index: every plan→simulate evaluation of §V as a
//! named [`SweepSpec`].
//!
//! Each id expands to the exact cells the legacy per-figure loop
//! evaluated — same scenario seeds, same Monte-Carlo seed derivation —
//! so the sweep rewrites of the figure harnesses are golden-parity
//! testable against the serial path (`rust/tests/sweep_parity.rs`).
//!
//! | catalog id | figure | grid |
//! |---|---|---|
//! | `fig2` / `fig3` | Figs. 2–3 | 3 validation variants, samples kept |
//! | `fig4a` / `fig4b` | Fig. 4 | §V-B roster (8 / 7 policies) |
//! | `fig5a` / `fig5b` | Fig. 5 | CDF roster (4 policies), samples kept |
//! | `fig6` | Fig. 6 | γ/u axis × 4 policies (20 cells) |
//! | `fig8_fitted` / `fig8_measured` | Fig. 8 | EC2 roster, ± throttling |
//! | `ablation_redundancy` | ablation | overhead-β axis, samples kept |
//! | `ablation_straggler` | ablation | zipped (prob, slowdown) × 2 policies |
//! | `serving` | — | online serving: load factor × churn rate × 3 policies (sojourn mean/p99) |
//! | `fault_recovery` | — | serving under injected faults: fault rate × 3 policies (health-derived churn) |
//! | `overload` | — | fleet-scale overload: burst arrivals, load factor > 1 × 2 policies, O(1)-memory tails |
//! | `smoke` | — | 2-cell CI smoke grid |
//!
//! Figs. 7 (trace fitting) and the `multimsg` / `sca_step` ablations are
//! not plan→simulate sweeps and stay bespoke.

use crate::assign::ValueModel;
use crate::config::CommModel;
use crate::policy::PolicySpec;
use crate::serve::ArrivalProcess;
use crate::traces::ec2::T2_MICRO_THROTTLE;

use super::spec::{ArrivalSpec, Axis, ScenarioSpec, SweepSpec};

/// All catalog ids, paper order (the `heavy_tail` scenario-gallery
/// sweep goes beyond the paper: a delay-family axis over mean-matched
/// Weibull tails — see DESIGN.md §Delay-model layer).
pub const IDS: &[&str] = &[
    "fig2",
    "fig3",
    "fig4a",
    "fig4b",
    "fig5a",
    "fig5b",
    "fig6",
    "fig8_fitted",
    "fig8_measured",
    "ablation_redundancy",
    "ablation_straggler",
    "heavy_tail",
    "serving",
    "fault_recovery",
    "overload",
    "smoke",
];

/// Load factors of the `serving` sweep: underload, near-capacity, and
/// overload relative to the planner's one-shot estimate.
pub const SERVING_LOAD_FACTORS: &[f64] = &[0.5, 0.9, 1.3];

/// Churn rates of the `serving` sweep (worker leave/rejoin cycles per
/// mean one-shot service): a static fleet and a churning one.
pub const SERVING_CHURN_RATES: &[f64] = &[0.0, 1.0];

/// Fleet fractions hit by injected faults in the `fault_recovery`
/// sweep: clean baseline, a quarter and half of the workers.
pub const FAULT_RECOVERY_RATES: &[f64] = &[0.0, 0.25, 0.5];

/// Load factors of the `overload` sweep — all past saturation, where
/// the queue (not the service draw) dominates the sojourn tail.
pub const OVERLOAD_LOAD_FACTORS: &[f64] = &[1.5, 2.5, 4.0];

/// Per-cell record-ring cap of the `overload` sweep: the sweep's point
/// is tails at fleet scale, so raw records are bounded and the sketch /
/// Welford paths carry the statistics.
pub const OVERLOAD_RECORD_CAP: usize = 256;

/// Weibull shapes of the `heavy_tail` sweep: 1.0 is the exponential
/// tail (the shifted-exp law itself, different sampler bits), smaller
/// shapes are progressively heavier tails at the SAME per-link mean.
pub const HEAVY_TAIL_SHAPES: &[f64] = &[1.0, 0.8, 0.65, 0.5];

/// Figure-harness Monte-Carlo seed derivation: figures decouple the MC
/// stream from the scenario-generation seed (`FigureOptions::mc` uses
/// this same function; ablations historically use the raw seed).
pub fn fig_mc_seed(seed: u64) -> u64 {
    seed ^ 0x5EED
}

/// The §V-B algorithm roster (Fig. 4/5/6/8 legends), by registry name.
/// `small_scale` adds the λ-sweep optimum (M = 2 only). `values`/`loads`
/// configure the proposed algorithms (Markov for the general case,
/// "exact" for computation-dominant scenarios like Fig. 8).
pub fn roster(small_scale: bool, values: ValueModel, loads: &str) -> Vec<PolicySpec> {
    let mut specs = vec![
        PolicySpec::new("uncoded", values, loads),
        PolicySpec::new("coded", values, loads),
        PolicySpec::new("dedi-simple", values, loads),
        PolicySpec::new("dedi-iter", values, loads),
        PolicySpec::new("dedi-iter", values, "sca"),
        PolicySpec::new("frac", values, loads),
        PolicySpec::new("frac", values, "sca"),
    ];
    if small_scale {
        specs.push(PolicySpec::new("optimal", values, "sca"));
    }
    specs
}

/// Figs. 2–3 validation variants with their display names.
pub fn validation_variants() -> Vec<(&'static str, PolicySpec)> {
    vec![
        (
            "Exact (Thm 2)",
            PolicySpec::new("dedi-iter", ValueModel::Exact, "exact"),
        ),
        (
            "Approx (Thm 1)",
            PolicySpec::new("dedi-iter", ValueModel::Markov, "markov"),
        ),
        (
            "Approx, enhanced",
            PolicySpec::new("dedi-iter", ValueModel::Markov, "exact"),
        ),
    ]
}

/// Fig. 5 CDF roster.
pub fn fig5_roster() -> Vec<PolicySpec> {
    let v = ValueModel::Markov;
    vec![
        PolicySpec::new("coded", v, "markov"),
        PolicySpec::new("dedi-iter", v, "markov"),
        PolicySpec::new("dedi-iter", v, "sca"),
        PolicySpec::new("frac", v, "sca"),
    ]
}

/// Fig. 6 sweep roster.
pub fn fig6_roster() -> Vec<PolicySpec> {
    let v = ValueModel::Markov;
    vec![
        PolicySpec::new("uncoded", v, "markov"),
        PolicySpec::new("coded", v, "markov"),
        PolicySpec::new("dedi-iter", v, "markov"),
        PolicySpec::new("frac", v, "markov"),
    ]
}

/// γ/u values swept by Fig. 6 (the paper's x-axis).
pub const FIG6_RATIOS: &[f64] = &[0.5, 1.0, 2.0, 4.0, 8.0];

/// Coding-overhead β values of the redundancy ablation.
pub const REDUNDANCY_BETAS: &[f64] = &[1.05, 1.1, 1.25, 1.5, 2.0, 3.0];

/// `(prob, slowdown)` grid of the straggler ablation (zipped axis — the
/// pairs move together, they are not crossed).
pub const STRAGGLER_POINTS: &[(f64, f64)] = &[
    (0.0, 1.0),
    (0.01, 10.0),
    (0.02, 10.0),
    (0.02, 20.0),
    (0.05, 20.0),
    (0.1, 8.0),
];

/// Resolve a catalog id into its sweep spec for the given trial count and
/// base seed (`seed` seeds the scenarios; the MC seed derivation per id
/// matches the legacy harness that id replaces).
pub fn spec(id: &str, trials: usize, seed: u64) -> anyhow::Result<SweepSpec> {
    anyhow::ensure!(
        seed <= super::spec::MAX_SEED,
        "seed {seed} exceeds the JSON-safe maximum {} (specs must round-trip exactly)",
        super::spec::MAX_SEED
    );
    let sp = match id {
        "fig2" | "fig3" => {
            let base = if id == "fig2" { "small" } else { "large" };
            SweepSpec {
                axes: Vec::new(),
                trials,
                seed: fig_mc_seed(seed),
                crn: true,
                keep_samples: true,
                ..SweepSpec::new(
                    id,
                    ScenarioSpec::base(base, seed, CommModel::CompDominant),
                    validation_variants().into_iter().map(|(_, p)| p).collect(),
                )
            }
        }
        "fig4a" | "fig4b" => {
            let small = id == "fig4a";
            SweepSpec {
                trials,
                seed: fig_mc_seed(seed),
                ..SweepSpec::new(
                    id,
                    ScenarioSpec::base(
                        if small { "small" } else { "large" },
                        seed,
                        CommModel::Stochastic,
                    ),
                    roster(small, ValueModel::Markov, "markov"),
                )
            }
        }
        "fig5a" | "fig5b" => SweepSpec {
            trials,
            seed: fig_mc_seed(seed),
            keep_samples: true,
            ..SweepSpec::new(
                id,
                ScenarioSpec::base(
                    if id == "fig5a" { "small" } else { "large" },
                    seed,
                    CommModel::Stochastic,
                ),
                fig5_roster(),
            )
        },
        "fig6" => SweepSpec {
            axes: vec![Axis::single("gamma_ratio", FIG6_RATIOS)],
            trials,
            seed: fig_mc_seed(seed),
            ..SweepSpec::new(
                id,
                ScenarioSpec::base("large", seed, CommModel::Stochastic),
                fig6_roster(),
            )
        },
        "fig8_fitted" | "fig8_measured" => {
            let mut sc = ScenarioSpec::base("ec2", seed, CommModel::CompDominant);
            if id == "fig8_measured" {
                sc.straggler_prob = T2_MICRO_THROTTLE.0;
                sc.straggler_slow = T2_MICRO_THROTTLE.1;
            }
            SweepSpec {
                trials,
                seed: fig_mc_seed(seed),
                ..SweepSpec::new(id, sc, roster(false, ValueModel::Exact, "exact"))
            }
        }
        "ablation_redundancy" => SweepSpec {
            axes: vec![Axis::single("overhead", REDUNDANCY_BETAS)],
            trials,
            seed, // ablations historically seed the MC stream directly
            keep_samples: true,
            ..SweepSpec::new(
                id,
                ScenarioSpec::base("large", seed, CommModel::Stochastic),
                vec![PolicySpec::new("dedi-iter", ValueModel::Markov, "markov")],
            )
        },
        "ablation_straggler" => SweepSpec {
            axes: vec![Axis::zipped(
                "straggler",
                &["straggler_prob", "straggler_slow"],
                STRAGGLER_POINTS.iter().map(|&(p, s)| vec![p, s]).collect(),
            )],
            trials: trials.min(20_000), // the legacy ablation's cap
            seed,
            ..SweepSpec::new(
                id,
                ScenarioSpec::base("ec2", seed, CommModel::CompDominant),
                vec![
                    PolicySpec::new("uncoded", ValueModel::Exact, "exact"),
                    PolicySpec::new("dedi-iter", ValueModel::Exact, "exact"),
                ],
            )
        },
        "heavy_tail" => SweepSpec {
            axes: vec![Axis::single("weibull_shape", HEAVY_TAIL_SHAPES)],
            trials,
            seed: fig_mc_seed(seed),
            keep_samples: true, // tail readouts want the CDF
            ..SweepSpec::new(
                id,
                ScenarioSpec::base("small", seed, CommModel::Stochastic),
                vec![
                    PolicySpec::new("uncoded", ValueModel::Markov, "markov"),
                    PolicySpec::new("dedi-iter", ValueModel::Markov, "markov"),
                    PolicySpec::new("dedi-iter", ValueModel::Markov, "sca"),
                    PolicySpec::new("frac", ValueModel::Markov, "markov"),
                ],
            )
        },
        // Beyond the paper: the online serving sweep — load factor ×
        // churn rate × policy on the small-scale fleet, per-job sojourn
        // (mean / p99) instead of one-shot delay. `trials` caps the job
        // count per master so `--trials` stays the single cost knob.
        "serving" => SweepSpec {
            axes: vec![
                Axis::single("load_factor", SERVING_LOAD_FACTORS),
                Axis::single("churn_rate", SERVING_CHURN_RATES),
            ],
            trials,
            seed: fig_mc_seed(seed),
            keep_samples: true, // p99 sojourn readout
            arrivals: Some(ArrivalSpec {
                process: ArrivalProcess::Poisson,
                load_factor: 0.8,
                jobs: trials.clamp(1, 400),
                churn_rate: 0.0,
                churn_downtime: 0.5,
                fault_rate: 0.0,
                record_cap: 0,
            }),
            ..SweepSpec::new(
                id,
                ScenarioSpec::base("small", seed, CommModel::Stochastic),
                vec![
                    PolicySpec::new("dedi-iter", ValueModel::Markov, "markov"),
                    PolicySpec::new("dedi-iter", ValueModel::Markov, "sca"),
                    PolicySpec::new("frac", ValueModel::Markov, "markov"),
                ],
            )
        },
        // Beyond the paper: serving resilience under injected faults —
        // each cell synthesizes a deterministic FaultPlan over its
        // fleet fraction and serves through the health-derived churn
        // timeline (crashes leave after the missed-beat window, gray
        // failures after the stall window, throttles recover through
        // breaker probes). Sojourn degradation vs. fault_rate is the
        // readout.
        "fault_recovery" => SweepSpec {
            axes: vec![Axis::single("fault_rate", FAULT_RECOVERY_RATES)],
            trials,
            seed: fig_mc_seed(seed),
            keep_samples: true, // p99 sojourn readout
            arrivals: Some(ArrivalSpec {
                process: ArrivalProcess::Poisson,
                load_factor: 0.8,
                jobs: trials.clamp(1, 400),
                churn_rate: 0.0,
                churn_downtime: 0.5,
                fault_rate: 0.0,
                record_cap: 0,
            }),
            ..SweepSpec::new(
                id,
                ScenarioSpec::base("small", seed, CommModel::Stochastic),
                vec![
                    PolicySpec::new("dedi-iter", ValueModel::Markov, "markov"),
                    PolicySpec::new("dedi-iter", ValueModel::Markov, "sca"),
                    PolicySpec::new("frac", ValueModel::Markov, "markov"),
                ],
            )
        },
        // Beyond the paper: the fleet-scale overload sweep — every load
        // factor past saturation, flash-crowd burst arrivals, and a
        // bounded record ring so cells scale to ≥ 10k jobs at O(1)
        // memory (tails read from the quantile sketches). `--trials` is
        // jobs per master, capped at 20k.
        "overload" => SweepSpec {
            axes: vec![Axis::single("load_factor", OVERLOAD_LOAD_FACTORS)],
            trials,
            seed: fig_mc_seed(seed),
            keep_samples: false, // sketches carry the tail, not samples
            arrivals: Some(ArrivalSpec {
                process: ArrivalProcess::Burst,
                load_factor: OVERLOAD_LOAD_FACTORS[0],
                jobs: trials.clamp(1, 20_000),
                churn_rate: 0.0,
                churn_downtime: 0.5,
                fault_rate: 0.0,
                record_cap: OVERLOAD_RECORD_CAP,
            }),
            ..SweepSpec::new(
                id,
                ScenarioSpec::base("small", seed, CommModel::Stochastic),
                vec![
                    PolicySpec::new("dedi-iter", ValueModel::Markov, "markov"),
                    PolicySpec::new("frac", ValueModel::Markov, "markov"),
                ],
            )
        },
        "smoke" => SweepSpec {
            trials,
            seed: fig_mc_seed(seed),
            ..SweepSpec::new(
                id,
                ScenarioSpec::base("small", seed, CommModel::Stochastic),
                vec![
                    PolicySpec::new("uncoded", ValueModel::Markov, "markov"),
                    PolicySpec::new("dedi-iter", ValueModel::Markov, "markov"),
                ],
            )
        },
        other => anyhow::bail!("unknown catalog sweep '{other}' (known: {})", IDS.join(" ")),
    };
    Ok(sp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_catalog_id_expands() {
        for id in IDS {
            let sp = spec(id, 1_000, 7).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert_eq!(&sp.name, id);
            let cells = sp.expand().unwrap_or_else(|e| panic!("{id}: {e}"));
            assert!(!cells.is_empty(), "{id}");
        }
        assert!(spec("fig99", 100, 1).is_err());
    }

    #[test]
    fn catalog_grid_shapes_match_legacy_loops() {
        assert_eq!(spec("fig2", 100, 1).unwrap().expand().unwrap().len(), 3);
        assert_eq!(spec("fig4a", 100, 1).unwrap().expand().unwrap().len(), 8);
        assert_eq!(spec("fig4b", 100, 1).unwrap().expand().unwrap().len(), 7);
        assert_eq!(spec("fig5a", 100, 1).unwrap().expand().unwrap().len(), 4);
        assert_eq!(spec("fig6", 100, 1).unwrap().expand().unwrap().len(), 20);
        assert_eq!(
            spec("fig8_measured", 100, 1).unwrap().expand().unwrap().len(),
            7
        );
        assert_eq!(
            spec("ablation_redundancy", 100, 1)
                .unwrap()
                .expand()
                .unwrap()
                .len(),
            6
        );
        assert_eq!(
            spec("ablation_straggler", 100, 1)
                .unwrap()
                .expand()
                .unwrap()
                .len(),
            12
        );
        assert_eq!(spec("smoke", 100, 1).unwrap().expand().unwrap().len(), 2);
        // 4 Weibull shapes × 4 policies.
        assert_eq!(spec("heavy_tail", 100, 1).unwrap().expand().unwrap().len(), 16);
        // 3 load factors × 2 churn rates × 3 policies.
        assert_eq!(spec("serving", 100, 1).unwrap().expand().unwrap().len(), 18);
        // 3 fault rates × 3 policies.
        assert_eq!(
            spec("fault_recovery", 100, 1).unwrap().expand().unwrap().len(),
            9
        );
        // 3 overload factors × 2 policies.
        assert_eq!(spec("overload", 100, 1).unwrap().expand().unwrap().len(), 6);
    }

    #[test]
    fn overload_cells_are_past_saturation_with_bounded_records() {
        let sp = spec("overload", 50_000, 7).unwrap();
        assert!(!sp.keep_samples, "overload tails come from sketches");
        let arr = sp.arrivals.as_ref().unwrap();
        assert_eq!(arr.process, ArrivalProcess::Burst);
        assert_eq!(arr.jobs, 20_000, "jobs cap at 20k per master");
        assert_eq!(arr.record_cap, OVERLOAD_RECORD_CAP);
        let cells = sp.expand().unwrap();
        for c in &cells {
            let a = c.arrivals.as_ref().unwrap();
            assert!(a.load_factor > 1.0, "overload cell below saturation");
            assert_eq!(a.process, ArrivalProcess::Burst);
            assert_eq!(a.record_cap, OVERLOAD_RECORD_CAP);
        }
    }

    #[test]
    fn fault_recovery_cells_sweep_the_fault_rate() {
        let cells = spec("fault_recovery", 100, 7).unwrap().expand().unwrap();
        // Policies innermost: cells 0–2 are the clean baseline.
        let rate = |c: &crate::experiment::Cell| c.arrivals.as_ref().unwrap().fault_rate;
        assert_eq!(rate(&cells[0]), 0.0);
        assert_eq!(rate(&cells[3]), 0.25);
        assert_eq!(rate(&cells[8]), 0.5);
        // No rate-based churn riding along.
        assert!(cells.iter().all(|c| c.arrivals.as_ref().unwrap().churn_rate == 0.0));
    }

    #[test]
    fn serving_catalog_cells_carry_arrivals() {
        let sp = spec("serving", 5_000, 7).unwrap();
        assert!(sp.arrivals.is_some());
        assert_eq!(sp.arrivals.as_ref().unwrap().jobs, 400, "jobs cap at 400");
        assert!(sp.keep_samples, "p99 readout needs samples");
        let cells = sp.expand().unwrap();
        // Policies innermost, churn next, load factor outermost.
        let a0 = cells[0].arrivals.as_ref().unwrap();
        assert_eq!(a0.load_factor, 0.5);
        assert_eq!(a0.churn_rate, 0.0);
        let last = cells[17].arrivals.as_ref().unwrap();
        assert_eq!(last.load_factor, 1.3);
        assert_eq!(last.churn_rate, 1.0);
        // Tiny --trials values floor at one job.
        assert_eq!(
            spec("serving", 0, 1).unwrap().arrivals.unwrap().jobs,
            1
        );
    }

    #[test]
    fn heavy_tail_sweep_selects_families_per_cell() {
        use crate::model::dist::FamilyKind;
        let cells = spec("heavy_tail", 100, 7).unwrap().expand().unwrap();
        // Policies innermost: the first 4 cells share shape 1.0.
        assert_eq!(
            cells[0].scenario.link(0, 1).family,
            FamilyKind::Weibull { shape: 1.0 }
        );
        assert_eq!(
            cells[cells.len() - 1].scenario.link(0, 1).family,
            FamilyKind::Weibull { shape: 0.5 }
        );
    }

    #[test]
    fn catalog_specs_roundtrip_through_json() {
        for id in IDS {
            let sp = spec(id, 5_000, 42).unwrap();
            let text = sp.to_json().to_string_pretty();
            let back =
                SweepSpec::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, sp, "{id}");
        }
    }

    #[test]
    fn measured_panel_attaches_t2_throttle_only() {
        let sp = spec("fig8_measured", 100, 1).unwrap();
        let cells = sp.expand().unwrap();
        let s = &cells[0].scenario;
        // first 40 links are t2.micro (throttled), last 10 c5.large (not)
        assert!(s.links[0][0].straggler.is_some());
        assert!(s.links[0][49].straggler.is_none());
        let fitted = spec("fig8_fitted", 100, 1).unwrap().expand().unwrap();
        assert!(fitted[0].scenario.links[0][0].straggler.is_none());
    }
}
