//! Declarative sweep specifications.
//!
//! A [`SweepSpec`] is the schema-versioned description of one evaluation
//! campaign: a base [`ScenarioSpec`], named parameter [`Axis`]es and a
//! policy roster, expanded by [`SweepSpec::expand`] into the cell grid
//! the batched engine ([`crate::exec::BatchRunner`]) evaluates. Specs
//! round-trip through JSON (`coded-coop sweep export` / `sweep run`), so
//! a new workload is a ~20-line JSON file instead of a new harness
//! module.

use crate::config::{AShift, CommModel, Scenario, Transform};
use crate::model::dist::FamilyKind;
use crate::policy::PolicySpec;
use crate::serve::ArrivalProcess;
use crate::sim::SampleOrder;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// Cross-product guard: a spec expanding to more cells than this is
/// almost certainly a typo'd axis; [`SweepSpec::expand`] refuses rather
/// than allocating an absurd grid.
pub const MAX_CELLS: usize = 10_000;

/// Largest seed a spec may carry: seeds serialize as JSON numbers (IEEE
/// doubles, exact only to 2⁵³), and the figure-harness seed derivation
/// xors low bits on top — 2⁵² keeps every derived value exactly
/// round-trippable. Builders reject larger seeds instead of silently
/// rounding them on an export→run round-trip.
pub const MAX_SEED: u64 = 1 << 52;

/// Axis parameter names [`SweepSpec::expand`] understands. All but
/// `overhead`, `load_factor` and `churn_rate` rewrite the
/// [`ScenarioSpec`] (`n_masters` / `n_workers` apply to the `random`
/// base only); `overhead` rescales the built plan via
/// [`crate::plan::Plan::with_overhead`]. The `weibull_shape` /
/// `pareto_alpha` / `bimodal_prob` / `bimodal_slow` params sweep the
/// worker delay family ([`ScenarioSpec::delay_family`]): each point
/// selects a mean-matched family with that parameter, overriding the
/// template's own family (the two bimodal params zip naturally).
/// `load_factor` / `churn_rate` / `fault_rate` rewrite the spec's
/// [`ArrivalSpec`] and are only valid on serving sweeps (specs with an
/// `arrivals` block).
pub const KNOWN_PARAMS: &[&str] = &[
    "seed",
    "gamma_ratio",
    "n_masters",
    "n_workers",
    "l_rows",
    "u_scale",
    "straggler_prob",
    "straggler_slow",
    "weibull_shape",
    "pareto_alpha",
    "bimodal_prob",
    "bimodal_slow",
    "overhead",
    "load_factor",
    "churn_rate",
    "fault_rate",
];

/// Serving-mode template: when a [`SweepSpec`] carries one of these,
/// its cells run on the online serving layer ([`crate::serve`]) instead
/// of the one-shot batch engine — each cell becomes a job stream
/// (arrival process × load factor × synthesized churn) whose outcome is
/// the per-job **sojourn** distribution rather than a one-shot delay.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrivalSpec {
    pub process: ArrivalProcess,
    /// Arrival rate × mean one-shot service (see
    /// [`crate::serve::ServeConfig::load_factor`]).
    pub load_factor: f64,
    /// Jobs per master per cell.
    pub jobs: usize,
    /// Worker leave/rejoin cycles per mean one-shot service (0 = static
    /// fleet; the script is synthesized per cell from the cell seed).
    pub churn_rate: f64,
    /// Fraction of each churn cycle spent away.
    pub churn_downtime: f64,
    /// Fraction of the fleet hit by an injected fault (0 = clean). Each
    /// cell synthesizes a deterministic [`crate::health::FaultPlan`]
    /// from its seed ([`crate::health::FaultPlan::synthesize`]) and
    /// derives the churn timeline from what the health layer would
    /// observe — instead of the rate-based `churn_rate` cycle.
    pub fault_rate: f64,
    /// Retain at most this many per-job records per cell (0 = all) —
    /// [`crate::serve::ServeConfig::record_cap`]. Sojourn summaries and
    /// quantile sketches always cover every job; the cap only bounds the
    /// raw-record ring, which is what lets overload cells run ≥ 10k jobs
    /// at O(1) memory.
    pub record_cap: usize,
}

impl Default for ArrivalSpec {
    fn default() -> Self {
        Self {
            process: ArrivalProcess::Poisson,
            load_factor: 0.8,
            jobs: 200,
            churn_rate: 0.0,
            churn_downtime: 0.5,
            fault_rate: 0.0,
            record_cap: 0,
        }
    }
}

impl ArrivalSpec {
    pub fn validate(&self) -> anyhow::Result<()> {
        // One validator shared with the direct ServeConfig path.
        crate::serve::validate_arrival_knobs(
            self.load_factor,
            self.churn_rate,
            self.churn_downtime,
        )?;
        // Sweep cells additionally need ≥ 1 job: an empty stream would
        // export as a feasible 0 ms measurement (empty Welford summary)
        // instead of "no data".
        anyhow::ensure!(
            self.jobs >= 1,
            "arrivals.jobs must be ≥ 1 on serving sweeps (a zero-job cell has no data)"
        );
        anyhow::ensure!(
            self.fault_rate.is_finite() && (0.0..=1.0).contains(&self.fault_rate),
            "arrivals.fault_rate must be in [0, 1], got {}",
            self.fault_rate
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("process", Json::Str(self.process.as_str().to_string()));
        j.set("load_factor", Json::Num(self.load_factor));
        j.set("jobs", Json::Num(self.jobs as f64));
        j.set("churn_rate", Json::Num(self.churn_rate));
        j.set("churn_downtime", Json::Num(self.churn_downtime));
        j.set("fault_rate", Json::Num(self.fault_rate));
        j.set("record_cap", Json::Num(self.record_cap as f64));
        j
    }

    /// Parse, defaulting omitted fields.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = ArrivalSpec::default();
        let num = |k: &str, dv: f64| -> anyhow::Result<f64> {
            match j.get(k) {
                None => Ok(dv),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("arrivals field '{k}' must be a number")),
            }
        };
        Ok(Self {
            process: match j.get("process").and_then(Json::as_str) {
                None => d.process,
                Some(s) => ArrivalProcess::parse(s)?,
            },
            load_factor: num("load_factor", d.load_factor)?,
            jobs: match j.get("jobs") {
                None => d.jobs,
                Some(v) => v.as_usize().ok_or_else(|| {
                    anyhow::anyhow!("arrivals field 'jobs' must be a non-negative integer")
                })?,
            },
            churn_rate: num("churn_rate", d.churn_rate)?,
            churn_downtime: num("churn_downtime", d.churn_downtime)?,
            fault_rate: num("fault_rate", d.fault_rate)?,
            record_cap: match j.get("record_cap") {
                None => d.record_cap,
                Some(v) => v.as_usize().ok_or_else(|| {
                    anyhow::anyhow!("arrivals field 'record_cap' must be a non-negative integer")
                })?,
            },
        })
    }
}

/// Serializable scenario template: a named base plus the knobs the sweep
/// axes may override. `build` composes the base constructor with
/// [`crate::config::Transform`]s, so axis values never need bespoke
/// builders.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    /// "small" | "large" | "random" | "ec2".
    pub base: String,
    /// Scenario-generation seed (the Monte-Carlo seed lives on the
    /// [`SweepSpec`]).
    pub seed: u64,
    pub comm: CommModel,
    /// γ/u of every worker link (ignored by the comp-dominant "ec2" base).
    pub gamma_ratio: f64,
    // ---- "random" base ----
    pub n_masters: usize,
    pub n_workers: usize,
    /// Worker computation shifts drawn uniformly from `[a_lo, a_hi]` ms.
    pub a_lo: f64,
    pub a_hi: f64,
    // ---- "ec2" base ----
    pub n_t2: usize,
    pub n_c5: usize,
    // ---- post-build transforms ----
    /// Override every master's task size (`None` = the base's own L).
    pub l_rows: Option<f64>,
    /// Scale every worker's computation rate `u`.
    pub u_scale: f64,
    /// Straggler mixture. On the "ec2" base this targets the t2.micro
    /// links only (CPU-credit throttling, like `Scenario::ec2`); on every
    /// other base it applies to all worker links. `prob = 0` disables it.
    pub straggler_prob: f64,
    pub straggler_slow: f64,
    /// Worker-link computation-delay family (mean-matched to each
    /// link's `(a, u)`; [`FamilyKind::ShiftedExp`] = the paper's model).
    /// Trace-driven families are a scenario-config/API feature — specs
    /// reject [`FamilyKind::Trace`] because they carry no trace table.
    pub delay_family: FamilyKind,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        Self {
            base: "small".into(),
            seed: 2022,
            comm: CommModel::Stochastic,
            gamma_ratio: 2.0,
            n_masters: 2,
            n_workers: 5,
            a_lo: 0.05,
            a_hi: 0.5,
            n_t2: 40,
            n_c5: 10,
            l_rows: None,
            u_scale: 1.0,
            straggler_prob: 0.0,
            straggler_slow: 1.0,
            delay_family: FamilyKind::ShiftedExp,
        }
    }
}

impl ScenarioSpec {
    /// Named-base convenience constructor.
    pub fn base(base: &str, seed: u64, comm: CommModel) -> Self {
        Self {
            base: base.to_string(),
            seed,
            comm,
            ..Default::default()
        }
    }

    /// Build the concrete [`Scenario`] this template describes.
    ///
    /// All template knobs are validated here with graceful errors — a
    /// hand-written spec (or axis point) with a non-positive `u_scale`,
    /// `l_rows` or `gamma_ratio` must never reach the `assert!`s inside
    /// the transforms/constructors.
    pub fn build(&self) -> anyhow::Result<Scenario> {
        anyhow::ensure!(
            self.seed <= MAX_SEED,
            "scenario seed {} exceeds the JSON-safe maximum {MAX_SEED}",
            self.seed
        );
        anyhow::ensure!(
            self.gamma_ratio.is_finite() && self.gamma_ratio > 0.0,
            "gamma_ratio must be positive and finite, got {}",
            self.gamma_ratio
        );
        anyhow::ensure!(
            self.u_scale.is_finite() && self.u_scale > 0.0,
            "u_scale must be positive and finite, got {}",
            self.u_scale
        );
        if let Some(l) = self.l_rows {
            anyhow::ensure!(
                l.is_finite() && l > 0.0,
                "l_rows must be positive and finite, got {l}"
            );
        }
        let mut s = match self.base.as_str() {
            "small" => Scenario::small_scale(self.seed, self.gamma_ratio, self.comm),
            "large" => Scenario::large_scale(self.seed, self.gamma_ratio, self.comm),
            "random" => {
                anyhow::ensure!(
                    self.n_masters >= 1 && self.n_workers >= 1,
                    "random base needs n_masters ≥ 1 and n_workers ≥ 1"
                );
                anyhow::ensure!(
                    self.a_lo > 0.0 && self.a_hi >= self.a_lo,
                    "random base needs 0 < a_lo ≤ a_hi (got [{}, {}])",
                    self.a_lo,
                    self.a_hi
                );
                Scenario::random(
                    &format!("random (M={}, N={})", self.n_masters, self.n_workers),
                    self.n_masters,
                    self.n_workers,
                    1e4,
                    AShift::Range(self.a_lo, self.a_hi),
                    self.gamma_ratio,
                    self.comm,
                    self.seed,
                )
            }
            "ec2" => Scenario::ec2(self.n_t2, self.n_c5, false),
            other => anyhow::bail!("unknown scenario base '{other}' (small|large|random|ec2)"),
        };
        let mut ts: Vec<Transform> = Vec::new();
        if self.u_scale != 1.0 {
            ts.push(Transform::ScaleU(self.u_scale));
        }
        if let Some(l) = self.l_rows {
            ts.push(Transform::LRows(l));
        }
        if self.straggler_prob > 0.0 {
            anyhow::ensure!(
                (0.0..=1.0).contains(&self.straggler_prob) && self.straggler_slow >= 1.0,
                "straggler mixture needs prob ∈ [0, 1] and slowdown ≥ 1 (got {} × {})",
                self.straggler_prob,
                self.straggler_slow
            );
            if self.base == "ec2" {
                // Throttling hits the burstable t2.micro links only, as in
                // `Scenario::ec2(.., stragglers = true)` — structurally the
                // first `n_t2` links of every row.
                for row in &mut s.links {
                    for p in row.iter_mut().take(self.n_t2) {
                        *p = p.with_straggler(self.straggler_prob, self.straggler_slow);
                    }
                }
            } else {
                ts.push(Transform::Straggler {
                    prob: self.straggler_prob,
                    slowdown: self.straggler_slow,
                });
            }
        }
        if self.delay_family != FamilyKind::ShiftedExp {
            anyhow::ensure!(
                !matches!(self.delay_family, FamilyKind::Trace { .. }),
                "trace-driven delay families are selected on scenario configs \
                 (a 'traces' table + per-link 'family') or via Scenario::add_trace, \
                 not on sweep specs"
            );
            self.delay_family.validate(0)?;
            ts.push(Transform::Family(self.delay_family));
        }
        Ok(s.transformed(&ts))
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("base", Json::Str(self.base.clone()));
        j.set("seed", Json::Num(self.seed as f64));
        j.set(
            "comm",
            Json::Str(
                match self.comm {
                    CommModel::Stochastic => "stochastic",
                    CommModel::CompDominant => "comp_dominant",
                }
                .into(),
            ),
        );
        j.set("gamma_ratio", Json::Num(self.gamma_ratio));
        j.set("n_masters", Json::Num(self.n_masters as f64));
        j.set("n_workers", Json::Num(self.n_workers as f64));
        j.set("a_lo", Json::Num(self.a_lo));
        j.set("a_hi", Json::Num(self.a_hi));
        j.set("n_t2", Json::Num(self.n_t2 as f64));
        j.set("n_c5", Json::Num(self.n_c5 as f64));
        if let Some(l) = self.l_rows {
            j.set("l_rows", Json::Num(l));
        }
        j.set("u_scale", Json::Num(self.u_scale));
        j.set("straggler_prob", Json::Num(self.straggler_prob));
        j.set("straggler_slow", Json::Num(self.straggler_slow));
        if self.delay_family != FamilyKind::ShiftedExp {
            j.set("delay_family", self.delay_family.to_json());
        }
        j
    }

    /// Parse, defaulting every omitted field — hand-written specs only
    /// need the fields they change.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = ScenarioSpec::default();
        let num = |k: &str, dv: f64| -> anyhow::Result<f64> {
            match j.get(k) {
                None => Ok(dv),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("scenario field '{k}' must be a number")),
            }
        };
        let int = |k: &str, dv: usize| -> anyhow::Result<usize> {
            match j.get(k) {
                None => Ok(dv),
                Some(v) => v.as_usize().ok_or_else(|| {
                    anyhow::anyhow!("scenario field '{k}' must be a non-negative integer")
                }),
            }
        };
        let comm = match j.get("comm").and_then(Json::as_str) {
            None => d.comm,
            Some("stochastic") => CommModel::Stochastic,
            Some("comp_dominant") => CommModel::CompDominant,
            Some(other) => anyhow::bail!("unknown comm model '{other}'"),
        };
        let l_rows = match j.get("l_rows") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("scenario field 'l_rows' must be a number"))?,
            ),
        };
        Ok(Self {
            base: j
                .get("base")
                .and_then(Json::as_str)
                .unwrap_or(&d.base)
                .to_string(),
            seed: int("seed", d.seed as usize)? as u64,
            comm,
            gamma_ratio: num("gamma_ratio", d.gamma_ratio)?,
            n_masters: int("n_masters", d.n_masters)?,
            n_workers: int("n_workers", d.n_workers)?,
            a_lo: num("a_lo", d.a_lo)?,
            a_hi: num("a_hi", d.a_hi)?,
            n_t2: int("n_t2", d.n_t2)?,
            n_c5: int("n_c5", d.n_c5)?,
            l_rows,
            u_scale: num("u_scale", d.u_scale)?,
            straggler_prob: num("straggler_prob", d.straggler_prob)?,
            straggler_slow: num("straggler_slow", d.straggler_slow)?,
            delay_family: match j.get("delay_family") {
                None | Some(Json::Null) => d.delay_family,
                Some(fj) => FamilyKind::from_json(fj)?,
            },
        })
    }
}

/// One named sweep axis: a list of grid *points*, each assigning every
/// parameter in `params`. A single-param axis is the usual value list; a
/// multi-param axis zips parameters that move together (e.g.
/// `(straggler_prob, straggler_slow)` pairs) instead of crossing them.
#[derive(Clone, Debug, PartialEq)]
pub struct Axis {
    pub name: String,
    pub params: Vec<String>,
    pub points: Vec<Vec<f64>>,
}

impl Axis {
    /// Single-parameter axis named after its parameter.
    pub fn single(param: &str, values: &[f64]) -> Self {
        Self {
            name: param.to_string(),
            params: vec![param.to_string()],
            points: values.iter().map(|&v| vec![v]).collect(),
        }
    }

    /// Zipped multi-parameter axis: each point assigns all `params`.
    pub fn zipped(name: &str, params: &[&str], points: Vec<Vec<f64>>) -> Self {
        Self {
            name: name.to_string(),
            params: params.iter().map(|p| p.to_string()).collect(),
            points,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set(
            "params",
            Json::Arr(
                self.params
                    .iter()
                    .map(|p| Json::Str(p.clone()))
                    .collect(),
            ),
        );
        j.set(
            "points",
            Json::Arr(
                self.points
                    .iter()
                    .map(|pt| Json::from_f64_slice(pt))
                    .collect(),
            ),
        );
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("axis missing 'params' array"))?
            .iter()
            .map(|p| {
                p.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("axis params must be strings"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let points = j
            .get("points")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("axis missing 'points' array"))?
            .iter()
            .map(|pt| {
                pt.as_arr()
                    .ok_or_else(|| anyhow::anyhow!("axis points must be arrays of numbers"))?
                    .iter()
                    .map(|v| {
                        v.as_f64()
                            .ok_or_else(|| anyhow::anyhow!("axis point values must be numbers"))
                    })
                    .collect::<anyhow::Result<Vec<f64>>>()
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or_else(|| params.first().map(String::as_str).unwrap_or("axis"))
            .to_string();
        Ok(Self {
            name,
            params,
            points,
        })
    }
}

/// One expanded grid point: a concrete scenario + policy (+ optional plan
/// overhead rescale) and the Monte-Carlo seed the runner will use.
#[derive(Clone, Debug)]
pub struct Cell {
    /// Position in the expanded grid (axes row-major, first axis
    /// outermost; policies innermost).
    pub index: usize,
    /// Flattened `(param, value)` pairs of this grid point, axis order.
    pub axis_values: Vec<(String, f64)>,
    pub scenario: Scenario,
    pub policy: PolicySpec,
    /// Plan-load rescale target from an `overhead` axis.
    pub overhead: Option<f64>,
    /// Serving-mode arrivals of this cell (the spec template with any
    /// `load_factor` / `churn_rate` axis values applied).
    pub arrivals: Option<ArrivalSpec>,
    /// Per-cell Monte-Carlo seed (identical across cells under CRN).
    pub seed: u64,
}

/// A declarative, serializable experiment: axes × policies on a scenario
/// template, evaluated at `trials` Monte-Carlo realizations per cell.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepSpec {
    pub name: String,
    pub scenario: ScenarioSpec,
    pub axes: Vec<Axis>,
    pub policies: Vec<PolicySpec>,
    /// Monte-Carlo trials per cell.
    pub trials: usize,
    /// Monte-Carlo seed (scenario-generation seeds live in
    /// `scenario.seed` / a `seed` axis).
    pub seed: u64,
    /// Common random numbers: every cell samples the same delay streams
    /// (seed shared), so cross-policy differences on the same scenario
    /// are variance-reduced. Off = each cell gets an independent derived
    /// seed.
    pub crn: bool,
    /// Keep raw per-trial system delays (needed for CDF readouts).
    pub keep_samples: bool,
    /// RNG consumption order of the Monte-Carlo kernel. `TrialMajor`
    /// (default) is bit-for-bit reproducible against serial `sim::run`;
    /// `Blocked` is the column-filled fast path — same distribution,
    /// different bits (`sim::engine`'s documented contract), so golden
    /// parity only holds trial-major.
    pub sample_order: SampleOrder,
    /// Draw exponentials through the ziggurat sampler (kernel v3).
    /// Requires `sample_order: "chunked"` — the only order whose bit
    /// contract already allows different bits; rejected by
    /// [`SweepSpec::expand`] otherwise. Distribution-equal, not
    /// bit-reproducible.
    pub ziggurat: bool,
    /// Serving mode: when present, cells run as online job streams on
    /// [`crate::serve`] (sojourn-time outcomes) instead of one-shot
    /// Monte-Carlo batches; `load_factor` / `churn_rate` axes apply.
    pub arrivals: Option<ArrivalSpec>,
}

impl SweepSpec {
    /// Sweep-document schema version (stamped by [`SweepSpec::to_json`];
    /// [`SweepSpec::from_json`] rejects other majors).
    pub const SCHEMA: u64 = 1;

    /// Spec with the given scenario and policies and default execution
    /// knobs (10⁴ trials, seed 2022, CRN on, no samples).
    pub fn new(name: &str, scenario: ScenarioSpec, policies: Vec<PolicySpec>) -> Self {
        Self {
            name: name.to_string(),
            scenario,
            axes: Vec::new(),
            policies,
            trials: 10_000,
            seed: 2022,
            crn: true,
            keep_samples: false,
            sample_order: SampleOrder::TrialMajor,
            ziggurat: false,
            arrivals: None,
        }
    }

    /// Grid size this spec expands to (validates axis shapes).
    pub fn n_cells(&self) -> anyhow::Result<usize> {
        let mut total = self.policies.len();
        for ax in &self.axes {
            anyhow::ensure!(!ax.points.is_empty(), "axis '{}' has no points", ax.name);
            total = total
                .checked_mul(ax.points.len())
                .ok_or_else(|| anyhow::anyhow!("cell grid size overflows"))?;
        }
        Ok(total)
    }

    /// Expand into the concrete cell grid: axes row-major (first axis
    /// outermost), policies innermost. Validates parameter names, point
    /// arity, duplicate params and the [`MAX_CELLS`] guard before
    /// building a single scenario.
    pub fn expand(&self) -> anyhow::Result<Vec<Cell>> {
        anyhow::ensure!(
            !self.policies.is_empty(),
            "sweep spec '{}' has no policies",
            self.name
        );
        anyhow::ensure!(
            self.seed <= MAX_SEED,
            "sweep spec '{}': MC seed {} exceeds the JSON-safe maximum {MAX_SEED}",
            self.name,
            self.seed
        );
        anyhow::ensure!(
            !self.ziggurat || self.sample_order == SampleOrder::Chunked,
            "sweep spec '{}': 'ziggurat' requires sample_order \"chunked\" \
             (the other orders are bit-exact by contract)",
            self.name
        );
        let mut seen: Vec<&str> = Vec::new();
        for ax in &self.axes {
            anyhow::ensure!(!ax.points.is_empty(), "axis '{}' has no points", ax.name);
            anyhow::ensure!(
                !ax.params.is_empty(),
                "axis '{}' names no params",
                ax.name
            );
            for p in &ax.params {
                anyhow::ensure!(
                    KNOWN_PARAMS.contains(&p.as_str()),
                    "axis '{}': unknown param '{p}' (known: {})",
                    ax.name,
                    KNOWN_PARAMS.join(", ")
                );
                anyhow::ensure!(
                    !seen.contains(&p.as_str()),
                    "param '{p}' appears on two axes"
                );
                if matches!(p.as_str(), "load_factor" | "churn_rate" | "fault_rate") {
                    anyhow::ensure!(
                        self.arrivals.is_some(),
                        "axis param '{p}' needs an 'arrivals' block (serving sweeps only)"
                    );
                }
                if p == "overhead" {
                    anyhow::ensure!(
                        self.arrivals.is_none(),
                        "the 'overhead' axis is not supported on serving sweeps"
                    );
                }
                seen.push(p.as_str());
            }
            for (i, pt) in ax.points.iter().enumerate() {
                anyhow::ensure!(
                    pt.len() == ax.params.len(),
                    "axis '{}' point {i} has {} values for {} params",
                    ax.name,
                    pt.len(),
                    ax.params.len()
                );
            }
        }
        let total = self.n_cells()?;
        anyhow::ensure!(
            total <= MAX_CELLS,
            "sweep spec '{}' expands to {total} cells (guard: {MAX_CELLS}); \
             shrink an axis or split the sweep",
            self.name
        );
        // Resolve every policy once so unknown names fail here with the
        // registry's suggestions, not mid-grid.
        for p in &self.policies {
            p.resolve()
                .map_err(|e| anyhow::anyhow!("sweep spec '{}': {e}", self.name))?;
        }
        if let Some(a) = &self.arrivals {
            a.validate()
                .map_err(|e| anyhow::anyhow!("sweep spec '{}': {e}", self.name))?;
        }

        let mut cells = Vec::with_capacity(total);
        let mut idx = vec![0usize; self.axes.len()];
        loop {
            let mut sc = self.scenario.clone();
            let mut overhead = None;
            let mut arrivals = self.arrivals.clone();
            let mut axis_values = Vec::new();
            for (ai, ax) in self.axes.iter().enumerate() {
                let pt = &ax.points[idx[ai]];
                for (pi, param) in ax.params.iter().enumerate() {
                    apply_param(&mut sc, &mut overhead, &mut arrivals, param, pt[pi])?;
                    axis_values.push((param.clone(), pt[pi]));
                }
            }
            let scenario = sc.build()?;
            for policy in &self.policies {
                let index = cells.len();
                let seed = if self.crn {
                    self.seed
                } else {
                    mix_seed(self.seed, index as u64)
                };
                cells.push(Cell {
                    index,
                    axis_values: axis_values.clone(),
                    scenario: scenario.clone(),
                    policy: policy.clone(),
                    overhead,
                    arrivals: arrivals.clone(),
                    seed,
                });
            }
            // Odometer over the axes, last axis fastest.
            let mut ai = self.axes.len();
            loop {
                if ai == 0 {
                    return Ok(cells);
                }
                ai -= 1;
                idx[ai] += 1;
                if idx[ai] < self.axes[ai].points.len() {
                    break;
                }
                idx[ai] = 0;
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", Json::Num(Self::SCHEMA as f64));
        j.set("name", Json::Str(self.name.clone()));
        j.set("scenario", self.scenario.to_json());
        j.set(
            "axes",
            Json::Arr(self.axes.iter().map(Axis::to_json).collect()),
        );
        j.set(
            "policies",
            Json::Arr(self.policies.iter().map(PolicySpec::to_json).collect()),
        );
        j.set("trials", Json::Num(self.trials as f64));
        j.set("seed", Json::Num(self.seed as f64));
        j.set("crn", Json::Bool(self.crn));
        j.set("keep_samples", Json::Bool(self.keep_samples));
        j.set(
            "sample_order",
            Json::Str(self.sample_order.as_str().to_string()),
        );
        j.set("ziggurat", Json::Bool(self.ziggurat));
        if let Some(a) = &self.arrivals {
            j.set("arrivals", a.to_json());
        }
        j
    }

    /// Parse + validate a serialized sweep spec (schema-checked
    /// round-trip of [`SweepSpec::to_json`]; execution knobs default
    /// when omitted).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let schema = j
            .get("schema")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("sweep spec missing 'schema'"))?;
        anyhow::ensure!(
            schema as u64 == Self::SCHEMA,
            "unsupported sweep schema {schema} (this build reads schema {})",
            Self::SCHEMA
        );
        let scenario = match j.get("scenario") {
            Some(sj) => ScenarioSpec::from_json(sj)?,
            None => ScenarioSpec::default(),
        };
        let axes = match j.get("axes") {
            None => Vec::new(),
            Some(aj) => aj
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'axes' must be an array"))?
                .iter()
                .map(Axis::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?,
        };
        let policies = j
            .get("policies")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("sweep spec missing 'policies'"))?
            .iter()
            .map(PolicySpec::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(!policies.is_empty(), "sweep spec has no policies");
        Ok(Self {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("sweep")
                .to_string(),
            scenario,
            axes,
            policies,
            trials: j.get("trials").and_then(Json::as_usize).unwrap_or(10_000),
            seed: j.get("seed").and_then(Json::as_usize).unwrap_or(2022) as u64,
            crn: j.get("crn").and_then(Json::as_bool).unwrap_or(true),
            keep_samples: j
                .get("keep_samples")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            sample_order: match j.get("sample_order") {
                None | Some(Json::Null) => SampleOrder::TrialMajor,
                Some(v) => SampleOrder::parse(v.as_str().ok_or_else(|| {
                    anyhow::anyhow!("'sample_order' must be a string")
                })?)?,
            },
            ziggurat: j.get("ziggurat").and_then(Json::as_bool).unwrap_or(false),
            arrivals: match j.get("arrivals") {
                None | Some(Json::Null) => None,
                Some(aj) => Some(ArrivalSpec::from_json(aj)?),
            },
        })
    }
}

fn apply_param(
    sc: &mut ScenarioSpec,
    overhead: &mut Option<f64>,
    arrivals: &mut Option<ArrivalSpec>,
    param: &str,
    v: f64,
) -> anyhow::Result<()> {
    match param {
        "seed" => {
            anyhow::ensure!(
                v >= 0.0 && v.fract() == 0.0 && v <= MAX_SEED as f64,
                "seed axis value {v} is not an integer in [0, {MAX_SEED}]"
            );
            sc.seed = v as u64;
        }
        "gamma_ratio" => sc.gamma_ratio = v,
        "n_masters" | "n_workers" => {
            anyhow::ensure!(
                sc.base == "random",
                "param '{param}' only applies to the 'random' scenario base (got '{}')",
                sc.base
            );
            anyhow::ensure!(
                v >= 1.0 && v.fract() == 0.0,
                "'{param}' axis value {v} is not a positive integer"
            );
            if param == "n_masters" {
                sc.n_masters = v as usize;
            } else {
                sc.n_workers = v as usize;
            }
        }
        "l_rows" => sc.l_rows = Some(v),
        "u_scale" => sc.u_scale = v,
        "straggler_prob" => sc.straggler_prob = v,
        "straggler_slow" => sc.straggler_slow = v,
        "weibull_shape" => {
            // Same bound as FamilyKind::validate (Γ-overflow guard).
            anyhow::ensure!(
                v.is_finite() && v >= 0.01,
                "weibull_shape axis value {v} must be ≥ 0.01"
            );
            sc.delay_family = FamilyKind::Weibull { shape: v };
        }
        "pareto_alpha" => {
            anyhow::ensure!(
                v.is_finite() && v > 1.0,
                "pareto_alpha axis value {v} must be > 1 (finite mean)"
            );
            sc.delay_family = FamilyKind::Pareto { alpha: v };
        }
        // The two bimodal params read-modify the current family so a
        // zipped (prob, slow) axis composes; a lone param starts from
        // the t2.micro-throttle-flavored default for the other half.
        "bimodal_prob" => {
            anyhow::ensure!(
                (0.0..=1.0).contains(&v),
                "bimodal_prob axis value {v} outside [0, 1]"
            );
            let slow = match sc.delay_family {
                FamilyKind::Bimodal { slow, .. } => slow,
                _ => 10.0,
            };
            sc.delay_family = FamilyKind::Bimodal { prob: v, slow };
        }
        "bimodal_slow" => {
            anyhow::ensure!(
                v.is_finite() && v >= 1.0,
                "bimodal_slow axis value {v} must be ≥ 1"
            );
            let prob = match sc.delay_family {
                FamilyKind::Bimodal { prob, .. } => prob,
                _ => 0.02,
            };
            sc.delay_family = FamilyKind::Bimodal { prob, slow: v };
        }
        "overhead" => *overhead = Some(v),
        "load_factor" => {
            let a = arrivals
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("'load_factor' axis needs an 'arrivals' block"))?;
            anyhow::ensure!(
                v.is_finite() && v > 0.0,
                "load_factor axis value {v} must be positive and finite"
            );
            a.load_factor = v;
        }
        "churn_rate" => {
            let a = arrivals
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("'churn_rate' axis needs an 'arrivals' block"))?;
            anyhow::ensure!(
                v.is_finite() && v >= 0.0,
                "churn_rate axis value {v} must be finite and ≥ 0"
            );
            a.churn_rate = v;
        }
        "fault_rate" => {
            let a = arrivals
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("'fault_rate' axis needs an 'arrivals' block"))?;
            anyhow::ensure!(
                v.is_finite() && (0.0..=1.0).contains(&v),
                "fault_rate axis value {v} must be in [0, 1]"
            );
            a.fault_rate = v;
        }
        other => anyhow::bail!("unknown axis param '{other}'"),
    }
    Ok(())
}

/// Independent per-cell seed derivation when CRN is off.
fn mix_seed(seed: u64, index: u64) -> u64 {
    SplitMix64::new(seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::util::json;
    use crate::util::prop::{check, Config};

    fn one_policy() -> Vec<PolicySpec> {
        vec![PolicySpec::new("dedi-iter", ValueModel::Markov, "markov")]
    }

    fn base_spec() -> SweepSpec {
        SweepSpec::new("t", ScenarioSpec::default(), one_policy())
    }

    #[test]
    fn single_cell_expansion() {
        let cells = base_spec().expand().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].index, 0);
        assert!(cells[0].axis_values.is_empty());
        assert_eq!(cells[0].seed, 2022);
        assert_eq!(cells[0].scenario.n_workers(), 5);
    }

    #[test]
    fn empty_axis_rejected() {
        let mut s = base_spec();
        s.axes.push(Axis::single("gamma_ratio", &[]));
        let e = s.expand().unwrap_err();
        assert!(e.to_string().contains("no points"), "{e}");
    }

    #[test]
    fn no_policies_rejected() {
        let mut s = base_spec();
        s.policies.clear();
        assert!(s.expand().is_err());
    }

    #[test]
    fn unknown_param_rejected() {
        let mut s = base_spec();
        s.axes.push(Axis::single("warp_factor", &[9.0]));
        let e = s.expand().unwrap_err();
        assert!(e.to_string().contains("unknown param"), "{e}");
    }

    #[test]
    fn duplicate_param_rejected() {
        let mut s = base_spec();
        s.axes.push(Axis::single("gamma_ratio", &[1.0]));
        s.axes.push(Axis::single("gamma_ratio", &[2.0]));
        let e = s.expand().unwrap_err();
        assert!(e.to_string().contains("two axes"), "{e}");
    }

    #[test]
    fn point_arity_mismatch_rejected() {
        let mut s = base_spec();
        s.axes.push(Axis {
            name: "straggler".into(),
            params: vec!["straggler_prob".into(), "straggler_slow".into()],
            points: vec![vec![0.1]],
        });
        let e = s.expand().unwrap_err();
        assert!(e.to_string().contains("1 values for 2 params"), "{e}");
    }

    #[test]
    fn worker_count_axis_needs_random_base() {
        let mut s = base_spec();
        s.axes.push(Axis::single("n_workers", &[4.0]));
        assert!(s.expand().is_err());
        s.scenario.base = "random".into();
        let cells = s.expand().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].scenario.n_workers(), 4);
    }

    #[test]
    fn cross_product_size_guard() {
        let mut s = base_spec();
        let many: Vec<f64> = (0..200).map(|i| 1.0 + i as f64 * 0.01).collect();
        s.axes.push(Axis::single("gamma_ratio", &many));
        s.axes.push(Axis::single("u_scale", &many)); // 200 × 200 > MAX_CELLS
        let e = s.expand().unwrap_err();
        assert!(e.to_string().contains("cells"), "{e}");
    }

    #[test]
    fn grid_is_row_major_with_policies_innermost() {
        let mut s = base_spec();
        s.policies = vec![
            PolicySpec::new("uncoded", ValueModel::Markov, "markov"),
            PolicySpec::new("dedi-iter", ValueModel::Markov, "markov"),
        ];
        s.axes.push(Axis::single("gamma_ratio", &[1.0, 2.0]));
        s.axes.push(Axis::single("u_scale", &[1.0, 1.5]));
        let cells = s.expand().unwrap();
        assert_eq!(cells.len(), 8);
        // first axis outermost: gamma stays 1.0 for the first 4 cells
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
            let gamma = c.axis_values[0].1;
            let u = c.axis_values[1].1;
            assert_eq!(gamma, if i < 4 { 1.0 } else { 2.0 }, "cell {i}");
            assert_eq!(u, if (i / 2) % 2 == 0 { 1.0 } else { 1.5 }, "cell {i}");
            assert_eq!(
                c.policy.policy.as_str(),
                if i % 2 == 0 { "uncoded" } else { "dedi-iter" },
                "cell {i}"
            );
        }
    }

    #[test]
    fn crn_seeds_shared_otherwise_derived() {
        let mut s = base_spec();
        s.policies = vec![
            PolicySpec::new("uncoded", ValueModel::Markov, "markov"),
            PolicySpec::new("dedi-iter", ValueModel::Markov, "markov"),
        ];
        let crn = s.expand().unwrap();
        assert!(crn.iter().all(|c| c.seed == s.seed));
        s.crn = false;
        let indep = s.expand().unwrap();
        assert_ne!(indep[0].seed, indep[1].seed);
        // derived seeds are deterministic
        let again = s.expand().unwrap();
        assert_eq!(indep[0].seed, again[0].seed);
    }

    #[test]
    fn overhead_axis_lands_on_cell_not_scenario() {
        let mut s = base_spec();
        s.axes.push(Axis::single("overhead", &[1.2, 2.0]));
        let cells = s.expand().unwrap();
        assert_eq!(cells[0].overhead, Some(1.2));
        assert_eq!(cells[1].overhead, Some(2.0));
    }

    #[test]
    fn delay_family_axis_sets_worker_families_per_cell() {
        let mut s = base_spec();
        s.axes.push(Axis::single("weibull_shape", &[1.0, 0.6]));
        let cells = s.expand().unwrap();
        assert_eq!(cells.len(), 2);
        for (cell, shape) in cells.iter().zip([1.0, 0.6]) {
            for n in 1..=cell.scenario.n_workers() {
                assert_eq!(
                    cell.scenario.link(0, n).family,
                    FamilyKind::Weibull { shape },
                    "cell {} worker {n}",
                    cell.index
                );
            }
            assert_eq!(cell.scenario.link(0, 0).family, FamilyKind::ShiftedExp);
        }
        // Zipped bimodal axis: both params move together.
        let mut s = base_spec();
        s.axes.push(Axis::zipped(
            "bimodal",
            &["bimodal_prob", "bimodal_slow"],
            vec![vec![0.01, 5.0], vec![0.1, 20.0]],
        ));
        let cells = s.expand().unwrap();
        assert_eq!(
            cells[0].scenario.link(0, 1).family,
            FamilyKind::Bimodal { prob: 0.01, slow: 5.0 }
        );
        assert_eq!(
            cells[1].scenario.link(0, 1).family,
            FamilyKind::Bimodal { prob: 0.1, slow: 20.0 }
        );
        // Invalid family axis values error gracefully at expand.
        let mut s = base_spec();
        s.axes.push(Axis::single("pareto_alpha", &[0.5]));
        assert!(s.expand().unwrap_err().to_string().contains("pareto_alpha"));
    }

    #[test]
    fn delay_family_template_roundtrips_and_rejects_traces() {
        let mut s = base_spec();
        s.scenario.delay_family = FamilyKind::Pareto { alpha: 2.5 };
        let text = s.to_json().to_string_pretty();
        let back = SweepSpec::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        let cells = back.expand().unwrap();
        assert_eq!(
            cells[0].scenario.link(0, 1).family,
            FamilyKind::Pareto { alpha: 2.5 }
        );
        // Specs carry no trace table ⇒ trace families are rejected.
        let mut s = base_spec();
        s.scenario.delay_family = FamilyKind::Trace { id: 0 };
        let e = s.expand().unwrap_err();
        assert!(e.to_string().contains("trace"), "{e}");
        // Unknown family kinds in JSON error gracefully too.
        let bad = r#"{
            "schema": 1,
            "scenario": {"delay_family": {"kind": "cauchy"}},
            "policies": [{"policy": "dedi-iter", "values": "markov", "loads": "markov"}]
        }"#;
        assert!(SweepSpec::from_json(&json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn serving_axes_rewrite_the_arrival_spec_per_cell() {
        let mut s = base_spec();
        s.arrivals = Some(ArrivalSpec {
            jobs: 50,
            ..Default::default()
        });
        s.axes.push(Axis::single("load_factor", &[0.5, 1.25]));
        s.axes.push(Axis::single("churn_rate", &[0.0, 2.0]));
        let cells = s.expand().unwrap();
        assert_eq!(cells.len(), 4);
        let ax = |c: &Cell, p: &str| {
            c.axis_values
                .iter()
                .find(|(k, _)| k == p)
                .map(|&(_, v)| v)
                .unwrap()
        };
        for c in &cells {
            let a = c.arrivals.as_ref().unwrap();
            assert_eq!(a.jobs, 50);
            assert_eq!(a.load_factor, ax(c, "load_factor"));
            assert_eq!(a.churn_rate, ax(c, "churn_rate"));
        }
        // Batch cells carry no arrivals.
        let batch = base_spec().expand().unwrap();
        assert!(batch[0].arrivals.is_none());
    }

    #[test]
    fn serving_param_guards() {
        // load_factor / churn_rate axes need an arrivals block…
        let mut s = base_spec();
        s.axes.push(Axis::single("load_factor", &[0.5]));
        let e = s.expand().unwrap_err();
        assert!(e.to_string().contains("arrivals"), "{e}");
        // …and overhead is batch-only.
        let mut s = base_spec();
        s.arrivals = Some(ArrivalSpec::default());
        s.axes.push(Axis::single("overhead", &[1.5]));
        let e = s.expand().unwrap_err();
        assert!(e.to_string().contains("overhead"), "{e}");
        // Malformed arrival templates fail at expand.
        let mut s = base_spec();
        s.arrivals = Some(ArrivalSpec {
            load_factor: 0.0,
            ..Default::default()
        });
        assert!(s.expand().unwrap_err().to_string().contains("load_factor"));
        let mut s = base_spec();
        s.arrivals = Some(ArrivalSpec {
            churn_downtime: 1.5,
            ..Default::default()
        });
        assert!(s.expand().is_err());
        // Invalid axis values too.
        let mut s = base_spec();
        s.arrivals = Some(ArrivalSpec::default());
        s.axes.push(Axis::single("churn_rate", &[-1.0]));
        assert!(s.expand().is_err());
        // fault_rate is a fraction of the fleet — and serving-only.
        let mut s = base_spec();
        s.axes.push(Axis::single("fault_rate", &[0.5]));
        assert!(s.expand().unwrap_err().to_string().contains("arrivals"));
        let mut s = base_spec();
        s.arrivals = Some(ArrivalSpec::default());
        s.axes.push(Axis::single("fault_rate", &[1.5]));
        assert!(s.expand().is_err());
        // Zero-job cells would export as feasible 0 ms measurements.
        let mut s = base_spec();
        s.arrivals = Some(ArrivalSpec {
            jobs: 0,
            ..Default::default()
        });
        assert!(s.expand().unwrap_err().to_string().contains("jobs"));
    }

    #[test]
    fn arrival_spec_json_roundtrips_with_defaults() {
        let mut s = base_spec();
        s.arrivals = Some(ArrivalSpec {
            process: ArrivalProcess::Deterministic,
            load_factor: 1.25,
            jobs: 77,
            churn_rate: 0.5,
            churn_downtime: 0.25,
            fault_rate: 0.25,
            record_cap: 3,
        });
        let text = s.to_json().to_string_pretty();
        let back = SweepSpec::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        // A minimal hand-written arrivals block picks up defaults.
        let text = r#"{
            "schema": 1,
            "arrivals": {"load_factor": 1.1},
            "policies": [{"policy": "dedi-iter", "values": "markov", "loads": "markov"}]
        }"#;
        let spec = SweepSpec::from_json(&json::parse(text).unwrap()).unwrap();
        let a = spec.arrivals.unwrap();
        assert_eq!(a.load_factor, 1.1);
        assert_eq!(a.jobs, ArrivalSpec::default().jobs);
        assert_eq!(a.process, ArrivalProcess::Poisson);
        // Unknown process names error gracefully.
        let bad = r#"{
            "schema": 1,
            "arrivals": {"process": "bursty"},
            "policies": [{"policy": "dedi-iter", "values": "markov", "loads": "markov"}]
        }"#;
        assert!(SweepSpec::from_json(&json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn unknown_base_rejected() {
        let mut s = base_spec();
        s.scenario.base = "quantum".into();
        assert!(s.expand().is_err());
    }

    #[test]
    fn invalid_knobs_error_gracefully_not_panic() {
        // Hand-written specs must get anyhow errors, never transform
        // asserts: negative/zero u_scale, l_rows, gamma_ratio.
        let mut s = base_spec();
        s.scenario.u_scale = -1.0;
        assert!(s.expand().unwrap_err().to_string().contains("u_scale"));
        let mut s = base_spec();
        s.scenario.l_rows = Some(0.0);
        assert!(s.expand().unwrap_err().to_string().contains("l_rows"));
        let mut s = base_spec();
        s.scenario.gamma_ratio = 0.0;
        assert!(s.expand().unwrap_err().to_string().contains("gamma_ratio"));
        // ...including via axis points
        let mut s = base_spec();
        s.axes.push(Axis::single("u_scale", &[0.0]));
        assert!(s.expand().is_err());
    }

    #[test]
    fn oversized_seeds_rejected_not_rounded() {
        // Seeds above 2^52 would silently round through JSON doubles.
        let mut s = base_spec();
        s.seed = MAX_SEED + 1;
        assert!(s.expand().unwrap_err().to_string().contains("JSON-safe"));
        let mut s = base_spec();
        s.scenario.seed = MAX_SEED + 1;
        assert!(s.expand().is_err());
        let mut s = base_spec();
        s.axes
            .push(Axis::single("seed", &[(MAX_SEED + 2) as f64]));
        assert!(s.expand().is_err());
    }

    #[test]
    fn unknown_policy_fails_at_expand_with_suggestions() {
        let mut s = base_spec();
        s.policies = vec![PolicySpec::new("bogus", ValueModel::Markov, "markov")];
        let e = s.expand().unwrap_err();
        assert!(e.to_string().contains("dedi-iter"), "{e}");
    }

    #[test]
    fn hand_written_minimal_spec_parses_with_defaults() {
        let text = r#"{
            "schema": 1,
            "scenario": {"base": "large"},
            "axes": [{"params": ["gamma_ratio"], "points": [[0.5], [2]]}],
            "policies": [{"policy": "dedi-iter", "values": "markov", "loads": "sca"}]
        }"#;
        let spec = SweepSpec::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(spec.name, "sweep");
        assert_eq!(spec.trials, 10_000);
        assert!(spec.crn);
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].scenario.n_workers(), 50);
    }

    #[test]
    fn sample_order_parses_defaults_and_rejects() {
        let text = r#"{
            "schema": 1,
            "policies": [{"policy": "dedi-iter", "values": "markov", "loads": "markov"}]
        }"#;
        let spec = SweepSpec::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(spec.sample_order, SampleOrder::TrialMajor);
        let text = r#"{
            "schema": 1, "sample_order": "blocked",
            "policies": [{"policy": "dedi-iter", "values": "markov", "loads": "markov"}]
        }"#;
        let spec = SweepSpec::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(spec.sample_order, SampleOrder::Blocked);
        let text = r#"{
            "schema": 1, "sample_order": "chunked", "ziggurat": true,
            "policies": [{"policy": "dedi-iter", "values": "markov", "loads": "markov"}]
        }"#;
        let spec = SweepSpec::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(spec.sample_order, SampleOrder::Chunked);
        assert!(spec.ziggurat);
        assert!(spec.expand().is_ok());
        let text = r#"{
            "schema": 1, "sample_order": "spiral",
            "policies": [{"policy": "dedi-iter", "values": "markov", "loads": "markov"}]
        }"#;
        let e = SweepSpec::from_json(&json::parse(text).unwrap()).unwrap_err();
        assert!(e.to_string().contains("sample order"), "{e}");
    }

    #[test]
    fn ziggurat_requires_chunked_order() {
        let text = r#"{
            "schema": 1, "sample_order": "blocked", "ziggurat": true,
            "policies": [{"policy": "dedi-iter", "values": "markov", "loads": "markov"}]
        }"#;
        let spec = SweepSpec::from_json(&json::parse(text).unwrap()).unwrap();
        let e = spec.expand().unwrap_err();
        assert!(e.to_string().contains("ziggurat"), "{e}");
    }

    #[test]
    fn from_json_rejects_bad_documents() {
        let parse = |s: &str| json::parse(s).unwrap();
        // wrong schema
        assert!(SweepSpec::from_json(&parse(r#"{"schema": 9, "policies": []}"#)).is_err());
        // missing schema
        assert!(SweepSpec::from_json(&parse(r#"{"policies": []}"#)).is_err());
        // no policies
        assert!(
            SweepSpec::from_json(&parse(r#"{"schema": 1, "policies": []}"#)).is_err()
        );
        // bad comm model
        assert!(SweepSpec::from_json(&parse(
            r#"{"schema": 1, "scenario": {"comm": "telepathy"},
                "policies": [{"policy": "frac", "values": "markov", "loads": "markov"}]}"#
        ))
        .is_err());
    }

    #[test]
    fn sweep_spec_json_roundtrip_property() {
        check(
            Config::default().cases(50),
            "SweepSpec JSON round-trip",
            |g| {
                let base = *g.rng().choose(&["small", "large", "random", "ec2"]);
                let mut sc = ScenarioSpec {
                    base: base.to_string(),
                    // keep seeds below 2^53 so Json::Num is exact
                    seed: g.rng().next_u64() >> 12,
                    ..Default::default()
                };
                sc.gamma_ratio = g.f64_range(0.25, 8.0);
                sc.u_scale = g.f64_range(0.5, 2.0);
                if g.bool() {
                    sc.l_rows = Some(g.f64_range(100.0, 1e5));
                }
                if g.bool() {
                    sc.comm = CommModel::CompDominant;
                }
                if g.bool() {
                    sc.straggler_prob = g.f64_range(0.0, 0.2);
                    sc.straggler_slow = g.f64_range(1.0, 20.0);
                }
                if g.bool() {
                    sc.delay_family = if g.bool() {
                        FamilyKind::Weibull {
                            shape: g.f64_range(0.4, 1.5),
                        }
                    } else {
                        FamilyKind::Pareto {
                            alpha: g.f64_range(1.5, 4.0),
                        }
                    };
                }
                let params = ["gamma_ratio", "u_scale", "l_rows", "overhead"];
                let n_axes = g.usize_range(0, 2);
                let mut axes = Vec::new();
                for ai in 0..n_axes {
                    let n_pts = g.usize_range(1, 4);
                    let vals = g.vec(n_pts, |g| g.f64_range(0.5, 4.0));
                    axes.push(Axis::single(params[ai], &vals));
                }
                let n_pol = g.usize_range(1, 3);
                let mut policies = Vec::new();
                for _ in 0..n_pol {
                    let policy =
                        *g.rng().choose(&["uncoded", "coded", "dedi-iter", "frac"]);
                    let loads = *g.rng().choose(&["markov", "sca"]);
                    policies.push(PolicySpec::new(policy, ValueModel::Markov, loads));
                }
                let spec = SweepSpec {
                    name: "prop".into(),
                    scenario: sc,
                    axes,
                    policies,
                    trials: g.usize_range(1, 100_000),
                    seed: g.rng().next_u64() >> 12,
                    crn: g.bool(),
                    keep_samples: g.bool(),
                    sample_order: if g.bool() {
                        SampleOrder::Blocked
                    } else if g.bool() {
                        SampleOrder::Chunked
                    } else {
                        SampleOrder::TrialMajor
                    },
                    ziggurat: g.bool(),
                    arrivals: if g.bool() {
                        Some(ArrivalSpec {
                            process: match g.usize_range(0, 2) {
                                0 => ArrivalProcess::Poisson,
                                1 => ArrivalProcess::Deterministic,
                                _ => ArrivalProcess::Burst,
                            },
                            load_factor: g.f64_range(0.25, 2.0),
                            jobs: g.usize_range(0, 500),
                            churn_rate: g.f64_range(0.0, 4.0),
                            churn_downtime: g.f64_range(0.1, 0.9),
                            fault_rate: g.f64_range(0.0, 1.0),
                            record_cap: g.usize_range(0, 64),
                        })
                    } else {
                        None
                    },
                };
                let text = spec.to_json().to_string_pretty();
                let back = SweepSpec::from_json(&json::parse(&text).unwrap()).unwrap();
                assert_eq!(back, spec);
            },
        );
    }
}
