//! Process-wide shared worker pool.
//!
//! `sim::run` used to spawn (and join) a fresh set of OS threads on
//! every call, so a figure roster or a short sweep paid thread creation
//! once per Monte-Carlo run — a fixed ~100µs-per-thread tax that
//! dominates small-trial cells. The pool here is created once per
//! process (first use) and reused by every subsequent run: callers
//! submit `'static` jobs and block until their own batch completes.
//!
//! Determinism is untouched by construction: the work a job does is
//! fully described by its inputs (RNG stream id, trial count), never by
//! which worker executes it or in which order batches drain.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex, Once, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Shared FIFO pool. Obtain via [`global`]; there is one per process.
pub struct Pool {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();
static SPAWN: Once = Once::new();

/// The process-wide pool, created (and its workers spawned) on first
/// use. Width = available cores.
pub fn global() -> &'static Pool {
    let pool: &'static Pool = POOL.get_or_init(|| Pool {
        queue: Mutex::new(VecDeque::new()),
        cv: Condvar::new(),
        workers: std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4),
    });
    SPAWN.call_once(|| {
        for i in 0..pool.workers {
            std::thread::Builder::new()
                .name(format!("coded-coop-pool-{i}"))
                .spawn(move || worker_loop(pool))
                .expect("spawn pool worker");
        }
    });
    pool
}

fn worker_loop(pool: &'static Pool) {
    loop {
        let job = {
            let mut q = pool.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                q = pool.cv.wait(q).unwrap();
            }
        };
        // Keep the worker alive across a panicking job; the submitter
        // notices the missing result (its channel sender is dropped
        // during unwind) and reports from its own thread.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
    }
}

impl Pool {
    /// Pool width (worker thread count).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Enqueue one job.
    pub fn spawn(&self, job: Job) {
        self.queue.lock().unwrap().push_back(job);
        self.cv.notify_one();
    }
}

/// Run every thunk on the shared pool and return the results in input
/// order, blocking the caller until its whole batch is done. Panics if a
/// thunk panicked on a worker.
pub fn run_all<T, F>(thunks: Vec<F>) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = thunks.len();
    if n == 0 {
        return Vec::new();
    }
    let pool = global();
    let (tx, rx) = mpsc::channel();
    for (i, f) in thunks.into_iter().enumerate() {
        let tx = tx.clone();
        pool.spawn(Box::new(move || {
            let _ = tx.send((i, f()));
        }));
    }
    drop(tx);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for _ in 0..n {
        let (i, v) = rx
            .recv()
            .expect("pool job vanished (worker panicked while running it)");
        slots[i] = Some(v);
    }
    slots
        .into_iter()
        .map(|s| s.expect("every job index delivered exactly once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_input_order() {
        let out = run_all((0..64usize).map(|i| move || i * i).collect::<Vec<_>>());
        assert_eq!(out.len(), 64);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn empty_batch_is_ok() {
        let out: Vec<u32> = run_all(Vec::<fn() -> u32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn pool_is_reused_across_batches() {
        let p1 = global() as *const Pool;
        let _ = run_all(vec![|| 1u8]);
        let p2 = global() as *const Pool;
        assert_eq!(p1, p2);
        assert!(global().workers() >= 1);
    }

    #[test]
    fn many_concurrent_submitters_all_complete() {
        // Mimics the test harness: several threads each block on their
        // own batch against the one shared pool.
        let handles: Vec<_> = (0..4usize)
            .map(|t| {
                std::thread::spawn(move || {
                    let out =
                        run_all((0..16usize).map(|i| move || t * 100 + i).collect::<Vec<_>>());
                    out.iter().sum::<usize>()
                })
            })
            .collect();
        for (t, h) in handles.into_iter().enumerate() {
            let want = (0..16usize).map(|i| t * 100 + i).sum::<usize>();
            assert_eq!(h.join().unwrap(), want);
        }
    }
}
