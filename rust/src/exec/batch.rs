//! Batched Monte-Carlo evaluation: many `(Scenario, Plan)` cells on ONE
//! shared thread pool.
//!
//! `sim::run` spawns a fresh set of threads per call, so a grid of cells
//! (a figure roster, a parameter sweep) pays the spawn + join cost once
//! per cell and leaves cores idle while a cell's slowest shard finishes.
//! [`BatchRunner`] instead flattens every cell into RNG-stream shards and
//! drains them all through one pool: by default the shared process pool
//! ([`crate::exec::pool`], zero spawns per grid), or a scoped pool of
//! exactly `pool_threads` threads when an explicit width is requested. A
//! fast cell's leftover capacity immediately picks up the next cell's
//! shards, and zero-trial trailing shards are never scheduled.
//!
//! **Bit-for-bit parity:** each cell is split into the exact shards
//! `sim::run` would use for `cell_streams` threads
//! ([`crate::sim::engine::effective_streams`] / `shard_sizes`), sampled by
//! the same [`crate::sim::engine::run_shard`] and merged in the same
//! stream order — so a batched cell's [`Outcome`] equals the serial
//! `sim::run` result for the same `(seed, cell_streams)`. That is what
//! makes the sweep rewrites of the figure harnesses golden-parity
//! testable (`rust/tests/sweep_parity.rs`).
//!
//! **Fused grid mode** (kernel v3, [`BatchRunner::fused`]): instead of
//! one `Compiled` (and its column allocations) per cell, the whole grid
//! is compiled into ONE column arena — a single allocation per column
//! across every master of every cell — and shards drive the engine's
//! column-view trial loops over per-cell sub-ranges. Compile arithmetic
//! and trial code are shared with the per-cell path, so fused results
//! are bit-for-bit the non-fused results for every sample order; what
//! changes is allocation count (O(columns) instead of O(cells × columns))
//! and compile locality on wide grids of small cells.
//!
//! Common random numbers (variance-reduced policy comparisons) are a
//! seeding choice, not an engine mode: give every job the same `seed` and
//! all cells sample identical delay streams (`experiment::SweepSpec`'s
//! `crn` flag does exactly that).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::config::Scenario;
use crate::plan::Plan;
use crate::sim::engine::{self, ColumnArena, Compiled, SampleOrder, ShardOut};

use super::{pool, Outcome};

/// One grid cell: evaluate `plan` on `scenario` for `trials` sampled
/// realizations seeded by `seed`.
pub struct BatchJob {
    pub scenario: Scenario,
    pub plan: Plan,
    /// Monte-Carlo seed (same seed across jobs = common random numbers).
    pub seed: u64,
    pub trials: usize,
    /// Keep raw per-trial system delays (needed for CDFs).
    pub keep_samples: bool,
    /// RNG consumption order (`TrialMajor` reproduces `sim::run`
    /// bit-for-bit; `Blocked` is the different-bits/same-distribution
    /// fast path; `Chunked` is `Blocked` with thread-local scratch reuse
    /// — see `sim::engine`'s bit contract).
    pub order: SampleOrder,
    /// Draw exponentials through the ziggurat sampler (honored by
    /// `SampleOrder::Chunked` only; distribution-equal, different bits).
    pub ziggurat: bool,
}

/// Shared-pool batch engine over [`crate::sim::engine`] shards.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchRunner {
    /// Worker threads in the shared pool (0 = all cores).
    pub pool_threads: usize,
    /// RNG streams per cell, with `McOptions::threads` semantics: a cell's
    /// result is bit-identical to `sim::run` at this thread count
    /// (0 = all cores). Independent of `pool_threads` — the pool only
    /// decides who executes a shard, never how trials are split.
    pub cell_streams: usize,
    /// Compile the whole grid into one fused column arena (kernel v3)
    /// instead of one `Compiled` per cell. Bit-for-bit the same results;
    /// kills the per-cell compile allocations.
    pub fused: bool,
}

/// One schedulable unit: everything a shard run needs, copied out of the
/// job so pool closures own their inputs.
#[derive(Clone, Copy)]
struct Shard {
    job: usize,
    stream: u64,
    trials: usize,
    seed: u64,
    keep_samples: bool,
    order: SampleOrder,
    ziggurat: bool,
}

/// The whole grid compiled into one column arena, plus where each job's
/// masters live in it.
struct FusedGrid {
    arena: ColumnArena,
    jobs: Vec<FusedJob>,
}

#[derive(Clone, Copy)]
struct FusedJob {
    m_start: usize,
    m_cnt: usize,
    max_links: usize,
}

impl FusedGrid {
    fn new(jobs: &[BatchJob]) -> Self {
        let n_masters = jobs.iter().map(|j| j.plan.masters.len()).sum();
        let n_links = jobs
            .iter()
            .flat_map(|j| j.plan.masters.iter())
            .map(|mp| mp.entries.len())
            .sum();
        let mut arena = ColumnArena::with_capacity(n_masters, n_links);
        let mut fjobs = Vec::with_capacity(jobs.len());
        let mut m_start = 0usize;
        for j in jobs {
            for (m, mp) in j.plan.masters.iter().enumerate() {
                arena.push_master(&j.scenario, m, mp, j.plan.uncoded);
            }
            let m_cnt = j.plan.masters.len();
            let max_links = j
                .plan
                .masters
                .iter()
                .map(|mp| mp.entries.len())
                .max()
                .unwrap_or(0);
            fjobs.push(FusedJob {
                m_start,
                m_cnt,
                max_links,
            });
            m_start += m_cnt;
        }
        FusedGrid { arena, jobs: fjobs }
    }

    fn run_shard(&self, sh: Shard) -> ShardOut {
        let fj = self.jobs[sh.job];
        let views: Vec<_> = (fj.m_start..fj.m_start + fj.m_cnt)
            .map(|m| self.arena.master(m))
            .collect();
        engine::run_shard_cols(
            &views,
            fj.max_links,
            sh.seed,
            sh.stream,
            sh.trials,
            sh.keep_samples,
            sh.order,
            sh.ziggurat,
        )
    }
}

// `&Vec` (not `&[..]`) because this must match the `fn(&C, Shard)`
// pointer shape `execute` takes, with `C = Vec<Compiled>` under `Arc`.
#[allow(clippy::ptr_arg)]
fn run_shard_per_cell(compiled: &Vec<Compiled>, sh: Shard) -> ShardOut {
    engine::run_shard_opts(
        &compiled[sh.job],
        sh.seed,
        sh.stream,
        sh.trials,
        sh.keep_samples,
        sh.order,
        sh.ziggurat,
    )
}

fn run_shard_fused(grid: &FusedGrid, sh: Shard) -> ShardOut {
    grid.run_shard(sh)
}

impl BatchRunner {
    /// Evaluate every job, returning one [`Outcome`] per job in input
    /// order. Fails fast (before any sampling) if a plan does not fit its
    /// scenario.
    pub fn run(&self, jobs: &[BatchJob]) -> anyhow::Result<Vec<Outcome>> {
        for (i, j) in jobs.iter().enumerate() {
            j.plan
                .validate(&j.scenario)
                .map_err(|e| anyhow::anyhow!("batch job {i} ('{}'): {e}", j.plan.label))?;
        }

        // Flatten cells into shards; shard indices are contiguous and in
        // stream order per job, so regrouping below preserves the merge
        // order `sim::run` uses. Zero-trial trailing shards (ceil-split
        // remainders) are never scheduled — their merge contribution is
        // the empty `ShardOut`, injected in stream order at regroup.
        let mut shards: Vec<Shard> = Vec::new();
        let mut sizes_per_job: Vec<Vec<usize>> = Vec::with_capacity(jobs.len());
        for (ji, j) in jobs.iter().enumerate() {
            let streams = engine::effective_streams(j.trials, self.cell_streams);
            let sizes = engine::shard_sizes(j.trials, streams);
            for (ti, &t) in sizes.iter().enumerate() {
                if t > 0 {
                    shards.push(Shard {
                        job: ji,
                        stream: ti as u64 + 1,
                        trials: t,
                        seed: j.seed,
                        keep_samples: j.keep_samples,
                        order: j.order,
                        ziggurat: j.ziggurat,
                    });
                }
            }
            sizes_per_job.push(sizes);
        }

        // Compile (per cell or fused) and drain the shards. Both paths
        // share the scheduling in `execute`; the compile state travels as
        // an `Arc` plus a plain-fn shard runner so the shared process
        // pool's `'static` closure bound is met without cloning state.
        let outs: Vec<ShardOut> = if self.fused {
            self.execute(Arc::new(FusedGrid::new(jobs)), &shards, run_shard_fused)
        } else {
            let compiled: Arc<Vec<Compiled>> = Arc::new(
                jobs.iter()
                    .map(|j| Compiled::new(&j.scenario, &j.plan))
                    .collect(),
            );
            self.execute(compiled, &shards, run_shard_per_cell)
        };

        let mut outs_iter = outs.into_iter();
        let mut outcomes = Vec::with_capacity(jobs.len());
        for (ji, j) in jobs.iter().enumerate() {
            let m_cnt = j.plan.masters.len();
            let outs: Vec<ShardOut> = sizes_per_job[ji]
                .iter()
                .map(|&t| {
                    if t > 0 {
                        outs_iter.next().expect("one output per scheduled shard")
                    } else {
                        ShardOut::empty(m_cnt, j.keep_samples)
                    }
                })
                .collect();
            let r = engine::merge_shards(m_cnt, outs, j.keep_samples);
            outcomes.push(Outcome {
                label: j.plan.label.clone(),
                executor: "batch".to_string(),
                per_master: r.per_master,
                system: r.system,
                t_est_ms: j.plan.t_est(),
                samples: r.samples,
            });
        }
        Ok(outcomes)
    }

    /// Drain `shards` through the configured pool, results in shard
    /// order. `run_one` is a plain fn so shared-pool closures stay
    /// `'static` (they own only the `Arc` and the `Copy` shard).
    fn execute<C: Send + Sync + 'static>(
        &self,
        ctx: Arc<C>,
        shards: &[Shard],
        run_one: fn(&C, Shard) -> ShardOut,
    ) -> Vec<ShardOut> {
        if self.pool_threads == 0 {
            // Shared process pool: no spawn/join per grid at all.
            pool::run_all(
                shards
                    .iter()
                    .map(|&sh| {
                        let c = Arc::clone(&ctx);
                        move || run_one(&c, sh)
                    })
                    .collect(),
            )
        } else {
            // Explicit width: a scoped work-stealing pool of exactly
            // `pool_threads` threads (sizing tests pin this path).
            let width = self.pool_threads.min(shards.len().max(1));
            let next = AtomicUsize::new(0);
            let mut collected: Vec<(usize, ShardOut)> = std::thread::scope(|scope| {
                let ctx_ref = &ctx;
                let next_ref = &next;
                let handles: Vec<_> = (0..width)
                    .map(|_| {
                        scope.spawn(move || {
                            let mut local: Vec<(usize, ShardOut)> = Vec::new();
                            loop {
                                let i = next_ref.fetch_add(1, Ordering::Relaxed);
                                if i >= shards.len() {
                                    break;
                                }
                                local.push((i, run_one(ctx_ref, shards[i])));
                            }
                            local
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().unwrap())
                    .collect()
            });
            collected.sort_by_key(|&(i, _)| i);
            collected.into_iter().map(|(_, o)| o).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::CommModel;
    use crate::policy::PolicySpec;
    use crate::sim::{self, McOptions};

    fn job(s: &Scenario, policy: &str, seed: u64, trials: usize) -> BatchJob {
        BatchJob {
            scenario: s.clone(),
            plan: PolicySpec::new(policy, ValueModel::Markov, "markov")
                .build(s)
                .unwrap(),
            seed,
            trials,
            keep_samples: true,
            order: SampleOrder::TrialMajor,
            ziggurat: false,
        }
    }

    #[test]
    fn batched_cells_reproduce_sim_run_bit_for_bit() {
        let s = Scenario::small_scale(3, 2.0, CommModel::Stochastic);
        let jobs = vec![
            job(&s, "dedi-iter", 7, 3_000),
            job(&s, "uncoded", 7, 3_000),
            job(&s, "frac", 11, 1_000),
        ];
        let outs = BatchRunner {
            pool_threads: 3,
            cell_streams: 2,
            fused: false,
        }
        .run(&jobs)
        .unwrap();
        assert_eq!(outs.len(), jobs.len());
        for (j, o) in jobs.iter().zip(&outs) {
            let direct = sim::run(
                &j.scenario,
                &j.plan,
                &McOptions {
                    trials: j.trials,
                    seed: j.seed,
                    keep_samples: true,
                    threads: 2,
                    ziggurat: false,
                },
            );
            assert_eq!(o.system.mean(), direct.system.mean(), "{}", o.label);
            assert_eq!(o.system.sem(), direct.system.sem(), "{}", o.label);
            assert_eq!(o.system.count(), direct.system.count());
            for (a, b) in o.per_master.iter().zip(&direct.per_master) {
                assert_eq!(a.mean(), b.mean(), "{}", o.label);
            }
            assert_eq!(
                o.samples.as_ref().unwrap(),
                direct.samples.as_ref().unwrap(),
                "{}",
                o.label
            );
            assert_eq!(o.executor, "batch");
            assert_eq!(o.t_est_ms, j.plan.t_est());
        }
    }

    #[test]
    fn pool_size_does_not_change_results() {
        let s = Scenario::small_scale(5, 2.0, CommModel::Stochastic);
        let jobs = vec![job(&s, "dedi-iter", 13, 2_000), job(&s, "coded", 13, 2_000)];
        let a = BatchRunner {
            pool_threads: 1,
            cell_streams: 3,
            fused: false,
        }
        .run(&jobs)
        .unwrap();
        let b = BatchRunner {
            pool_threads: 8,
            cell_streams: 3,
            fused: false,
        }
        .run(&jobs)
        .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.system.mean(), y.system.mean());
            assert_eq!(x.samples, y.samples);
        }
    }

    #[test]
    fn zero_trial_shards_skipped_without_changing_results() {
        // trials=4 at cell_streams=3 → shard split [2, 2, 0]; the zero
        // shard is never scheduled but the merged cell still matches a
        // serial sim::run at the same stream count, bit-for-bit.
        let s = Scenario::small_scale(6, 2.0, CommModel::Stochastic);
        let jobs = vec![job(&s, "dedi-iter", 3, 4)];
        let outs = BatchRunner {
            pool_threads: 2,
            cell_streams: 3,
            fused: false,
        }
        .run(&jobs)
        .unwrap();
        let direct = sim::run(
            &s,
            &jobs[0].plan,
            &McOptions {
                trials: 4,
                seed: 3,
                keep_samples: true,
                threads: 3,
                ziggurat: false,
            },
        );
        assert_eq!(outs[0].system.count(), 4);
        assert_eq!(outs[0].system.mean(), direct.system.mean());
        assert_eq!(
            outs[0].samples.as_ref().unwrap(),
            direct.samples.as_ref().unwrap()
        );
    }

    #[test]
    fn blocked_jobs_match_run_ordered() {
        let s = Scenario::small_scale(9, 2.0, CommModel::Stochastic);
        let mut j = job(&s, "dedi-iter", 17, 2_500);
        j.order = SampleOrder::Blocked;
        let plan = j.plan.clone();
        let outs = BatchRunner {
            pool_threads: 2,
            cell_streams: 2,
            fused: false,
        }
        .run(&[j])
        .unwrap();
        let direct = sim::run_ordered(
            &s,
            &plan,
            &McOptions {
                trials: 2_500,
                seed: 17,
                keep_samples: true,
                threads: 2,
                ziggurat: false,
            },
            SampleOrder::Blocked,
        );
        assert_eq!(outs[0].system.mean(), direct.system.mean());
        assert_eq!(
            outs[0].samples.as_ref().unwrap(),
            direct.samples.as_ref().unwrap()
        );
    }

    #[test]
    fn fused_grid_is_bit_identical_to_per_cell_compile() {
        // The fused arena shares the compile arithmetic and the trial
        // loops with the per-cell path, so every order must agree to the
        // last bit — including the mixed-policy, mixed-seed grid shape a
        // real sweep produces.
        let s = Scenario::small_scale(4, 2.0, CommModel::Stochastic);
        let s2 = Scenario::small_scale(8, 3.0, CommModel::Stochastic);
        for order in [
            SampleOrder::TrialMajor,
            SampleOrder::Blocked,
            SampleOrder::Chunked,
        ] {
            let mk = || {
                let mut jobs = vec![
                    job(&s, "dedi-iter", 7, 1_500),
                    job(&s, "uncoded", 7, 1_500),
                    job(&s2, "frac", 11, 700),
                ];
                for j in &mut jobs {
                    j.order = order;
                }
                jobs
            };
            let plain = BatchRunner {
                pool_threads: 2,
                cell_streams: 2,
                fused: false,
            }
            .run(&mk())
            .unwrap();
            let fused = BatchRunner {
                pool_threads: 2,
                cell_streams: 2,
                fused: true,
            }
            .run(&mk())
            .unwrap();
            for (x, y) in plain.iter().zip(&fused) {
                assert_eq!(x.system.mean(), y.system.mean(), "{:?} {}", order, x.label);
                assert_eq!(x.system.sem(), y.system.sem(), "{:?} {}", order, x.label);
                assert_eq!(x.samples, y.samples, "{:?} {}", order, x.label);
            }
        }
    }

    #[test]
    fn fused_ziggurat_jobs_sample_the_same_law() {
        // Fused + Chunked + ziggurat: different bits from the inverse
        // transform by construction, but the same distribution — and the
        // fused/non-fused pair must still agree bit-for-bit with each
        // other (same ziggurat draws through the same core).
        let s = Scenario::small_scale(12, 2.0, CommModel::Stochastic);
        let mk = |zig: bool| {
            let mut j = job(&s, "dedi-iter", 23, 20_000);
            j.order = SampleOrder::Chunked;
            j.ziggurat = zig;
            vec![j]
        };
        let runner_fused = BatchRunner {
            pool_threads: 2,
            cell_streams: 2,
            fused: true,
        };
        let runner_plain = BatchRunner {
            pool_threads: 2,
            cell_streams: 2,
            fused: false,
        };
        let zig_fused = runner_fused.run(&mk(true)).unwrap();
        let zig_plain = runner_plain.run(&mk(true)).unwrap();
        assert_eq!(zig_fused[0].samples, zig_plain[0].samples);
        let inv = runner_plain.run(&mk(false)).unwrap();
        let (m1, m2) = (inv[0].system.mean(), zig_fused[0].system.mean());
        let sem = (inv[0].system.sem().powi(2) + zig_fused[0].system.sem().powi(2)).sqrt();
        assert!(
            (m1 - m2).abs() < 6.0 * sem,
            "ziggurat mean {m2} vs inverse-transform {m1} (6σ = {})",
            6.0 * sem
        );
    }

    #[test]
    fn invalid_plan_fails_before_sampling() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        let mut j = job(&s, "dedi-iter", 1, 100);
        j.plan.masters[0].entries[0].node = 99; // no such worker
        let err = BatchRunner::default().run(&[j]).unwrap_err();
        assert!(err.to_string().contains("batch job 0"), "{err}");
    }

    #[test]
    fn empty_batch_is_ok() {
        let outs = BatchRunner::default().run(&[]).unwrap();
        assert!(outs.is_empty());
    }
}
