//! Batched Monte-Carlo evaluation: many `(Scenario, Plan)` cells on ONE
//! shared thread pool.
//!
//! `sim::run` spawns a fresh set of threads per call, so a grid of cells
//! (a figure roster, a parameter sweep) pays the spawn + join cost once
//! per cell and leaves cores idle while a cell's slowest shard finishes.
//! [`BatchRunner`] instead flattens every cell into RNG-stream shards and
//! drains them all through one pool: by default the shared process pool
//! ([`crate::exec::pool`], zero spawns per grid), or a scoped pool of
//! exactly `pool_threads` threads when an explicit width is requested. A
//! fast cell's leftover capacity immediately picks up the next cell's
//! shards, and zero-trial trailing shards are never scheduled.
//!
//! **Bit-for-bit parity:** each cell is split into the exact shards
//! `sim::run` would use for `cell_streams` threads
//! ([`crate::sim::engine::effective_streams`] / `shard_sizes`), sampled by
//! the same [`crate::sim::engine::run_shard`] and merged in the same
//! stream order — so a batched cell's [`Outcome`] equals the serial
//! `sim::run` result for the same `(seed, cell_streams)`. That is what
//! makes the sweep rewrites of the figure harnesses golden-parity
//! testable (`rust/tests/sweep_parity.rs`).
//!
//! Common random numbers (variance-reduced policy comparisons) are a
//! seeding choice, not an engine mode: give every job the same `seed` and
//! all cells sample identical delay streams (`experiment::SweepSpec`'s
//! `crn` flag does exactly that).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::config::Scenario;
use crate::plan::Plan;
use crate::sim::engine::{self, Compiled, SampleOrder, ShardOut};

use super::{pool, Outcome};

/// One grid cell: evaluate `plan` on `scenario` for `trials` sampled
/// realizations seeded by `seed`.
pub struct BatchJob {
    pub scenario: Scenario,
    pub plan: Plan,
    /// Monte-Carlo seed (same seed across jobs = common random numbers).
    pub seed: u64,
    pub trials: usize,
    /// Keep raw per-trial system delays (needed for CDFs).
    pub keep_samples: bool,
    /// RNG consumption order (`TrialMajor` reproduces `sim::run`
    /// bit-for-bit; `Blocked` is the different-bits/same-distribution
    /// fast path — see `sim::engine`'s bit contract).
    pub order: SampleOrder,
}

/// Shared-pool batch engine over [`crate::sim::engine`] shards.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchRunner {
    /// Worker threads in the shared pool (0 = all cores).
    pub pool_threads: usize,
    /// RNG streams per cell, with `McOptions::threads` semantics: a cell's
    /// result is bit-identical to `sim::run` at this thread count
    /// (0 = all cores). Independent of `pool_threads` — the pool only
    /// decides who executes a shard, never how trials are split.
    pub cell_streams: usize,
}

/// One schedulable unit: everything `engine::run_shard_ordered` needs,
/// copied out of the job so pool closures own their inputs.
#[derive(Clone, Copy)]
struct Shard {
    job: usize,
    stream: u64,
    trials: usize,
    seed: u64,
    keep_samples: bool,
    order: SampleOrder,
}

impl BatchRunner {
    /// Evaluate every job, returning one [`Outcome`] per job in input
    /// order. Fails fast (before any sampling) if a plan does not fit its
    /// scenario.
    pub fn run(&self, jobs: &[BatchJob]) -> anyhow::Result<Vec<Outcome>> {
        for (i, j) in jobs.iter().enumerate() {
            j.plan
                .validate(&j.scenario)
                .map_err(|e| anyhow::anyhow!("batch job {i} ('{}'): {e}", j.plan.label))?;
        }
        let compiled: Arc<Vec<Compiled>> = Arc::new(
            jobs.iter()
                .map(|j| Compiled::new(&j.scenario, &j.plan))
                .collect(),
        );

        // Flatten cells into shards; shard indices are contiguous and in
        // stream order per job, so regrouping below preserves the merge
        // order `sim::run` uses. Zero-trial trailing shards (ceil-split
        // remainders) are never scheduled — their merge contribution is
        // the empty `ShardOut`, injected in stream order at regroup.
        let mut shards: Vec<Shard> = Vec::new();
        let mut sizes_per_job: Vec<Vec<usize>> = Vec::with_capacity(jobs.len());
        for (ji, j) in jobs.iter().enumerate() {
            let streams = engine::effective_streams(j.trials, self.cell_streams);
            let sizes = engine::shard_sizes(j.trials, streams);
            for (ti, &t) in sizes.iter().enumerate() {
                if t > 0 {
                    shards.push(Shard {
                        job: ji,
                        stream: ti as u64 + 1,
                        trials: t,
                        seed: j.seed,
                        keep_samples: j.keep_samples,
                        order: j.order,
                    });
                }
            }
            sizes_per_job.push(sizes);
        }

        let run_one = |c: &Compiled, sh: Shard| {
            engine::run_shard_ordered(c, sh.seed, sh.stream, sh.trials, sh.keep_samples, sh.order)
        };
        let outs: Vec<ShardOut> = if self.pool_threads == 0 {
            // Shared process pool: no spawn/join per grid at all.
            pool::run_all(
                shards
                    .iter()
                    .map(|&sh| {
                        let c = Arc::clone(&compiled);
                        move || run_one(&c[sh.job], sh)
                    })
                    .collect(),
            )
        } else {
            // Explicit width: a scoped work-stealing pool of exactly
            // `pool_threads` threads (sizing tests pin this path).
            let width = self.pool_threads.min(shards.len().max(1));
            let next = AtomicUsize::new(0);
            let mut collected: Vec<(usize, ShardOut)> = std::thread::scope(|scope| {
                let shards_ref = &shards;
                let compiled_ref = &compiled;
                let next_ref = &next;
                let run_ref = &run_one;
                let handles: Vec<_> = (0..width)
                    .map(|_| {
                        scope.spawn(move || {
                            let mut local: Vec<(usize, ShardOut)> = Vec::new();
                            loop {
                                let i = next_ref.fetch_add(1, Ordering::Relaxed);
                                if i >= shards_ref.len() {
                                    break;
                                }
                                let sh = shards_ref[i];
                                local.push((i, run_ref(&compiled_ref[sh.job], sh)));
                            }
                            local
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().unwrap())
                    .collect()
            });
            collected.sort_by_key(|&(i, _)| i);
            collected.into_iter().map(|(_, o)| o).collect()
        };

        let mut outs_iter = outs.into_iter();
        let mut outcomes = Vec::with_capacity(jobs.len());
        for (ji, j) in jobs.iter().enumerate() {
            let m_cnt = compiled[ji].n_masters();
            let outs: Vec<ShardOut> = sizes_per_job[ji]
                .iter()
                .map(|&t| {
                    if t > 0 {
                        outs_iter.next().expect("one output per scheduled shard")
                    } else {
                        ShardOut::empty(m_cnt, j.keep_samples)
                    }
                })
                .collect();
            let r = engine::merge_shards(m_cnt, outs, j.keep_samples);
            outcomes.push(Outcome {
                label: j.plan.label.clone(),
                executor: "batch".to_string(),
                per_master: r.per_master,
                system: r.system,
                t_est_ms: j.plan.t_est(),
                samples: r.samples,
            });
        }
        Ok(outcomes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::CommModel;
    use crate::policy::PolicySpec;
    use crate::sim::{self, McOptions};

    fn job(s: &Scenario, policy: &str, seed: u64, trials: usize) -> BatchJob {
        BatchJob {
            scenario: s.clone(),
            plan: PolicySpec::new(policy, ValueModel::Markov, "markov")
                .build(s)
                .unwrap(),
            seed,
            trials,
            keep_samples: true,
            order: SampleOrder::TrialMajor,
        }
    }

    #[test]
    fn batched_cells_reproduce_sim_run_bit_for_bit() {
        let s = Scenario::small_scale(3, 2.0, CommModel::Stochastic);
        let jobs = vec![
            job(&s, "dedi-iter", 7, 3_000),
            job(&s, "uncoded", 7, 3_000),
            job(&s, "frac", 11, 1_000),
        ];
        let outs = BatchRunner {
            pool_threads: 3,
            cell_streams: 2,
        }
        .run(&jobs)
        .unwrap();
        assert_eq!(outs.len(), jobs.len());
        for (j, o) in jobs.iter().zip(&outs) {
            let direct = sim::run(
                &j.scenario,
                &j.plan,
                &McOptions {
                    trials: j.trials,
                    seed: j.seed,
                    keep_samples: true,
                    threads: 2,
                },
            );
            assert_eq!(o.system.mean(), direct.system.mean(), "{}", o.label);
            assert_eq!(o.system.sem(), direct.system.sem(), "{}", o.label);
            assert_eq!(o.system.count(), direct.system.count());
            for (a, b) in o.per_master.iter().zip(&direct.per_master) {
                assert_eq!(a.mean(), b.mean(), "{}", o.label);
            }
            assert_eq!(
                o.samples.as_ref().unwrap(),
                direct.samples.as_ref().unwrap(),
                "{}",
                o.label
            );
            assert_eq!(o.executor, "batch");
            assert_eq!(o.t_est_ms, j.plan.t_est());
        }
    }

    #[test]
    fn pool_size_does_not_change_results() {
        let s = Scenario::small_scale(5, 2.0, CommModel::Stochastic);
        let jobs = vec![job(&s, "dedi-iter", 13, 2_000), job(&s, "coded", 13, 2_000)];
        let a = BatchRunner {
            pool_threads: 1,
            cell_streams: 3,
        }
        .run(&jobs)
        .unwrap();
        let b = BatchRunner {
            pool_threads: 8,
            cell_streams: 3,
        }
        .run(&jobs)
        .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.system.mean(), y.system.mean());
            assert_eq!(x.samples, y.samples);
        }
    }

    #[test]
    fn zero_trial_shards_skipped_without_changing_results() {
        // trials=4 at cell_streams=3 → shard split [2, 2, 0]; the zero
        // shard is never scheduled but the merged cell still matches a
        // serial sim::run at the same stream count, bit-for-bit.
        let s = Scenario::small_scale(6, 2.0, CommModel::Stochastic);
        let jobs = vec![job(&s, "dedi-iter", 3, 4)];
        let outs = BatchRunner {
            pool_threads: 2,
            cell_streams: 3,
        }
        .run(&jobs)
        .unwrap();
        let direct = sim::run(
            &s,
            &jobs[0].plan,
            &McOptions {
                trials: 4,
                seed: 3,
                keep_samples: true,
                threads: 3,
            },
        );
        assert_eq!(outs[0].system.count(), 4);
        assert_eq!(outs[0].system.mean(), direct.system.mean());
        assert_eq!(
            outs[0].samples.as_ref().unwrap(),
            direct.samples.as_ref().unwrap()
        );
    }

    #[test]
    fn blocked_jobs_match_run_ordered() {
        let s = Scenario::small_scale(9, 2.0, CommModel::Stochastic);
        let mut j = job(&s, "dedi-iter", 17, 2_500);
        j.order = SampleOrder::Blocked;
        let plan = j.plan.clone();
        let outs = BatchRunner {
            pool_threads: 2,
            cell_streams: 2,
        }
        .run(&[j])
        .unwrap();
        let direct = sim::run_ordered(
            &s,
            &plan,
            &McOptions {
                trials: 2_500,
                seed: 17,
                keep_samples: true,
                threads: 2,
            },
            SampleOrder::Blocked,
        );
        assert_eq!(outs[0].system.mean(), direct.system.mean());
        assert_eq!(
            outs[0].samples.as_ref().unwrap(),
            direct.samples.as_ref().unwrap()
        );
    }

    #[test]
    fn invalid_plan_fails_before_sampling() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        let mut j = job(&s, "dedi-iter", 1, 100);
        j.plan.masters[0].entries[0].node = 99; // no such worker
        let err = BatchRunner::default().run(&[j]).unwrap_err();
        assert!(err.to_string().contains("batch job 0"), "{err}");
    }

    #[test]
    fn empty_batch_is_ok() {
        let outs = BatchRunner::default().run(&[]).unwrap();
        assert!(outs.is_empty());
    }
}
