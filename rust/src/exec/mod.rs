//! Unified execution: one [`Executor`] seam over the Monte-Carlo engine
//! and the real multi-threaded coordinator.
//!
//! Figures, the CLI and services evaluate a `(Scenario, Plan)` pair
//! through the same call site and swap the engine behind it:
//!
//! * [`SimExecutor`] — statistical evaluation, `opts.trials` sampled
//!   realizations ([`crate::sim`]);
//! * [`CoordinatorExecutor`] — one real deployment: encode, dispatch
//!   over delay-injected channels, decode at any `L_m` arrivals
//!   ([`crate::coordinator`]).
//!
//! Both produce the same [`Outcome`] (per-master + system delay
//! [`Summary`]s plus the planner's `t_est`), so `plan export` → `plan
//! run --executor sim|coordinator` is a drop-in swap.
//!
//! [`batch`] adds the grid-scale engine: [`BatchRunner`] evaluates many
//! `(Scenario, Plan)` cells on one shared thread pool, bit-identical per
//! cell to [`SimExecutor`] (the `experiment` sweep layer runs on it).

pub mod batch;
pub mod pool;

pub use batch::{BatchJob, BatchRunner};

use crate::config::Scenario;
use crate::coordinator::{self, Backend, RunOptions};
use crate::plan::Plan;
use crate::sim::{self, McOptions};
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Options shared by every executor. Executors read the subset they
/// understand (documented per field).
#[derive(Clone, Debug)]
pub struct ExecOptions {
    /// Monte-Carlo trials (sim only; the coordinator is one realization).
    pub trials: usize,
    pub seed: u64,
    /// Worker threads for the sim engine (0 = all cores).
    pub threads: usize,
    /// Keep raw per-trial system delays (sim only; needed for CDFs).
    pub keep_samples: bool,
    /// Task width `S_m` (coordinator only).
    pub cols: usize,
    /// Wall-clock seconds per virtual millisecond (coordinator only).
    pub time_scale: f64,
    /// Verify recovered products against the direct computation
    /// (coordinator only).
    pub verify: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        Self {
            trials: 10_000,
            seed: 2022,
            threads: 0,
            keep_samples: false,
            cols: 64,
            time_scale: 1e-4,
            verify: false,
        }
    }
}

/// Common execution result: per-master + system delay summaries.
#[derive(Clone, Debug)]
pub struct Outcome {
    /// Plan legend label.
    pub label: String,
    /// Which executor produced this ("sim" / "coordinator").
    pub executor: String,
    /// Per-master completion-delay summaries (ms).
    pub per_master: Vec<Summary>,
    /// System delay = max over masters (ms).
    pub system: Summary,
    /// Planner's predicted system delay `max_m t_m*` (ms).
    pub t_est_ms: f64,
    /// Raw system-delay samples when requested and available.
    pub samples: Option<Vec<f64>>,
}

impl Outcome {
    /// Mean observed system delay (ms).
    pub fn system_mean_ms(&self) -> f64 {
        self.system.mean()
    }

    /// Structured export (one record per master + the system view).
    ///
    /// Non-finite delays serialize as JSON `null` (JSON has no `Inf`),
    /// which on its own loses the *reason* on a round-trip — so every
    /// outcome also carries an explicit `"feasible"` flag: `false` marks
    /// an infeasible cell (Σl < L, a starved serving job, …) whose mean
    /// delay is `∞`, distinguishing it from merely-missing data.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("label", Json::Str(self.label.clone()));
        j.set("executor", Json::Str(self.executor.clone()));
        j.set("mean_system_delay_ms", Json::Num(self.system.mean()));
        j.set("feasible", Json::Bool(self.system.mean().is_finite()));
        j.set("sem_ms", Json::Num(self.system.sem()));
        j.set("t_est_ms", Json::Num(self.t_est_ms));
        j.set("realizations", Json::Num(self.system.count() as f64));
        j.set(
            "per_master_mean_ms",
            Json::from_f64_slice(
                &self
                    .per_master
                    .iter()
                    .map(|s| s.mean())
                    .collect::<Vec<_>>(),
            ),
        );
        j
    }
}

/// One engine that can evaluate a plan on a scenario.
pub trait Executor {
    /// Registry-style name ("sim", "coordinator").
    fn name(&self) -> &'static str;

    /// Evaluate `plan` on `s`.
    fn execute(&self, s: &Scenario, plan: &Plan, opts: &ExecOptions)
        -> anyhow::Result<Outcome>;
}

/// Monte-Carlo evaluation (§V methodology): `opts.trials` sampled
/// realizations, thread-parallel.
pub struct SimExecutor;

impl Executor for SimExecutor {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn execute(
        &self,
        s: &Scenario,
        plan: &Plan,
        opts: &ExecOptions,
    ) -> anyhow::Result<Outcome> {
        plan.validate(s)?;
        let r = sim::run(
            s,
            plan,
            &McOptions {
                trials: opts.trials,
                seed: opts.seed,
                keep_samples: opts.keep_samples,
                threads: opts.threads,
                ziggurat: false,
            },
        );
        Ok(Outcome {
            label: plan.label.clone(),
            executor: self.name().to_string(),
            per_master: r.per_master,
            system: r.system,
            t_est_ms: plan.t_est(),
            samples: r.samples,
        })
    }
}

/// Real deployment through the multi-threaded coordinator: one
/// realization with actual encode / mat-vec / decode.
pub struct CoordinatorExecutor {
    /// Compute backend for encode + worker mat-vec.
    pub backend: Backend,
}

impl Default for CoordinatorExecutor {
    fn default() -> Self {
        Self {
            backend: Backend::Native,
        }
    }
}

impl Executor for CoordinatorExecutor {
    fn name(&self) -> &'static str {
        "coordinator"
    }

    fn execute(
        &self,
        s: &Scenario,
        plan: &Plan,
        opts: &ExecOptions,
    ) -> anyhow::Result<Outcome> {
        plan.validate(s)?;
        let report = coordinator::run_plan(
            s,
            plan,
            &RunOptions {
                cols: opts.cols,
                time_scale: opts.time_scale,
                backend: self.backend.clone(),
                seed: opts.seed,
                verify: opts.verify,
                transport: coordinator::Transport::Thread,
                fault: None,
                health: crate::health::HealthConfig::default(),
            },
        )?;
        let mut per_master = Vec::with_capacity(report.masters.len());
        for mr in &report.masters {
            let mut sm = Summary::new();
            sm.push(mr.completion_ms);
            per_master.push(sm);
        }
        let mut system = Summary::new();
        system.push(report.system_completion_ms());
        Ok(Outcome {
            label: plan.label.clone(),
            executor: self.name().to_string(),
            per_master,
            system,
            t_est_ms: plan.t_est(),
            samples: opts
                .keep_samples
                .then(|| vec![report.system_completion_ms()]),
        })
    }
}

/// Resolve an executor by name ("sim" | "coordinator"; the coordinator
/// uses the native backend — construct [`CoordinatorExecutor`] directly
/// for PJRT or fault-injecting backends).
pub fn executor_by_name(name: &str) -> anyhow::Result<Box<dyn Executor>> {
    match name {
        "sim" => Ok(Box::new(SimExecutor)),
        "coordinator" => Ok(Box::new(CoordinatorExecutor::default())),
        other => anyhow::bail!("unknown executor '{other}' (sim|coordinator)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::{AShift, CommModel};
    use crate::policy::PolicySpec;

    fn tiny_scenario() -> Scenario {
        Scenario::random(
            "exec-test",
            2,
            4,
            128.0,
            AShift::Range(0.01, 0.05),
            2.0,
            CommModel::Stochastic,
            17,
        )
    }

    #[test]
    fn sim_outcome_matches_engine() {
        let s = tiny_scenario();
        let plan = PolicySpec::new("dedi-iter", ValueModel::Markov, "markov")
            .build(&s)
            .unwrap();
        let opts = ExecOptions {
            trials: 2_000,
            seed: 5,
            ..Default::default()
        };
        let out = SimExecutor.execute(&s, &plan, &opts).unwrap();
        let direct = sim::run(
            &s,
            &plan,
            &McOptions {
                trials: 2_000,
                seed: 5,
                keep_samples: false,
                threads: 0,
                ziggurat: false,
            },
        );
        assert_eq!(out.system.mean(), direct.system.mean());
        assert_eq!(out.executor, "sim");
        assert_eq!(out.per_master.len(), 2);
        assert!((out.t_est_ms - plan.t_est()).abs() < 1e-12);
    }

    #[test]
    fn coordinator_executor_runs_native() {
        let s = tiny_scenario();
        let plan = PolicySpec::new("dedi-iter", ValueModel::Markov, "markov")
            .build(&s)
            .unwrap();
        let opts = ExecOptions {
            seed: 5,
            cols: 16,
            time_scale: 1e-6,
            verify: true,
            ..Default::default()
        };
        let out = CoordinatorExecutor::default()
            .execute(&s, &plan, &opts)
            .unwrap();
        assert_eq!(out.executor, "coordinator");
        assert_eq!(out.system.count(), 1);
        assert!(out.system_mean_ms().is_finite() && out.system_mean_ms() > 0.0);
    }

    #[test]
    fn executor_by_name_resolves() {
        assert_eq!(executor_by_name("sim").unwrap().name(), "sim");
        assert_eq!(
            executor_by_name("coordinator").unwrap().name(),
            "coordinator"
        );
        assert!(executor_by_name("quantum").is_err());
    }

    #[test]
    fn outcome_json_parses_back() {
        let s = tiny_scenario();
        let plan = PolicySpec::new("frac", ValueModel::Markov, "markov")
            .build(&s)
            .unwrap();
        let out = SimExecutor
            .execute(
                &s,
                &plan,
                &ExecOptions {
                    trials: 500,
                    ..Default::default()
                },
            )
            .unwrap();
        let j = out.to_json().to_string_pretty();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(
            back.get("executor").and_then(|v| v.as_str()),
            Some("sim")
        );
        assert_eq!(
            back.get("realizations").and_then(|v| v.as_usize()),
            Some(500)
        );
        assert_eq!(back.get("feasible").and_then(|v| v.as_bool()), Some(true));
    }

    #[test]
    fn infeasible_outcome_exports_null_delay_with_explicit_flag() {
        // The round-trip-fidelity regression: a cell whose delay is ∞
        // must not silently collapse into "no data" — the JSON carries
        // `"mean_system_delay_ms": null` AND `"feasible": false`, and a
        // parser can reconstruct the infeasibility from the export.
        let mut sm = Summary::new();
        sm.push(f64::INFINITY);
        let out = Outcome {
            label: "starved".into(),
            executor: "serve".into(),
            per_master: vec![sm.clone()],
            system: sm,
            t_est_ms: 1.0,
            samples: None,
        };
        let text = out.to_json().to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(
            back.get("mean_system_delay_ms"),
            Some(&crate::util::json::Json::Null),
            "non-finite delay must serialize as null"
        );
        assert_eq!(back.get("feasible").and_then(|v| v.as_bool()), Some(false));
        // Export → parse → re-export is stable (no information decays
        // further on a second round-trip).
        assert_eq!(
            crate::util::json::parse(&back.to_string_pretty()).unwrap(),
            back
        );
    }
}
