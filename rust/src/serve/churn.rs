//! Worker churn: scripted join/leave/throttle events and their
//! compilation into per-worker [`CapacityProfile`]s.
//!
//! A [`ChurnScript`] is the static description of how the shared worker
//! fleet changes over a serving run's virtual timeline: workers leave
//! (capacity → 0; in-flight work suspends and resumes on rejoin), join
//! back, or get throttled to a fraction of their planned rate. The
//! script is known up front — the serving loop queries the *state at
//! admission time* for planning (the fingerprint the plan cache keys
//! on) and warps in-flight sub-task durations through the full profile
//! (see [`CapacityProfile::warp`]), so no event rescheduling is ever
//! needed.

use crate::sim::engine::CapacityProfile;
use crate::util::rng::Rng;

/// What happens to a worker at one churn event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ChurnAction {
    /// Capacity → 0: the worker is gone; its in-flight work suspends.
    Leave,
    /// Capacity → 1: back at full planned rate.
    Join,
    /// Capacity → the given factor (relative to the fitted rate).
    Throttle(f64),
}

/// One scripted fleet change.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnEvent {
    /// Virtual time of the change (ms).
    pub at_ms: f64,
    /// 1-based worker id.
    pub worker: usize,
    pub action: ChurnAction,
}

/// A whole run's scripted fleet timeline.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChurnScript {
    pub events: Vec<ChurnEvent>,
}

/// Synthesized scripts never exceed this many events — a guard against
/// degenerate `t_ref / rate` spacings producing absurd timelines.
const MAX_SYNTH_EVENTS: usize = 200_000;

impl ChurnScript {
    /// The empty script (a static fleet).
    pub fn none() -> Self {
        Self::default()
    }

    /// Build a script from events in any order (stable time sort —
    /// same-instant events keep their given order). Used by
    /// `health::churn_from_faults`, which emits per-spec timelines that
    /// interleave.
    pub fn from_events(mut events: Vec<ChurnEvent>) -> Self {
        events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
        Self { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Check every event against a fleet of `n_workers` workers.
    pub fn validate(&self, n_workers: usize) -> anyhow::Result<()> {
        for e in &self.events {
            anyhow::ensure!(
                e.at_ms.is_finite() && e.at_ms >= 0.0,
                "churn event time {} must be finite and ≥ 0",
                e.at_ms
            );
            anyhow::ensure!(
                (1..=n_workers).contains(&e.worker),
                "churn event names worker {} (scenario has workers 1..={n_workers})",
                e.worker
            );
            if let ChurnAction::Throttle(f) = e.action {
                anyhow::ensure!(
                    f.is_finite() && f >= 0.0,
                    "throttle factor {f} must be finite and ≥ 0"
                );
            }
        }
        Ok(())
    }

    /// Compile into per-node capacity profiles: index 0 is the
    /// master-local slot (always constant — churn addresses shared
    /// workers only), index `w` is worker `w`. Events apply in time
    /// order (ties: script order).
    pub fn profiles(&self, n_workers: usize) -> anyhow::Result<Vec<CapacityProfile>> {
        self.validate(n_workers)?;
        let mut sorted = self.events.clone();
        sorted.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms)); // stable
        let mut points: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n_workers + 1];
        for e in &sorted {
            let f = match e.action {
                ChurnAction::Leave => 0.0,
                ChurnAction::Join => 1.0,
                ChurnAction::Throttle(f) => f,
            };
            points[e.worker].push((e.at_ms, f));
        }
        points
            .into_iter()
            .map(CapacityProfile::from_breakpoints)
            .collect()
    }

    /// Number of script events at or before `t` — the fleet "epoch"
    /// stamped on job records for observability.
    pub fn epoch_at(&self, t: f64) -> usize {
        self.events.iter().filter(|e| e.at_ms <= t).count()
    }

    /// Synthesize a leave/rejoin timeline: every `t_ref / rate` ms one
    /// seed-chosen worker leaves and rejoins after `downtime` (clamped
    /// to [0.05, 0.95]) of that cycle, until `horizon_ms`. `rate = 0`
    /// (or an empty fleet) yields the empty script. Because the
    /// downtime is strictly shorter than the cycle, at most one worker
    /// is away at any instant — the fleet state space stays small and
    /// the serving layer's plan cache converges after one cycle per
    /// distinct worker.
    pub fn synthesize(
        n_workers: usize,
        rate: f64,
        downtime: f64,
        t_ref: f64,
        horizon_ms: f64,
        seed: u64,
    ) -> Self {
        if !(rate.is_finite() && rate > 0.0) || n_workers == 0 {
            return Self::none();
        }
        let spacing = t_ref / rate;
        if !(spacing.is_finite() && spacing > 0.0) {
            return Self::none();
        }
        let down = spacing * downtime.clamp(0.05, 0.95);
        let mut rng = Rng::new(seed ^ 0xC42A_51ED);
        let mut events = Vec::new();
        let mut t = spacing;
        while t < horizon_ms && events.len() + 2 <= MAX_SYNTH_EVENTS {
            let w = 1 + rng.index(n_workers);
            events.push(ChurnEvent {
                at_ms: t,
                worker: w,
                action: ChurnAction::Leave,
            });
            events.push(ChurnEvent {
                at_ms: t + down,
                worker: w,
                action: ChurnAction::Join,
            });
            t += spacing;
        }
        Self { events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_follow_leave_join_throttle() {
        let script = ChurnScript {
            events: vec![
                ChurnEvent { at_ms: 10.0, worker: 2, action: ChurnAction::Leave },
                ChurnEvent { at_ms: 20.0, worker: 2, action: ChurnAction::Join },
                ChurnEvent { at_ms: 15.0, worker: 1, action: ChurnAction::Throttle(0.25) },
            ],
        };
        let profiles = script.profiles(3).unwrap();
        assert_eq!(profiles.len(), 4);
        assert!(profiles[0].is_constant(), "local slot never churns");
        assert!(profiles[3].is_constant(), "untouched worker stays constant");
        assert_eq!(profiles[2].factor_at(5.0), 1.0);
        assert_eq!(profiles[2].factor_at(10.0), 0.0);
        assert_eq!(profiles[2].factor_at(19.9), 0.0);
        assert_eq!(profiles[2].factor_at(20.0), 1.0);
        assert_eq!(profiles[1].factor_at(14.0), 1.0);
        assert_eq!(profiles[1].factor_at(15.0), 0.25);
        // Epochs count events at or before t.
        assert_eq!(script.epoch_at(0.0), 0);
        assert_eq!(script.epoch_at(10.0), 1);
        assert_eq!(script.epoch_at(15.0), 2);
        assert_eq!(script.epoch_at(1e9), 3);
    }

    #[test]
    fn validation_rejects_malformed_events() {
        let bad_worker = ChurnScript {
            events: vec![ChurnEvent { at_ms: 1.0, worker: 9, action: ChurnAction::Leave }],
        };
        assert!(bad_worker.validate(3).is_err());
        let bad_time = ChurnScript {
            events: vec![ChurnEvent { at_ms: f64::NAN, worker: 1, action: ChurnAction::Join }],
        };
        assert!(bad_time.validate(3).is_err());
        let bad_factor = ChurnScript {
            events: vec![ChurnEvent { at_ms: 1.0, worker: 1, action: ChurnAction::Throttle(-0.5) }],
        };
        assert!(bad_factor.validate(3).is_err());
        assert!(ChurnScript::none().validate(0).is_ok());
    }

    #[test]
    fn synthesized_scripts_alternate_leave_join_and_terminate() {
        let sc = ChurnScript::synthesize(5, 1.0, 0.5, 20.0, 200.0, 7);
        assert!(!sc.is_empty());
        sc.validate(5).unwrap();
        assert_eq!(sc.events.len() % 2, 0);
        for pair in sc.events.chunks(2) {
            assert_eq!(pair[0].action, ChurnAction::Leave);
            assert_eq!(pair[1].action, ChurnAction::Join);
            assert_eq!(pair[0].worker, pair[1].worker);
            assert!(pair[1].at_ms > pair[0].at_ms);
            // Downtime strictly inside the cycle: at most one worker out.
            assert!(pair[1].at_ms - pair[0].at_ms < 20.0);
        }
        // Deterministic in the seed; different seeds pick differently.
        assert_eq!(sc, ChurnScript::synthesize(5, 1.0, 0.5, 20.0, 200.0, 7));
        // Zero rate or empty fleet → empty script.
        assert!(ChurnScript::synthesize(5, 0.0, 0.5, 20.0, 200.0, 7).is_empty());
        assert!(ChurnScript::synthesize(0, 1.0, 0.5, 20.0, 200.0, 7).is_empty());
        // Degenerate spacings terminate via the event cap.
        let huge = ChurnScript::synthesize(5, 1e12, 0.5, 1.0, 1e9, 7);
        assert!(huge.events.len() <= super::MAX_SYNTH_EVENTS);
    }
}
