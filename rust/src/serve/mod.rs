//! Online serving: task arrivals over time + worker churn on one shared
//! heterogeneous fleet.
//!
//! The paper plans ONE batch of `M` matmul tasks; a production system
//! serves a continuous stream (the regime of Stream Distributed Coded
//! Computing, arXiv:2103.01921). This module is that serving layer, in
//! virtual time:
//!
//! * **Arrivals** — each master receives `jobs` tasks from a
//!   deterministic, Poisson, or flash-crowd burst process whose mean
//!   inter-arrival is `t*_base / load_factor` (`t*_base` = the
//!   full-fleet planner estimate), so `load_factor < 1` is underload
//!   and `> 1` overload. Each master serves its own queue FIFO, one job
//!   at a time; all masters run concurrently on the shared fleet (the
//!   paper's fractional sharing).
//! * **Event core** — a hierarchical timer wheel
//!   ([`wheel::TimerWheel`]) behind the [`wheel::EventQueue`] trait
//!   drives the virtual clock; the original binary heap stays in-tree
//!   as [`wheel::HeapQueue`], the parity oracle. Both order events by
//!   `(total_cmp(time), push seq)`, so they are bit-for-bit
//!   interchangeable ([`ServeConfig::queue`] selects; tests pin it).
//! * **Tail stats** — per-master and system sojourn tails accumulate in
//!   bounded-memory [`QuantileSketch`]es and Welford [`Summary`]s as
//!   jobs complete, and [`ServeConfig::record_cap`] bounds the retained
//!   [`JobRecord`] ring — million-job overload runs hold O(1) memory
//!   per stream. The exact [`percentile`] path survives as the test
//!   oracle ([`p99_sojourn_ms`]).
//! * **Admission → (re)planning** — when a job reaches the head of its
//!   queue, the serving loop needs a plan for the CURRENT fleet state.
//!   A **plan cache** keyed by the fleet fingerprint (every worker's
//!   capacity factor, bit-exact) skips replanning while the state is
//!   unchanged; on a miss, the policy registry replans on the active
//!   subset ([`crate::config::Scenario::subset_workers`]), with a
//!   **warm start** for SCA-load policies — the previous plan's
//!   [`crate::alloc::Allocation`] (projected onto the surviving
//!   workers) seeds Algorithm 3 instead of the Theorem-1 start.
//! * **Churn** — a [`ChurnScript`] moves workers in/out/throttled over
//!   the timeline; compiled per-worker [`CapacityProfile`]s both drive
//!   the fingerprint and time-warp in-flight sub-task durations
//!   ([`crate::sim::engine::Compiled::sample_master_warped`]), so a job
//!   whose worker leaves mid-service suspends that link (and starves —
//!   `feasible: false` — only if the surviving coded links cannot reach
//!   `L_m`).
//! * **Records** — every job yields a [`JobRecord`] (arrival, start,
//!   service, sojourn, epoch, cache hit) that streams as one JSON line
//!   from `coded-coop serve`; the aggregate [`ServeOutcome`] reports
//!   per-master and system sojourn summaries (mean / p99).
//!
//! **Parity contract:** with constant shares and no churn, the plan is
//! built once, every admission is a cache hit, and job service times
//! are drawn from the stream `Rng::new(seed).fork(1)` through the exact
//! batch-kernel draw ([`Compiled::sample_master`]) — so a deterministic
//! lockstep arrival pattern reproduces `sim::run`'s completion delays
//! **bit-for-bit** on the same seed (`rust/tests/serving.rs` pins this).

pub mod churn;
pub mod tcp;
pub mod wheel;

pub use churn::{ChurnAction, ChurnEvent, ChurnScript};
pub use tcp::{TcpJobRecord, TcpServeConfig, TcpServeOutcome};
pub use wheel::{EventQueue, HeapQueue, TimerWheel};

use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::Arc;

use crate::alloc::{self, markov, sca, Allocation, EffLink};
use crate::config::Scenario;
use crate::exec::pool;
use crate::health::{self, FaultPlan, HealthConfig};
use crate::plan::{self, Plan};
use crate::policy::{LoadAllocator, PolicySpec};
use crate::sim::engine::{CapacityProfile, Compiled};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::{percentile, QuantileSketch, Summary};

/// XOR salt separating the arrival-time RNG from the service stream —
/// service draws must consume `Rng::new(seed).fork(1)` exactly like the
/// batch engine's stream 1, independent of how arrivals are generated.
const ARRIVAL_SALT: u64 = 0x0A44_1CA1;

/// XOR salt for [`ServiceStreams::PerMaster`] service draws: master `m`
/// consumes `Rng::new(seed ^ SHARD_SALT).fork(m + 1)`, a stream
/// disjoint from both the shared-service stream (`fork(1)` unsalted)
/// and the arrival streams ([`ARRIVAL_SALT`]). Per-master streams make
/// each master's timeline independent of event interleaving, which is
/// what lets [`run_sharded`] farm masters out to the pool bit-for-bit.
const SHARD_SALT: u64 = 0x5EA4_D00D;

/// Jobs released at each flash-crowd epoch of
/// [`ArrivalProcess::Burst`]. Burst epochs are Poisson with mean
/// spacing `BURST_SIZE × period`, so the long-run arrival rate still
/// matches `load_factor` — the burstiness moves mass into the queue's
/// tail, not into the mean load.
pub const BURST_SIZE: usize = 8;

/// Shared validation of the arrival/churn knobs, used by both direct
/// [`ServeConfig`] runs and `experiment::ArrivalSpec` templates so the
/// two entry paths cannot drift. (Job counts are NOT checked here: a
/// zero-job stream is a legitimate library edge case, while the sweep
/// layer rejects it because an empty cell would export as a feasible
/// 0 ms measurement.)
pub fn validate_arrival_knobs(
    load_factor: f64,
    churn_rate: f64,
    churn_downtime: f64,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        load_factor.is_finite() && load_factor > 0.0,
        "load_factor must be positive and finite, got {load_factor}"
    );
    anyhow::ensure!(
        churn_rate.is_finite() && churn_rate >= 0.0,
        "churn_rate must be finite and ≥ 0, got {churn_rate}"
    );
    anyhow::ensure!(
        churn_downtime > 0.0 && churn_downtime < 1.0,
        "churn_downtime must be in (0, 1), got {churn_downtime}"
    );
    Ok(())
}

/// Render a JSON value as one line — the JSONL record form `coded-coop
/// serve` streams. The pretty serializer's newlines are purely
/// structural (string contents escape theirs as `\n`), so stripping
/// each newline together with the indentation that follows it yields
/// equivalent compact JSON.
pub fn json_line(j: &Json) -> String {
    let pretty = j.to_string_pretty();
    let mut out = String::with_capacity(pretty.len());
    for (i, line) in pretty.lines().enumerate() {
        out.push_str(if i == 0 { line } else { line.trim_start() });
    }
    out
}

/// Per-master job arrival process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalProcess {
    /// Fixed inter-arrival `period`; every master arrives in lockstep.
    Deterministic,
    /// Exponential inter-arrivals with mean `period`, independent per
    /// master.
    Poisson,
    /// Flash crowds: [`BURST_SIZE`] jobs land simultaneously at Poisson
    /// epochs with mean spacing `BURST_SIZE × period`, independent per
    /// master. Same long-run rate as `Poisson`, far heavier queue tail
    /// — the overload catalog's arrival shape.
    Burst,
}

impl ArrivalProcess {
    pub fn as_str(self) -> &'static str {
        match self {
            ArrivalProcess::Deterministic => "deterministic",
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Burst => "burst",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "deterministic" => Ok(ArrivalProcess::Deterministic),
            "poisson" => Ok(ArrivalProcess::Poisson),
            "burst" => Ok(ArrivalProcess::Burst),
            other => {
                anyhow::bail!("unknown arrival process '{other}' (deterministic|poisson|burst)")
            }
        }
    }
}

/// Which event core drives the serving clock. Both obey the same
/// `(total_cmp(time), seq)` contract and produce identical results;
/// the heap exists as the parity oracle and the bench baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EventQueueKind {
    /// Hierarchical timer wheel ([`wheel::TimerWheel`]) — O(1)
    /// amortized per event; the production core.
    #[default]
    Wheel,
    /// Binary heap ([`wheel::HeapQueue`]) — O(log n) per event; the
    /// PR 5 core, kept as the oracle.
    Heap,
}

impl EventQueueKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EventQueueKind::Wheel => "wheel",
            EventQueueKind::Heap => "heap",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "wheel" => Ok(EventQueueKind::Wheel),
            "heap" => Ok(EventQueueKind::Heap),
            other => anyhow::bail!("unknown event queue '{other}' (wheel|heap)"),
        }
    }
}

/// How service-time draws consume randomness.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ServiceStreams {
    /// One stream (`Rng::new(seed).fork(1)`) consumed by every master
    /// in event order — the batch-engine parity contract (module docs).
    /// Results depend on the cross-master event interleaving.
    #[default]
    Shared,
    /// One independent stream per master (`SHARD_SALT`): each master's
    /// timeline is invariant to interleaving, so a sequential
    /// multi-master run and [`run_sharded`] agree bit-for-bit.
    PerMaster,
}

/// Everything one serving run needs beyond the scenario.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub policy: PolicySpec,
    pub process: ArrivalProcess,
    /// Arrival rate × mean one-shot service (per master): the mean
    /// inter-arrival is `t*_base / load_factor`.
    pub load_factor: f64,
    /// Jobs per master.
    pub jobs: usize,
    /// Explicit fleet timeline; `None` synthesizes one from
    /// `churn_rate` / `churn_downtime` ([`ChurnScript::synthesize`]).
    pub script: Option<ChurnScript>,
    /// Health-driven churn: when set (and no explicit `script`), the
    /// fleet timeline is what the coordinator's health layer would
    /// OBSERVE under this fault plan — crashes become leaves after the
    /// missed-beat window, gray failures after the stall window, spikes
    /// and slow starts become throttles with breaker-probed recovery
    /// ([`health::churn_from_faults`]). Takes precedence over the
    /// rate-based `churn_rate` synthesis.
    pub faults: Option<FaultPlan>,
    /// Worker leave/rejoin cycles per `t*_base` (0 = static fleet).
    pub churn_rate: f64,
    /// Fraction of each churn cycle the worker spends away.
    pub churn_downtime: f64,
    pub seed: u64,
    /// Reuse plans across admissions with an unchanged fleet state
    /// (disable to force a cold replan per admission — the plan-cache
    /// parity tests do).
    pub use_cache: bool,
    /// Seed SCA-load replans with the previous allocation.
    pub warm_start: bool,
    /// Event core driving the virtual clock (results are identical
    /// either way — the knob exists for the parity tests and benches).
    pub queue: EventQueueKind,
    /// Retain at most this many [`JobRecord`]s (0 = keep every job).
    /// A capped run keeps the LAST `record_cap` records in arrival
    /// order — a bounded ring — while the sketches and summaries still
    /// see every job, so tails stay exact-to-bound at O(1) memory.
    pub record_cap: usize,
    /// Service-draw stream layout (shared = batch parity, per-master =
    /// interleaving-invariant; see [`ServiceStreams`]).
    pub streams: ServiceStreams,
}

impl ServeConfig {
    /// Defaults: deterministic arrivals at 0.8 load, 50 jobs/master,
    /// static fleet, cache + warm starts on, timer-wheel event core,
    /// unbounded records, shared service stream.
    pub fn new(policy: PolicySpec) -> Self {
        Self {
            policy,
            process: ArrivalProcess::Deterministic,
            load_factor: 0.8,
            jobs: 50,
            script: None,
            faults: None,
            churn_rate: 0.0,
            churn_downtime: 0.5,
            seed: 2022,
            use_cache: true,
            warm_start: true,
            queue: EventQueueKind::default(),
            record_cap: 0,
            streams: ServiceStreams::default(),
        }
    }
}

/// One served job's lifecycle.
#[derive(Clone, Debug, PartialEq)]
pub struct JobRecord {
    /// Per-master job index (arrival order).
    pub job: usize,
    pub master: usize,
    pub arrival_ms: f64,
    /// Admission (service start) time.
    pub start_ms: f64,
    /// Sampled service duration; `∞` = the job starved (the coded links
    /// still finishing carry fewer than `L_m` rows after churn).
    pub service_ms: f64,
    /// Churn-script epoch at admission (events at or before start).
    pub epoch: usize,
    /// Whether admission reused a cached plan for the fleet state.
    pub cache_hit: bool,
}

impl JobRecord {
    pub fn feasible(&self) -> bool {
        self.service_ms.is_finite()
    }

    pub fn wait_ms(&self) -> f64 {
        self.start_ms - self.arrival_ms
    }

    pub fn completion_ms(&self) -> f64 {
        self.start_ms + self.service_ms
    }

    /// Arrival → completion (the serving metric; `∞` when starved).
    pub fn sojourn_ms(&self) -> f64 {
        self.completion_ms() - self.arrival_ms
    }

    /// One streaming record. Non-finite durations serialize as `null`
    /// with the explicit `"feasible": false` flag alongside, so an
    /// export → parse round-trip keeps the starvation information.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("job", Json::Num(self.job as f64));
        j.set("master", Json::Num(self.master as f64));
        j.set("arrival_ms", Json::Num(self.arrival_ms));
        j.set("start_ms", Json::Num(self.start_ms));
        j.set("wait_ms", Json::Num(self.wait_ms()));
        j.set("service_ms", Json::Num(self.service_ms));
        j.set("sojourn_ms", Json::Num(self.sojourn_ms()));
        j.set("feasible", Json::Bool(self.feasible()));
        j.set("epoch", Json::Num(self.epoch as f64));
        j.set("cache_hit", Json::Bool(self.cache_hit));
        j
    }
}

/// Aggregate result of one serving run.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// Plan legend label (policy roster name).
    pub label: String,
    /// Retained job records in admission order — every job when
    /// [`ServeConfig::record_cap`] is 0, else the last `record_cap`
    /// (statistics below always cover EVERY job).
    pub records: Vec<JobRecord>,
    /// Sojourn summaries over FEASIBLE jobs per master.
    pub per_master: Vec<Summary>,
    /// Sojourn summary over all feasible jobs.
    pub system: Summary,
    /// Bounded-memory sojourn tail per master (feasible jobs) — see
    /// [`QuantileSketch`] for the rank-error bound.
    pub per_master_sketch: Vec<QuantileSketch>,
    /// Bounded-memory system sojourn tail (feasible jobs).
    pub system_sketch: QuantileSketch,
    /// Jobs recorded per master, starved ones included (independent of
    /// the record ring, so a capped run still knows who had traffic).
    pub per_master_jobs: Vec<usize>,
    /// Total jobs recorded (= Σ `per_master_jobs`).
    pub jobs: usize,
    /// The t = 0 fleet plan's predicted system delay.
    pub t_est_ms: f64,
    /// The plan of the initial fleet state.
    pub cold_plan: Plan,
    /// Plans actually built (cache misses).
    pub replans: usize,
    /// Admissions that reused a cached plan.
    pub cache_hits: usize,
    /// Jobs that never completed (recorded `feasible: false`).
    pub infeasible: usize,
    /// Total SCA subproblem solves across replans (0 for closed-form
    /// load policies).
    pub sca_iters: usize,
    /// Mean inter-arrival the run used (`t*_base / load_factor`).
    pub period_ms: f64,
}

impl ServeOutcome {
    /// Sojourns of the feasible RETAINED jobs, admission order — the
    /// exact-path view. Covers every job only when `record_cap` was 0;
    /// capped runs should read the sketches instead.
    pub fn sojourn_samples(&self) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| r.feasible())
            .map(JobRecord::sojourn_ms)
            .collect()
    }

    /// p99 sojourn over ALL feasible jobs (`None` when nothing
    /// completed), read from the system sketch in O(stored items) —
    /// accurate to the sketch's documented rank error and independent
    /// of the record ring. The exact-path oracle is
    /// [`p99_sojourn_ms`]; tests pin the two within bound.
    pub fn p99_ms(&self) -> Option<f64> {
        self.system_sketch.quantile(0.99)
    }

    /// Per-master p99 from the bounded-memory sketches.
    pub fn p99_master_ms(&self, m: usize) -> Option<f64> {
        self.per_master_sketch.get(m)?.quantile(0.99)
    }
}

/// EXACT p99 sojourn over the feasible jobs of a record set (`None`
/// when nothing completed). This is the test oracle for the sketch
/// path — it re-collects a `Vec<f64>` and sorts, so production readouts
/// go through [`ServeOutcome::p99_ms`] instead.
pub fn p99_sojourn_ms(records: &[JobRecord]) -> Option<f64> {
    let xs: Vec<f64> = records
        .iter()
        .filter(|r| r.feasible())
        .map(JobRecord::sojourn_ms)
        .collect();
    percentile(&xs, 0.99)
}

// ----------------------------------------------------------------------
// Planning at a fleet state (subset + throttle + warm start)
// ----------------------------------------------------------------------

/// Build the plan for a fleet state. `factors[w]` (`w = 1..=N`; index 0
/// is the master-local slot and must stay 1.0) is each worker's current
/// capacity factor: 0 excludes the worker from planning, other values
/// scale its fitted computation rate `u`. Node ids in the returned plan
/// refer to the FULL scenario. `warm` seeds SCA-load policies with a
/// previous plan's allocation projected onto the surviving workers; the
/// second return value counts SCA subproblem solves (0 for closed-form
/// allocators).
pub fn plan_for(
    s: &Scenario,
    policy: &PolicySpec,
    factors: &[f64],
    warm: Option<&Plan>,
) -> anyhow::Result<(Plan, usize)> {
    let n = s.n_workers();
    anyhow::ensure!(
        factors.len() == n + 1,
        "need one capacity factor per node (index 0 = local), got {} for {n} workers",
        factors.len()
    );
    for (i, &f) in factors.iter().enumerate() {
        anyhow::ensure!(
            f.is_finite() && f >= 0.0,
            "capacity factor {f} at node {i} must be finite and ≥ 0"
        );
    }
    let active: Vec<usize> = (1..=n).filter(|&w| factors[w] > 0.0).collect();
    anyhow::ensure!(
        !active.is_empty(),
        "no active workers to plan on (every capacity factor is 0)"
    );
    let full_fleet = active.len() == n && active.iter().all(|&w| factors[w] == 1.0);
    let sub = if full_fleet {
        s.clone()
    } else {
        let mut sub = s.subset_workers(&active)?;
        for (j, &w) in active.iter().enumerate() {
            throttle_link_u(&mut sub, j, factors[w]);
        }
        sub
    };
    let resolved = policy.resolve()?;
    let (mut built, iters) = if resolved.loads == "sca" {
        // SCA with an optional warm start: project the previous plan's
        // loads onto the surviving nodes (sub-scenario ids) and seed
        // Algorithm 3 there instead of at the Theorem-1 closed form.
        let prev: Vec<HashMap<usize, f64>> = (0..s.n_masters())
            .map(|m| match warm {
                Some(p) => p.masters[m]
                    .entries
                    .iter()
                    .filter_map(|e| {
                        let sub_node = if e.node == 0 {
                            Some(0)
                        } else {
                            active.binary_search(&e.node).ok().map(|j| j + 1)
                        };
                        sub_node.map(|sn| (sn, e.load))
                    })
                    .collect(),
                None => HashMap::new(),
            })
            .collect();
        let warm_alloc = WarmSca {
            prev,
            iters: AtomicUsize::new(0),
        };
        let p = plan::build_with(&sub, resolved.assigner.as_ref(), &warm_alloc, &resolved.label());
        let iters = warm_alloc.iters.load(AtomicOrdering::Relaxed);
        (p, iters)
    } else {
        (resolved.build(&sub), 0)
    };
    if active.len() != n {
        // Remap sub-scenario worker ids back onto the full fleet.
        for mp in built.masters.iter_mut() {
            for e in mp.entries.iter_mut() {
                if e.node >= 1 {
                    e.node = active[e.node - 1];
                }
            }
        }
    }
    Ok((built, iters))
}

/// The plan-time throttling rule, in ONE place for both the planning
/// subset and the execution scenario: a host running at `factor` of its
/// capacity stretches its WHOLE per-row computation law by `1/factor`
/// (`a → a/factor`, `u → u·factor` — every mean-matched parametric
/// family resolves to the base law scaled by `1/factor`), leaving the
/// comm parameters alone. Stretching the whole law is what makes
/// [`CapacityProfile::warp_scaled`]'s normalization (`work = d·f_admit`)
/// EXACT for parametric families: a duration sampled under the throttle
/// is the base draw over `factor`. Factors of 0 (absent — never planned
/// onto) and exactly 1 (bit-exact full rate) are no-ops.
///
/// Trace-driven links cannot be throttled this way — their sampler
/// ignores the fitted `(a, u)` surrogate — so [`run`] rejects
/// fractional throttles on scenarios with trace-family worker links
/// (leave/join churn is fine: it never rescales the law).
fn throttle_link_u(s: &mut Scenario, col: usize, factor: f64) {
    if factor > 0.0 && factor != 1.0 {
        for row in s.links.iter_mut() {
            row[col].a /= factor;
            row[col].u *= factor;
        }
    }
}

/// The full scenario with each worker's fitted computation rate scaled
/// by its current capacity factor — what serving plans compile against
/// (absent workers keep their base parameters; no plan references them).
fn throttled_scenario(s: &Scenario, factors: &[f64]) -> Scenario {
    let mut out = s.clone();
    for w in 1..=s.n_workers() {
        throttle_link_u(&mut out, w - 1, factors[w]);
    }
    out
}

/// SCA load allocator with a warm-start seed (the serving layer's
/// replacement for the registry's cold `ScaAllocator` — identical when
/// `prev` is empty).
struct WarmSca {
    /// Per-master previous loads keyed by SUB-scenario node id.
    prev: Vec<HashMap<usize, f64>>,
    iters: AtomicUsize,
}

impl LoadAllocator for WarmSca {
    fn label_suffix(&self) -> &'static str {
        " + SCA"
    }

    fn allocate(
        &self,
        s: &Scenario,
        m: usize,
        nodes: &[usize],
        shares: &[(f64, f64)],
    ) -> Allocation {
        let l_rows = s.l_rows(m);
        let links: Vec<EffLink> = nodes
            .iter()
            .zip(shares)
            .map(|(&nd, &(k, b))| EffLink::fractional(&s.link(m, nd), k, b))
            .collect();
        let thetas: Vec<f64> = links.iter().map(EffLink::theta).collect();
        let cold = markov::allocate(&thetas, l_rows);
        let start = if self.prev[m].is_empty() {
            cold
        } else {
            let mut loads = cold.loads.clone();
            for (i, nd) in nodes.iter().enumerate() {
                if let Some(&pl) = self.prev[m].get(nd) {
                    if pl > 0.0 && thetas[i].is_finite() {
                        loads[i] = pl;
                    }
                }
            }
            let total: f64 = loads.iter().sum();
            if total > l_rows * (1.0 + 1e-9) {
                // Exact-model boundary t for the projected loads — a
                // feasible SCA start by construction.
                let t = alloc::exact_t_for_loads(&links, &loads, l_rows);
                Allocation { loads, t_star: t }
            } else {
                cold
            }
        };
        let (a, it) = sca::enhance_traced(&links, l_rows, &start, &sca::ScaOptions::default());
        self.iters.fetch_add(it, AtomicOrdering::Relaxed);
        a
    }
}

// ----------------------------------------------------------------------
// The event loop
// ----------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
enum EvKind {
    Arrival { master: usize, job: usize },
    Completion { master: usize },
}

struct PlanCtx {
    plan: Plan,
    compiled: Compiled,
}

struct ServeLoop<'a> {
    s: &'a Scenario,
    cfg: &'a ServeConfig,
    profiles: &'a [CapacityProfile],
    /// Script event times, presorted for O(log n) epoch lookups.
    epoch_times: Vec<f64>,
    /// The event core — wheel or heap oracle, per [`ServeConfig::queue`].
    queue: Box<dyn EventQueue<EvKind>>,
    queues: Vec<VecDeque<(usize, f64)>>,
    busy: Vec<bool>,
    cache: HashMap<Vec<u64>, Rc<PlanCtx>>,
    cold: Option<Rc<PlanCtx>>,
    last_plan: Option<Plan>,
    /// One entry under [`ServiceStreams::Shared`] (every master draws
    /// from it in event order), one per master under `PerMaster`.
    service_rngs: Vec<Rng>,
    times: Vec<f64>,
    loads: Vec<f64>,
    records: Vec<JobRecord>,
    /// Next overwrite slot once `records` reached the cap.
    ring_pos: usize,
    per_master: Vec<Summary>,
    system: Summary,
    per_master_sketch: Vec<QuantileSketch>,
    system_sketch: QuantileSketch,
    per_master_jobs: Vec<usize>,
    jobs_recorded: usize,
    replans: usize,
    cache_hits: usize,
    infeasible: usize,
    sca_iters: usize,
}

impl ServeLoop<'_> {
    fn push(&mut self, at: f64, kind: EvKind) {
        self.queue.push(at, kind);
    }

    /// Churn epoch at `t` — [`ChurnScript::epoch_at`] over the
    /// presorted event times, O(log events) per admission instead of a
    /// linear scan (synthesized scripts can carry thousands of events).
    fn epoch_at(&self, t: f64) -> usize {
        self.epoch_times.partition_point(|&bt| bt <= t)
    }

    /// Record one job: summaries + sketches see every record exactly
    /// once (feasible sojourns only — the ∞ of a starved job is counted
    /// in `infeasible`, not averaged); the record ring keeps the last
    /// `record_cap` in arrival order when a cap is set.
    fn record(&mut self, rec: JobRecord) {
        self.jobs_recorded += 1;
        self.per_master_jobs[rec.master] += 1;
        if rec.feasible() {
            let sojourn = rec.sojourn_ms();
            self.per_master[rec.master].push(sojourn);
            self.system.push(sojourn);
            self.per_master_sketch[rec.master].insert(sojourn);
            self.system_sketch.insert(sojourn);
        } else {
            self.infeasible += 1;
        }
        let cap = self.cfg.record_cap;
        if cap == 0 || self.records.len() < cap {
            self.records.push(rec);
        } else {
            self.records[self.ring_pos] = rec;
            self.ring_pos = (self.ring_pos + 1) % cap;
        }
    }

    /// Plan (or fetch) for the fleet state at `now`. Either way, the
    /// FIRST plan an admission actually uses becomes `cold` — the
    /// "initial fleet state" plan the outcome reports (a cache hit on
    /// the pre-seeded full-fleet entry counts; a churned first
    /// admission does too).
    fn plan_at(&mut self, now: f64) -> anyhow::Result<(Rc<PlanCtx>, bool)> {
        let n = self.s.n_workers();
        let key: Vec<u64> = (1..=n)
            .map(|w| self.profiles[w].factor_at(now).to_bits())
            .collect();
        if self.cfg.use_cache {
            if let Some(ctx) = self.cache.get(&key) {
                self.cache_hits += 1;
                let ctx = Rc::clone(ctx);
                if self.cold.is_none() {
                    self.cold = Some(Rc::clone(&ctx));
                }
                return Ok((ctx, true));
            }
        }
        let mut factors = vec![1.0f64; n + 1];
        for w in 1..=n {
            factors[w] = self.profiles[w].factor_at(now);
        }
        let warm = if self.cfg.warm_start {
            self.last_plan.as_ref()
        } else {
            None
        };
        let (built, iters) = plan_for(self.s, &self.cfg.policy, &factors, warm)?;
        self.replans += 1;
        self.sca_iters += iters;
        let exec_s = throttled_scenario(self.s, &factors);
        built.validate(&exec_s)?;
        let compiled = Compiled::new(&exec_s, &built);
        self.last_plan = Some(built.clone());
        let ctx = Rc::new(PlanCtx {
            plan: built,
            compiled,
        });
        if self.cold.is_none() {
            self.cold = Some(Rc::clone(&ctx));
        }
        if self.cfg.use_cache {
            self.cache.insert(key, Rc::clone(&ctx));
        }
        Ok((ctx, false))
    }

    /// Admit the head of master `m`'s queue at time `now`. Starved jobs
    /// (`service = ∞`) are recorded infeasible and the server freed
    /// immediately — an operator would kill a stalled job rather than
    /// block the queue forever — so admission loops until a feasible
    /// job is in service or the queue drains. A job admitted while the
    /// ENTIRE fleet is away (an explicit script can empty it; synthesized
    /// churn never does) is the same starvation case, not a run abort.
    fn admit(&mut self, m: usize, now: f64) -> anyhow::Result<()> {
        while let Some((job, arrival)) = self.queues[m].pop_front() {
            let n = self.s.n_workers();
            if !(1..=n).any(|w| self.profiles[w].factor_at(now) > 0.0) {
                self.record(JobRecord {
                    job,
                    master: m,
                    arrival_ms: arrival,
                    start_ms: now,
                    service_ms: f64::INFINITY,
                    epoch: self.epoch_at(now),
                    cache_hit: false,
                });
                continue;
            }
            let (ctx, cache_hit) = self.plan_at(now)?;
            let rng_idx = match self.cfg.streams {
                ServiceStreams::Shared => 0,
                ServiceStreams::PerMaster => m,
            };
            let service = ctx.compiled.sample_master_warped(
                m,
                &mut self.service_rngs[rng_idx],
                now,
                self.profiles,
                &mut self.times,
                &mut self.loads,
            );
            self.record(JobRecord {
                job,
                master: m,
                arrival_ms: arrival,
                start_ms: now,
                service_ms: service,
                epoch: self.epoch_at(now),
                cache_hit,
            });
            if service.is_finite() {
                self.busy[m] = true;
                self.push(now + service, EvKind::Completion { master: m });
                return Ok(());
            }
        }
        Ok(())
    }
}

/// Run one serving timeline on `s`. Deterministic in `(scenario, cfg)`:
/// arrivals, churn synthesis and service draws all derive from
/// `cfg.seed` through separate streams.
pub fn run(s: &Scenario, cfg: &ServeConfig) -> anyhow::Result<ServeOutcome> {
    run_stream(s, cfg, None)
}

/// The serving loop proper. `only = Some(m)` restricts arrivals to
/// master `m` — the shard body of [`run_sharded`]. Everything else
/// (planning scale, churn script, RNG streams) is derived identically,
/// so a shard reproduces master `m`'s slice of the sequential run
/// bit-for-bit under [`ServiceStreams::PerMaster`].
fn run_stream(s: &Scenario, cfg: &ServeConfig, only: Option<usize>) -> anyhow::Result<ServeOutcome> {
    validate_arrival_knobs(cfg.load_factor, cfg.churn_rate, cfg.churn_downtime)?;
    let m_cnt = s.n_masters();
    let n = s.n_workers();

    // Time-scale reference: the full-fleet plan's predicted system delay.
    let base_plan = cfg.policy.build(s)?;
    let t_ref = base_plan.t_est();
    anyhow::ensure!(
        t_ref.is_finite() && t_ref > 0.0,
        "planner t* must be positive and finite to scale arrivals (got {t_ref})"
    );
    let period = t_ref / cfg.load_factor;
    // The synthesized-churn horizon must cover the whole run even under
    // overload, where the busy period (≈ jobs × service ≈ jobs × t*)
    // outlives the arrival span (jobs × period) — otherwise the queue's
    // tail would silently serve a static fleet. 4·t* per job bounds the
    // empirical mean service (≤ ~2·t*) with slack.
    let span = period.max(4.0 * t_ref) * cfg.jobs.max(1) as f64;
    let horizon = span * 2.0 + 4.0 * t_ref;
    let script = match (&cfg.script, &cfg.faults) {
        (Some(sc), _) => sc.clone(),
        // Health-driven churn: the timeline the coordinator's detection
        // layer would emit under this fault plan (leaves delayed by the
        // missed-beat / stall windows, throttles recovered through
        // breaker probes) instead of a rate-driven cycle.
        (None, Some(fp)) => {
            health::churn_from_faults(fp, n, horizon, &HealthConfig::default())
        }
        (None, None) => ChurnScript::synthesize(
            n,
            cfg.churn_rate,
            cfg.churn_downtime,
            t_ref,
            horizon,
            cfg.seed,
        ),
    };
    script.validate(n)?;
    // No silent caps: a synthesized script that hit MAX_SYNTH_EVENTS
    // before covering the horizon leaves the tail of the run on a
    // static fleet — say so instead of letting the churn axis lie.
    // (Fault-derived scripts are exact: every fault maps to a bounded
    // set of events, so there is nothing to truncate.)
    if cfg.script.is_none() && cfg.faults.is_none() {
        if let Some(last) = script.events.last() {
            if last.at_ms < horizon * 0.9 {
                eprintln!(
                    "serve: synthesized churn truncated at {} events (covers {:.0} of \
                     {:.0} virtual ms); later jobs run on a static fleet",
                    script.events.len(),
                    last.at_ms,
                    horizon
                );
            }
        }
    }
    // Fractional throttles rescale the fitted computation law, which
    // trace-driven links ignore entirely (they sample the raw ECDF) —
    // the throttle would be a silent sampling no-op while the warp
    // still renormalized by it, producing impossible service times.
    // Leave/join churn (factors 0 / 1) never rescales and stays valid.
    let has_trace = (0..m_cnt).any(|m| {
        (1..=n).any(|w| {
            matches!(
                s.link(m, w).family,
                crate::model::dist::FamilyKind::Trace { .. }
            )
        })
    });
    if has_trace {
        let fractional = script.events.iter().any(
            |e| matches!(e.action, ChurnAction::Throttle(f) if f != 0.0 && f != 1.0),
        );
        anyhow::ensure!(
            !fractional,
            "fractional throttles are not supported on scenarios with trace-driven \
             worker links (the trace sampler ignores the fitted rate); use leave/join churn"
        );
    }
    let profiles = script.profiles(n)?;

    // Pre-seed the plan cache with the full-fleet plan: it was already
    // built above for the arrival time scale, and the t = 0 fingerprint
    // is the all-ones fleet whenever the script carries no event at 0 —
    // without this the first admission would redo the identical (for
    // SCA-load policies, expensive) solve.
    let mut cache: HashMap<Vec<u64>, Rc<PlanCtx>> = HashMap::new();
    if cfg.use_cache {
        let base_ctx = Rc::new(PlanCtx {
            compiled: Compiled::new(s, &base_plan),
            plan: base_plan.clone(),
        });
        cache.insert(vec![1.0f64.to_bits(); n], base_ctx);
    }

    // Arrival streams (salted: independent of the service stream).
    // Always derived for EVERY master from the same per-master forks,
    // so a sharded run (`only = Some(m)`) sees identical arrival times.
    let arrivals: Vec<Vec<f64>> = (0..m_cnt)
        .map(|m| match cfg.process {
            ArrivalProcess::Deterministic => {
                (0..cfg.jobs).map(|j| j as f64 * period).collect()
            }
            ArrivalProcess::Poisson => {
                let mut rng = Rng::new(cfg.seed ^ ARRIVAL_SALT).fork(m as u64 + 1);
                let rate = 1.0 / period;
                let mut t = 0.0;
                (0..cfg.jobs)
                    .map(|_| {
                        t += rng.exp(rate);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Burst => {
                // Flash crowds: BURST_SIZE simultaneous jobs at Poisson
                // epochs with mean spacing BURST_SIZE × period — the
                // long-run rate matches `Poisson`, the tail does not.
                let mut rng = Rng::new(cfg.seed ^ ARRIVAL_SALT).fork(m as u64 + 1);
                let rate = 1.0 / (period * BURST_SIZE as f64);
                let mut t = 0.0;
                let mut out = Vec::with_capacity(cfg.jobs);
                while out.len() < cfg.jobs {
                    t += rng.exp(rate);
                    let take = BURST_SIZE.min(cfg.jobs - out.len());
                    out.extend(std::iter::repeat(t).take(take));
                }
                out
            }
        })
        .collect();

    // Event core: the wheel's tick is sized for the expected event
    // count over the run's span (arrival + completion per job); the
    // heap needs no sizing. Both obey the same `(time, seq)` contract.
    let queue: Box<dyn EventQueue<EvKind>> = match cfg.queue {
        EventQueueKind::Wheel => Box::new(TimerWheel::for_span(
            horizon,
            (m_cnt * cfg.jobs.max(1) * 2).max(64),
        )),
        EventQueueKind::Heap => Box::new(HeapQueue::new()),
    };
    let service_rngs = match cfg.streams {
        ServiceStreams::Shared => vec![Rng::new(cfg.seed).fork(1)],
        ServiceStreams::PerMaster => (0..m_cnt)
            .map(|m| Rng::new(cfg.seed ^ SHARD_SALT).fork(m as u64 + 1))
            .collect(),
    };
    let record_hint = {
        let total = m_cnt * cfg.jobs;
        if cfg.record_cap == 0 {
            total
        } else {
            cfg.record_cap.min(total)
        }
    };

    let mut lp = ServeLoop {
        s,
        cfg,
        profiles: &profiles,
        epoch_times: {
            let mut ts: Vec<f64> = script.events.iter().map(|e| e.at_ms).collect();
            ts.sort_by(f64::total_cmp);
            ts
        },
        queue,
        queues: vec![VecDeque::new(); m_cnt],
        busy: vec![false; m_cnt],
        cache,
        cold: None,
        // Warm starts may seed from the full-fleet plan on the very
        // first state change, not only from replans this loop performed.
        last_plan: cfg.warm_start.then(|| base_plan.clone()),
        // Shared = stream 1, the batch engine's first shard stream: the
        // constant-share parity contract (module docs). PerMaster =
        // salted fork(m + 1) per master.
        service_rngs,
        times: Vec::new(),
        loads: Vec::new(),
        records: Vec::with_capacity(record_hint),
        ring_pos: 0,
        per_master: vec![Summary::new(); m_cnt],
        system: Summary::new(),
        per_master_sketch: vec![QuantileSketch::default(); m_cnt],
        system_sketch: QuantileSketch::default(),
        per_master_jobs: vec![0; m_cnt],
        jobs_recorded: 0,
        replans: 0,
        cache_hits: 0,
        infeasible: 0,
        sca_iters: 0,
    };
    // Arrivals pushed job-major, master-minor: same-instant ties process
    // in master order (lockstep = the batch trial loop's master order).
    for j in 0..cfg.jobs {
        for (m, arr) in arrivals.iter().enumerate() {
            if only.map_or(true, |o| o == m) {
                lp.push(arr[j], EvKind::Arrival { master: m, job: j });
            }
        }
    }
    while let Some((at, kind)) = lp.queue.pop() {
        match kind {
            EvKind::Arrival { master, job } => {
                lp.queues[master].push_back((job, at));
                if !lp.busy[master] {
                    lp.admit(master, at)?;
                }
            }
            EvKind::Completion { master } => {
                lp.busy[master] = false;
                if !lp.queues[master].is_empty() {
                    lp.admit(master, at)?;
                }
            }
        }
    }

    // A wrapped record ring leaves the oldest retained record at
    // `ring_pos`; rotate it back to the front so `records` reads in
    // admission order regardless of the cap.
    let mut records = lp.records;
    if lp.ring_pos > 0 {
        records.rotate_left(lp.ring_pos);
    }
    let (cold_plan, t_est_ms) = match &lp.cold {
        Some(ctx) => (ctx.plan.clone(), ctx.plan.t_est()),
        None => (base_plan.clone(), t_ref),
    };
    Ok(ServeOutcome {
        label: cold_plan.label.clone(),
        records,
        per_master: lp.per_master,
        system: lp.system,
        per_master_sketch: lp.per_master_sketch,
        system_sketch: lp.system_sketch,
        per_master_jobs: lp.per_master_jobs,
        jobs: lp.jobs_recorded,
        t_est_ms,
        cold_plan,
        replans: lp.replans,
        cache_hits: lp.cache_hits,
        infeasible: lp.infeasible,
        sca_iters: lp.sca_iters,
        period_ms: period,
    })
}

/// Run the serving timeline sharded: each master's stream becomes one
/// task on the process-wide worker pool ([`pool::run_all`]), and the
/// shard outcomes merge at the barrier — sketches via
/// [`QuantileSketch::merge`], Welford summaries via [`Summary::merge`].
///
/// Masters do not interact in the serving model (per-master FIFO
/// queues, plans keyed on fleet state only), so the ONLY sequential
/// coupling is the shared service stream — which is why this entry
/// forces [`ServiceStreams::PerMaster`]. Under per-master streams a
/// shard reproduces the sequential run's slice for its master
/// bit-for-bit (tests pin records and per-master summaries).
///
/// Merged caveats, documented rather than hidden: `replans`,
/// `cache_hits`, and `sca_iters` are SUMS over shards (each shard plans
/// for itself — up to `m` cold solves where the sequential loop did
/// one), and the merged `system` summary can differ from the sequential
/// interleaved push order by float-summation ulps; the per-master
/// summaries are exact.
pub fn run_sharded(s: &Scenario, cfg: &ServeConfig) -> anyhow::Result<ServeOutcome> {
    let m_cnt = s.n_masters();
    let mut shard_cfg = cfg.clone();
    shard_cfg.streams = ServiceStreams::PerMaster;
    if m_cnt <= 1 {
        return run_stream(s, &shard_cfg, None);
    }
    let shared: Arc<(Scenario, ServeConfig)> = Arc::new((s.clone(), shard_cfg));
    let tasks: Vec<_> = (0..m_cnt)
        .map(|m| {
            let shared = Arc::clone(&shared);
            move || {
                let (s, cfg) = &*shared;
                run_stream(s, cfg, Some(m))
            }
        })
        .collect();
    let shards = pool::run_all(tasks);

    let mut merged: Option<ServeOutcome> = None;
    for (m, shard) in shards.into_iter().enumerate() {
        let shard = shard?;
        match &mut merged {
            None => merged = Some(shard),
            Some(out) => {
                // Shard m only served master m: fold its slice in.
                out.records.extend(shard.records);
                out.per_master[m] = shard.per_master[m].clone();
                out.per_master_sketch[m] = shard.per_master_sketch[m].clone();
                out.per_master_jobs[m] = shard.per_master_jobs[m];
                out.jobs += shard.jobs;
                out.replans += shard.replans;
                out.cache_hits += shard.cache_hits;
                out.infeasible += shard.infeasible;
                out.sca_iters += shard.sca_iters;
            }
        }
    }
    let mut out = merged.expect("n_masters >= 1");
    // Shard 0 seeded the merge with ITS system view (= master 0 only);
    // rebuild the system summary/sketch as the merge of every master so
    // shard count and merge order cannot skew it.
    out.system = Summary::new();
    out.system_sketch = QuantileSketch::default();
    for m in 0..m_cnt {
        out.system.merge(&out.per_master[m]);
        out.system_sketch.merge(&out.per_master_sketch[m]);
    }
    // Deterministic cross-master record order: by arrival, master-order
    // ties (= the sequential push order; under overload the sequential
    // loop records in ADMISSION order instead, so only per-master
    // slices — not the global interleaving — are pinned identical).
    out.records.sort_by(|a, b| {
        a.arrival_ms
            .total_cmp(&b.arrival_ms)
            .then(a.master.cmp(&b.master))
            .then(a.job.cmp(&b.job))
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::CommModel;

    fn policy(loads: &str) -> PolicySpec {
        PolicySpec::new("dedi-iter", ValueModel::Markov, loads)
    }

    fn small() -> Scenario {
        Scenario::small_scale(5, 2.0, CommModel::Stochastic)
    }

    #[test]
    fn static_fleet_run_is_deterministic_and_well_formed() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 20;
        cfg.load_factor = 0.9;
        let a = run(&s, &cfg).unwrap();
        let b = run(&s, &cfg).unwrap();
        assert_eq!(a.records, b.records, "serving must be deterministic");
        assert_eq!(a.records.len(), 2 * 20);
        assert_eq!(a.infeasible, 0);
        // The full-fleet plan is built once up front (the time-scale
        // reference doubles as the cache seed): a static fleet never
        // replans at all.
        assert_eq!(a.replans, 0, "static fleet must reuse the pre-seeded plan");
        assert_eq!(a.cache_hits, 2 * 20);
        assert!(a.system.count() == 40 && a.system.mean() > 0.0);
        for r in &a.records {
            assert!(r.feasible());
            assert!(r.wait_ms() >= 0.0, "{r:?}");
            assert!(r.start_ms >= r.arrival_ms);
            assert!(
                (r.sojourn_ms() - (r.wait_ms() + r.service_ms)).abs() < 1e-9,
                "{r:?}"
            );
            assert!(r.cache_hit, "static fleet: every admission is a cache hit");
            assert_eq!(r.epoch, 0);
        }
        // Per-master jobs appear in order.
        for m in 0..2 {
            let jobs: Vec<usize> = a
                .records
                .iter()
                .filter(|r| r.master == m)
                .map(|r| r.job)
                .collect();
            assert_eq!(jobs, (0..20).collect::<Vec<_>>());
        }
        assert!(a.p99_ms().unwrap() >= a.system.mean());
    }

    #[test]
    fn overload_queues_and_underload_does_not() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 30;
        cfg.load_factor = 8.0; // heavy overload: arrivals far above service rate
        let over = run(&s, &cfg).unwrap();
        let waited = over.records.iter().filter(|r| r.wait_ms() > 1e-9).count();
        assert!(waited > 10, "overload produced almost no queueing ({waited})");
        cfg.load_factor = 0.05; // deep underload
        let under = run(&s, &cfg).unwrap();
        let waited = under.records.iter().filter(|r| r.wait_ms() > 1e-9).count();
        assert!(waited < 5, "deep underload queued {waited} jobs");
        assert!(under.system.mean() < over.system.mean());
    }

    #[test]
    fn poisson_arrivals_are_seeded_and_monotone() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.process = ArrivalProcess::Poisson;
        cfg.jobs = 15;
        let a = run(&s, &cfg).unwrap();
        let b = run(&s, &cfg).unwrap();
        assert_eq!(a.records, b.records);
        for m in 0..2 {
            let arr: Vec<f64> = a
                .records
                .iter()
                .filter(|r| r.master == m)
                .map(|r| r.arrival_ms)
                .collect();
            assert!(arr.windows(2).all(|w| w[1] > w[0]), "arrivals not increasing");
        }
        cfg.seed = 777;
        let c = run(&s, &cfg).unwrap();
        assert_ne!(a.records[0].arrival_ms, c.records[0].arrival_ms);
    }

    #[test]
    fn zero_arrival_stream_is_empty_but_well_formed() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 0;
        let out = run(&s, &cfg).unwrap();
        assert!(out.records.is_empty());
        assert_eq!(out.system.count(), 0);
        assert_eq!(out.replans, 0);
        assert!(out.p99_ms().is_none());
        assert!(out.t_est_ms > 0.0);
        assert_eq!(out.cold_plan.label, out.label);
    }

    #[test]
    fn plan_for_excludes_absent_workers_and_remaps_ids() {
        let s = small();
        let n = s.n_workers();
        let mut factors = vec![1.0; n + 1];
        factors[2] = 0.0; // worker 2 away
        let (p, _) = plan_for(&s, &policy("markov"), &factors, None).unwrap();
        for mp in &p.masters {
            for e in &mp.entries {
                assert_ne!(e.node, 2, "absent worker planned");
                assert!(e.node <= n, "node id not remapped to the full fleet");
            }
        }
        p.validate(&s).unwrap();
        // Full-capacity factors reproduce the registry build exactly.
        let ones = vec![1.0; n + 1];
        let (full, _) = plan_for(&s, &policy("markov"), &ones, None).unwrap();
        assert_eq!(full, policy("markov").build(&s).unwrap());
        // All-zero factors are a graceful error.
        let mut dead = vec![1.0; n + 1];
        for f in dead.iter_mut().skip(1) {
            *f = 0.0;
        }
        assert!(plan_for(&s, &policy("markov"), &dead, None).is_err());
        // Throttling raises the planner's estimate.
        let mut slow = vec![1.0; n + 1];
        for f in slow.iter_mut().skip(1) {
            *f = 0.25;
        }
        let (thr, _) = plan_for(&s, &policy("markov"), &slow, None).unwrap();
        assert!(thr.t_est() > full.t_est());
    }

    #[test]
    fn warm_started_sca_replan_matches_cold_and_is_no_slower() {
        let s = small();
        let n = s.n_workers();
        let full = vec![1.0; n + 1];
        let (cold, cold_iters) = plan_for(&s, &policy("sca"), &full, None).unwrap();
        assert!(cold_iters >= 1);
        // Warm start from the cold optimum on the SAME fleet state: the
        // fixed point must be reached at least as fast, same plan.
        let (warm, warm_iters) = plan_for(&s, &policy("sca"), &full, Some(&cold)).unwrap();
        assert!(warm_iters <= cold_iters, "warm {warm_iters} > cold {cold_iters}");
        assert!(
            (warm.t_est() - cold.t_est()).abs() / cold.t_est() < 1e-6,
            "warm restart moved the optimum: {} vs {}",
            warm.t_est(),
            cold.t_est()
        );
        // Across a fleet change the warm plan still matches a cold
        // replan's quality on the new state.
        let mut less = vec![1.0; n + 1];
        less[1] = 0.0;
        let (cold2, _) = plan_for(&s, &policy("sca"), &less, None).unwrap();
        let (warm2, _) = plan_for(&s, &policy("sca"), &less, Some(&cold)).unwrap();
        assert!(
            (warm2.t_est() - cold2.t_est()).abs() / cold2.t_est() < 1e-3,
            "warm replan degraded the optimum: {} vs {}",
            warm2.t_est(),
            cold2.t_est()
        );
    }

    #[test]
    fn churned_fleet_replans_and_caches_per_state() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 40;
        cfg.load_factor = 0.8;
        cfg.churn_rate = 1.0;
        cfg.churn_downtime = 0.5;
        let out = run(&s, &cfg).unwrap();
        assert!(out.replans >= 2, "churn never triggered a replan");
        assert!(
            out.replans <= s.n_workers() + 1,
            "cache missed repeated fleet states ({} replans)",
            out.replans
        );
        assert!(out.cache_hits > 0);
        assert!(out.records.iter().any(|r| r.epoch > 0));
        // The reported cold plan is the INITIAL fleet's (admissions at
        // t = 0 precede the first churn event), never a churned replan.
        assert_eq!(
            out.cold_plan,
            policy("markov").build(&s).unwrap(),
            "cold plan drifted to a churned state"
        );
        // The serving stream still completes almost everywhere (churned
        // workers rejoin).
        assert!(out.infeasible <= out.records.len() / 4);
    }

    #[test]
    fn empty_fleet_admission_starves_instead_of_aborting() {
        let s = small();
        let n = s.n_workers();
        let period = policy("markov").build(&s).unwrap().t_est() * 1e6;
        // Every worker away across job 1's arrival; back before job 2's.
        let mut events = Vec::new();
        for w in 1..=n {
            events.push(ChurnEvent {
                at_ms: 0.5 * period,
                worker: w,
                action: ChurnAction::Leave,
            });
            events.push(ChurnEvent {
                at_ms: 1.5 * period,
                worker: w,
                action: ChurnAction::Join,
            });
        }
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 3;
        cfg.load_factor = 1e-6; // lockstep spacing ≫ any service time
        cfg.script = Some(ChurnScript { events });
        let out = run(&s, &cfg).expect("empty fleet must starve jobs, not abort");
        assert_eq!(out.records.len(), 2 * 3);
        for m in 0..2 {
            let by_job: Vec<bool> = (0..3)
                .map(|j| {
                    out.records
                        .iter()
                        .find(|r| r.master == m && r.job == j)
                        .unwrap()
                        .feasible()
                })
                .collect();
            assert_eq!(by_job, vec![true, false, true], "master {m}");
        }
        assert_eq!(out.infeasible, 2);
    }

    #[test]
    fn arrival_process_names_roundtrip() {
        for p in [
            ArrivalProcess::Deterministic,
            ArrivalProcess::Poisson,
            ArrivalProcess::Burst,
        ] {
            assert_eq!(ArrivalProcess::parse(p.as_str()).unwrap(), p);
        }
        assert!(ArrivalProcess::parse("bursty").is_err());
        for q in [EventQueueKind::Wheel, EventQueueKind::Heap] {
            assert_eq!(EventQueueKind::parse(q.as_str()).unwrap(), q);
        }
        assert!(EventQueueKind::parse("btree").is_err());
    }

    /// The tentpole parity pin: the timer wheel IS the heap, bit for
    /// bit, across every arrival shape and under churn (which stresses
    /// same-instant completion/arrival interleavings).
    #[test]
    fn wheel_and_heap_event_cores_agree_bit_for_bit() {
        let s = small();
        for process in [
            ArrivalProcess::Deterministic,
            ArrivalProcess::Poisson,
            ArrivalProcess::Burst,
        ] {
            for load in [0.8, 2.5] {
                let mut cfg = ServeConfig::new(policy("markov"));
                cfg.process = process;
                cfg.load_factor = load;
                cfg.jobs = 25;
                cfg.churn_rate = 1.0;
                cfg.queue = EventQueueKind::Wheel;
                let wheel = run(&s, &cfg).unwrap();
                cfg.queue = EventQueueKind::Heap;
                let heap = run(&s, &cfg).unwrap();
                assert_eq!(
                    wheel.records, heap.records,
                    "{process:?} load {load}: event cores diverged"
                );
                assert_eq!(wheel.replans, heap.replans);
                assert_eq!(wheel.infeasible, heap.infeasible);
                assert_eq!(
                    wheel.system.mean().to_bits(),
                    heap.system.mean().to_bits(),
                    "summaries must be bit-identical"
                );
            }
        }
    }

    #[test]
    fn burst_arrivals_land_in_flash_crowds() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.process = ArrivalProcess::Burst;
        cfg.jobs = 3 * BURST_SIZE;
        cfg.load_factor = 0.5;
        let out = run(&s, &cfg).unwrap();
        assert_eq!(out.jobs, 2 * 3 * BURST_SIZE);
        for m in 0..2 {
            let mut arr: Vec<f64> = out
                .records
                .iter()
                .filter(|r| r.master == m)
                .map(|r| r.arrival_ms)
                .collect();
            arr.sort_by(f64::total_cmp);
            // Exactly 3 distinct epochs, each carrying BURST_SIZE jobs.
            let mut epochs: Vec<f64> = arr.clone();
            epochs.dedup_by(|a, b| a == b);
            assert_eq!(epochs.len(), 3, "master {m}: {arr:?}");
            for e in &epochs {
                assert_eq!(
                    arr.iter().filter(|&&t| t == *e).count(),
                    BURST_SIZE,
                    "master {m}: ragged burst at {e}"
                );
            }
        }
        // Same-instant bursts queue behind one server: within one burst
        // someone always waits.
        let waited = out.records.iter().filter(|r| r.wait_ms() > 1e-9).count();
        assert!(waited >= 2 * 2 * (BURST_SIZE - 1), "bursts did not queue ({waited})");
        // Determinism across reruns.
        let again = run(&s, &cfg).unwrap();
        assert_eq!(out.records, again.records);
    }

    #[test]
    fn record_cap_keeps_last_records_and_exact_stats() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 30;
        let full = run(&s, &cfg).unwrap();
        cfg.record_cap = 7;
        let capped = run(&s, &cfg).unwrap();
        // The ring holds exactly the LAST 7 records, admission order.
        assert_eq!(capped.records.len(), 7);
        assert_eq!(capped.records[..], full.records[full.records.len() - 7..]);
        // Statistics still cover EVERY job, bit-identically.
        assert_eq!(capped.jobs, full.jobs);
        assert_eq!(capped.system.count(), full.system.count());
        assert_eq!(capped.system.mean().to_bits(), full.system.mean().to_bits());
        assert_eq!(capped.p99_ms(), full.p99_ms());
        assert_eq!(capped.per_master_jobs, vec![30, 30]);
        // A cap wider than the run retains everything.
        cfg.record_cap = 10_000;
        let wide = run(&s, &cfg).unwrap();
        assert_eq!(wide.records, full.records);
    }

    /// Sharded = sequential under per-master service streams: records
    /// and per-master summaries bit-identical, system summary within
    /// merge-order ulps.
    #[test]
    fn sharded_run_matches_sequential_per_master_streams() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 20;
        cfg.process = ArrivalProcess::Poisson;
        cfg.load_factor = 1.5;
        cfg.streams = ServiceStreams::PerMaster;
        let seq = run(&s, &cfg).unwrap();
        let shard = run_sharded(&s, &cfg).unwrap();
        for m in 0..2 {
            let seq_m: Vec<&JobRecord> =
                seq.records.iter().filter(|r| r.master == m).collect();
            let shard_m: Vec<&JobRecord> =
                shard.records.iter().filter(|r| r.master == m).collect();
            assert_eq!(seq_m, shard_m, "master {m} slice diverged across sharding");
            assert_eq!(
                seq.per_master[m].mean().to_bits(),
                shard.per_master[m].mean().to_bits(),
                "master {m} summary not bit-identical"
            );
            assert_eq!(seq.per_master[m].count(), shard.per_master[m].count());
        }
        assert_eq!(seq.jobs, shard.jobs);
        assert_eq!(seq.infeasible, shard.infeasible);
        // System mean agrees to merge-order ulps (documented caveat).
        let rel = (seq.system.mean() - shard.system.mean()).abs() / seq.system.mean();
        assert!(rel < 1e-12, "system means diverged: rel {rel}");
    }

    /// PerMaster streams genuinely decouple masters: they draw different
    /// service times than the shared stream (different salt), and each
    /// master's records are invariant to the other's job count.
    #[test]
    fn per_master_streams_are_interleaving_invariant() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 12;
        cfg.process = ArrivalProcess::Poisson;
        cfg.streams = ServiceStreams::PerMaster;
        let a = run(&s, &cfg).unwrap();
        cfg.load_factor = 4.0; // reshuffle the cross-master interleaving
        let b = run(&s, &cfg).unwrap();
        for m in 0..2 {
            let svc_a: Vec<u64> = a
                .records
                .iter()
                .filter(|r| r.master == m)
                .map(|r| r.service_ms.to_bits())
                .collect();
            let svc_b: Vec<u64> = b
                .records
                .iter()
                .filter(|r| r.master == m)
                .map(|r| r.service_ms.to_bits())
                .collect();
            assert_eq!(svc_a, svc_b, "master {m} draws depend on interleaving");
        }
    }

    /// The acceptance overload cell: load_factor > 1, ≥ 10k jobs,
    /// bounded retained records, sketch p99 within its documented rank
    /// error of the exact percentile over all sojourns.
    #[test]
    fn overload_cell_holds_bounded_memory_with_accurate_tail() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.process = ArrivalProcess::Burst;
        cfg.load_factor = 1.5;
        cfg.jobs = 5_000; // × 2 masters = 10k jobs
        cfg.record_cap = 512;
        let out = run(&s, &cfg).unwrap();
        assert_eq!(out.jobs, 10_000);
        assert_eq!(out.records.len(), 512, "record ring exceeded its cap");
        assert_eq!(out.system.count(), 10_000);
        // O(1) memory witness: far fewer stored values than samples.
        assert!(
            out.system_sketch.stored() < 10_000 / 2,
            "sketch stored {} of 10000",
            out.system_sketch.stored()
        );
        // Sketch p99 vs the exact oracle, in rank space: rerun uncapped
        // to recover every sojourn.
        cfg.record_cap = 0;
        let exact_run = run(&s, &cfg).unwrap();
        let mut exact: Vec<f64> = exact_run.sojourn_samples();
        exact.sort_by(f64::total_cmp);
        let p99 = out.p99_ms().unwrap();
        let n = exact.len() as f64;
        let target = (0.99 * n).ceil();
        let lo = exact.partition_point(|&x| x < p99) as f64;
        let hi = exact.partition_point(|&x| x <= p99) as f64;
        let rank_err = if target < lo {
            lo - target
        } else if target > hi {
            target - hi
        } else {
            0.0
        };
        let bound = (out.system_sketch.error_bound() * n).ceil() + 1.0;
        assert!(
            rank_err <= bound,
            "sketch p99 rank error {rank_err} exceeds documented bound {bound}"
        );
    }

    #[test]
    fn job_record_json_keeps_starvation_information() {
        let rec = JobRecord {
            job: 3,
            master: 1,
            arrival_ms: 10.0,
            start_ms: 12.5,
            service_ms: f64::INFINITY,
            epoch: 2,
            cache_hit: false,
        };
        let line = json_line(&rec.to_json());
        assert!(!line.contains('\n'));
        let back = crate::util::json::parse(&line).unwrap();
        assert_eq!(back.get("service_ms"), Some(&Json::Null));
        assert_eq!(back.get("sojourn_ms"), Some(&Json::Null));
        assert_eq!(back.get("feasible").and_then(Json::as_bool), Some(false));
        assert_eq!(back.get("epoch").and_then(Json::as_usize), Some(2));
        // Feasible records carry numbers and the true flag.
        let ok = JobRecord {
            service_ms: 4.0,
            ..rec
        };
        let back = crate::util::json::parse(&json_line(&ok.to_json())).unwrap();
        assert_eq!(back.get("sojourn_ms").and_then(Json::as_f64), Some(6.5));
        assert_eq!(back.get("feasible").and_then(Json::as_bool), Some(true));
    }
}
