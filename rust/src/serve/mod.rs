//! Online serving: task arrivals over time + worker churn on one shared
//! heterogeneous fleet.
//!
//! The paper plans ONE batch of `M` matmul tasks; a production system
//! serves a continuous stream (the regime of Stream Distributed Coded
//! Computing, arXiv:2103.01921). This module is that serving layer, in
//! virtual time:
//!
//! * **Arrivals** — each master receives `jobs` tasks from a
//!   deterministic or Poisson process whose mean inter-arrival is
//!   `t*_base / load_factor` (`t*_base` = the full-fleet planner
//!   estimate), so `load_factor < 1` is underload and `> 1` overload.
//!   Each master serves its own queue FIFO, one job at a time; all
//!   masters run concurrently on the shared fleet (the paper's
//!   fractional sharing).
//! * **Admission → (re)planning** — when a job reaches the head of its
//!   queue, the serving loop needs a plan for the CURRENT fleet state.
//!   A **plan cache** keyed by the fleet fingerprint (every worker's
//!   capacity factor, bit-exact) skips replanning while the state is
//!   unchanged; on a miss, the policy registry replans on the active
//!   subset ([`crate::config::Scenario::subset_workers`]), with a
//!   **warm start** for SCA-load policies — the previous plan's
//!   [`crate::alloc::Allocation`] (projected onto the surviving
//!   workers) seeds Algorithm 3 instead of the Theorem-1 start.
//! * **Churn** — a [`ChurnScript`] moves workers in/out/throttled over
//!   the timeline; compiled per-worker [`CapacityProfile`]s both drive
//!   the fingerprint and time-warp in-flight sub-task durations
//!   ([`crate::sim::engine::Compiled::sample_master_warped`]), so a job
//!   whose worker leaves mid-service suspends that link (and starves —
//!   `feasible: false` — only if the surviving coded links cannot reach
//!   `L_m`).
//! * **Records** — every job yields a [`JobRecord`] (arrival, start,
//!   service, sojourn, epoch, cache hit) that streams as one JSON line
//!   from `coded-coop serve`; the aggregate [`ServeOutcome`] reports
//!   per-master and system sojourn summaries (mean / p99).
//!
//! **Parity contract:** with constant shares and no churn, the plan is
//! built once, every admission is a cache hit, and job service times
//! are drawn from the stream `Rng::new(seed).fork(1)` through the exact
//! batch-kernel draw ([`Compiled::sample_master`]) — so a deterministic
//! lockstep arrival pattern reproduces `sim::run`'s completion delays
//! **bit-for-bit** on the same seed (`rust/tests/serving.rs` pins this).

pub mod churn;
pub mod tcp;

pub use churn::{ChurnAction, ChurnEvent, ChurnScript};
pub use tcp::{TcpJobRecord, TcpServeConfig, TcpServeOutcome};

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};

use crate::alloc::{self, markov, sca, Allocation, EffLink};
use crate::config::Scenario;
use crate::health::{self, FaultPlan, HealthConfig};
use crate::plan::{self, Plan};
use crate::policy::{LoadAllocator, PolicySpec};
use crate::sim::engine::{CapacityProfile, Compiled};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::{percentile, Summary};

/// XOR salt separating the arrival-time RNG from the service stream —
/// service draws must consume `Rng::new(seed).fork(1)` exactly like the
/// batch engine's stream 1, independent of how arrivals are generated.
const ARRIVAL_SALT: u64 = 0x0A44_1CA1;

/// Shared validation of the arrival/churn knobs, used by both direct
/// [`ServeConfig`] runs and `experiment::ArrivalSpec` templates so the
/// two entry paths cannot drift. (Job counts are NOT checked here: a
/// zero-job stream is a legitimate library edge case, while the sweep
/// layer rejects it because an empty cell would export as a feasible
/// 0 ms measurement.)
pub fn validate_arrival_knobs(
    load_factor: f64,
    churn_rate: f64,
    churn_downtime: f64,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        load_factor.is_finite() && load_factor > 0.0,
        "load_factor must be positive and finite, got {load_factor}"
    );
    anyhow::ensure!(
        churn_rate.is_finite() && churn_rate >= 0.0,
        "churn_rate must be finite and ≥ 0, got {churn_rate}"
    );
    anyhow::ensure!(
        churn_downtime > 0.0 && churn_downtime < 1.0,
        "churn_downtime must be in (0, 1), got {churn_downtime}"
    );
    Ok(())
}

/// Render a JSON value as one line — the JSONL record form `coded-coop
/// serve` streams. The pretty serializer's newlines are purely
/// structural (string contents escape theirs as `\n`), so stripping
/// each newline together with the indentation that follows it yields
/// equivalent compact JSON.
pub fn json_line(j: &Json) -> String {
    let pretty = j.to_string_pretty();
    let mut out = String::with_capacity(pretty.len());
    for (i, line) in pretty.lines().enumerate() {
        out.push_str(if i == 0 { line } else { line.trim_start() });
    }
    out
}

/// Per-master job arrival process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalProcess {
    /// Fixed inter-arrival `period`; every master arrives in lockstep.
    Deterministic,
    /// Exponential inter-arrivals with mean `period`, independent per
    /// master.
    Poisson,
}

impl ArrivalProcess {
    pub fn as_str(self) -> &'static str {
        match self {
            ArrivalProcess::Deterministic => "deterministic",
            ArrivalProcess::Poisson => "poisson",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "deterministic" => Ok(ArrivalProcess::Deterministic),
            "poisson" => Ok(ArrivalProcess::Poisson),
            other => anyhow::bail!("unknown arrival process '{other}' (deterministic|poisson)"),
        }
    }
}

/// Everything one serving run needs beyond the scenario.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub policy: PolicySpec,
    pub process: ArrivalProcess,
    /// Arrival rate × mean one-shot service (per master): the mean
    /// inter-arrival is `t*_base / load_factor`.
    pub load_factor: f64,
    /// Jobs per master.
    pub jobs: usize,
    /// Explicit fleet timeline; `None` synthesizes one from
    /// `churn_rate` / `churn_downtime` ([`ChurnScript::synthesize`]).
    pub script: Option<ChurnScript>,
    /// Health-driven churn: when set (and no explicit `script`), the
    /// fleet timeline is what the coordinator's health layer would
    /// OBSERVE under this fault plan — crashes become leaves after the
    /// missed-beat window, gray failures after the stall window, spikes
    /// and slow starts become throttles with breaker-probed recovery
    /// ([`health::churn_from_faults`]). Takes precedence over the
    /// rate-based `churn_rate` synthesis.
    pub faults: Option<FaultPlan>,
    /// Worker leave/rejoin cycles per `t*_base` (0 = static fleet).
    pub churn_rate: f64,
    /// Fraction of each churn cycle the worker spends away.
    pub churn_downtime: f64,
    pub seed: u64,
    /// Reuse plans across admissions with an unchanged fleet state
    /// (disable to force a cold replan per admission — the plan-cache
    /// parity tests do).
    pub use_cache: bool,
    /// Seed SCA-load replans with the previous allocation.
    pub warm_start: bool,
}

impl ServeConfig {
    /// Defaults: deterministic arrivals at 0.8 load, 50 jobs/master,
    /// static fleet, cache + warm starts on.
    pub fn new(policy: PolicySpec) -> Self {
        Self {
            policy,
            process: ArrivalProcess::Deterministic,
            load_factor: 0.8,
            jobs: 50,
            script: None,
            faults: None,
            churn_rate: 0.0,
            churn_downtime: 0.5,
            seed: 2022,
            use_cache: true,
            warm_start: true,
        }
    }
}

/// One served job's lifecycle.
#[derive(Clone, Debug, PartialEq)]
pub struct JobRecord {
    /// Per-master job index (arrival order).
    pub job: usize,
    pub master: usize,
    pub arrival_ms: f64,
    /// Admission (service start) time.
    pub start_ms: f64,
    /// Sampled service duration; `∞` = the job starved (the coded links
    /// still finishing carry fewer than `L_m` rows after churn).
    pub service_ms: f64,
    /// Churn-script epoch at admission (events at or before start).
    pub epoch: usize,
    /// Whether admission reused a cached plan for the fleet state.
    pub cache_hit: bool,
}

impl JobRecord {
    pub fn feasible(&self) -> bool {
        self.service_ms.is_finite()
    }

    pub fn wait_ms(&self) -> f64 {
        self.start_ms - self.arrival_ms
    }

    pub fn completion_ms(&self) -> f64 {
        self.start_ms + self.service_ms
    }

    /// Arrival → completion (the serving metric; `∞` when starved).
    pub fn sojourn_ms(&self) -> f64 {
        self.completion_ms() - self.arrival_ms
    }

    /// One streaming record. Non-finite durations serialize as `null`
    /// with the explicit `"feasible": false` flag alongside, so an
    /// export → parse round-trip keeps the starvation information.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("job", Json::Num(self.job as f64));
        j.set("master", Json::Num(self.master as f64));
        j.set("arrival_ms", Json::Num(self.arrival_ms));
        j.set("start_ms", Json::Num(self.start_ms));
        j.set("wait_ms", Json::Num(self.wait_ms()));
        j.set("service_ms", Json::Num(self.service_ms));
        j.set("sojourn_ms", Json::Num(self.sojourn_ms()));
        j.set("feasible", Json::Bool(self.feasible()));
        j.set("epoch", Json::Num(self.epoch as f64));
        j.set("cache_hit", Json::Bool(self.cache_hit));
        j
    }
}

/// Aggregate result of one serving run.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// Plan legend label (policy roster name).
    pub label: String,
    /// Every job in admission order.
    pub records: Vec<JobRecord>,
    /// Sojourn summaries over FEASIBLE jobs per master.
    pub per_master: Vec<Summary>,
    /// Sojourn summary over all feasible jobs.
    pub system: Summary,
    /// The t = 0 fleet plan's predicted system delay.
    pub t_est_ms: f64,
    /// The plan of the initial fleet state.
    pub cold_plan: Plan,
    /// Plans actually built (cache misses).
    pub replans: usize,
    /// Admissions that reused a cached plan.
    pub cache_hits: usize,
    /// Jobs that never completed (recorded `feasible: false`).
    pub infeasible: usize,
    /// Total SCA subproblem solves across replans (0 for closed-form
    /// load policies).
    pub sca_iters: usize,
    /// Mean inter-arrival the run used (`t*_base / load_factor`).
    pub period_ms: f64,
}

impl ServeOutcome {
    /// Sojourns of the feasible jobs, admission order.
    pub fn sojourn_samples(&self) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| r.feasible())
            .map(JobRecord::sojourn_ms)
            .collect()
    }

    /// p99 sojourn over feasible jobs (`None` when nothing completed).
    pub fn p99_ms(&self) -> Option<f64> {
        p99_sojourn_ms(&self.records)
    }
}

/// p99 sojourn over the feasible jobs of a record set (`None` when
/// nothing completed) — the one tail readout shared by the CLI tables
/// and [`ServeOutcome::p99_ms`].
pub fn p99_sojourn_ms(records: &[JobRecord]) -> Option<f64> {
    let xs: Vec<f64> = records
        .iter()
        .filter(|r| r.feasible())
        .map(JobRecord::sojourn_ms)
        .collect();
    percentile(&xs, 0.99)
}

// ----------------------------------------------------------------------
// Planning at a fleet state (subset + throttle + warm start)
// ----------------------------------------------------------------------

/// Build the plan for a fleet state. `factors[w]` (`w = 1..=N`; index 0
/// is the master-local slot and must stay 1.0) is each worker's current
/// capacity factor: 0 excludes the worker from planning, other values
/// scale its fitted computation rate `u`. Node ids in the returned plan
/// refer to the FULL scenario. `warm` seeds SCA-load policies with a
/// previous plan's allocation projected onto the surviving workers; the
/// second return value counts SCA subproblem solves (0 for closed-form
/// allocators).
pub fn plan_for(
    s: &Scenario,
    policy: &PolicySpec,
    factors: &[f64],
    warm: Option<&Plan>,
) -> anyhow::Result<(Plan, usize)> {
    let n = s.n_workers();
    anyhow::ensure!(
        factors.len() == n + 1,
        "need one capacity factor per node (index 0 = local), got {} for {n} workers",
        factors.len()
    );
    for (i, &f) in factors.iter().enumerate() {
        anyhow::ensure!(
            f.is_finite() && f >= 0.0,
            "capacity factor {f} at node {i} must be finite and ≥ 0"
        );
    }
    let active: Vec<usize> = (1..=n).filter(|&w| factors[w] > 0.0).collect();
    anyhow::ensure!(
        !active.is_empty(),
        "no active workers to plan on (every capacity factor is 0)"
    );
    let full_fleet = active.len() == n && active.iter().all(|&w| factors[w] == 1.0);
    let sub = if full_fleet {
        s.clone()
    } else {
        let mut sub = s.subset_workers(&active)?;
        for (j, &w) in active.iter().enumerate() {
            throttle_link_u(&mut sub, j, factors[w]);
        }
        sub
    };
    let resolved = policy.resolve()?;
    let (mut built, iters) = if resolved.loads == "sca" {
        // SCA with an optional warm start: project the previous plan's
        // loads onto the surviving nodes (sub-scenario ids) and seed
        // Algorithm 3 there instead of at the Theorem-1 closed form.
        let prev: Vec<HashMap<usize, f64>> = (0..s.n_masters())
            .map(|m| match warm {
                Some(p) => p.masters[m]
                    .entries
                    .iter()
                    .filter_map(|e| {
                        let sub_node = if e.node == 0 {
                            Some(0)
                        } else {
                            active.binary_search(&e.node).ok().map(|j| j + 1)
                        };
                        sub_node.map(|sn| (sn, e.load))
                    })
                    .collect(),
                None => HashMap::new(),
            })
            .collect();
        let warm_alloc = WarmSca {
            prev,
            iters: AtomicUsize::new(0),
        };
        let p = plan::build_with(&sub, resolved.assigner.as_ref(), &warm_alloc, &resolved.label());
        let iters = warm_alloc.iters.load(AtomicOrdering::Relaxed);
        (p, iters)
    } else {
        (resolved.build(&sub), 0)
    };
    if active.len() != n {
        // Remap sub-scenario worker ids back onto the full fleet.
        for mp in built.masters.iter_mut() {
            for e in mp.entries.iter_mut() {
                if e.node >= 1 {
                    e.node = active[e.node - 1];
                }
            }
        }
    }
    Ok((built, iters))
}

/// The plan-time throttling rule, in ONE place for both the planning
/// subset and the execution scenario: a host running at `factor` of its
/// capacity stretches its WHOLE per-row computation law by `1/factor`
/// (`a → a/factor`, `u → u·factor` — every mean-matched parametric
/// family resolves to the base law scaled by `1/factor`), leaving the
/// comm parameters alone. Stretching the whole law is what makes
/// [`CapacityProfile::warp_scaled`]'s normalization (`work = d·f_admit`)
/// EXACT for parametric families: a duration sampled under the throttle
/// is the base draw over `factor`. Factors of 0 (absent — never planned
/// onto) and exactly 1 (bit-exact full rate) are no-ops.
///
/// Trace-driven links cannot be throttled this way — their sampler
/// ignores the fitted `(a, u)` surrogate — so [`run`] rejects
/// fractional throttles on scenarios with trace-family worker links
/// (leave/join churn is fine: it never rescales the law).
fn throttle_link_u(s: &mut Scenario, col: usize, factor: f64) {
    if factor > 0.0 && factor != 1.0 {
        for row in s.links.iter_mut() {
            row[col].a /= factor;
            row[col].u *= factor;
        }
    }
}

/// The full scenario with each worker's fitted computation rate scaled
/// by its current capacity factor — what serving plans compile against
/// (absent workers keep their base parameters; no plan references them).
fn throttled_scenario(s: &Scenario, factors: &[f64]) -> Scenario {
    let mut out = s.clone();
    for w in 1..=s.n_workers() {
        throttle_link_u(&mut out, w - 1, factors[w]);
    }
    out
}

/// SCA load allocator with a warm-start seed (the serving layer's
/// replacement for the registry's cold `ScaAllocator` — identical when
/// `prev` is empty).
struct WarmSca {
    /// Per-master previous loads keyed by SUB-scenario node id.
    prev: Vec<HashMap<usize, f64>>,
    iters: AtomicUsize,
}

impl LoadAllocator for WarmSca {
    fn label_suffix(&self) -> &'static str {
        " + SCA"
    }

    fn allocate(
        &self,
        s: &Scenario,
        m: usize,
        nodes: &[usize],
        shares: &[(f64, f64)],
    ) -> Allocation {
        let l_rows = s.l_rows(m);
        let links: Vec<EffLink> = nodes
            .iter()
            .zip(shares)
            .map(|(&nd, &(k, b))| EffLink::fractional(&s.link(m, nd), k, b))
            .collect();
        let thetas: Vec<f64> = links.iter().map(EffLink::theta).collect();
        let cold = markov::allocate(&thetas, l_rows);
        let start = if self.prev[m].is_empty() {
            cold
        } else {
            let mut loads = cold.loads.clone();
            for (i, nd) in nodes.iter().enumerate() {
                if let Some(&pl) = self.prev[m].get(nd) {
                    if pl > 0.0 && thetas[i].is_finite() {
                        loads[i] = pl;
                    }
                }
            }
            let total: f64 = loads.iter().sum();
            if total > l_rows * (1.0 + 1e-9) {
                // Exact-model boundary t for the projected loads — a
                // feasible SCA start by construction.
                let t = alloc::exact_t_for_loads(&links, &loads, l_rows);
                Allocation { loads, t_star: t }
            } else {
                cold
            }
        };
        let (a, it) = sca::enhance_traced(&links, l_rows, &start, &sca::ScaOptions::default());
        self.iters.fetch_add(it, AtomicOrdering::Relaxed);
        a
    }
}

// ----------------------------------------------------------------------
// The event loop
// ----------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
enum EvKind {
    Arrival { master: usize, job: usize },
    Completion { master: usize },
}

/// Heap key: virtual time, ties broken by insertion sequence (so
/// same-instant arrivals process in master order — the lockstep case
/// the batch-parity test relies on).
#[derive(Clone, Copy, Debug)]
struct Ev {
    at: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, o: &Self) -> bool {
        self.at.to_bits() == o.at.to_bits() && self.seq == o.seq
    }
}
impl Eq for Ev {}
impl Ord for Ev {
    fn cmp(&self, o: &Self) -> Ordering {
        self.at.total_cmp(&o.at).then(self.seq.cmp(&o.seq))
    }
}
impl PartialOrd for Ev {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

struct PlanCtx {
    plan: Plan,
    compiled: Compiled,
}

struct ServeLoop<'a> {
    s: &'a Scenario,
    cfg: &'a ServeConfig,
    profiles: &'a [CapacityProfile],
    /// Script event times, presorted for O(log n) epoch lookups.
    epoch_times: Vec<f64>,
    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
    queues: Vec<VecDeque<(usize, f64)>>,
    busy: Vec<bool>,
    cache: HashMap<Vec<u64>, Rc<PlanCtx>>,
    cold: Option<Rc<PlanCtx>>,
    last_plan: Option<Plan>,
    service_rng: Rng,
    times: Vec<f64>,
    loads: Vec<f64>,
    records: Vec<JobRecord>,
    replans: usize,
    cache_hits: usize,
    infeasible: usize,
    sca_iters: usize,
}

impl ServeLoop<'_> {
    fn push(&mut self, at: f64, kind: EvKind) {
        let ev = Ev {
            at,
            seq: self.seq,
            kind,
        };
        self.seq += 1;
        self.heap.push(Reverse(ev));
    }

    /// Churn epoch at `t` — [`ChurnScript::epoch_at`] over the
    /// presorted event times, O(log events) per admission instead of a
    /// linear scan (synthesized scripts can carry thousands of events).
    fn epoch_at(&self, t: f64) -> usize {
        self.epoch_times.partition_point(|&bt| bt <= t)
    }

    /// Plan (or fetch) for the fleet state at `now`. Either way, the
    /// FIRST plan an admission actually uses becomes `cold` — the
    /// "initial fleet state" plan the outcome reports (a cache hit on
    /// the pre-seeded full-fleet entry counts; a churned first
    /// admission does too).
    fn plan_at(&mut self, now: f64) -> anyhow::Result<(Rc<PlanCtx>, bool)> {
        let n = self.s.n_workers();
        let key: Vec<u64> = (1..=n)
            .map(|w| self.profiles[w].factor_at(now).to_bits())
            .collect();
        if self.cfg.use_cache {
            if let Some(ctx) = self.cache.get(&key) {
                self.cache_hits += 1;
                let ctx = Rc::clone(ctx);
                if self.cold.is_none() {
                    self.cold = Some(Rc::clone(&ctx));
                }
                return Ok((ctx, true));
            }
        }
        let mut factors = vec![1.0f64; n + 1];
        for w in 1..=n {
            factors[w] = self.profiles[w].factor_at(now);
        }
        let warm = if self.cfg.warm_start {
            self.last_plan.as_ref()
        } else {
            None
        };
        let (built, iters) = plan_for(self.s, &self.cfg.policy, &factors, warm)?;
        self.replans += 1;
        self.sca_iters += iters;
        let exec_s = throttled_scenario(self.s, &factors);
        built.validate(&exec_s)?;
        let compiled = Compiled::new(&exec_s, &built);
        self.last_plan = Some(built.clone());
        let ctx = Rc::new(PlanCtx {
            plan: built,
            compiled,
        });
        if self.cold.is_none() {
            self.cold = Some(Rc::clone(&ctx));
        }
        if self.cfg.use_cache {
            self.cache.insert(key, Rc::clone(&ctx));
        }
        Ok((ctx, false))
    }

    /// Admit the head of master `m`'s queue at time `now`. Starved jobs
    /// (`service = ∞`) are recorded infeasible and the server freed
    /// immediately — an operator would kill a stalled job rather than
    /// block the queue forever — so admission loops until a feasible
    /// job is in service or the queue drains. A job admitted while the
    /// ENTIRE fleet is away (an explicit script can empty it; synthesized
    /// churn never does) is the same starvation case, not a run abort.
    fn admit(&mut self, m: usize, now: f64) -> anyhow::Result<()> {
        while let Some((job, arrival)) = self.queues[m].pop_front() {
            let n = self.s.n_workers();
            if !(1..=n).any(|w| self.profiles[w].factor_at(now) > 0.0) {
                self.records.push(JobRecord {
                    job,
                    master: m,
                    arrival_ms: arrival,
                    start_ms: now,
                    service_ms: f64::INFINITY,
                    epoch: self.epoch_at(now),
                    cache_hit: false,
                });
                self.infeasible += 1;
                continue;
            }
            let (ctx, cache_hit) = self.plan_at(now)?;
            let service = ctx.compiled.sample_master_warped(
                m,
                &mut self.service_rng,
                now,
                self.profiles,
                &mut self.times,
                &mut self.loads,
            );
            self.records.push(JobRecord {
                job,
                master: m,
                arrival_ms: arrival,
                start_ms: now,
                service_ms: service,
                epoch: self.epoch_at(now),
                cache_hit,
            });
            if service.is_finite() {
                self.busy[m] = true;
                self.push(now + service, EvKind::Completion { master: m });
                return Ok(());
            }
            self.infeasible += 1;
        }
        Ok(())
    }
}

/// Run one serving timeline on `s`. Deterministic in `(scenario, cfg)`:
/// arrivals, churn synthesis and service draws all derive from
/// `cfg.seed` through separate streams.
pub fn run(s: &Scenario, cfg: &ServeConfig) -> anyhow::Result<ServeOutcome> {
    validate_arrival_knobs(cfg.load_factor, cfg.churn_rate, cfg.churn_downtime)?;
    let m_cnt = s.n_masters();
    let n = s.n_workers();

    // Time-scale reference: the full-fleet plan's predicted system delay.
    let base_plan = cfg.policy.build(s)?;
    let t_ref = base_plan.t_est();
    anyhow::ensure!(
        t_ref.is_finite() && t_ref > 0.0,
        "planner t* must be positive and finite to scale arrivals (got {t_ref})"
    );
    let period = t_ref / cfg.load_factor;
    // The synthesized-churn horizon must cover the whole run even under
    // overload, where the busy period (≈ jobs × service ≈ jobs × t*)
    // outlives the arrival span (jobs × period) — otherwise the queue's
    // tail would silently serve a static fleet. 4·t* per job bounds the
    // empirical mean service (≤ ~2·t*) with slack.
    let span = period.max(4.0 * t_ref) * cfg.jobs.max(1) as f64;
    let horizon = span * 2.0 + 4.0 * t_ref;
    let script = match (&cfg.script, &cfg.faults) {
        (Some(sc), _) => sc.clone(),
        // Health-driven churn: the timeline the coordinator's detection
        // layer would emit under this fault plan (leaves delayed by the
        // missed-beat / stall windows, throttles recovered through
        // breaker probes) instead of a rate-driven cycle.
        (None, Some(fp)) => {
            health::churn_from_faults(fp, n, horizon, &HealthConfig::default())
        }
        (None, None) => ChurnScript::synthesize(
            n,
            cfg.churn_rate,
            cfg.churn_downtime,
            t_ref,
            horizon,
            cfg.seed,
        ),
    };
    script.validate(n)?;
    // No silent caps: a synthesized script that hit MAX_SYNTH_EVENTS
    // before covering the horizon leaves the tail of the run on a
    // static fleet — say so instead of letting the churn axis lie.
    // (Fault-derived scripts are exact: every fault maps to a bounded
    // set of events, so there is nothing to truncate.)
    if cfg.script.is_none() && cfg.faults.is_none() {
        if let Some(last) = script.events.last() {
            if last.at_ms < horizon * 0.9 {
                eprintln!(
                    "serve: synthesized churn truncated at {} events (covers {:.0} of \
                     {:.0} virtual ms); later jobs run on a static fleet",
                    script.events.len(),
                    last.at_ms,
                    horizon
                );
            }
        }
    }
    // Fractional throttles rescale the fitted computation law, which
    // trace-driven links ignore entirely (they sample the raw ECDF) —
    // the throttle would be a silent sampling no-op while the warp
    // still renormalized by it, producing impossible service times.
    // Leave/join churn (factors 0 / 1) never rescales and stays valid.
    let has_trace = (0..m_cnt).any(|m| {
        (1..=n).any(|w| {
            matches!(
                s.link(m, w).family,
                crate::model::dist::FamilyKind::Trace { .. }
            )
        })
    });
    if has_trace {
        let fractional = script.events.iter().any(
            |e| matches!(e.action, ChurnAction::Throttle(f) if f != 0.0 && f != 1.0),
        );
        anyhow::ensure!(
            !fractional,
            "fractional throttles are not supported on scenarios with trace-driven \
             worker links (the trace sampler ignores the fitted rate); use leave/join churn"
        );
    }
    let profiles = script.profiles(n)?;

    // Pre-seed the plan cache with the full-fleet plan: it was already
    // built above for the arrival time scale, and the t = 0 fingerprint
    // is the all-ones fleet whenever the script carries no event at 0 —
    // without this the first admission would redo the identical (for
    // SCA-load policies, expensive) solve.
    let mut cache: HashMap<Vec<u64>, Rc<PlanCtx>> = HashMap::new();
    if cfg.use_cache {
        let base_ctx = Rc::new(PlanCtx {
            compiled: Compiled::new(s, &base_plan),
            plan: base_plan.clone(),
        });
        cache.insert(vec![1.0f64.to_bits(); n], base_ctx);
    }

    // Arrival streams (salted: independent of the service stream).
    let arrivals: Vec<Vec<f64>> = (0..m_cnt)
        .map(|m| match cfg.process {
            ArrivalProcess::Deterministic => {
                (0..cfg.jobs).map(|j| j as f64 * period).collect()
            }
            ArrivalProcess::Poisson => {
                let mut rng = Rng::new(cfg.seed ^ ARRIVAL_SALT).fork(m as u64 + 1);
                let rate = 1.0 / period;
                let mut t = 0.0;
                (0..cfg.jobs)
                    .map(|_| {
                        t += rng.exp(rate);
                        t
                    })
                    .collect()
            }
        })
        .collect();

    let mut lp = ServeLoop {
        s,
        cfg,
        profiles: &profiles,
        epoch_times: {
            let mut ts: Vec<f64> = script.events.iter().map(|e| e.at_ms).collect();
            ts.sort_by(f64::total_cmp);
            ts
        },
        heap: BinaryHeap::new(),
        seq: 0,
        queues: vec![VecDeque::new(); m_cnt],
        busy: vec![false; m_cnt],
        cache,
        cold: None,
        // Warm starts may seed from the full-fleet plan on the very
        // first state change, not only from replans this loop performed.
        last_plan: cfg.warm_start.then(|| base_plan.clone()),
        // Stream 1 = the batch engine's first shard stream: the
        // constant-share parity contract (module docs).
        service_rng: Rng::new(cfg.seed).fork(1),
        times: Vec::new(),
        loads: Vec::new(),
        records: Vec::with_capacity(m_cnt * cfg.jobs),
        replans: 0,
        cache_hits: 0,
        infeasible: 0,
        sca_iters: 0,
    };
    // Arrivals pushed job-major, master-minor: same-instant ties process
    // in master order (lockstep = the batch trial loop's master order).
    for j in 0..cfg.jobs {
        for (m, arr) in arrivals.iter().enumerate() {
            lp.push(arr[j], EvKind::Arrival { master: m, job: j });
        }
    }
    while let Some(Reverse(ev)) = lp.heap.pop() {
        match ev.kind {
            EvKind::Arrival { master, job } => {
                lp.queues[master].push_back((job, ev.at));
                if !lp.busy[master] {
                    lp.admit(master, ev.at)?;
                }
            }
            EvKind::Completion { master } => {
                lp.busy[master] = false;
                if !lp.queues[master].is_empty() {
                    lp.admit(master, ev.at)?;
                }
            }
        }
    }

    let mut per_master = vec![Summary::new(); m_cnt];
    let mut system = Summary::new();
    for r in &lp.records {
        if r.feasible() {
            per_master[r.master].push(r.sojourn_ms());
            system.push(r.sojourn_ms());
        }
    }
    let (cold_plan, t_est_ms) = match &lp.cold {
        Some(ctx) => (ctx.plan.clone(), ctx.plan.t_est()),
        None => (base_plan.clone(), t_ref),
    };
    Ok(ServeOutcome {
        label: cold_plan.label.clone(),
        records: lp.records,
        per_master,
        system,
        t_est_ms,
        cold_plan,
        replans: lp.replans,
        cache_hits: lp.cache_hits,
        infeasible: lp.infeasible,
        sca_iters: lp.sca_iters,
        period_ms: period,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::CommModel;

    fn policy(loads: &str) -> PolicySpec {
        PolicySpec::new("dedi-iter", ValueModel::Markov, loads)
    }

    fn small() -> Scenario {
        Scenario::small_scale(5, 2.0, CommModel::Stochastic)
    }

    #[test]
    fn static_fleet_run_is_deterministic_and_well_formed() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 20;
        cfg.load_factor = 0.9;
        let a = run(&s, &cfg).unwrap();
        let b = run(&s, &cfg).unwrap();
        assert_eq!(a.records, b.records, "serving must be deterministic");
        assert_eq!(a.records.len(), 2 * 20);
        assert_eq!(a.infeasible, 0);
        // The full-fleet plan is built once up front (the time-scale
        // reference doubles as the cache seed): a static fleet never
        // replans at all.
        assert_eq!(a.replans, 0, "static fleet must reuse the pre-seeded plan");
        assert_eq!(a.cache_hits, 2 * 20);
        assert!(a.system.count() == 40 && a.system.mean() > 0.0);
        for r in &a.records {
            assert!(r.feasible());
            assert!(r.wait_ms() >= 0.0, "{r:?}");
            assert!(r.start_ms >= r.arrival_ms);
            assert!(
                (r.sojourn_ms() - (r.wait_ms() + r.service_ms)).abs() < 1e-9,
                "{r:?}"
            );
            assert!(r.cache_hit, "static fleet: every admission is a cache hit");
            assert_eq!(r.epoch, 0);
        }
        // Per-master jobs appear in order.
        for m in 0..2 {
            let jobs: Vec<usize> = a
                .records
                .iter()
                .filter(|r| r.master == m)
                .map(|r| r.job)
                .collect();
            assert_eq!(jobs, (0..20).collect::<Vec<_>>());
        }
        assert!(a.p99_ms().unwrap() >= a.system.mean());
    }

    #[test]
    fn overload_queues_and_underload_does_not() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 30;
        cfg.load_factor = 8.0; // heavy overload: arrivals far above service rate
        let over = run(&s, &cfg).unwrap();
        let waited = over.records.iter().filter(|r| r.wait_ms() > 1e-9).count();
        assert!(waited > 10, "overload produced almost no queueing ({waited})");
        cfg.load_factor = 0.05; // deep underload
        let under = run(&s, &cfg).unwrap();
        let waited = under.records.iter().filter(|r| r.wait_ms() > 1e-9).count();
        assert!(waited < 5, "deep underload queued {waited} jobs");
        assert!(under.system.mean() < over.system.mean());
    }

    #[test]
    fn poisson_arrivals_are_seeded_and_monotone() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.process = ArrivalProcess::Poisson;
        cfg.jobs = 15;
        let a = run(&s, &cfg).unwrap();
        let b = run(&s, &cfg).unwrap();
        assert_eq!(a.records, b.records);
        for m in 0..2 {
            let arr: Vec<f64> = a
                .records
                .iter()
                .filter(|r| r.master == m)
                .map(|r| r.arrival_ms)
                .collect();
            assert!(arr.windows(2).all(|w| w[1] > w[0]), "arrivals not increasing");
        }
        cfg.seed = 777;
        let c = run(&s, &cfg).unwrap();
        assert_ne!(a.records[0].arrival_ms, c.records[0].arrival_ms);
    }

    #[test]
    fn zero_arrival_stream_is_empty_but_well_formed() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 0;
        let out = run(&s, &cfg).unwrap();
        assert!(out.records.is_empty());
        assert_eq!(out.system.count(), 0);
        assert_eq!(out.replans, 0);
        assert!(out.p99_ms().is_none());
        assert!(out.t_est_ms > 0.0);
        assert_eq!(out.cold_plan.label, out.label);
    }

    #[test]
    fn plan_for_excludes_absent_workers_and_remaps_ids() {
        let s = small();
        let n = s.n_workers();
        let mut factors = vec![1.0; n + 1];
        factors[2] = 0.0; // worker 2 away
        let (p, _) = plan_for(&s, &policy("markov"), &factors, None).unwrap();
        for mp in &p.masters {
            for e in &mp.entries {
                assert_ne!(e.node, 2, "absent worker planned");
                assert!(e.node <= n, "node id not remapped to the full fleet");
            }
        }
        p.validate(&s).unwrap();
        // Full-capacity factors reproduce the registry build exactly.
        let ones = vec![1.0; n + 1];
        let (full, _) = plan_for(&s, &policy("markov"), &ones, None).unwrap();
        assert_eq!(full, policy("markov").build(&s).unwrap());
        // All-zero factors are a graceful error.
        let mut dead = vec![1.0; n + 1];
        for f in dead.iter_mut().skip(1) {
            *f = 0.0;
        }
        assert!(plan_for(&s, &policy("markov"), &dead, None).is_err());
        // Throttling raises the planner's estimate.
        let mut slow = vec![1.0; n + 1];
        for f in slow.iter_mut().skip(1) {
            *f = 0.25;
        }
        let (thr, _) = plan_for(&s, &policy("markov"), &slow, None).unwrap();
        assert!(thr.t_est() > full.t_est());
    }

    #[test]
    fn warm_started_sca_replan_matches_cold_and_is_no_slower() {
        let s = small();
        let n = s.n_workers();
        let full = vec![1.0; n + 1];
        let (cold, cold_iters) = plan_for(&s, &policy("sca"), &full, None).unwrap();
        assert!(cold_iters >= 1);
        // Warm start from the cold optimum on the SAME fleet state: the
        // fixed point must be reached at least as fast, same plan.
        let (warm, warm_iters) = plan_for(&s, &policy("sca"), &full, Some(&cold)).unwrap();
        assert!(warm_iters <= cold_iters, "warm {warm_iters} > cold {cold_iters}");
        assert!(
            (warm.t_est() - cold.t_est()).abs() / cold.t_est() < 1e-6,
            "warm restart moved the optimum: {} vs {}",
            warm.t_est(),
            cold.t_est()
        );
        // Across a fleet change the warm plan still matches a cold
        // replan's quality on the new state.
        let mut less = vec![1.0; n + 1];
        less[1] = 0.0;
        let (cold2, _) = plan_for(&s, &policy("sca"), &less, None).unwrap();
        let (warm2, _) = plan_for(&s, &policy("sca"), &less, Some(&cold)).unwrap();
        assert!(
            (warm2.t_est() - cold2.t_est()).abs() / cold2.t_est() < 1e-3,
            "warm replan degraded the optimum: {} vs {}",
            warm2.t_est(),
            cold2.t_est()
        );
    }

    #[test]
    fn churned_fleet_replans_and_caches_per_state() {
        let s = small();
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 40;
        cfg.load_factor = 0.8;
        cfg.churn_rate = 1.0;
        cfg.churn_downtime = 0.5;
        let out = run(&s, &cfg).unwrap();
        assert!(out.replans >= 2, "churn never triggered a replan");
        assert!(
            out.replans <= s.n_workers() + 1,
            "cache missed repeated fleet states ({} replans)",
            out.replans
        );
        assert!(out.cache_hits > 0);
        assert!(out.records.iter().any(|r| r.epoch > 0));
        // The reported cold plan is the INITIAL fleet's (admissions at
        // t = 0 precede the first churn event), never a churned replan.
        assert_eq!(
            out.cold_plan,
            policy("markov").build(&s).unwrap(),
            "cold plan drifted to a churned state"
        );
        // The serving stream still completes almost everywhere (churned
        // workers rejoin).
        assert!(out.infeasible <= out.records.len() / 4);
    }

    #[test]
    fn empty_fleet_admission_starves_instead_of_aborting() {
        let s = small();
        let n = s.n_workers();
        let period = policy("markov").build(&s).unwrap().t_est() * 1e6;
        // Every worker away across job 1's arrival; back before job 2's.
        let mut events = Vec::new();
        for w in 1..=n {
            events.push(ChurnEvent {
                at_ms: 0.5 * period,
                worker: w,
                action: ChurnAction::Leave,
            });
            events.push(ChurnEvent {
                at_ms: 1.5 * period,
                worker: w,
                action: ChurnAction::Join,
            });
        }
        let mut cfg = ServeConfig::new(policy("markov"));
        cfg.jobs = 3;
        cfg.load_factor = 1e-6; // lockstep spacing ≫ any service time
        cfg.script = Some(ChurnScript { events });
        let out = run(&s, &cfg).expect("empty fleet must starve jobs, not abort");
        assert_eq!(out.records.len(), 2 * 3);
        for m in 0..2 {
            let by_job: Vec<bool> = (0..3)
                .map(|j| {
                    out.records
                        .iter()
                        .find(|r| r.master == m && r.job == j)
                        .unwrap()
                        .feasible()
                })
                .collect();
            assert_eq!(by_job, vec![true, false, true], "master {m}");
        }
        assert_eq!(out.infeasible, 2);
    }

    #[test]
    fn arrival_process_names_roundtrip() {
        for p in [ArrivalProcess::Deterministic, ArrivalProcess::Poisson] {
            assert_eq!(ArrivalProcess::parse(p.as_str()).unwrap(), p);
        }
        assert!(ArrivalProcess::parse("bursty").is_err());
    }

    #[test]
    fn job_record_json_keeps_starvation_information() {
        let rec = JobRecord {
            job: 3,
            master: 1,
            arrival_ms: 10.0,
            start_ms: 12.5,
            service_ms: f64::INFINITY,
            epoch: 2,
            cache_hit: false,
        };
        let line = json_line(&rec.to_json());
        assert!(!line.contains('\n'));
        let back = crate::util::json::parse(&line).unwrap();
        assert_eq!(back.get("service_ms"), Some(&Json::Null));
        assert_eq!(back.get("sojourn_ms"), Some(&Json::Null));
        assert_eq!(back.get("feasible").and_then(Json::as_bool), Some(false));
        assert_eq!(back.get("epoch").and_then(Json::as_usize), Some(2));
        // Feasible records carry numbers and the true flag.
        let ok = JobRecord {
            service_ms: 4.0,
            ..rec
        };
        let back = crate::util::json::parse(&json_line(&ok.to_json())).unwrap();
        assert_eq!(back.get("sojourn_ms").and_then(Json::as_f64), Some(6.5));
        assert_eq!(back.get("feasible").and_then(Json::as_bool), Some(true));
    }
}
