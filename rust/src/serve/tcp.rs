//! Serving over a real TCP fleet: churn *observed*, not scripted.
//!
//! The virtual serving loop ([`super::run`]) drives its fleet timeline
//! from a [`super::ChurnScript`] — a declaration of when workers leave,
//! rejoin or throttle. On a real deployment nobody hands the
//! coordinator that script; the only truth is the connection lifecycle.
//! This module is the serving layer for `--transport tcp`: each admitted
//! job executes for real over [`Transport::Tcp`]
//! ([`coordinator::run_plan`] — encode, framed dispatch, decode,
//! verify), and the health events that run *observed* (disconnects,
//! suspicions, resumes) are what drive the fleet state for the next
//! admission:
//!
//! * a worker whose session disconnected or was declared sick trips its
//!   **persistent breaker** (per shared worker, carried across jobs);
//! * the next admission plans around breaker-open workers
//!   ([`super::plan_for`] with capacity factor 0 — the same subset +
//!   remap path the virtual loop uses), with the plan cache and SCA
//!   warm starts riding along;
//! * a breaker whose backoff elapsed lets its worker back in as a
//!   half-open probe; a clean job (or an in-run `Reconnect`) closes it;
//! * when EVERY shared worker is breaker-open the loop falls back to
//!   planning on the full fleet — the abandon-to-redundancy floor: MDS
//!   redundancy plus in-run re-queue is the last line, and serving
//!   never wedges on an empty candidate set.
//!
//! Each job emits one JSONL-able [`TcpJobRecord`]; the aggregate
//! [`TcpServeOutcome`] carries the merged health timeline so a smoke
//! run can assert `disconnect → backoff → reconnect/requeue` ordering
//! end-to-end.

use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use crate::config::Scenario;
use crate::coordinator::{self, Backend, RunOptions, TcpOptions, Transport};
use crate::health::{CircuitBreaker, FaultPlan, HealthConfig, HealthEvent, HealthEventKind};
use crate::plan::Plan;
use crate::policy::PolicySpec;
use crate::util::json::Json;

use super::plan_for;

/// Everything a serve-over-TCP run needs beyond the scenario.
#[derive(Clone)]
pub struct TcpServeConfig {
    pub policy: PolicySpec,
    /// Jobs served sequentially (each is one full coded run).
    pub jobs: usize,
    /// Task width `S_m` (columns of every `A_m`).
    pub cols: usize,
    /// Wall-clock seconds per virtual millisecond.
    pub time_scale: f64,
    pub seed: u64,
    /// Worker endpoints; empty = auto-spawn loopback processes per job.
    pub addrs: Vec<String>,
    /// Shared-secret auth token (see [`TcpOptions::auth`]).
    pub auth: Option<String>,
    /// Fault plan injected into the FIRST job only — the recovery story
    /// (exclusion, probe, re-admission) then plays out on later jobs.
    pub fault: Option<FaultPlan>,
    pub health: HealthConfig,
    /// Reuse plans across admissions with an unchanged fleet state.
    pub use_cache: bool,
    /// Seed SCA replans with the previous admission's plan.
    pub warm_start: bool,
}

impl TcpServeConfig {
    pub fn new(policy: PolicySpec) -> Self {
        Self {
            policy,
            jobs: 3,
            cols: 32,
            time_scale: 2e-3,
            seed: 2022,
            addrs: Vec::new(),
            auth: None,
            fault: None,
            health: HealthConfig::default(),
            use_cache: true,
            warm_start: true,
        }
    }
}

/// One served job's outcome on the real TCP runtime.
#[derive(Clone, Debug)]
pub struct TcpJobRecord {
    pub job: usize,
    /// Plan label the admission used.
    pub label: String,
    /// Wall-clock the run took (ms).
    pub wall_ms: f64,
    /// Virtual system completion (slowest master, ms).
    pub completion_ms: f64,
    /// Decode verified against the direct product for every master.
    pub verified: bool,
    pub cache_hit: bool,
    /// Scenario worker ids (1-based) planned around because their
    /// breaker was open at admission.
    pub excluded: Vec<usize>,
    /// The admission hit the abandon-to-redundancy floor: every shared
    /// worker was breaker-open, so it planned on the full fleet anyway.
    pub redundancy_floor: bool,
    /// Lifecycle observations from this job's run.
    pub disconnects: usize,
    pub reconnects: usize,
    pub requeues: usize,
}

impl TcpJobRecord {
    /// One streaming JSONL record (`coded-coop serve --transport tcp`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("job", Json::Num(self.job as f64));
        j.set("label", Json::Str(self.label.clone()));
        j.set("wall_ms", Json::Num(self.wall_ms));
        j.set("completion_ms", Json::Num(self.completion_ms));
        j.set("verified", Json::Bool(self.verified));
        j.set("cache_hit", Json::Bool(self.cache_hit));
        j.set(
            "excluded",
            Json::Arr(self.excluded.iter().map(|&w| Json::Num(w as f64)).collect()),
        );
        j.set("redundancy_floor", Json::Bool(self.redundancy_floor));
        j.set("disconnects", Json::Num(self.disconnects as f64));
        j.set("reconnects", Json::Num(self.reconnects as f64));
        j.set("requeues", Json::Num(self.requeues as f64));
        j
    }
}

/// Aggregate result of one serve-over-TCP run.
#[derive(Clone, Debug)]
pub struct TcpServeOutcome {
    pub records: Vec<TcpJobRecord>,
    /// Plans actually built (cache misses).
    pub replans: usize,
    /// Admissions that reused a cached plan.
    pub cache_hits: usize,
    /// Merged health timeline across all jobs, in job order (event
    /// `at_ms` values are per-run clocks; `job_of` indexes them).
    pub health: Vec<HealthEvent>,
    /// `health[i]` came from job `job_of[i]`.
    pub job_of: Vec<usize>,
}

impl TcpServeOutcome {
    pub fn all_verified(&self) -> bool {
        self.records.iter().all(|r| r.verified)
    }
}

/// The admission-time fleet view: capacity factors from the breakers
/// (`factors[0]` = master-local slot, always 1), the excluded worker
/// ids, and whether the abandon-to-redundancy floor kicked in (every
/// shared worker open → plan on the full fleet, lean on MDS redundancy
/// and in-run re-queue). Pure — unit-tested without sockets.
fn admission_factors(
    breakers: &mut [CircuitBreaker],
    now_ms: f64,
    n: usize,
) -> (Vec<f64>, Vec<usize>, bool) {
    let mut factors = vec![1.0f64; n + 1];
    let mut excluded = Vec::new();
    for w in 1..=n {
        if !breakers[w - 1].allow(now_ms) {
            factors[w] = 0.0;
            excluded.push(w);
        }
    }
    if excluded.len() == n {
        // Graceful-degradation floor: nobody is trusted, so trust
        // everybody — a plan over the full fleet still carries MDS
        // redundancy, and the in-run health layer re-queues what the
        // truly dead drop. Serving must degrade, never wedge.
        return (vec![1.0f64; n + 1], excluded, true);
    }
    (factors, excluded, false)
}

/// Serve `cfg.jobs` sequential jobs over the real TCP runtime, fleet
/// state driven by observed connection lifecycle (module docs). Errors
/// only on infrastructure failure (cannot spawn/reach any worker,
/// planning bug); per-job compute faults degrade records, not the run.
pub fn run_tcp(s: &Scenario, cfg: &TcpServeConfig) -> anyhow::Result<TcpServeOutcome> {
    anyhow::ensure!(cfg.jobs >= 1, "serve-over-tcp needs at least one job");
    let n = s.n_workers();
    let mut breakers: Vec<CircuitBreaker> = (0..n)
        .map(|_| {
            CircuitBreaker::new(
                cfg.health.breaker_backoff_ms,
                cfg.health.breaker_backoff_cap_ms,
            )
        })
        .collect();
    let mut cache: HashMap<Vec<u64>, Rc<Plan>> = HashMap::new();
    let mut last_plan: Option<Plan> = None;
    let mut records = Vec::with_capacity(cfg.jobs);
    let mut health: Vec<HealthEvent> = Vec::new();
    let mut job_of: Vec<usize> = Vec::new();
    let mut replans = 0usize;
    let mut cache_hits = 0usize;
    let t0 = Instant::now();

    for job in 0..cfg.jobs {
        let now_ms = t0.elapsed().as_secs_f64() * 1e3;
        let (factors, excluded, floor) = admission_factors(&mut breakers, now_ms, n);

        // ---- plan for the observed fleet state (cache + warm start) --
        let key: Vec<u64> = factors.iter().map(|f| f.to_bits()).collect();
        let (plan, cache_hit) = match cfg.use_cache.then(|| cache.get(&key)).flatten() {
            Some(p) => {
                cache_hits += 1;
                (Rc::clone(p), true)
            }
            None => {
                let warm = if cfg.warm_start {
                    last_plan.as_ref()
                } else {
                    None
                };
                let (built, _iters) = plan_for(s, &cfg.policy, &factors, warm)?;
                replans += 1;
                last_plan = Some(built.clone());
                let rc = Rc::new(built);
                if cfg.use_cache {
                    cache.insert(key, Rc::clone(&rc));
                }
                (rc, false)
            }
        };

        // ---- execute the job for real over TCP -----------------------
        let report = coordinator::run_plan(
            s,
            &plan,
            &RunOptions {
                cols: cfg.cols,
                time_scale: cfg.time_scale,
                backend: Backend::Native,
                seed: cfg.seed.wrapping_add(job as u64),
                verify: true,
                transport: Transport::Tcp(TcpOptions {
                    addrs: cfg.addrs.clone(),
                    auth: cfg.auth.clone(),
                }),
                fault: if job == 0 { cfg.fault.clone() } else { None },
                health: cfg.health.clone(),
            },
        )?;

        // ---- fold the observed lifecycle into the breakers -----------
        // Queue index w < n is shared worker w (scenario id w + 1);
        // master-local queues (w ≥ n) never churn the fleet view.
        let fold_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut disconnects = 0usize;
        let mut reconnects = 0usize;
        let mut requeues = 0usize;
        let mut failed = vec![false; n];
        for ev in &report.health {
            match &ev.kind {
                HealthEventKind::Disconnect => {
                    disconnects += 1;
                    if ev.worker < n {
                        failed[ev.worker] = true;
                        breakers[ev.worker].on_failure(fold_ms);
                    }
                }
                HealthEventKind::Suspect { .. } => {
                    if ev.worker < n {
                        failed[ev.worker] = true;
                        breakers[ev.worker].on_failure(fold_ms);
                    }
                }
                HealthEventKind::Reconnect => {
                    reconnects += 1;
                    if ev.worker < n {
                        failed[ev.worker] = false;
                        breakers[ev.worker].on_success();
                    }
                }
                HealthEventKind::Requeue { .. } => requeues += 1,
                _ => {}
            }
        }
        // A worker that served this job without incident passed its
        // probe: close its breaker (half-open → closed, and also heal
        // stale opens whose backoff elapsed).
        for w in 1..=n {
            if factors[w] > 0.0 && !failed[w - 1] {
                breakers[w - 1].on_success();
            }
        }
        job_of.extend(std::iter::repeat(job).take(report.health.len()));
        health.extend(report.health.iter().cloned());

        records.push(TcpJobRecord {
            job,
            label: report.label.clone(),
            wall_ms: report.wall_ms,
            completion_ms: report.system_completion_ms(),
            verified: report.all_verified(1e-2),
            cache_hit,
            excluded,
            redundancy_floor: floor,
            disconnects,
            reconnects,
            requeues,
        });
    }

    Ok(TcpServeOutcome {
        records,
        replans,
        cache_hits,
        health,
        job_of,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::CommModel;
    use crate::net::worker::{WorkerConfig, WorkerServer};

    fn policy() -> PolicySpec {
        PolicySpec::new("dedi-iter", ValueModel::Markov, "markov")
    }

    fn small() -> Scenario {
        Scenario::small_scale(4, 2.0, CommModel::Stochastic)
    }

    /// Spin N in-process worker servers; they serve until dropped.
    fn loopback_workers(n: usize) -> Vec<String> {
        (0..n)
            .map(|_| {
                let server = WorkerServer::bind("127.0.0.1:0").expect("bind");
                let addr = server.local_addr().expect("addr").to_string();
                std::thread::spawn(move || {
                    let _ = server.run(&WorkerConfig::default());
                });
                addr
            })
            .collect()
    }

    #[test]
    fn admission_factors_exclude_open_breakers_and_floor_gracefully() {
        let n = 3;
        let mut breakers: Vec<CircuitBreaker> =
            (0..n).map(|_| CircuitBreaker::new(100.0, 1000.0)).collect();
        // Clean fleet: everyone in, no floor.
        let (f, ex, floor) = admission_factors(&mut breakers, 0.0, n);
        assert_eq!(f, vec![1.0; n + 1]);
        assert!(ex.is_empty() && !floor);
        // Worker 2's breaker trips: excluded while the backoff holds.
        breakers[1].on_failure(10.0);
        let (f, ex, floor) = admission_factors(&mut breakers, 20.0, n);
        assert_eq!(f[2], 0.0);
        assert_eq!(ex, vec![2]);
        assert!(!floor);
        assert_eq!(f[0], 1.0, "master-local slot never churns");
        // Backoff elapsed: the next admission probes it half-open.
        let (f, ex, _) = admission_factors(&mut breakers, 10_000.0, n);
        assert_eq!(f[2], 1.0, "elapsed backoff re-admits the worker");
        assert!(ex.is_empty());
        // Everyone open: the abandon-to-redundancy floor plans on the
        // full fleet instead of erroring out on an empty candidate set.
        for b in breakers.iter_mut() {
            b.on_failure(20_000.0);
        }
        let (f, ex, floor) = admission_factors(&mut breakers, 20_001.0, n);
        assert_eq!(f, vec![1.0; n + 1]);
        assert_eq!(ex.len(), n);
        assert!(floor, "all-open fleet must hit the redundancy floor");
    }

    #[test]
    fn clean_tcp_serve_verifies_and_caches() {
        let s = small();
        let addrs = loopback_workers(2);
        let mut cfg = TcpServeConfig::new(policy());
        cfg.jobs = 3;
        cfg.cols = 24;
        cfg.time_scale = 1e-4;
        cfg.addrs = addrs;
        let out = run_tcp(&s, &cfg).expect("clean serve");
        assert_eq!(out.records.len(), 3);
        assert!(out.all_verified(), "every job must decode: {:?}", out.records);
        // A static healthy fleet plans once and hits the cache after.
        assert_eq!(out.replans, 1);
        assert_eq!(out.cache_hits, 2);
        for r in &out.records {
            assert!(r.excluded.is_empty(), "{r:?}");
            assert!(!r.redundancy_floor);
            assert_eq!(r.disconnects, 0);
        }
        assert!(out.health.is_empty(), "clean runs are disarmed: {:?}", out.health);
        assert_eq!(out.job_of.len(), out.health.len());
    }

    #[test]
    fn observed_crash_excludes_worker_on_next_admission() {
        let s = small();
        // Worker process 0 crashes mid-queue on EVERY connection it
        // serves; the rest are clean. Job 0 observes the disconnect,
        // job 1 must plan around scenario worker 1.
        let crash_addr = {
            let server = WorkerServer::bind("127.0.0.1:0").expect("bind");
            let addr = server.local_addr().expect("addr").to_string();
            std::thread::spawn(move || {
                let _ = server.run(&WorkerConfig {
                    fault: Some(crate::health::FaultPlan::parse("crash:w1@0%").expect("plan")),
                    ..WorkerConfig::default()
                });
            });
            addr
        };
        let mut addrs = vec![crash_addr];
        addrs.extend(loopback_workers(3));
        let mut cfg = TcpServeConfig::new(policy());
        cfg.jobs = 2;
        cfg.cols = 24;
        cfg.time_scale = 1e-3;
        cfg.addrs = addrs;
        // Arm health without a coordinator-side fault plan: the crash
        // is the WORKER's, the coordinator only observes the lifecycle.
        cfg.health = HealthConfig::fast();
        cfg.health.armed = true;
        // Long breaker backoff so job 1's admission is safely inside
        // the exclusion window.
        cfg.health.breaker_backoff_ms = 60_000.0;
        cfg.health.breaker_backoff_cap_ms = 60_000.0;
        let out = run_tcp(&s, &cfg).expect("serve with crashing worker");
        assert_eq!(out.records.len(), 2);
        assert!(out.all_verified(), "{:?}", out.records);
        assert!(
            out.records[0].disconnects > 0,
            "job 0 must observe the crash: {:?}",
            out.records[0]
        );
        assert_eq!(
            out.records[1].excluded,
            vec![1],
            "job 1 must plan around the crashed worker: {:?}",
            out.records[1]
        );
        assert!(!out.records[1].redundancy_floor);
        // The merged timeline shows the observation.
        let kinds: Vec<&'static str> = out.health.iter().map(|e| e.kind_label()).collect();
        assert!(kinds.contains(&"disconnect"), "{kinds:?}");
    }
}
