//! Event cores for the online serving loop: a hierarchical timer wheel
//! and the binary-heap oracle it is pinned against.
//!
//! ## The event-core contract
//!
//! Both queues implement [`EventQueue`]: `push(at, payload)` stamps the
//! event with a monotonically increasing sequence number, and `pop`
//! returns the pending event that is minimal under the **total order**
//! `(f64::total_cmp(at), seq)`. The `seq` tie-break makes simultaneous
//! events (lockstep arrivals, flash-crowd bursts) drain in insertion
//! order, so the serving loop is deterministic and the two
//! implementations are *bit-for-bit interchangeable*: swapping one for
//! the other changes neither the pop order nor any downstream RNG
//! draw. `serve::run` uses the wheel; the heap stays in-tree as the
//! parity oracle (`tests/serving.rs` and the property test below pin
//! them against each other, duplicate timestamps included).
//!
//! ## Why a wheel
//!
//! The heap costs `O(log n)` per operation with `n` pending events; at
//! the ROADMAP scale (millions of jobs in virtual time) the pending set
//! is large but *near-sorted* — arrivals are known up front and
//! completions land a bounded horizon ahead of `now`. The wheel buckets
//! events by quantized time into `SLOTS`-slot levels of geometrically
//! coarser width (a hashed hierarchical timing wheel): insertion is
//! O(1) bucket placement, each event cascades down at most `LEVELS`
//! times as the cursor passes, and only single-tick level-0 buckets are
//! ever sorted. Tick granularity affects bucket occupancy only — never
//! order: ticks are monotone in time, and entries sharing a tick are
//! sorted by the exact `(total_cmp(at), seq)` key when their bucket is
//! drained.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Slots per wheel level: `64^4 ≈ 1.7e7` ticks of in-wheel range
/// before the overflow list engages.
const SLOTS: usize = 64;
/// Hierarchy depth. Events beyond `SLOTS^LEVELS` ticks sit in an
/// overflow list and are re-bucketed when the wheel drains down to
/// them.
const LEVELS: usize = 4;

/// A pending-event queue ordered by `(f64::total_cmp(time), insertion
/// seq)`. See the module docs for the exact contract.
pub trait EventQueue<T> {
    /// Schedule `payload` at virtual time `at`. Events pushed with `at`
    /// not after an already-popped time are still delivered — as the
    /// minimum of the *remaining* events, exactly like a heap.
    fn push(&mut self, at: f64, payload: T);
    /// Remove and return the minimal pending event `(time, payload)`.
    fn pop(&mut self) -> Option<(f64, T)>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One scheduled event. `seq` is assigned by the queue at push time.
#[derive(Clone, Debug)]
struct Entry<T> {
    at: f64,
    seq: u64,
    payload: T,
}

fn cmp_entries<T>(a: &Entry<T>, b: &Entry<T>) -> Ordering {
    a.at.total_cmp(&b.at).then(a.seq.cmp(&b.seq))
}

/// The parity oracle: `BinaryHeap<Reverse<_>>` under the contract
/// order. This is the event core `serve` shipped with (PR 5), kept as
/// the reference implementation for tests and benches.
pub struct HeapQueue<T> {
    heap: BinaryHeap<std::cmp::Reverse<HeapEv<T>>>,
    seq: u64,
}

struct HeapEv<T>(Entry<T>);

impl<T> PartialEq for HeapEv<T> {
    fn eq(&self, other: &Self) -> bool {
        self.0.at.to_bits() == other.0.at.to_bits() && self.0.seq == other.0.seq
    }
}
impl<T> Eq for HeapEv<T> {}
impl<T> PartialOrd for HeapEv<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for HeapEv<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        cmp_entries(&self.0, &other.0)
    }
}

impl<T> Default for HeapQueue<T> {
    fn default() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }
}

impl<T> HeapQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }
}

impl<T> EventQueue<T> for HeapQueue<T> {
    fn push(&mut self, at: f64, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap
            .push(std::cmp::Reverse(HeapEv(Entry { at, seq, payload })));
    }

    fn pop(&mut self) -> Option<(f64, T)> {
        self.heap
            .pop()
            .map(|std::cmp::Reverse(HeapEv(e))| (e.at, e.payload))
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

/// Hierarchical timer wheel, bit-for-bit order-equivalent to
/// [`HeapQueue`] (property-tested below).
///
/// Invariants:
/// - Every bucketed entry has tick index ≥ `cur`; `ready` (the drained
///   active bucket plus any late pushes) holds everything below.
/// - A level-0 slot only ever holds entries of a single tick index
///   (placement requires `idx − cur < SLOTS`, and slots are residues
///   mod `SLOTS`, so exactly one index per slot can be live).
/// - `flushed_below[l]` marks the tick boundary under which level `l`
///   holds no entries: a flushed slot may immediately re-receive its
///   own next-rotation entries, and this watermark keeps the candidate
///   scan from re-flushing it forever.
pub struct TimerWheel<T> {
    /// Level-0 slot width in virtual-time units.
    tick: f64,
    /// Virtual time of tick index 0 (fixed at construction).
    start: f64,
    /// All bucketed entries have tick index ≥ `cur`.
    cur: u64,
    /// `levels[l][s]`: slot `s` of level `l`, width `SLOTS^l` ticks,
    /// addressed by absolute tick index `(idx / SLOTS^l) % SLOTS`.
    levels: Vec<Vec<Vec<Entry<T>>>>,
    /// Entries beyond the top level's range; `overflow_min` caches
    /// their minimal tick index so the scan can rank them in O(1).
    overflow: Vec<Entry<T>>,
    overflow_min: u64,
    /// Per-level watermark: level `l` holds nothing below this tick.
    flushed_below: Vec<u64>,
    /// The active single-tick bucket, sorted DESCENDING by the
    /// contract order and drained from the back; late pushes (at or
    /// before the active tick) are sorted in, so the next pop is
    /// always the minimum of the remaining events.
    ready: Vec<Entry<T>>,
    len: usize,
    seq: u64,
}

impl<T> TimerWheel<T> {
    /// `tick` is the finest bucket width; it must be finite and
    /// positive. Correctness does not depend on it — see
    /// [`TimerWheel::for_span`] for the sizing heuristic.
    pub fn new(tick: f64) -> Self {
        assert!(tick.is_finite() && tick > 0.0, "wheel tick must be > 0");
        Self {
            tick,
            start: 0.0,
            cur: 0,
            levels: (0..LEVELS)
                .map(|_| (0..SLOTS).map(|_| Vec::new()).collect())
                .collect(),
            overflow: Vec::new(),
            overflow_min: u64::MAX,
            flushed_below: vec![0; LEVELS],
            ready: Vec::new(),
            len: 0,
            seq: 0,
        }
    }

    /// Size the tick so `events` spread across `span` land ~1 per
    /// level-0 slot: `tick = span / max(events, SLOTS)`. Degenerate
    /// spans fall back to a unit tick — the wheel stays correct, only
    /// bucket occupancy changes.
    pub fn for_span(span: f64, events: usize) -> Self {
        let span = if span.is_finite() && span > 0.0 { span } else { 1.0 };
        let tick = span / events.max(SLOTS) as f64;
        Self::new(tick.max(span * 1e-12).max(f64::MIN_POSITIVE * 1e6))
    }

    /// Absolute tick index of `at`, saturating on both ends. Monotone
    /// in `at`, which is all ordering needs: entries that share an
    /// index (including both saturation plateaus) are sorted by the
    /// exact `(at, seq)` key when their bucket is drained.
    fn tick_index(&self, at: f64) -> u64 {
        let idx = ((at - self.start) / self.tick).floor();
        if !(idx >= 0.0) {
            return 0; // the past (and any NaN-adjacent junk): tick 0
        }
        if idx >= (u64::MAX / 2) as f64 {
            return u64::MAX / 2;
        }
        idx as u64
    }

    /// Bucket an entry with tick index `idx ≥ self.cur`.
    fn place(&mut self, idx: u64, e: Entry<T>) {
        let delta = idx - self.cur;
        let mut width = 1u64;
        for l in 0..LEVELS {
            let range = width * SLOTS as u64;
            if delta < range {
                let slot = (idx / width) as usize % SLOTS;
                self.levels[l][slot].push(e);
                return;
            }
            width = range;
        }
        self.overflow_min = self.overflow_min.min(idx);
        self.overflow.push(e);
    }

    /// Sorted-insert into the active bucket (descending order, drained
    /// from the back): entries ordered before everything pending become
    /// the next pop — exactly the heap's "minimum of the remaining".
    fn insert_ready(&mut self, e: Entry<T>) {
        let pos = self
            .ready
            .partition_point(|x| cmp_entries(x, &e) == Ordering::Greater);
        self.ready.insert(pos, e);
    }

    /// Load the next pending bucket into `ready`. The scan ranks every
    /// non-empty slot by the earliest tick it can still hold
    /// (`max(slot start, cur)`, bumped a rotation if below the flush
    /// watermark) and takes the minimum — preferring *higher* levels on
    /// ties, because a wide slot covering the cursor may contain events
    /// that belong inside a lower candidate's tick and must cascade
    /// down first. Each iteration either emits a level-0 bucket or
    /// strictly advances a watermark/cursor, so this terminates.
    ///
    /// Precondition: `ready` is empty and `len > 0`.
    fn advance(&mut self) {
        loop {
            // (effective start, level, slot); level LEVELS = overflow.
            let mut best: Option<(u64, usize, usize)> = None;
            if !self.overflow.is_empty() {
                best = Some((self.overflow_min.max(self.cur), LEVELS, 0));
            }
            let mut width = (SLOTS as u64).pow(LEVELS as u32 - 1);
            for l in (0..LEVELS).rev() {
                let range = width.saturating_mul(SLOTS as u64);
                for s in 0..SLOTS {
                    if self.levels[l][s].is_empty() {
                        continue;
                    }
                    // Covering-or-next slot start for this residue.
                    let base = self.cur / range * range;
                    let mut cand = base + s as u64 * width;
                    if cand + width <= self.cur {
                        cand += range;
                    }
                    if cand < self.flushed_below[l] {
                        cand += range;
                    }
                    let eff = cand.max(self.cur);
                    // Strict `<` keeps the higher level on ties.
                    if best.map(|(b, _, _)| eff < b).unwrap_or(true) {
                        best = Some((eff, l, s));
                    }
                }
                width /= SLOTS as u64;
            }
            let Some((eff, l, s)) = best else {
                debug_assert!(self.len == 0, "len/bucket bookkeeping divergence");
                return;
            };
            self.cur = self.cur.max(eff);
            if l == 0 {
                // Single-tick bucket: sort descending, drain from back.
                let mut bucket = std::mem::take(&mut self.levels[0][s]);
                bucket.sort_by(|a, b| cmp_entries(b, a));
                debug_assert!(self.ready.is_empty());
                self.ready = bucket;
                self.cur += 1;
                return;
            }
            if l == LEVELS {
                // Re-base the wheel onto the overflow's earliest tick.
                let pending = std::mem::take(&mut self.overflow);
                self.overflow_min = u64::MAX;
                for e in pending {
                    let idx = self.tick_index(e.at).max(self.cur);
                    self.place(idx, e);
                }
                continue;
            }
            // Cascade a wide slot downward from its effective start.
            let width = (SLOTS as u64).pow(l as u32);
            self.flushed_below[l] = self.flushed_below[l].max(eff + width);
            let bucket = std::mem::take(&mut self.levels[l][s]);
            for e in bucket {
                let idx = self.tick_index(e.at).max(self.cur);
                self.place(idx, e);
            }
        }
    }
}

impl<T> EventQueue<T> for TimerWheel<T> {
    fn push(&mut self, at: f64, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        let e = Entry { at, seq, payload };
        self.len += 1;
        let idx = self.tick_index(at);
        if idx < self.cur {
            // At or before the active tick: joins the ready bucket in
            // contract order.
            self.insert_ready(e);
        } else {
            self.place(idx, e);
        }
    }

    fn pop(&mut self) -> Option<(f64, T)> {
        if self.len == 0 {
            return None;
        }
        if self.ready.is_empty() {
            self.advance();
        }
        let e = self.ready.pop()?;
        self.len -= 1;
        Some((e.at, e.payload))
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain<T>(q: &mut dyn EventQueue<T>) -> Vec<(f64, T)> {
        let mut out = Vec::new();
        while let Some(ev) = q.pop() {
            out.push(ev);
        }
        out
    }

    #[test]
    fn wheel_pops_in_time_then_seq_order() {
        let mut w = TimerWheel::new(1.0);
        w.push(5.0, 'a');
        w.push(1.0, 'b');
        w.push(5.0, 'c'); // duplicate timestamp: insertion order
        w.push(0.0, 'd');
        w.push(1_000_000.0, 'e'); // above level-2 range at tick = 1
        let got = drain(&mut w);
        let order: Vec<char> = got.iter().map(|&(_, c)| c).collect();
        assert_eq!(order, vec!['d', 'b', 'a', 'c', 'e']);
        assert!(w.pop().is_none());
        assert!(w.is_empty());
    }

    #[test]
    fn wheel_handles_pushes_during_drain_like_a_heap() {
        let mut w = TimerWheel::new(0.5);
        let mut h = HeapQueue::new();
        for q in [&mut w as &mut dyn EventQueue<u32>, &mut h] {
            q.push(10.0, 0);
            q.push(10.0, 1);
            q.push(20.0, 2);
        }
        assert_eq!(w.pop(), h.pop());
        // Schedule into the active tick and into the past mid-drain:
        // both must come out next, in contract order.
        for q in [&mut w as &mut dyn EventQueue<u32>, &mut h] {
            q.push(10.0, 3);
            q.push(2.0, 4);
        }
        assert_eq!(drain(&mut w), drain(&mut h));
    }

    #[test]
    fn wheel_spans_every_level_and_rebases_overflow() {
        // tick = 1.0 → level ranges 64 / 4096 / 262144 / 16.7M; beyond
        // that is the overflow list. Cover every placement path,
        // including interleaved near/far pushes while draining.
        let times = [
            0.0,
            63.0,
            64.0,
            4_095.0,
            4_096.0,
            262_143.0,
            262_144.0,
            16_777_215.0,
            16_777_216.0, // overflow
            90_000_000.0, // deep overflow
        ];
        let mut w = TimerWheel::new(1.0);
        let mut h = HeapQueue::new();
        // Push in reverse so placement never benefits from sortedness.
        for (i, &t) in times.iter().enumerate().rev() {
            w.push(t, i);
            h.push(t, i);
        }
        assert_eq!(w.len(), times.len());
        for step in 0..times.len() {
            assert_eq!(w.pop(), h.pop(), "divergence at pop {step}");
            // Near/far pushes against a moving cursor.
            let t = 100.0 + step as f64 * 5_000.0;
            w.push(t, 100 + step);
            h.push(t, 100 + step);
        }
        assert_eq!(drain(&mut w), drain(&mut h));
    }

    #[test]
    fn wheel_matches_heap_on_random_schedules_with_duplicates() {
        use crate::util::prop::{check, Config};
        check(
            Config::default().cases(40),
            "TimerWheel ≡ HeapQueue pop order (duplicate timestamps, interleaved ops)",
            |g| {
                let n = g.usize_range(1, 400);
                // Quantized times force exact duplicate timestamps;
                // scales vary from sub-tick-dense to deep-overflow.
                let scale = [0.01, 1.0, 1e4, 1e9][g.usize_range(0, 3)];
                let tick = [1e-3, 1.0, 977.0][g.usize_range(0, 2)];
                let mut w = TimerWheel::new(tick);
                let mut h = HeapQueue::new();
                let mut live = 0usize;
                for i in 0..n {
                    if live > 0 && g.bool() {
                        assert_eq!(w.pop(), h.pop(), "mid-drain divergence at op {i}");
                        live -= 1;
                    } else {
                        let t = g.usize_range(0, 200) as f64 * 0.5 * scale;
                        w.push(t, i);
                        h.push(t, i);
                        live += 1;
                    }
                    assert_eq!(w.len(), h.len());
                }
                assert_eq!(drain(&mut w), drain(&mut h), "final drain divergence");
            },
        );
    }
}
