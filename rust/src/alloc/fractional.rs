//! Theorem 3 (§IV-A): fractional-assignment optimality condition and the
//! `V_m` sum-value machinery of P7.
//!
//! Given any resource shares `(k, b)`, the optimal loads satisfy
//! `l*_{m,n} = t*/(2θ_{m,n})`, which reduces constraint (25b) to
//! `L_m ≤ Σ_n t/(4θ_{m,n})` — so `1/t*_m = V_m ≜ (1/L_m)·Σ_n 1/(4θ_{m,n})`
//! and P6 becomes the max-min allocation P7 over `(k, b)` only.
//!
//! The actual loads therefore coincide with Theorem 1 evaluated at the
//! fractional θ's ([`crate::alloc::markov::allocate`]); this module adds
//! the `V_m` helpers and the Theorem-3 identity used by Algorithm 4.

use super::markov;
use super::Allocation;
use crate::model::params::{theta_fractional, LinkParams};

/// θ row of one master: local node followed by all workers, under shares
/// `k[m][n]`, `b[m][n]` (worker-indexed, `n ∈ 0..N`).
pub fn theta_row(
    local: &LinkParams,
    links: &[LinkParams],
    k_row: &[f64],
    b_row: &[f64],
) -> Vec<f64> {
    assert_eq!(links.len(), k_row.len());
    assert_eq!(links.len(), b_row.len());
    let mut thetas = Vec::with_capacity(links.len() + 1);
    thetas.push(local.theta()); // k_{m,0} = b_{m,0} = 1
    for ((p, &k), &b) in links.iter().zip(k_row).zip(b_row) {
        thetas.push(theta_fractional(p, k, b));
    }
    thetas
}

/// Sum value `V_m = (1/L_m)·Σ_{n=0}^{N} 1/(4θ_{m,n})` (eq. 28a). Nodes
/// with zero share contribute zero (θ = ∞).
pub fn sum_value(thetas: &[f64], l_rows: f64) -> f64 {
    thetas.iter().map(|&t| markov::node_value(t, l_rows)).sum()
}

/// Theorem-3 loads for the given θ row: `l_n = t*/(2θ_n)` with
/// `t* = 1/V_m`. Identical to Theorem 1's closed form — asserted in tests.
pub fn allocate(thetas: &[f64], l_rows: f64) -> Allocation {
    markov::allocate(thetas, l_rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> (LinkParams, Vec<LinkParams>) {
        (
            LinkParams::local(0.4, 2.5),
            vec![
                LinkParams::new(10.0, 0.2, 5.0),
                LinkParams::new(8.0, 0.25, 4.0),
                LinkParams::new(6.0, 0.3, 3.33),
            ],
        )
    }

    #[test]
    fn theorem3_identity_l_eq_t_over_2theta() {
        let (local, links) = params();
        let k = [0.5, 1.0, 0.25];
        let b = [0.5, 0.75, 0.25];
        let thetas = theta_row(&local, &links, &k, &b);
        let alloc = allocate(&thetas, 1e4);
        for (&th, &l) in thetas.iter().zip(&alloc.loads) {
            assert!(
                (l - alloc.t_star / (2.0 * th)).abs() < 1e-6,
                "l={l} vs t/(2θ)={}",
                alloc.t_star / (2.0 * th)
            );
        }
    }

    #[test]
    fn t_star_is_inverse_sum_value() {
        let (local, links) = params();
        let k = [1.0, 0.5, 0.5];
        let b = [1.0, 0.5, 0.5];
        let thetas = theta_row(&local, &links, &k, &b);
        let l_rows = 1e4;
        let v = sum_value(&thetas, l_rows);
        let alloc = allocate(&thetas, l_rows);
        assert!((alloc.t_star * v - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_share_workers_excluded() {
        let (local, links) = params();
        let k = [1.0, 0.0, 1.0];
        let b = [1.0, 0.0, 1.0];
        let thetas = theta_row(&local, &links, &k, &b);
        assert!(thetas[2].is_infinite());
        let alloc = allocate(&thetas, 100.0);
        assert_eq!(alloc.loads[2], 0.0);
    }

    #[test]
    fn more_resources_lower_delay() {
        let (local, links) = params();
        let t_half = allocate(
            &theta_row(&local, &links, &[0.5; 3], &[0.5; 3]),
            1e4,
        )
        .t_star;
        let t_full = allocate(
            &theta_row(&local, &links, &[1.0; 3], &[1.0; 3]),
            1e4,
        )
        .t_star;
        assert!(t_full < t_half);
    }

    #[test]
    fn dedicated_equals_fractional_with_unit_shares() {
        let (local, links) = params();
        let thetas_frac = theta_row(&local, &links, &[1.0; 3], &[1.0; 3]);
        let mut thetas_dedi = vec![local.theta()];
        thetas_dedi.extend(links.iter().map(|p| p.theta()));
        for (a, b) in thetas_frac.iter().zip(&thetas_dedi) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
