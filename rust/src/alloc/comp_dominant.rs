//! Theorem 2: exact optimal load allocation when computation delay
//! dominates (§III-B).
//!
//! With `T_n = ShiftedExp(a_n·l_n, u_n/l_n)` the original problem P3 is
//! convex; the KKT system yields
//!
//! ```text
//! φ_n  = (−W₋₁(−e^{−u_n·a_n − 1}) − 1)/u_n          (per-row time budget)
//! l_n* = L / (φ_n · Σ_j u_j/(1 + u_j·φ_j))
//! t*   = L / Σ_j u_j/(1 + u_j·φ_j)
//! ```
//!
//! The same closed form serves the **communication-dominant** case by
//! substituting `u ← γ`, `a ← 0⁺` (§III-B末); see [`comm_dominant_phi`].

use super::Allocation;
use crate::util::lambert::phi;

/// Per-node shifted-exponential parameters `(a, u)` after resource
/// scaling (`a/k`, `k·u` under fractional shares).
#[derive(Clone, Copy, Debug)]
pub struct CompParams {
    pub a: f64,
    pub u: f64,
}

/// Theorem-2 allocation.
pub fn allocate(nodes: &[CompParams], l_rows: f64) -> Allocation {
    assert!(!nodes.is_empty() && l_rows > 0.0);
    let phis: Vec<f64> = nodes.iter().map(|p| phi(p.a, p.u)).collect();
    let denom: f64 = nodes
        .iter()
        .zip(&phis)
        .map(|(p, &f)| p.u / (1.0 + p.u * f))
        .sum();
    let t_star = l_rows / denom;
    let loads = phis.iter().map(|&f| t_star / f).collect();
    Allocation { loads, t_star }
}

/// Node value for worker assignment in the computation-dominant case
/// (§III-C): `v = u / (L·(1 + u·φ))`, so `1/t* = Σ v` again.
pub fn node_value(p: CompParams, l_rows: f64) -> f64 {
    let f = phi(p.a, p.u);
    p.u / (l_rows * (1.0 + p.u * f))
}

/// Communication-dominant limit: exponential delay without shift. The
/// Lambert form needs `a > 0`, but the limit `a → 0⁺` exists:
/// `φ(0, γ) = (−W₋₁(−e⁻¹)·…)`… numerically we evaluate at a tiny shift.
pub fn comm_dominant_phi(gamma: f64) -> f64 {
    phi(1e-9, gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{expected_results, EffLink};

    fn exact_progress(nodes: &[CompParams], loads: &[f64], t: f64) -> f64 {
        // E[X(t)] with the pure shifted-exponential CDF (eq. 14).
        let links: Vec<EffLink> = nodes
            .iter()
            .map(|p| EffLink {
                comm: None,
                comp: p.u,
                shift: p.a,
            })
            .collect();
        expected_results(&links, loads, t)
    }

    #[test]
    fn constraint_tight_at_optimum() {
        // (35b): at (l*, t*) the expectation constraint is active.
        let nodes = [
            CompParams { a: 0.2, u: 5.0 },
            CompParams { a: 0.25, u: 4.0 },
            CompParams { a: 0.3, u: 10.0 / 3.0 },
            CompParams { a: 0.4, u: 2.5 },
        ];
        let l_rows = 1e4;
        let alloc = allocate(&nodes, l_rows);
        let progress = exact_progress(&nodes, &alloc.loads, alloc.t_star);
        assert!(
            (progress - l_rows).abs() / l_rows < 1e-9,
            "E[X(t*)] = {progress}"
        );
    }

    #[test]
    fn stationarity_t_over_l_equals_phi() {
        // (36): t*/l_n* = φ_n for every node.
        let nodes = [
            CompParams { a: 0.2, u: 5.0 },
            CompParams { a: 0.5, u: 2.0 },
        ];
        let alloc = allocate(&nodes, 100.0);
        for (p, &l) in nodes.iter().zip(&alloc.loads) {
            let ratio = alloc.t_star / l;
            assert!((ratio - phi(p.a, p.u)).abs() < 1e-9);
        }
    }

    #[test]
    fn t_exceeds_all_shifts() {
        // §III-B observation: t* > max a_n·l_n* — every node can finish.
        let nodes = [
            CompParams { a: 1.36, u: 4.976 }, // t2.micro
            CompParams { a: 0.97, u: 19.29 }, // c5.large
        ];
        let alloc = allocate(&nodes, 1e4);
        for (p, &l) in nodes.iter().zip(&alloc.loads) {
            assert!(alloc.t_star > p.a * l, "t*={} ≤ a·l={}", alloc.t_star, p.a * l);
        }
    }

    #[test]
    fn optimality_vs_perturbations() {
        // No feasibility-preserving reallocation of load should beat t*:
        // perturb loads, recompute the exact t needed, must be ≥ t*.
        use crate::alloc::exact_t_for_loads;
        let nodes = [
            CompParams { a: 0.2, u: 5.0 },
            CompParams { a: 0.25, u: 4.0 },
            CompParams { a: 0.3, u: 10.0 / 3.0 },
        ];
        let links: Vec<EffLink> = nodes
            .iter()
            .map(|p| EffLink {
                comm: None,
                comp: p.u,
                shift: p.a,
            })
            .collect();
        let l_rows = 1000.0;
        let alloc = allocate(&nodes, l_rows);
        let deltas = [
            vec![1.05, 1.0, 0.95],
            vec![0.9, 1.1, 1.0],
            vec![1.2, 0.9, 0.95],
        ];
        for d in &deltas {
            let loads: Vec<f64> = alloc
                .loads
                .iter()
                .zip(d)
                .map(|(&l, &f)| l * f)
                .collect();
            let t = exact_t_for_loads(&links, &loads, l_rows);
            assert!(
                t >= alloc.t_star - 1e-6,
                "perturbed allocation beat the optimum: {t} < {}",
                alloc.t_star
            );
        }
    }

    #[test]
    fn faster_node_gets_more_load() {
        let nodes = [
            CompParams { a: 0.2, u: 5.0 },  // fast
            CompParams { a: 0.4, u: 2.5 },  // slow
        ];
        let alloc = allocate(&nodes, 100.0);
        assert!(alloc.loads[0] > alloc.loads[1]);
    }

    #[test]
    fn redundancy_below_markov() {
        // Theorem 2's exact solution needs less redundancy than the
        // 2× of the Markov allocation.
        let nodes = [
            CompParams { a: 0.2, u: 5.0 },
            CompParams { a: 0.25, u: 4.0 },
        ];
        let alloc = allocate(&nodes, 1e4);
        let overhead = alloc.total_load() / 1e4;
        assert!(overhead > 1.0 && overhead < 2.0, "overhead={overhead}");
    }

    #[test]
    fn node_value_sums_to_inverse_t() {
        let nodes = [
            CompParams { a: 0.2, u: 5.0 },
            CompParams { a: 0.5, u: 2.0 },
            CompParams { a: 0.3, u: 3.0 },
        ];
        let l = 777.0;
        let alloc = allocate(&nodes, l);
        let vsum: f64 = nodes.iter().map(|&p| node_value(p, l)).sum();
        assert!((1.0 / alloc.t_star - vsum).abs() < 1e-12);
    }

    #[test]
    fn comm_dominant_phi_finite() {
        let f = comm_dominant_phi(2.0);
        assert!(f.is_finite() && f > 0.0);
    }
}
