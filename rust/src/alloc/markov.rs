//! Theorem 1: closed-form load allocation for the Markov-approximation
//! problem P4.
//!
//! Given serving nodes with expected unit delays `θ_n`:
//!
//! ```text
//! l_n* = L / (θ_n · Σ_j 1/(2θ_j)),    t* = L / Σ_j 1/(4θ_j)
//! ```
//!
//! Distribution-free (Remark 1): only the mean delay per unit load enters.
//! The allocation doubles the minimum load (Σ l_n* = 2L), i.e. the Markov
//! bound buys robustness with 2× coding redundancy.

use super::Allocation;

/// Theorem-1 allocation from expected unit delays. Nodes with `θ = ∞`
/// (zero resource share) receive zero load.
pub fn allocate(thetas: &[f64], l_rows: f64) -> Allocation {
    assert!(!thetas.is_empty(), "need at least one serving node");
    assert!(l_rows > 0.0);
    assert!(
        thetas.iter().all(|&t| t > 0.0),
        "unit delays must be positive"
    );
    let denom: f64 = thetas
        .iter()
        .filter(|t| t.is_finite())
        .map(|&t| 1.0 / (2.0 * t))
        .sum();
    assert!(denom > 0.0, "no node with finite θ");
    let loads = thetas
        .iter()
        .map(|&t| if t.is_finite() { l_rows / (t * denom) } else { 0.0 })
        .collect();
    let t_star = l_rows / (denom / 2.0); // Σ 1/(4θ) = denom/2
    Allocation { loads, t_star }
}

/// Per-node value `v_{m,n} = 1/(4·L_m·θ_{m,n})` — the worker-assignment
/// currency of P5/P7 (`1/t_m* = Σ v_{m,n}` over serving nodes).
pub fn node_value(theta: f64, l_rows: f64) -> f64 {
    if theta.is_finite() {
        1.0 / (4.0 * l_rows * theta)
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{expected_results, EffLink};
    use crate::model::params::LinkParams;

    #[test]
    fn closed_form_matches_formula() {
        let thetas = [1.0, 2.0, 4.0];
        let l = 100.0;
        let alloc = allocate(&thetas, l);
        let denom: f64 = thetas.iter().map(|t| 1.0 / (2.0 * t)).sum();
        for (i, &th) in thetas.iter().enumerate() {
            assert!((alloc.loads[i] - l / (th * denom)).abs() < 1e-9);
        }
        assert!((alloc.t_star - l / (denom / 2.0)).abs() < 1e-9);
    }

    #[test]
    fn total_load_is_twice_l() {
        // Σ l_n = Σ L/(θ_n Σ 1/(2θ)) = L·(Σ 1/θ)/(Σ 1/(2θ)) = 2L.
        let alloc = allocate(&[0.3, 0.9, 1.7, 5.0], 1e4);
        assert!((alloc.total_load() - 2e4).abs() < 1e-6);
    }

    #[test]
    fn loads_inverse_to_theta() {
        let alloc = allocate(&[1.0, 2.0], 10.0);
        assert!((alloc.loads[0] / alloc.loads[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn markov_constraint_tight_at_optimum() {
        // At (l*, t*): Σ l(1 − θl/t) = L exactly (KKT complementary
        // slackness of P4).
        let thetas = [0.7, 1.3, 2.9];
        let l_rows = 500.0;
        let alloc = allocate(&thetas, l_rows);
        let lhs: f64 = thetas
            .iter()
            .zip(&alloc.loads)
            .map(|(&th, &l)| l * (1.0 - th * l / alloc.t_star))
            .sum();
        assert!((lhs - l_rows).abs() < 1e-6, "lhs={lhs}");
    }

    #[test]
    fn allocation_feasible_under_exact_model() {
        // The Markov bound is conservative: under the true CDF the
        // expected progress at t* must be ≥ L.
        let params = [
            LinkParams::new(10.0, 0.2, 5.0),
            LinkParams::new(8.0, 0.25, 4.0),
            LinkParams::new(6.7, 0.3, 3.33),
        ];
        let links: Vec<EffLink> = params.iter().map(EffLink::dedicated).collect();
        let thetas: Vec<f64> = links.iter().map(EffLink::theta).collect();
        let l_rows = 1e4;
        let alloc = allocate(&thetas, l_rows);
        let progress = expected_results(&links, &alloc.loads, alloc.t_star);
        assert!(
            progress >= l_rows,
            "E[X(t*)] = {progress} < L = {l_rows}"
        );
    }

    #[test]
    fn infinite_theta_gets_zero_load() {
        let alloc = allocate(&[1.0, f64::INFINITY, 2.0], 10.0);
        assert_eq!(alloc.loads[1], 0.0);
        assert!(alloc.loads[0] > 0.0 && alloc.loads[2] > 0.0);
    }

    #[test]
    fn node_value_definition() {
        assert!((node_value(2.0, 10.0) - 1.0 / 80.0).abs() < 1e-12);
        assert_eq!(node_value(f64::INFINITY, 10.0), 0.0);
    }

    #[test]
    fn t_star_is_reciprocal_value_sum() {
        // 1/t* = Σ v_n with v_n = 1/(4 L θ_n) — eq. (17).
        let thetas = [0.5, 1.5, 3.5];
        let l = 200.0;
        let alloc = allocate(&thetas, l);
        let vsum: f64 = thetas.iter().map(|&t| node_value(t, l)).sum();
        assert!((1.0 / alloc.t_star - vsum).abs() < 1e-12);
    }
}
