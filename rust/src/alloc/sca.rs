//! Algorithm 3: SCA-enhanced load allocation (§III-D).
//!
//! The original constraint (8b) `E[X_m(t)] ≥ L_m` is non-convex, but
//! `L − E[X]` decomposes into a difference of convex functions (eq. 20):
//! with `ψ(l, t; r, a) ≜ l·e^{−(r/l)(t−a·l)}` (convex — Appendix B),
//!
//! ```text
//! l·(1 − P[T ≤ t]) = c⁺·ψ(l,t; r_lo, a) − c⁻·ψ(l,t; r_hi, a)
//!   r_lo = min(γ_eff, u_eff), r_hi = max(γ_eff, u_eff)
//!   c⁺ = r_hi/(r_hi − r_lo),   c⁻ = r_lo/(r_hi − r_lo)
//! ```
//!
//! (local / computation-dominant nodes: `c⁺ = 1, c⁻ = 0` with `r = u`).
//! Linearizing the concave part at the current point `z` gives the convex
//! subproblem P(z) (eq. 22), which we solve **exactly**: for fixed `t` the
//! inner minimization over each `l_n` has a closed form via the same
//! Lambert `W₋₁` as Theorem 2, and the partial minimum `g(t)` is convex in
//! `t`, so the smallest feasible `t` falls to bisection. The outer loop is
//! the diminishing-step SCA of Scutari et al. [32] with
//! `γ_{r+1} = γ_r(1 − α·γ_r)` (paper: α = 0.995).

use super::{Allocation, EffLink};
use crate::util::lambert::lambert_wm1;

/// Outer-loop step rule.
///
/// Because each subproblem P(z) tightens the true constraint (eq. 21 is
/// an upper bound, tangent at z), its solution `w` is itself feasible for
/// P3 with `t(w) ≤ t(z)` — so the full step `z ← w` (the classic
/// convex–concave procedure / DCA) descends monotonically and converges
/// in a handful of iterations. The paper's diminishing rule
/// `γ_{r+1} = γ_r(1 − α·γ_r)` [32] is kept as an option; both reach the
/// same stationary point (asserted in tests), DCA ~50× faster (§Perf).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepRule {
    /// Full step `z ← w` (default).
    Dca,
    /// The paper's diminishing step with ratio α.
    Diminishing,
}

/// SCA hyper-parameters (α follows §V: 0.995).
#[derive(Clone, Copy, Debug)]
pub struct ScaOptions {
    pub max_iters: usize,
    pub alpha: f64,
    pub step_rule: StepRule,
    /// Relative convergence tolerance on `‖w − z‖`.
    pub tol: f64,
    /// Per-node load cap as a multiple of `L` (bounds the subproblem).
    pub load_cap_factor: f64,
}

impl Default for ScaOptions {
    fn default() -> Self {
        Self {
            max_iters: 200,
            alpha: 0.995,
            step_rule: StepRule::Dca,
            tol: 1e-9,
            load_cap_factor: 2.5,
        }
    }
}

/// DC decomposition of one node's term.
#[derive(Clone, Copy, Debug)]
struct Decomp {
    /// Rate of the convex ψ term.
    r_lo: f64,
    /// Rate of the concave ψ term (`None` for single-exponential nodes).
    r_hi: Option<f64>,
    c_plus: f64,
    c_minus: f64,
    shift: f64,
}

impl Decomp {
    fn new(link: &EffLink) -> Self {
        match link.comm {
            None => Self {
                r_lo: link.comp,
                r_hi: None,
                c_plus: 1.0,
                c_minus: 0.0,
                shift: link.shift,
            },
            Some(g) => {
                // Near-equal rates (eq. 4's Erlang-2 limit) make the
                // two-exponential decomposition ill-conditioned: with
                // hi − lo = ε·r the mixture weights blow up as
                // c± ≈ r/(hi − lo), and c⁺ψ − c⁻ψ (plus the linearized
                // subproblem constants, which multiply ∇ψ by c⁻)
                // cancels catastrophically. The old code perturbed one
                // rate by 1e-6, yielding c± ≈ 1e6 AND a first-order
                // O(ε) model error. Instead split SYMMETRICALLY around
                // the mean rate, r(1 ± δ): the odd error terms cancel,
                // so the mixture reproduces the Erlang-2 survival to
                // O(δ²) while the weights stay at c± ≈ 1/(2δ) ≈ 5e3 —
                // both the conditioning and the accuracy improve.
                const EQUAL_RATE_DELTA: f64 = 1e-4;
                let rel = (g - link.comp).abs() / g.max(link.comp);
                let (lo, hi) = if rel < 2.0 * EQUAL_RATE_DELTA {
                    let r = 0.5 * (g + link.comp);
                    (r * (1.0 - EQUAL_RATE_DELTA), r * (1.0 + EQUAL_RATE_DELTA))
                } else if g < link.comp {
                    (g, link.comp)
                } else {
                    (link.comp, g)
                };
                Self {
                    r_lo: lo,
                    r_hi: Some(hi),
                    c_plus: hi / (hi - lo),
                    c_minus: lo / (hi - lo),
                    shift: link.shift,
                }
            }
        }
    }
}

/// `ψ(l, t; r, a) = l·exp(r·a − r·t/l)`, extended by 0 at `l = 0`.
#[inline]
fn psi(l: f64, t: f64, r: f64, a: f64) -> f64 {
    if l <= 0.0 {
        return 0.0;
    }
    l * (r * a - r * t / l).exp()
}

/// `(∂ψ/∂l, ∂ψ/∂t)`.
#[inline]
fn psi_grad(l: f64, t: f64, r: f64, a: f64) -> (f64, f64) {
    if l <= 0.0 {
        return (0.0, 0.0);
    }
    let e = (r * a - r * t / l).exp();
    (e * (1.0 + r * t / l), -r * e)
}

/// Exact minimizer of `q(l) = c⁺·ψ(l, t; r, a) − s·l` over `l ∈ [0, cap]`.
///
/// Stationarity `c⁺·e^{ra}·(1+y)e^{−y} = s` with `y = r·t/l` solves to
/// `y = −W₋₁(−c/e) − 1`, `c = s/(c⁺·e^{ra})` — the same Lambert mechanics
/// as Theorem 2.
fn inner_argmin(t: f64, c_plus: f64, r: f64, a: f64, s: f64, cap: f64) -> f64 {
    debug_assert!(s > 0.0 && c_plus > 0.0 && r > 0.0 && t > 0.0);
    let c = s / (c_plus * (r * a).exp());
    if c >= 1.0 {
        // q is decreasing on all of [0, cap].
        return cap;
    }
    let arg = -c / std::f64::consts::E;
    let y = match lambert_wm1(arg) {
        Some(w) => -w - 1.0,
        None => return cap, // numerically at the branch point: y → 0
    };
    if y <= 0.0 {
        return cap;
    }
    (r * t / y).min(cap)
}

/// One SCA state: loads + t.
#[derive(Clone, Debug)]
struct Point {
    loads: Vec<f64>,
    t: f64,
}

/// Solve the convex subproblem P(z) exactly. Returns the minimizing point
/// `w` with its active-constraint loads.
fn solve_subproblem(
    decomps: &[Decomp],
    l_rows: f64,
    z: &Point,
    cap: f64,
) -> Point {
    let n = decomps.len();
    // Linearization of the concave parts at z.
    // term_n(w) = c⁺ψ(l,t;r_lo) − c⁻[ψ(z) + ∇ψ(z)·(w − z)] − l
    // Collect per-node: s_n (coefficient of l in the linear part, moved so
    // the inner problem is c⁺ψ − s·l), and the t-linear + constant parts.
    let mut s = vec![0.0; n];
    let mut lin_t = 0.0; // Σ coefficient of t
    let mut constant = l_rows;
    for (i, d) in decomps.iter().enumerate() {
        match d.r_hi {
            None => {
                s[i] = 1.0;
            }
            Some(rh) => {
                let (dl, dt) = psi_grad(z.loads[i], z.t, rh, d.shift);
                let p = psi(z.loads[i], z.t, rh, d.shift);
                s[i] = 1.0 + d.c_minus * dl;
                lin_t += -d.c_minus * dt;
                constant += d.c_minus * (-p + dl * z.loads[i] + dt * z.t);
            }
        }
    }

    // g(t) = constant + lin_t·t + Σ_n min_l [c⁺ψ(l,t;r_lo,a) − s_n·l]
    let g = |t: f64, loads_out: Option<&mut Vec<f64>>| -> f64 {
        let mut total = constant + lin_t * t;
        let mut loads = loads_out;
        for (i, d) in decomps.iter().enumerate() {
            let l = inner_argmin(t, d.c_plus, d.r_lo, d.shift, s[i], cap);
            total += d.c_plus * psi(l, t, d.r_lo, d.shift) - s[i] * l;
            if let Some(v) = loads.as_deref_mut() {
                v[i] = l;
            }
        }
        total
    };

    // z is feasible for P(z) (F(z) = L − E[X](z) ≤ 0 at a feasible z),
    // so bisect the left edge of {t : g(t) ≤ 0} on [0, z.t].
    debug_assert!(g(z.t, None) <= 1e-6 * l_rows, "z must be P(z)-feasible");
    let (mut lo, mut hi) = (0.0, z.t);
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if g(mid, None) <= 0.0 {
            hi = mid;
        } else {
            lo = mid;
        }
        if hi - lo <= 1e-14 * hi.max(1.0) {
            break;
        }
    }
    let mut loads = vec![0.0; n];
    g(hi, Some(&mut loads));
    Point { loads, t: hi }
}

/// Run Algorithm 3 from a feasible starting allocation (Theorem 1's
/// closed form is the canonical `z₀`).
pub fn enhance(
    links: &[EffLink],
    l_rows: f64,
    start: &Allocation,
    opts: &ScaOptions,
) -> Allocation {
    enhance_traced(links, l_rows, start, opts).0
}

/// [`enhance`] plus the number of subproblem solves performed — the cost
/// metric warm-started re-planning (the serving layer seeds SCA with the
/// previous epoch's allocation) is trying to minimize.
pub fn enhance_traced(
    links: &[EffLink],
    l_rows: f64,
    start: &Allocation,
    opts: &ScaOptions,
) -> (Allocation, usize) {
    assert_eq!(links.len(), start.loads.len());
    // Filter zero-load nodes (zero-share in fractional plans): they stay
    // at zero load.
    let active: Vec<usize> = (0..links.len())
        .filter(|&i| start.loads[i] > 0.0 && links[i].theta().is_finite())
        .collect();
    if active.is_empty() {
        return (start.clone(), 0);
    }
    let decomps: Vec<Decomp> = active
        .iter()
        .map(|&i| Decomp::new(&links[i]))
        .collect();
    let cap = opts.load_cap_factor * l_rows;

    let mut z = Point {
        loads: active.iter().map(|&i| start.loads[i]).collect(),
        t: start.t_star,
    };
    let mut gamma = 1.0f64;
    let mut prev_w_t = f64::INFINITY;
    let mut iters = 0usize;
    for _ in 0..opts.max_iters {
        let w = solve_subproblem(&decomps, l_rows, &z, cap);
        iters += 1;
        // Fixed-point stop: once successive subproblem solutions agree,
        // the stationary point is reached — adopt w and stop.
        if (w.t - prev_w_t).abs() <= opts.tol * w.t.max(1e-300) {
            z = w;
            break;
        }
        prev_w_t = w.t;
        match opts.step_rule {
            StepRule::Dca => {
                // Full step: w is feasible for P3 (F upper-bounds the
                // true constraint) and t is non-increasing.
                z = w;
            }
            StepRule::Diminishing => {
                // Lines 4–5 of Algorithm 3.
                let mut delta = (w.t - z.t).abs() / z.t.max(1e-300);
                for (zl, wl) in z.loads.iter().zip(&w.loads) {
                    delta = delta.max((wl - zl).abs() / (1.0 + zl.abs()));
                }
                z.t += gamma * (w.t - z.t);
                for (zl, wl) in z.loads.iter_mut().zip(&w.loads) {
                    *zl += gamma * (*wl - *zl);
                }
                gamma *= 1.0 - opts.alpha * gamma;
                if delta < opts.tol {
                    break;
                }
            }
        }
    }

    // The averaged point may sit strictly inside the feasible region;
    // tighten t to the exact boundary for the final report.
    let sub_links: Vec<EffLink> = active.iter().map(|&i| links[i]).collect();
    let t_final = super::exact_t_for_loads(&sub_links, &z.loads, l_rows);

    let mut loads = vec![0.0; links.len()];
    for (slot, &i) in active.iter().enumerate() {
        loads[i] = z.loads[slot];
    }
    (
        Allocation {
            loads,
            t_star: t_final.min(z.t),
        },
        iters,
    )
}

/// Convenience: Theorem-1 start + SCA enhancement in one call.
pub fn allocate(links: &[EffLink], l_rows: f64, opts: &ScaOptions) -> Allocation {
    let thetas: Vec<f64> = links.iter().map(EffLink::theta).collect();
    let start = super::markov::allocate(&thetas, l_rows);
    enhance(links, l_rows, &start, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{comp_dominant, expected_results, markov};
    use crate::model::params::LinkParams;
    use crate::util::rng::Rng;

    fn random_links(rng: &mut Rng, n: usize, ratio: f64) -> Vec<EffLink> {
        (0..n)
            .map(|_| {
                let a = rng.range(0.05, 0.5);
                let u = 1.0 / a;
                EffLink::dedicated(&LinkParams::new(ratio * u, a, u))
            })
            .collect()
    }

    #[test]
    fn psi_gradient_matches_finite_difference() {
        let (l, t, r, a) = (7.0, 11.0, 0.8, 0.3);
        let (dl, dt) = psi_grad(l, t, r, a);
        let h = 1e-6;
        let ndl = (psi(l + h, t, r, a) - psi(l - h, t, r, a)) / (2.0 * h);
        let ndt = (psi(l, t + h, r, a) - psi(l, t - h, r, a)) / (2.0 * h);
        assert!((dl - ndl).abs() < 1e-6, "{dl} vs {ndl}");
        assert!((dt - ndt).abs() < 1e-6, "{dt} vs {ndt}");
    }

    #[test]
    fn inner_argmin_is_stationary() {
        // The closed-form minimizer must zero the derivative of
        // q(l) = c⁺ψ − s·l (when interior).
        let (t, c_plus, r, a, s, cap) = (10.0, 2.0, 0.5, 0.2, 1.3, 1e6);
        let l = inner_argmin(t, c_plus, r, a, s, cap);
        assert!(l > 0.0 && l < cap);
        let h = 1e-5 * l;
        let q = |l: f64| c_plus * psi(l, t, r, a) - s * l;
        let d = (q(l + h) - q(l - h)) / (2.0 * h);
        assert!(d.abs() < 1e-6, "dq/dl = {d}");
        // And it is a minimum:
        assert!(q(l) <= q(l * 0.9) && q(l) <= q(l * 1.1));
    }

    #[test]
    fn sca_improves_on_markov_start() {
        let mut rng = Rng::new(10);
        let links = random_links(&mut rng, 6, 2.0);
        let thetas: Vec<f64> = links.iter().map(EffLink::theta).collect();
        let l_rows = 1e4;
        let start = markov::allocate(&thetas, l_rows);
        let enhanced = enhance(&links, l_rows, &start, &ScaOptions::default());
        assert!(
            enhanced.t_star <= start.t_star * (1.0 + 1e-9),
            "SCA worsened: {} > {}",
            enhanced.t_star,
            start.t_star
        );
        // The paper reports ~9–17% gains; expect at least a few percent.
        assert!(
            enhanced.t_star < start.t_star * 0.99,
            "SCA gained <1%: {} vs {}",
            enhanced.t_star,
            start.t_star
        );
    }

    #[test]
    fn sca_solution_feasible_under_exact_model() {
        let mut rng = Rng::new(11);
        for trial in 0..5 {
            let links = random_links(&mut rng, 4 + trial, 2.0);
            let l_rows = 1e4;
            let alloc = allocate(&links, l_rows, &ScaOptions::default());
            let progress = expected_results(&links, &alloc.loads, alloc.t_star);
            assert!(
                progress >= l_rows * (1.0 - 1e-6),
                "trial {trial}: E[X] = {progress} < {l_rows}"
            );
        }
    }

    #[test]
    fn sca_matches_theorem2_in_comp_dominant_case() {
        // With no comm leg, P3 is convex and Theorem 2 is the global
        // optimum — SCA must land on it.
        let nodes = [
            comp_dominant::CompParams { a: 0.2, u: 5.0 },
            comp_dominant::CompParams { a: 0.25, u: 4.0 },
            comp_dominant::CompParams { a: 0.4, u: 2.5 },
        ];
        let links: Vec<EffLink> = nodes
            .iter()
            .map(|p| EffLink {
                comm: None,
                comp: p.u,
                shift: p.a,
            })
            .collect();
        let l_rows = 1e4;
        let exact = comp_dominant::allocate(&nodes, l_rows);
        let sca = allocate(&links, l_rows, &ScaOptions::default());
        assert!(
            (sca.t_star - exact.t_star).abs() / exact.t_star < 1e-3,
            "SCA {} vs Theorem-2 {}",
            sca.t_star,
            exact.t_star
        );
        for (s, e) in sca.loads.iter().zip(&exact.loads) {
            assert!((s - e).abs() / e < 0.02, "loads {s} vs {e}");
        }
    }

    #[test]
    fn sca_constraint_active_at_solution() {
        let mut rng = Rng::new(12);
        let links = random_links(&mut rng, 5, 2.0);
        let l_rows = 5e3;
        let alloc = allocate(&links, l_rows, &ScaOptions::default());
        let progress = expected_results(&links, &alloc.loads, alloc.t_star);
        // Tight within numerical tolerance (otherwise t could shrink).
        assert!(
            (progress - l_rows).abs() / l_rows < 1e-3,
            "slack at optimum: {progress}"
        );
    }

    #[test]
    fn zero_load_nodes_stay_zero() {
        let links = vec![
            EffLink::dedicated(&LinkParams::new(10.0, 0.2, 5.0)),
            EffLink {
                comm: Some(f64::INFINITY),
                comp: f64::INFINITY,
                shift: 0.0,
            },
        ];
        let start = Allocation {
            loads: vec![2e4, 0.0],
            t_star: 1e4 * 0.8,
        };
        let out = enhance(&links, 1e4, &start, &ScaOptions::default());
        assert_eq!(out.loads[1], 0.0);
    }

    #[test]
    fn dca_and_diminishing_steps_agree() {
        // Both step rules must reach the same stationary point (the paper
        // uses the diminishing rule; we default to the DCA full step).
        let mut rng = Rng::new(21);
        let links = random_links(&mut rng, 6, 2.0);
        let l_rows = 1e4;
        let dca = allocate(&links, l_rows, &ScaOptions::default());
        let dim = allocate(
            &links,
            l_rows,
            &ScaOptions {
                step_rule: StepRule::Diminishing,
                ..Default::default()
            },
        );
        assert!(
            (dca.t_star - dim.t_star).abs() / dim.t_star < 1e-3,
            "DCA {} vs diminishing {}",
            dca.t_star,
            dim.t_star
        );
    }

    #[test]
    fn dca_descends_monotonically() {
        // t(w_{r+1}) ≤ t(w_r) under the full step: verify the end point
        // is no worse than a single subproblem solve.
        let mut rng = Rng::new(22);
        let links = random_links(&mut rng, 5, 2.0);
        let thetas: Vec<f64> = links.iter().map(EffLink::theta).collect();
        let start = markov::allocate(&thetas, 1e4);
        let one = enhance(
            &links,
            1e4,
            &start,
            &ScaOptions {
                max_iters: 1,
                ..Default::default()
            },
        );
        let full = enhance(&links, 1e4, &start, &ScaOptions::default());
        assert!(full.t_star <= one.t_star * (1.0 + 1e-9));
    }

    #[test]
    fn equal_rate_links_handled() {
        // γ == u triggers the symmetric Erlang-limit branch.
        let links = vec![
            EffLink::dedicated(&LinkParams::new(5.0, 0.2, 5.0)),
            EffLink::dedicated(&LinkParams::new(4.0, 0.25, 4.0)),
        ];
        let alloc = allocate(&links, 1e3, &ScaOptions::default());
        assert!(alloc.t_star.is_finite() && alloc.t_star > 0.0);
        let progress = expected_results(&links, &alloc.loads, alloc.t_star);
        assert!(progress >= 1e3 * (1.0 - 1e-6));
    }

    #[test]
    fn equal_rate_decomposition_is_well_conditioned() {
        // The regression the symmetric split fixes: at γ_eff = u_eff the
        // old one-sided 1e-6 perturbation produced c± ≈ 1e6 and
        // catastrophic cancellation in c⁺ψ − c⁻ψ. The weights must now
        // stay at the O(1/(2δ)) ≈ 5e3 scale.
        let d = Decomp::new(&EffLink::dedicated(&LinkParams::new(5.0, 0.2, 5.0)));
        assert!(
            d.c_plus < 1e4 && d.c_minus < 1e4,
            "ill-conditioned equal-rate weights: c⁺={} c⁻={}",
            d.c_plus,
            d.c_minus
        );
        assert!((d.c_plus - d.c_minus - 1.0).abs() < 1e-9, "mixture weights must differ by 1");
        // Rates that are merely close (but outside the branch) keep the
        // exact decomposition.
        let e = Decomp::new(&EffLink::dedicated(&LinkParams::new(5.05, 0.2, 5.0)));
        assert_eq!(e.r_lo, 5.0);
        assert_eq!(e.r_hi, Some(5.05));
    }

    #[test]
    fn equal_rate_allocation_pinned_against_nearby_rate_reference() {
        // Allocation at exactly γ = u must agree with a reference link
        // whose comm rate is nudged just outside the Erlang branch
        // (continuity of the optimum in γ): same t* and loads to ~1%.
        let mk = |ratio: f64| -> Vec<EffLink> {
            [(0.2, 5.0), (0.25, 4.0), (0.3, 10.0 / 3.0)]
                .iter()
                .map(|&(a, u)| EffLink::dedicated(&LinkParams::new(ratio * u, a, u)))
                .collect()
        };
        let l_rows = 1e4;
        let at_equal = allocate(&mk(1.0), l_rows, &ScaOptions::default());
        let nearby = allocate(&mk(1.001), l_rows, &ScaOptions::default());
        assert!(
            (at_equal.t_star - nearby.t_star).abs() / nearby.t_star < 0.01,
            "t* discontinuous at the Erlang limit: {} vs {}",
            at_equal.t_star,
            nearby.t_star
        );
        for (x, y) in at_equal.loads.iter().zip(&nearby.loads) {
            assert!(
                (x - y).abs() / y.max(1.0) < 0.02,
                "loads discontinuous at the Erlang limit: {x} vs {y}"
            );
        }
        // And the equal-rate solution is feasible under the EXACT
        // (eq. 4 Erlang) model, not just the δ-mixture surrogate.
        let progress = expected_results(&mk(1.0), &at_equal.loads, at_equal.t_star);
        assert!(
            progress >= l_rows * (1.0 - 1e-5),
            "equal-rate allocation infeasible: E[X] = {progress}"
        );
    }

    #[test]
    fn enhance_traced_counts_subproblem_solves() {
        let mut rng = Rng::new(33);
        let links = random_links(&mut rng, 5, 2.0);
        let thetas: Vec<f64> = links.iter().map(EffLink::theta).collect();
        let l_rows = 1e4;
        let start = markov::allocate(&thetas, l_rows);
        let (cold, cold_iters) = enhance_traced(&links, l_rows, &start, &ScaOptions::default());
        assert!(cold_iters >= 1, "at least one subproblem solve");
        // Warm start from the stationary point itself: the fixed-point
        // stop must fire almost immediately, never later than cold.
        let (warm, warm_iters) =
            enhance_traced(&links, l_rows, &cold, &ScaOptions::default());
        assert!(warm_iters <= cold_iters, "warm {warm_iters} > cold {cold_iters}");
        assert!(
            (warm.t_star - cold.t_star).abs() / cold.t_star < 1e-6,
            "warm restart moved the optimum: {} vs {}",
            warm.t_star,
            cold.t_star
        );
    }
}
