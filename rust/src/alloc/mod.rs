//! Load allocation (§III-A/B/D, §IV-A): given a set of serving nodes and
//! their delay statistics, split the coded load `l_{m,n}` and estimate the
//! completion delay `t_m`.
//!
//! * [`markov`] — Theorem 1: closed-form optimum of the Markov-inequality
//!   approximation P4 (distribution-free; needs only means).
//! * [`comp_dominant`] — Theorem 2: exact optimum of P3 when computation
//!   delay dominates (Lambert `W₋₁`).
//! * [`fractional`] — Theorem 3: KKT condition `l* = t*/(2θ)` under
//!   fractional resource shares + the `V_m` sum-value helpers of §IV.
//! * [`sca`] — Algorithm 3: SCA-enhanced allocation solving the original
//!   non-convex P3 from the Theorem-1 starting point.
//!
//! The shared currency is [`EffLink`]: per-row delay parameters after
//! resource scaling (`γ → bγ`, `u → ku`, `a → a/k`), so every allocator
//! works unchanged for both dedicated and fractional policies.
//!
//! **Delay-family validity.** [`EffLink`] is intrinsically the
//! shifted-exponential analytic machinery — its CDF is eqs. (3)–(5).
//! The distribution-free Theorem-1 path ([`markov`]) instead consumes
//! first moments through the family-aware
//! [`crate::config::Scenario::theta`], so it is exact-assumption-clean
//! for every delay family; [`comp_dominant`] and [`sca`] require the
//! closed-form CDF and therefore operate on the fitted `(a, u)`
//! surrogate for non-shifted families (DESIGN.md §Delay-model layer
//! tabulates which bounds hold where).

pub mod markov;
pub mod comp_dominant;
pub mod fractional;
pub mod sca;

use crate::model::params::LinkParams;

/// Effective per-row delay parameters of one serving node after resource
/// scaling. For dedicated assignment `k = b = 1`; local nodes have no
/// communication leg (`comm = None`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EffLink {
    /// Effective communication rate `b·γ` per row; `None` if no comm leg
    /// (local processing or computation-dominant model).
    pub comm: Option<f64>,
    /// Effective computation rate `k·u` per row.
    pub comp: f64,
    /// Effective shift `a/k` per row.
    pub shift: f64,
}

impl EffLink {
    /// Dedicated view of a link (`k = b = 1`).
    pub fn dedicated(p: &LinkParams) -> Self {
        Self::fractional(p, 1.0, 1.0)
    }

    /// Fractional view with compute share `k`, bandwidth share `b`.
    ///
    /// Validating constructor: rejects shares outside `(0, 1]` (or
    /// non-finite) instead of panicking, so malformed fractional shares
    /// arriving from JSON configs surface as planner errors.
    pub fn try_fractional(p: &LinkParams, k: f64, b: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(
            k.is_finite() && k > 0.0 && k <= 1.0,
            "compute share k={k} outside (0, 1]"
        );
        let comm = if p.is_local() {
            None
        } else {
            anyhow::ensure!(
                b.is_finite() && b > 0.0 && b <= 1.0,
                "bandwidth share b={b} outside (0, 1]"
            );
            Some(b * p.gamma)
        };
        Ok(Self {
            comm,
            comp: k * p.u,
            shift: p.a / k,
        })
    }

    /// Fractional view with compute share `k`, bandwidth share `b`.
    ///
    /// Internal planner paths always pass validated shares; this infallible
    /// variant debug-asserts and, in release builds, clamps malformed
    /// shares into `(0, 1]` (a near-zero share degrades to a uselessly
    /// slow link, θ → huge, rather than crashing). External inputs should
    /// go through [`EffLink::try_fractional`] — the JSON boundary
    /// ([`crate::plan::Plan::from_json`]) validates shares up front.
    pub fn fractional(p: &LinkParams, k: f64, b: f64) -> Self {
        match Self::try_fractional(p, k, b) {
            Ok(e) => e,
            Err(err) => {
                debug_assert!(false, "EffLink::fractional: {err}");
                let clamp = |x: f64| {
                    if x.is_finite() && x > 0.0 {
                        x.min(1.0)
                    } else {
                        1e-12
                    }
                };
                let (k, b) = (clamp(k), clamp(b));
                Self {
                    comm: (!p.is_local()).then_some(b * p.gamma),
                    comp: k * p.u,
                    shift: p.a / k,
                }
            }
        }
    }

    /// Expected unit delay θ (eqs. 10 / 24).
    pub fn theta(&self) -> f64 {
        self.comm.map_or(0.0, |g| 1.0 / g) + 1.0 / self.comp + self.shift
    }

    /// `P[T ≤ t]` for a load of `l` rows (eqs. 3–5).
    pub fn cdf(&self, l: f64, t: f64) -> f64 {
        debug_assert!(l > 0.0);
        let x = t - self.shift * l;
        if x <= 0.0 {
            return 0.0;
        }
        let l2 = self.comp / l;
        match self.comm {
            None => 1.0 - (-l2 * x).exp(),
            Some(g) => {
                let l1 = g / l;
                if (l1 - l2).abs() / l1.max(l2) < 1e-9 {
                    let lx = l2 * x;
                    1.0 - (1.0 + lx) * (-lx).exp()
                } else {
                    1.0 - (l1 * (-l2 * x).exp() - l2 * (-l1 * x).exp()) / (l1 - l2)
                }
            }
        }
    }
}

/// Result of a load allocation for one master.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Loads `l_{m,n}` in the same order as the input links.
    pub loads: Vec<f64>,
    /// Predicted completion delay `t_m*`.
    pub t_star: f64,
}

impl Allocation {
    /// Total coded rows `L̃_m = Σ l_{m,n}` (the code length the master
    /// must encode to).
    pub fn total_load(&self) -> f64 {
        self.loads.iter().sum()
    }
}

/// Exact expected progress `E[X_m(t)] = Σ l_n·P[T_n ≤ t]` (eq. 8 / 14 /
/// 19). Zero-load nodes contribute nothing.
pub fn expected_results(links: &[EffLink], loads: &[f64], t: f64) -> f64 {
    assert_eq!(links.len(), loads.len());
    links
        .iter()
        .zip(loads)
        .filter(|&(_, &l)| l > 0.0)
        .map(|(e, &l)| l * e.cdf(l, t))
        .sum()
}

/// Smallest `t` with `E[X(t)] ≥ L` for fixed loads (bisection; used to
/// evaluate how a given allocation performs under the exact model).
pub fn exact_t_for_loads(links: &[EffLink], loads: &[f64], l_rows: f64) -> f64 {
    let total: f64 = loads.iter().sum();
    assert!(
        total > l_rows,
        "loads sum {total} must exceed L={l_rows} for finite t"
    );
    let mut lo = 0.0;
    // Upper bound: every node finishing with margin.
    let mut hi = links
        .iter()
        .zip(loads)
        .filter(|&(_, &l)| l > 0.0)
        .map(|(e, &l)| l * e.theta())
        .fold(1e-6, f64::max)
        * 64.0;
    while expected_results(links, loads, hi) < l_rows {
        hi *= 2.0;
        assert!(hi < 1e18, "exact_t_for_loads diverged");
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if expected_results(links, loads, mid) >= l_rows {
            hi = mid;
        } else {
            lo = mid;
        }
        if hi - lo <= 1e-12 * hi.max(1.0) {
            break;
        }
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker(gamma: f64, a: f64, u: f64) -> EffLink {
        EffLink::dedicated(&LinkParams::new(gamma, a, u))
    }

    #[test]
    fn efflink_theta_matches_params() {
        let p = LinkParams::new(2.0, 0.25, 4.0);
        assert!((EffLink::dedicated(&p).theta() - p.theta()).abs() < 1e-12);
        let f = EffLink::fractional(&p, 0.5, 0.25);
        let want = 1.0 / (0.25 * 2.0) + 1.0 / (0.5 * 4.0) + 0.25 / 0.5;
        assert!((f.theta() - want).abs() < 1e-12);
    }

    #[test]
    fn efflink_cdf_matches_linkdelay() {
        use crate::model::dist::LinkDelay;
        let p = LinkParams::new(1.7, 0.3, 2.2);
        let e = EffLink::fractional(&p, 0.6, 0.8);
        let l = 12.0;
        let d = LinkDelay::new(&p, l, 0.6, 0.8);
        for &t in &[1.0, 5.0, 10.0, 20.0, 50.0] {
            assert!(
                (e.cdf(l, t) - d.cdf(t)).abs() < 1e-12,
                "t={t}: {} vs {}",
                e.cdf(l, t),
                d.cdf(t)
            );
        }
    }

    #[test]
    fn expected_results_monotone_in_t() {
        let links = vec![worker(2.0, 0.2, 5.0), worker(4.0, 0.25, 4.0)];
        let loads = vec![10.0, 8.0];
        let mut prev = 0.0;
        for i in 1..100 {
            let t = i as f64 * 0.2;
            let e = expected_results(&links, &loads, t);
            assert!(e >= prev - 1e-12);
            prev = e;
        }
        assert!(prev <= 18.0 + 1e-9);
    }

    #[test]
    fn exact_t_achieves_target() {
        let links = vec![
            worker(2.0, 0.2, 5.0),
            worker(4.0, 0.25, 4.0),
            EffLink::dedicated(&LinkParams::local(0.4, 2.5)),
        ];
        let loads = vec![10.0, 8.0, 6.0];
        let l_target = 20.0;
        let t = exact_t_for_loads(&links, &loads, l_target);
        let e = expected_results(&links, &loads, t);
        assert!((e - l_target).abs() < 1e-6, "E[X(t*)]={e}");
    }

    #[test]
    #[should_panic(expected = "must exceed")]
    fn exact_t_requires_redundancy() {
        let links = vec![worker(2.0, 0.2, 5.0)];
        exact_t_for_loads(&links, &[10.0], 10.0);
    }

    #[test]
    fn try_fractional_rejects_malformed_shares() {
        let p = LinkParams::new(2.0, 0.25, 4.0);
        assert!(EffLink::try_fractional(&p, 0.0, 0.5).is_err());
        assert!(EffLink::try_fractional(&p, 1.5, 0.5).is_err());
        assert!(EffLink::try_fractional(&p, 0.5, 0.0).is_err());
        assert!(EffLink::try_fractional(&p, 0.5, f64::NAN).is_err());
        assert!(EffLink::try_fractional(&p, f64::INFINITY, 0.5).is_err());
        let ok = EffLink::try_fractional(&p, 0.5, 0.25).unwrap();
        assert_eq!(ok, EffLink::fractional(&p, 0.5, 0.25));
    }

    #[test]
    fn try_fractional_local_ignores_bandwidth() {
        // Local links have no comm leg; b is not validated (b_{m,0} = 1
        // by assumption in the paper).
        let p = LinkParams::local(0.4, 2.5);
        let e = EffLink::try_fractional(&p, 1.0, 0.0).unwrap();
        assert_eq!(e.comm, None);
    }
}
