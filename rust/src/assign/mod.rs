//! Worker assignment (§III-C, §IV-B): decide which workers serve which
//! masters, and with what resource shares.
//!
//! The assignment currency is the **value matrix** `v_{m,n}` — the rate a
//! node adds to `1/t_m*` (eq. 17): under the Markov allocation
//! `v = 1/(4·L_m·θ_{m,n})`; under the computation-dominant exact
//! allocation `v = u/(L_m·(1+u·φ))` (§III-C note). Both make P5/P7 a
//! max-min allocation problem.
//!
//! * [`dedicated_iter`] — Algorithm 1 (iterated greedy: insertion,
//!   interchange, exploration);
//! * [`dedicated_simple`] — Algorithm 2 (largest-value-first greedy);
//! * [`fractional`] — Algorithm 4 (resource balancing from a dedicated
//!   start);
//! * [`optimal`] — the small-scale "brute-force" baseline as a supported-
//!   point λ-sweep + coordinate refinement (DESIGN.md §Substitutions);
//! * [`uniform`] — §V benchmarks 1–2 (uncoded / coded with `N/M` workers
//!   per master).

pub mod dedicated_iter;
pub mod dedicated_simple;
pub mod fractional;
pub mod optimal;
pub mod uniform;

use crate::alloc::{comp_dominant, markov};
use crate::config::Scenario;

/// Which allocator's node values drive the assignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValueModel {
    /// Markov/Theorem-1 values `1/(4·L·θ)` — distribution-free.
    Markov,
    /// Theorem-2 values `u/(L·(1+u·φ))` — computation-dominant exact.
    Exact,
}

/// Per-(master, node) assignment values. `v0[m]` is the master's local
/// value (always owned by m); `v[m][w]` is worker `w`'s value for `m`
/// (workers 0-indexed here; node id = w + 1).
#[derive(Clone, Debug)]
pub struct ValueMatrix {
    pub v0: Vec<f64>,
    pub v: Vec<Vec<f64>>,
}

impl ValueMatrix {
    pub fn new(s: &Scenario, model: ValueModel) -> Self {
        let m = s.n_masters();
        let n = s.n_workers();
        let value = |mm: usize, node: usize| -> f64 {
            let l = s.l_rows(mm);
            match model {
                // Markov values are distribution-free (Remark 1): they
                // consume the family-aware first moment θ, not the raw
                // (a, u) pair — heavy-tail and trace-driven links value
                // through their true means.
                ValueModel::Markov => markov::node_value(s.theta(mm, node, 1.0, 1.0), l),
                // Theorem-2 values are closed-form in the shifted-exp
                // parameters; for other families they evaluate the
                // fitted (a, u) surrogate (DESIGN.md §Delay-model layer).
                ValueModel::Exact => {
                    let p = s.link(mm, node);
                    comp_dominant::node_value(
                        comp_dominant::CompParams { a: p.a, u: p.u },
                        l,
                    )
                }
            }
        };
        Self {
            v0: (0..m).map(|mm| value(mm, 0)).collect(),
            v: (0..m)
                .map(|mm| (1..=n).map(|w| value(mm, w)).collect())
                .collect(),
        }
    }

    pub fn n_masters(&self) -> usize {
        self.v0.len()
    }

    pub fn n_workers(&self) -> usize {
        self.v.first().map_or(0, Vec::len)
    }
}

/// A dedicated assignment: every worker serves at most one master.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Dedicated {
    /// `owner[w]` = master served by worker `w` (always assigned by our
    /// greedy algorithms — leaving a worker idle never helps).
    pub owner: Vec<usize>,
}

impl Dedicated {
    /// Workers serving master `m` (0-indexed worker ids).
    pub fn workers_of(&self, m: usize) -> Vec<usize> {
        (0..self.owner.len())
            .filter(|&w| self.owner[w] == m)
            .collect()
    }

    /// Sum values `V_m = v0[m] + Σ_{w∈Ω_m} v[m][w]` for all masters.
    pub fn sum_values(&self, vm: &ValueMatrix) -> Vec<f64> {
        let mut vs = vm.v0.clone();
        for (w, &m) in self.owner.iter().enumerate() {
            vs[m] += vm.v[m][w];
        }
        vs
    }

    /// The max-min objective: `min_m V_m`.
    pub fn min_value(&self, vm: &ValueMatrix) -> f64 {
        self.sum_values(vm)
            .into_iter()
            .fold(f64::INFINITY, f64::min)
    }
}

/// A fractional assignment: per-(master, worker) compute share `k` and
/// bandwidth share `b`, with `Σ_m k[m][w] ≤ 1`, `Σ_m b[m][w] ≤ 1`.
#[derive(Clone, Debug)]
pub struct Fractional {
    pub k: Vec<Vec<f64>>,
    pub b: Vec<Vec<f64>>,
}

impl Fractional {
    /// Lift a dedicated assignment (k = b = 1 on owned workers).
    pub fn from_dedicated(d: &Dedicated, n_masters: usize) -> Self {
        let n = d.owner.len();
        let mut k = vec![vec![0.0; n]; n_masters];
        let mut b = vec![vec![0.0; n]; n_masters];
        for (w, &m) in d.owner.iter().enumerate() {
            k[m][w] = 1.0;
            b[m][w] = 1.0;
        }
        Self { k, b }
    }

    /// Check the per-worker resource constraints (6c).
    pub fn is_feasible(&self) -> bool {
        let n = self.k.first().map_or(0, Vec::len);
        (0..n).all(|w| {
            let ks: f64 = self.k.iter().map(|row| row[w]).sum();
            let bs: f64 = self.b.iter().map(|row| row[w]).sum();
            ks <= 1.0 + 1e-9
                && bs <= 1.0 + 1e-9
                && self
                    .k
                    .iter()
                    .zip(&self.b)
                    .all(|(kr, br)| (0.0..=1.0 + 1e-9).contains(&kr[w])
                        && (0.0..=1.0 + 1e-9).contains(&br[w]))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CommModel, Scenario};

    #[test]
    fn value_matrix_shapes_and_positivity() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        let vm = ValueMatrix::new(&s, ValueModel::Markov);
        assert_eq!(vm.n_masters(), 2);
        assert_eq!(vm.n_workers(), 5);
        assert!(vm.v0.iter().all(|&v| v > 0.0));
        assert!(vm.v.iter().flatten().all(|&v| v > 0.0));
    }

    #[test]
    fn exact_values_exceed_markov_values() {
        // Theorem 2 extracts more rate per node than the conservative
        // Markov bound: v_exact > v_markov for the same node.
        let s = Scenario::small_scale(2, 2.0, CommModel::CompDominant);
        let mv = ValueMatrix::new(&s, ValueModel::Markov);
        let ev = ValueMatrix::new(&s, ValueModel::Exact);
        for m in 0..2 {
            for w in 0..5 {
                assert!(
                    ev.v[m][w] > mv.v[m][w],
                    "m={m} w={w}: {} ≤ {}",
                    ev.v[m][w],
                    mv.v[m][w]
                );
            }
        }
    }

    #[test]
    fn dedicated_sum_values() {
        let vm = ValueMatrix {
            v0: vec![1.0, 2.0],
            v: vec![vec![0.5, 0.3, 0.1], vec![0.2, 0.9, 0.4]],
        };
        let d = Dedicated {
            owner: vec![0, 1, 0],
        };
        let vs = d.sum_values(&vm);
        assert!((vs[0] - (1.0 + 0.5 + 0.1)).abs() < 1e-12);
        assert!((vs[1] - (2.0 + 0.9)).abs() < 1e-12);
        assert!((d.min_value(&vm) - 1.6).abs() < 1e-12);
        assert_eq!(d.workers_of(0), vec![0, 2]);
    }

    #[test]
    fn fractional_from_dedicated_feasible() {
        let d = Dedicated {
            owner: vec![0, 1, 1, 0],
        };
        let f = Fractional::from_dedicated(&d, 2);
        assert!(f.is_feasible());
        assert_eq!(f.k[0][0], 1.0);
        assert_eq!(f.k[1][0], 0.0);
    }

    #[test]
    fn fractional_feasibility_detects_violation() {
        let f = Fractional {
            k: vec![vec![0.7], vec![0.7]],
            b: vec![vec![0.5], vec![0.4]],
        };
        assert!(!f.is_feasible());
    }
}
