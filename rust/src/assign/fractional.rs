//! Algorithm 4: greedy fractional worker assignment (§IV-B).
//!
//! Start from a dedicated assignment (Algorithm 1 or 2 with k = b = 1 on
//! owned workers), then iteratively balance: take the richest master
//! `m₁ = argmax V` and the poorest `m₂ = argmin V`, pick the worker of
//! `m₁` (not yet serving `m₂`) with the highest potential value for `m₂`,
//! and move either **all** of `m₁`'s share of it, or the exact fraction
//! that equalizes `V_{m₁} = V_{m₂}` (paper line 7; the split fraction is
//! under-specified there — we move the same fraction of compute and
//! bandwidth and solve for it by bisection, which is the unique equalizer
//! since `V₁` is strictly decreasing and `V₂` strictly increasing in it).

use super::{Dedicated, Fractional, ValueMatrix};
use crate::alloc::markov::node_value;
use crate::config::Scenario;

/// Options for Algorithm 4.
#[derive(Clone, Copy, Debug)]
pub struct FracOptions {
    pub max_iters: usize,
    /// Stop when `(max V − min V)/max V` falls below this.
    pub tol: f64,
}

impl Default for FracOptions {
    fn default() -> Self {
        Self {
            max_iters: 500,
            tol: 1e-6,
        }
    }
}

/// Sum values `V_m` under the current shares (eq. 28a). θ flows through
/// the family-aware moment interface ([`Scenario::theta`]) — the
/// balancing currency stays correct for heavy-tail and trace-driven
/// links (bit-identical to the legacy formulas on shifted-exp links).
pub fn sum_values(s: &Scenario, f: &Fractional) -> Vec<f64> {
    (0..s.n_masters())
        .map(|m| {
            let l = s.l_rows(m);
            let mut v = node_value(s.theta(m, 0, 1.0, 1.0), l);
            for w in 0..s.n_workers() {
                if f.k[m][w] > 0.0 {
                    let th = s.theta(m, w + 1, f.k[m][w], f.b[m][w]);
                    v += node_value(th, l);
                }
            }
            v
        })
        .collect()
}

/// Run Algorithm 4 from a dedicated starting assignment.
pub fn assign(s: &Scenario, start: &Dedicated, opts: &FracOptions) -> Fractional {
    let m_cnt = s.n_masters();
    let mut f = Fractional::from_dedicated(start, m_cnt);
    if m_cnt < 2 {
        return f;
    }
    let mut values = sum_values(s, &f);

    // Value contribution of worker w for master m under shares (k, b).
    let contrib = |m: usize, w: usize, k: f64, b: f64| -> f64 {
        if k <= 0.0 || b <= 0.0 {
            return 0.0;
        }
        node_value(s.theta(m, w + 1, k, b), s.l_rows(m))
    };

    for _ in 0..opts.max_iters {
        let m1 = argmax(&values);
        let m2 = argmin(&values);
        if values[m1] - values[m2] <= opts.tol * values[m1].max(1e-300) {
            break;
        }

        // Workers serving m1 but not m2, with their potential gain for m2
        // if ALL of m1's share moved (paper lines 3–5).
        let mut best: Option<(usize, f64)> = None;
        for w in 0..s.n_workers() {
            if f.k[m1][w] > 0.0 && f.k[m2][w] == 0.0 {
                let gain = contrib(m2, w, f.k[m1][w], f.b[m1][w]);
                if best.map_or(true, |(_, g)| gain > g) {
                    best = Some((w, gain));
                }
            }
        }
        let (n1, full_gain) = match best {
            Some(x) => x,
            None => break, // no transferable worker
        };

        let (k0, b0) = (f.k[m1][n1], f.b[m1][n1]);
        let c1 = contrib(m1, n1, k0, b0); // m1's current contribution of n1

        if values[m1] - c1 <= values[m2] + full_gain {
            // Partial move: find x with V1(x) = V2(x) (paper lines 6–7).
            let v1 = |x: f64| values[m1] - c1 + contrib(m1, n1, (1.0 - x) * k0, (1.0 - x) * b0);
            let v2 = |x: f64| values[m2] + contrib(m2, n1, x * k0, x * b0);
            let (mut lo, mut hi) = (0.0f64, 1.0f64);
            for _ in 0..60 {
                let mid = 0.5 * (lo + hi);
                if v1(mid) >= v2(mid) {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            let x = 0.5 * (lo + hi);
            f.k[m1][n1] = (1.0 - x) * k0;
            f.b[m1][n1] = (1.0 - x) * b0;
            f.k[m2][n1] = x * k0;
            f.b[m2][n1] = x * b0;
        } else {
            // Full move (paper line 9).
            f.k[m2][n1] = k0;
            f.b[m2][n1] = b0;
            f.k[m1][n1] = 0.0;
            f.b[m1][n1] = 0.0;
        }
        values = sum_values(s, &f);
    }
    debug_assert!(f.is_feasible());
    f
}

/// Convenience: Algorithm 1/2 start → Algorithm 4, returning both.
pub fn assign_from_values(
    s: &Scenario,
    vm: &ValueMatrix,
    iterated: bool,
    opts: &FracOptions,
) -> (Dedicated, Fractional) {
    let d = if iterated {
        super::dedicated_iter::assign(vm, &Default::default())
    } else {
        super::dedicated_simple::assign(vm)
    };
    let f = assign(s, &d, opts);
    (d, f)
}

fn argmax(xs: &[f64]) -> usize {
    (0..xs.len())
        .max_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap())
        .unwrap()
}

fn argmin(xs: &[f64]) -> usize {
    (0..xs.len())
        .min_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap())
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::{dedicated_iter, ValueModel};
    use crate::config::{CommModel, Scenario};

    fn setup(seed: u64) -> (Scenario, Dedicated) {
        let s = Scenario::small_scale(seed, 2.0, CommModel::Stochastic);
        let vm = ValueMatrix::new(&s, ValueModel::Markov);
        let d = dedicated_iter::assign(&vm, &Default::default());
        (s, d)
    }

    #[test]
    fn output_is_feasible() {
        for seed in 0..8 {
            let (s, d) = setup(seed);
            let f = assign(&s, &d, &FracOptions::default());
            assert!(f.is_feasible(), "seed {seed}");
        }
    }

    #[test]
    fn min_value_never_decreases() {
        // Fractionalization can only help the poorest master.
        for seed in 0..8 {
            let (s, d) = setup(seed);
            let start = Fractional::from_dedicated(&d, s.n_masters());
            let v_before = sum_values(&s, &start)
                .into_iter()
                .fold(f64::INFINITY, f64::min);
            let f = assign(&s, &d, &FracOptions::default());
            let v_after = sum_values(&s, &f)
                .into_iter()
                .fold(f64::INFINITY, f64::min);
            assert!(
                v_after >= v_before - 1e-12,
                "seed {seed}: {v_after} < {v_before}"
            );
        }
    }

    #[test]
    fn balances_master_values() {
        // After Algorithm 4 the V_m spread should be small (that is its
        // fixed point) unless it ran out of transferable workers.
        let (s, d) = setup(3);
        let f = assign(&s, &d, &FracOptions::default());
        let vs = sum_values(&s, &f);
        let (mn, mx) = (
            vs.iter().fold(f64::INFINITY, |a, &b| a.min(b)),
            vs.iter().fold(0.0f64, |a, &b| a.max(b)),
        );
        assert!(
            (mx - mn) / mx < 0.05,
            "V spread too large: {vs:?}"
        );
    }

    #[test]
    fn split_worker_serves_two_masters() {
        // On the small scale a partial split is the common outcome.
        let mut found_split = false;
        for seed in 0..10 {
            let (s, d) = setup(seed);
            let f = assign(&s, &d, &FracOptions::default());
            for w in 0..s.n_workers() {
                let serving = (0..s.n_masters())
                    .filter(|&m| f.k[m][w] > 1e-12)
                    .count();
                if serving > 1 {
                    found_split = true;
                    // shares on a split worker must sum to ≤ 1
                    let ks: f64 = (0..s.n_masters()).map(|m| f.k[m][w]).sum();
                    assert!(ks <= 1.0 + 1e-9);
                }
            }
        }
        assert!(found_split, "no worker was ever split across 10 seeds");
    }

    #[test]
    fn comp_dominant_scenario_works() {
        let s = Scenario::ec2(8, 2, false);
        let vm = ValueMatrix::new(&s, ValueModel::Markov);
        let d = dedicated_iter::assign(&vm, &Default::default());
        let f = assign(&s, &d, &FracOptions::default());
        assert!(f.is_feasible());
        let vs = sum_values(&s, &f);
        assert!(vs.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn single_master_is_noop() {
        let s = Scenario::random(
            "single",
            1,
            4,
            1e3,
            crate::config::AShift::Range(0.1, 0.4),
            2.0,
            CommModel::Stochastic,
            9,
        );
        let d = Dedicated {
            owner: vec![0, 0, 0, 0],
        };
        let f = assign(&s, &d, &FracOptions::default());
        assert!(f.k[0].iter().all(|&k| k == 1.0));
    }
}
