//! Algorithm 1: iterated greedy dedicated worker assignment.
//!
//! Phases (after Fanjul-Peyro & Ruiz [30]):
//! 1. **Initialization** — each worker to the master valuing it most;
//! 2. **Insertion** — move a worker to the poorest other master when that
//!    raises the min sum value;
//! 3. **Interchange** — swap two workers between masters when both sums
//!    stay above the current minimum and total value grows;
//! 4. **Exploration** — evict a random worker subset, re-add greedily.
//!
//! The loop stops after `max_rounds` or when a full round leaves the
//! objective unchanged; the reported assignment is the best one observed
//! **after an interchange phase** (paper: "the final output is the worker
//! assignment after the interchange phase").

use super::{Dedicated, ValueMatrix};
use crate::util::rng::Rng;

/// Options for Algorithm 1.
#[derive(Clone, Copy, Debug)]
pub struct IterOptions {
    pub max_rounds: usize,
    /// Fraction of workers evicted in the exploration phase.
    pub explore_frac: f64,
    pub seed: u64,
}

impl Default for IterOptions {
    fn default() -> Self {
        Self {
            max_rounds: 60,
            explore_frac: 0.2,
            seed: 0xA551_614E,
        }
    }
}

/// Rounds without improvement before the iteration terminates (the
/// paper's "min sum value does not improve any more", made robust to the
/// randomized exploration phase).
const STALL_LIMIT: usize = 8;

/// Exhaustive max-min assignment for tiny instances (`M^N ≤ 65536`, e.g.
/// the paper's 2×5 small scale): the search space is smaller than one
/// round of local search, so solve exactly.
fn assign_exhaustive(vm: &ValueMatrix) -> Dedicated {
    let (m_cnt, n_cnt) = (vm.n_masters(), vm.n_workers());
    let total: u64 = (m_cnt as u64).pow(n_cnt as u32);
    let mut best = Dedicated {
        owner: vec![0; n_cnt],
    };
    let mut best_min = f64::NEG_INFINITY;
    let mut owner = vec![0usize; n_cnt];
    for code in 0..total {
        let mut c = code;
        for o in owner.iter_mut() {
            *o = (c % m_cnt as u64) as usize;
            c /= m_cnt as u64;
        }
        let d = Dedicated {
            owner: owner.clone(),
        };
        let v = d.min_value(vm);
        if v > best_min {
            best_min = v;
            best = d;
        }
    }
    best
}

/// Run Algorithm 1.
pub fn assign(vm: &ValueMatrix, opts: &IterOptions) -> Dedicated {
    let m_cnt = vm.n_masters();
    let n_cnt = vm.n_workers();
    assert!(m_cnt > 0);
    // Tiny instances: exact enumeration beats any heuristic and costs
    // less than one local-search round.
    if (m_cnt as f64).powi(n_cnt as i32) <= 65536.0 {
        return assign_exhaustive(vm);
    }
    let mut rng = Rng::new(opts.seed);

    // ---- Initialization: worker → argmax_m v[m][w] --------------------
    let mut owner: Vec<usize> = (0..n_cnt)
        .map(|w| {
            (0..m_cnt)
                .max_by(|&a, &b| vm.v[a][w].partial_cmp(&vm.v[b][w]).unwrap())
                .unwrap()
        })
        .collect();
    let mut values = sum_values(vm, &owner);

    let mut best_owner = owner.clone();
    let mut best_min = min_of(&values);
    let mut stall = 0usize;

    // Incumbent hardening: seed the best-so-far with Algorithm 2's
    // constructive solution, so the iterated search never reports worse
    // than the simple greedy (matches the dominance the paper observes in
    // Figs. 4b/8; the local-search loop itself is unchanged).
    {
        let simple = super::dedicated_simple::assign(vm);
        let simple_min = simple.min_value(vm);
        if simple_min > best_min {
            best_min = simple_min;
            best_owner = simple.owner;
        }
    }

    for _round in 0..opts.max_rounds {

        // ---- Insertion phase ------------------------------------------
        for w in 0..n_cnt {
            let m1 = owner[w];
            // Poorest other master.
            let m2 = match (0..m_cnt)
                .filter(|&m| m != m1)
                .min_by(|&a, &b| values[a].partial_cmp(&values[b]).unwrap())
            {
                Some(m) => m,
                None => break, // single master: nothing to insert into
            };
            let old_min = min_of(&values);
            let v1_new = values[m1] - vm.v[m1][w];
            let v2_new = values[m2] + vm.v[m2][w];
            // New min over all masters after the move.
            let new_min = (0..m_cnt)
                .map(|m| {
                    if m == m1 {
                        v1_new
                    } else if m == m2 {
                        v2_new
                    } else {
                        values[m]
                    }
                })
                .fold(f64::INFINITY, f64::min);
            if new_min > old_min {
                owner[w] = m2;
                values[m1] = v1_new;
                values[m2] = v2_new;
            }
        }

        // ---- Interchange phase ----------------------------------------
        let mut v_min = min_of(&values);
        for w1 in 0..n_cnt {
            for w2 in w1 + 1..n_cnt {
                let (m1, m2) = (owner[w1], owner[w2]);
                if m1 == m2 {
                    continue;
                }
                // Swap improves total contribution and keeps both masters
                // above the current min (paper line 15).
                if vm.v[m1][w1] + vm.v[m2][w2] < vm.v[m1][w2] + vm.v[m2][w1] {
                    let v1_new = values[m1] - vm.v[m1][w1] + vm.v[m1][w2];
                    let v2_new = values[m2] - vm.v[m2][w2] + vm.v[m2][w1];
                    if v1_new > v_min && v2_new > v_min {
                        owner.swap(w1, w2);
                        values[m1] = v1_new;
                        values[m2] = v2_new;
                        v_min = min_of(&values);
                    }
                }
            }
        }

        // Output point: after interchange (paper).
        let cur_min = min_of(&values);
        if cur_min > best_min {
            best_min = cur_min;
            best_owner = owner.clone();
            stall = 0;
        } else {
            stall += 1;
            if stall >= STALL_LIMIT {
                break;
            }
        }

        // ---- Exploration phase ----------------------------------------
        let evict = ((n_cnt as f64 * opts.explore_frac).round() as usize)
            .clamp(1, n_cnt);
        let victims = rng.subset(n_cnt, evict);
        for &w in &victims {
            values[owner[w]] -= vm.v[owner[w]][w];
            owner[w] = usize::MAX;
        }
        // Greedy re-add: place (master, victim) pairs in decreasing value
        // order (paper lines 20–23). §Perf item 5: the per-victim best
        // master never changes during re-add, so precompute + sort once
        // (O(|pool| log |pool|) instead of O(|pool|²·M)).
        let mut pool: Vec<(usize, usize, f64)> = victims
            .iter()
            .map(|&w| {
                let m = (0..m_cnt)
                    .max_by(|&a, &b| vm.v[a][w].partial_cmp(&vm.v[b][w]).unwrap())
                    .unwrap();
                (w, m, vm.v[m][w])
            })
            .collect();
        pool.sort_unstable_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        for (w, m, v) in pool {
            owner[w] = m;
            values[m] += v;
        }
    }

    Dedicated { owner: best_owner }
}

fn sum_values(vm: &ValueMatrix, owner: &[usize]) -> Vec<f64> {
    let mut vs = vm.v0.clone();
    for (w, &m) in owner.iter().enumerate() {
        vs[m] += vm.v[m][w];
    }
    vs
}

fn min_of(xs: &[f64]) -> f64 {
    xs.iter().fold(f64::INFINITY, |a, &b| a.min(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::{dedicated_simple, ValueModel};
    use crate::config::{CommModel, Scenario};

    fn default_assign(vm: &ValueMatrix) -> Dedicated {
        assign(vm, &IterOptions::default())
    }

    #[test]
    fn assigns_every_worker() {
        let s = Scenario::large_scale(5, 2.0, CommModel::Stochastic);
        let vm = ValueMatrix::new(&s, ValueModel::Markov);
        let d = default_assign(&vm);
        assert_eq!(d.owner.len(), 50);
        assert!(d.owner.iter().all(|&m| m < 4));
    }

    #[test]
    fn at_least_as_good_as_simple_greedy() {
        // The iterated greedy's whole point (Fig. 4b/8): it should match
        // or beat Algorithm 2 on the max-min objective.
        for seed in 0..10 {
            let s = Scenario::large_scale(seed, 2.0, CommModel::Stochastic);
            let vm = ValueMatrix::new(&s, ValueModel::Markov);
            let iter_min = default_assign(&vm).min_value(&vm);
            let simple_min = dedicated_simple::assign(&vm).min_value(&vm);
            assert!(
                iter_min >= simple_min * (1.0 - 1e-9),
                "seed {seed}: iter {iter_min} < simple {simple_min}"
            );
        }
    }

    #[test]
    fn finds_optimum_on_tiny_instance() {
        // 2 masters, 2 workers; exhaustive optimum over 4 assignments.
        let vm = ValueMatrix {
            v0: vec![0.1, 0.1],
            v: vec![vec![1.0, 0.6], vec![0.5, 0.55]],
        };
        let mut best = f64::NEG_INFINITY;
        for a in 0..2 {
            for b in 0..2 {
                let d = Dedicated { owner: vec![a, b] };
                best = best.max(d.min_value(&vm));
            }
        }
        let got = default_assign(&vm).min_value(&vm);
        assert!((got - best).abs() < 1e-12, "{got} vs optimal {best}");
    }

    #[test]
    fn exhaustive_optimality_small_random() {
        // 2 masters × 6 workers: check against brute force (64 cases).
        for seed in 0..5 {
            let s = Scenario::small_scale(seed, 2.0, CommModel::Stochastic);
            let vm = ValueMatrix::new(&s, ValueModel::Markov);
            let n = vm.n_workers();
            let mut best = f64::NEG_INFINITY;
            for mask in 0..(1usize << n) {
                let owner: Vec<usize> =
                    (0..n).map(|w| (mask >> w) & 1).collect();
                let d = Dedicated { owner };
                best = best.max(d.min_value(&vm));
            }
            let got = default_assign(&vm).min_value(&vm);
            // Iterated greedy is a heuristic; accept within 2% of optimal
            // on these tiny instances (it usually hits it exactly).
            assert!(
                got >= best * 0.98,
                "seed {seed}: {got} < 0.98·{best}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let s = Scenario::large_scale(1, 2.0, CommModel::Stochastic);
        let vm = ValueMatrix::new(&s, ValueModel::Markov);
        let a = default_assign(&vm);
        let b = default_assign(&vm);
        assert_eq!(a, b);
    }

    #[test]
    fn single_master_everything_assigned_to_it() {
        let vm = ValueMatrix {
            v0: vec![0.3],
            v: vec![vec![0.1, 0.5, 0.2]],
        };
        let d = default_assign(&vm);
        assert_eq!(d.owner, vec![0, 0, 0]);
    }
}
