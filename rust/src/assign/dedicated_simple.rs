//! Algorithm 2: simple greedy dedicated worker assignment.
//!
//! Largest-value-first (after Deuermeyer–Friesen–Langston [31]): while
//! unassigned workers remain, give the currently-poorest master (smallest
//! sum value `V_m`) its most valuable remaining worker.

use super::{Dedicated, ValueMatrix};

/// Run Algorithm 2.
pub fn assign(vm: &ValueMatrix) -> Dedicated {
    let m_cnt = vm.n_masters();
    let n_cnt = vm.n_workers();
    assert!(m_cnt > 0);
    let mut values = vm.v0.clone();
    let mut owner = vec![usize::MAX; n_cnt];
    let mut remaining: Vec<usize> = (0..n_cnt).collect();

    while !remaining.is_empty() {
        // Poorest master.
        let m_star = (0..m_cnt)
            .min_by(|&a, &b| values[a].partial_cmp(&values[b]).unwrap())
            .unwrap();
        // Its best remaining worker.
        let (pos, &w_star) = remaining
            .iter()
            .enumerate()
            .max_by(|(_, &a), (_, &b)| {
                vm.v[m_star][a].partial_cmp(&vm.v[m_star][b]).unwrap()
            })
            .unwrap();
        values[m_star] += vm.v[m_star][w_star];
        owner[w_star] = m_star;
        remaining.swap_remove(pos);
    }
    Dedicated { owner }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::{ValueModel};
    use crate::config::{CommModel, Scenario};

    #[test]
    fn assigns_every_worker_exactly_once() {
        let s = Scenario::large_scale(3, 2.0, CommModel::Stochastic);
        let vm = ValueMatrix::new(&s, ValueModel::Markov);
        let d = assign(&vm);
        assert_eq!(d.owner.len(), 50);
        assert!(d.owner.iter().all(|&m| m < 4));
        let total: usize = (0..4).map(|m| d.workers_of(m).len()).sum();
        assert_eq!(total, 50);
    }

    #[test]
    fn poorest_master_is_served_first() {
        // Master 1 starts much poorer; the single worker must go to it.
        let vm = ValueMatrix {
            v0: vec![10.0, 0.1],
            v: vec![vec![5.0], vec![1.0]],
        };
        let d = assign(&vm);
        assert_eq!(d.owner[0], 1);
    }

    #[test]
    fn balances_identical_workers() {
        // 2 masters with equal locals, 6 identical workers: 3 each.
        let vm = ValueMatrix {
            v0: vec![1.0, 1.0],
            v: vec![vec![1.0; 6], vec![1.0; 6]],
        };
        let d = assign(&vm);
        assert_eq!(d.workers_of(0).len(), 3);
        assert_eq!(d.workers_of(1).len(), 3);
    }

    #[test]
    fn single_master_takes_everything() {
        let vm = ValueMatrix {
            v0: vec![0.5],
            v: vec![vec![0.1, 0.2, 0.3]],
        };
        let d = assign(&vm);
        assert_eq!(d.workers_of(0), vec![0, 1, 2]);
    }
}
