//! "Brute-force" optimal fractional assignment baseline (§V-B benchmark 3,
//! small scale only).
//!
//! The paper states it traverses all `k_{m,n}, b_{m,n}` at step 0.01 —
//! literally 101^(2·M·N) points, infeasible even for M=2, N=5. What the
//! search actually needs is the max-min optimum of P7, whose objective is
//! separable per worker: `V_m = v₀_m + Σ_w v_m(k_{m,w}, b_{m,w})`. We
//! recover the supported optima with a Pareto λ-sweep — for each weight λ
//! each worker independently maximizes `λ·v₁ + (1−λ)·v₂` over the same
//! 0.01 grid — followed by per-worker coordinate-descent refinement of
//! `min(V₁, V₂)` on the grid (handles unsupported max-min points). See
//! DESIGN.md §Substitutions.
//!
//! Restricted to M = 2 like the paper's use of it (Fig. 4a / 5a).

use super::Fractional;
use crate::alloc::markov::node_value;
use crate::config::Scenario;

/// Search options.
#[derive(Clone, Copy, Debug)]
pub struct OptimalOptions {
    /// Grid step for k and b (paper: 0.01).
    pub step: f64,
    /// Number of λ values swept over [0, 1].
    pub lambda_steps: usize,
    /// Coordinate-descent refinement passes.
    pub refine_passes: usize,
}

impl Default for OptimalOptions {
    fn default() -> Self {
        Self {
            step: 0.01,
            lambda_steps: 201,
            refine_passes: 3,
        }
    }
}

/// Exhaustive-grid max-min fractional assignment for M = 2.
pub fn assign(s: &Scenario, opts: &OptimalOptions) -> Fractional {
    assert_eq!(
        s.n_masters(),
        2,
        "optimal search is defined for M = 2 (paper small scale)"
    );
    let n = s.n_workers();
    let steps = (1.0 / opts.step).round() as usize; // grid 0..=steps

    // v[m][w][(ik, ib)] would be huge; evaluate lazily instead.
    let value = |m: usize, w: usize, k: f64, b: f64| -> f64 {
        if k <= 0.0 || b <= 0.0 {
            return 0.0;
        }
        // Family-aware θ: the grid search values heavy-tail/trace links
        // by their true means (bit-identical legacy on shifted-exp).
        node_value(s.theta(m, w + 1, k, b), s.l_rows(m))
    };
    let v0: Vec<f64> = (0..2)
        .map(|m| node_value(s.theta(m, 0, 1.0, 1.0), s.l_rows(m)))
        .collect();

    // Assignment state: per worker the (k1, b1) grid indices; master 2
    // receives the complement (never wasteful: values are monotone in
    // shares).
    let objective = |shares: &[(usize, usize)]| -> (f64, f64) {
        let mut v1 = v0[0];
        let mut v2 = v0[1];
        for (w, &(ik, ib)) in shares.iter().enumerate() {
            let (k1, b1) = (ik as f64 * opts.step, ib as f64 * opts.step);
            v1 += value(0, w, k1, b1);
            v2 += value(1, w, 1.0 - k1, 1.0 - b1);
        }
        (v1, v2)
    };

    // ---- λ-sweep over supported points --------------------------------
    let mut best: Option<(f64, Vec<(usize, usize)>)> = None;
    for li in 0..opts.lambda_steps {
        let lambda = li as f64 / (opts.lambda_steps - 1) as f64;
        let mut shares = Vec::with_capacity(n);
        for w in 0..n {
            let mut arg = (0usize, 0usize);
            let mut bestv = f64::NEG_INFINITY;
            for ik in 0..=steps {
                let k1 = ik as f64 * opts.step;
                for ib in 0..=steps {
                    let b1 = ib as f64 * opts.step;
                    let sc = lambda * value(0, w, k1, b1)
                        + (1.0 - lambda) * value(1, w, 1.0 - k1, 1.0 - b1);
                    if sc > bestv {
                        bestv = sc;
                        arg = (ik, ib);
                    }
                }
            }
            shares.push(arg);
        }
        let (v1, v2) = objective(&shares);
        let mm = v1.min(v2);
        if best.as_ref().map_or(true, |(b, _)| mm > *b) {
            best = Some((mm, shares));
        }
    }
    let (_, mut shares) = best.unwrap();

    // ---- Coordinate-descent refinement ---------------------------------
    for _ in 0..opts.refine_passes {
        let mut improved = false;
        for w in 0..n {
            let (mut v1, mut v2) = objective(&shares);
            let (ik0, ib0) = shares[w];
            // Remove worker w's contribution.
            let (k1, b1) = (ik0 as f64 * opts.step, ib0 as f64 * opts.step);
            v1 -= value(0, w, k1, b1);
            v2 -= value(1, w, 1.0 - k1, 1.0 - b1);
            let mut best_mm = f64::NEG_INFINITY;
            let mut arg = (ik0, ib0);
            for ik in 0..=steps {
                let k1 = ik as f64 * opts.step;
                for ib in 0..=steps {
                    let b1 = ib as f64 * opts.step;
                    let mm = (v1 + value(0, w, k1, b1))
                        .min(v2 + value(1, w, 1.0 - k1, 1.0 - b1));
                    if mm > best_mm {
                        best_mm = mm;
                        arg = (ik, ib);
                    }
                }
            }
            if arg != (ik0, ib0) {
                shares[w] = arg;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }

    // Materialize.
    let mut f = Fractional {
        k: vec![vec![0.0; n]; 2],
        b: vec![vec![0.0; n]; 2],
    };
    for (w, &(ik, ib)) in shares.iter().enumerate() {
        let (k1, b1) = (ik as f64 * opts.step, ib as f64 * opts.step);
        f.k[0][w] = k1;
        f.b[0][w] = b1;
        f.k[1][w] = 1.0 - k1;
        f.b[1][w] = 1.0 - b1;
    }
    debug_assert!(f.is_feasible());
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::fractional::{self, FracOptions};
    use crate::assign::{dedicated_iter, ValueMatrix, ValueModel};
    use crate::config::{CommModel, Scenario};

    fn coarse() -> OptimalOptions {
        // Fast grid for tests; production default is 0.01.
        OptimalOptions {
            step: 0.05,
            lambda_steps: 41,
            refine_passes: 2,
        }
    }

    fn min_value(s: &Scenario, f: &Fractional) -> f64 {
        fractional::sum_values(s, f)
            .into_iter()
            .fold(f64::INFINITY, f64::min)
    }

    #[test]
    fn output_feasible() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        let f = assign(&s, &coarse());
        assert!(f.is_feasible());
    }

    #[test]
    fn beats_or_matches_algorithm4() {
        // The grid optimum must dominate the greedy heuristic (up to grid
        // resolution).
        for seed in 0..4 {
            let s = Scenario::small_scale(seed, 2.0, CommModel::Stochastic);
            let vm = ValueMatrix::new(&s, ValueModel::Markov);
            let d = dedicated_iter::assign(&vm, &Default::default());
            let greedy = fractional::assign(&s, &d, &FracOptions::default());
            let opt = assign(&s, &coarse());
            let (g, o) = (min_value(&s, &greedy), min_value(&s, &opt));
            // The greedy splits resources continuously; a 0.05 grid can
            // concede a little resolution. Production runs use step 0.01.
            assert!(
                o >= g * 0.97,
                "seed {seed}: optimal {o} < greedy {g}"
            );
        }
    }

    #[test]
    fn no_resource_left_unused() {
        // k1 + k2 = 1 on every worker by construction.
        let s = Scenario::small_scale(2, 2.0, CommModel::Stochastic);
        let f = assign(&s, &coarse());
        for w in 0..s.n_workers() {
            assert!((f.k[0][w] + f.k[1][w] - 1.0).abs() < 1e-9);
            assert!((f.b[0][w] + f.b[1][w] - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    #[should_panic(expected = "M = 2")]
    fn rejects_more_masters() {
        let s = Scenario::large_scale(1, 2.0, CommModel::Stochastic);
        assign(&s, &coarse());
    }
}
