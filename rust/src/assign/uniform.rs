//! §V benchmarks 1–2: uniform worker assignment.
//!
//! Both benchmarks give every master an equal block of `N/M` workers
//! (round-robin blocks, no value information):
//!
//! 1. **Uncoded**: `A_m` split equally over the `N/M` workers, no coding,
//!    no local computation — completion needs ALL workers to finish.
//! 2. **Coded**: the scheme of Reisizadeh et al. [5] — Theorem-2 load
//!    allocation over {local} ∪ workers, using computation delay only
//!    (this benchmark ignores the communication leg by design; that is
//!    exactly the gap Figs. 4–6 expose).

use super::Dedicated;

/// Block-uniform dedicated assignment: worker `w` serves master
/// `w·M/N`-ish so each master receives `⌊N/M⌋` or `⌈N/M⌉` workers.
pub fn assign(n_masters: usize, n_workers: usize) -> Dedicated {
    assert!(n_masters > 0);
    let owner = (0..n_workers)
        .map(|w| w * n_masters / n_workers.max(1))
        .map(|m| m.min(n_masters - 1))
        .collect();
    Dedicated { owner }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_blocks_when_divisible() {
        let d = assign(4, 52);
        for m in 0..4 {
            assert_eq!(d.workers_of(m).len(), 13, "master {m}");
        }
    }

    #[test]
    fn near_equal_when_not_divisible() {
        let d = assign(3, 10);
        let sizes: Vec<usize> = (0..3).map(|m| d.workers_of(m).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s == 3 || s == 4), "{sizes:?}");
    }

    #[test]
    fn blocks_are_contiguous() {
        let d = assign(2, 6);
        assert_eq!(d.owner, vec![0, 0, 0, 1, 1, 1]);
    }

    #[test]
    fn single_master() {
        let d = assign(1, 5);
        assert!(d.owner.iter().all(|&m| m == 0));
    }
}
