//! Shared figure types + helpers.
//!
//! Since the sweep rewrite, each figure is a thin declaration: its cells
//! live in [`crate::experiment::catalog`] as a `SweepSpec`, [`sweep`]
//! runs them on the batched engine, and the figure module only formats
//! tables/JSON from the returned cells. [`evaluate`] remains as the
//! serial single-spec path (and as the legacy fixture the golden-parity
//! tests compare the sweeps against).

use crate::config::Scenario;
use crate::experiment::{self, catalog, CellResult, SweepOptions, SweepResult};
use crate::plan::Plan;
use crate::policy::PolicySpec;
use crate::sim::{self, McOptions, McResults};
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::table::Table;

pub use crate::experiment::catalog::roster;

/// Harness options shared by all figures.
#[derive(Clone, Copy, Debug)]
pub struct FigureOptions {
    /// Monte-Carlo trials per evaluated plan (paper: 10⁶; default 10⁵ —
    /// the reported shapes are stable from ~10⁴).
    pub trials: usize,
    pub seed: u64,
    /// Samples per trace in Fig. 7 (paper: 10⁶).
    pub fit_samples: usize,
    /// Threads for the MC engine (0 = all cores).
    pub threads: usize,
}

impl Default for FigureOptions {
    fn default() -> Self {
        Self {
            trials: 100_000,
            seed: 2022,
            fit_samples: 200_000,
            threads: 0,
        }
    }
}

impl FigureOptions {
    pub fn mc(&self, keep_samples: bool) -> McOptions {
        McOptions {
            trials: self.trials,
            seed: catalog::fig_mc_seed(self.seed),
            keep_samples,
            threads: self.threads,
            ziggurat: false,
        }
    }
}

/// A regenerated figure: captioned tables + JSON export.
#[derive(Clone, Debug)]
pub struct Figure {
    pub id: String,
    pub title: String,
    pub tables: Vec<(String, Table)>,
    pub json: Json,
}

impl Figure {
    pub fn new(id: &str, title: &str) -> Self {
        let mut json = Json::obj();
        json.set("id", Json::Str(id.into()));
        json.set("title", Json::Str(title.into()));
        Self {
            id: id.into(),
            title: title.into(),
            tables: Vec::new(),
            json,
        }
    }

    pub fn add_table(&mut self, caption: &str, table: Table) {
        self.tables.push((caption.to_string(), table));
    }

    pub fn render(&self) -> String {
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        for (caption, t) in &self.tables {
            out.push_str(&format!("\n-- {caption} --\n"));
            out.push_str(&t.render());
        }
        out
    }

    /// Write `<id>.json` and `<id>.txt` into `dir`.
    pub fn save(&self, dir: &str) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            format!("{dir}/{}.json", self.id),
            self.json.to_string_pretty(),
        )?;
        std::fs::write(format!("{dir}/{}.txt", self.id), self.render())?;
        Ok(())
    }
}

/// Run one catalog sweep with this figure's options on the batched
/// engine. Panics on failure — catalog ids are library-internal and a
/// broken one is a bug, matching the figures' historical behavior.
pub fn sweep(id: &str, opts: &FigureOptions) -> SweepResult {
    let spec = catalog::spec(id, opts.trials, opts.seed)
        .unwrap_or_else(|e| panic!("catalog spec '{id}': {e}"));
    experiment::run_sweep(
        &spec,
        &SweepOptions {
            threads: opts.threads,
            cell_streams: opts.threads,
            fused: false,
        },
    )
    .unwrap_or_else(|e| panic!("sweep '{id}': {e}"))
}

/// One evaluated algorithm: label + plan + Monte-Carlo results.
pub struct Evaluated {
    pub label: String,
    pub plan: Plan,
    pub results: McResults,
}

/// Build + evaluate one registry-resolved policy spec serially (the
/// pre-sweep evaluation path, kept as the single-spec API and as the
/// golden-parity fixture for the batched engine).
pub fn evaluate(
    s: &Scenario,
    spec: &PolicySpec,
    opts: &FigureOptions,
    keep_samples: bool,
) -> Evaluated {
    let plan = spec
        .build(s)
        .unwrap_or_else(|e| panic!("figure spec failed to resolve: {e}"));
    let results = sim::run(s, &plan, &opts.mc(keep_samples));
    Evaluated {
        label: plan.label.clone(),
        plan,
        results,
    }
}

/// JSON record for one evaluated result — the stable five-key figure
/// schema, shared by the sweep and serial paths.
fn result_record(label: &str, system: &Summary, per_master: &[Summary], t_est: f64) -> Json {
    let mut j = Json::obj();
    j.set("label", Json::Str(label.to_string()));
    j.set("mean_system_delay_ms", Json::Num(system.mean()));
    j.set("sem_ms", Json::Num(system.sem()));
    j.set("t_est_ms", Json::Num(t_est));
    j.set(
        "per_master_mean_ms",
        Json::from_f64_slice(&per_master.iter().map(|s| s.mean()).collect::<Vec<_>>()),
    );
    j
}

/// JSON record for one algorithm's serially evaluated MC outcome.
pub fn result_json(e: &Evaluated) -> Json {
    result_record(
        &e.label,
        &e.results.system,
        &e.results.per_master,
        e.plan.t_est(),
    )
}

/// JSON record for one sweep cell's outcome — same keys as
/// [`result_json`] so figure JSON is stable across the sweep rewrite.
pub fn result_json_cell(c: &CellResult) -> Json {
    result_record(
        &c.outcome.label,
        &c.outcome.system,
        &c.outcome.per_master,
        c.outcome.t_est_ms,
    )
}
