//! Shared figure types + helpers.
//!
//! Figures resolve strategies through [`crate::policy::registry`] by
//! name — a policy registered at runtime is immediately addressable from
//! [`roster`]-style spec lists with no figure-code edits.

use crate::assign::ValueModel;
use crate::config::Scenario;
use crate::plan::Plan;
use crate::policy::PolicySpec;
use crate::sim::{self, McOptions, McResults};
use crate::util::json::Json;
use crate::util::table::Table;

/// Harness options shared by all figures.
#[derive(Clone, Copy, Debug)]
pub struct FigureOptions {
    /// Monte-Carlo trials per evaluated plan (paper: 10⁶; default 10⁵ —
    /// the reported shapes are stable from ~10⁴).
    pub trials: usize,
    pub seed: u64,
    /// Samples per trace in Fig. 7 (paper: 10⁶).
    pub fit_samples: usize,
    /// Threads for the MC engine (0 = all cores).
    pub threads: usize,
}

impl Default for FigureOptions {
    fn default() -> Self {
        Self {
            trials: 100_000,
            seed: 2022,
            fit_samples: 200_000,
            threads: 0,
        }
    }
}

impl FigureOptions {
    pub fn mc(&self, keep_samples: bool) -> McOptions {
        McOptions {
            trials: self.trials,
            seed: self.seed ^ 0x5EED,
            keep_samples,
            threads: self.threads,
        }
    }
}

/// A regenerated figure: captioned tables + JSON export.
#[derive(Clone, Debug)]
pub struct Figure {
    pub id: String,
    pub title: String,
    pub tables: Vec<(String, Table)>,
    pub json: Json,
}

impl Figure {
    pub fn new(id: &str, title: &str) -> Self {
        let mut json = Json::obj();
        json.set("id", Json::Str(id.into()));
        json.set("title", Json::Str(title.into()));
        Self {
            id: id.into(),
            title: title.into(),
            tables: Vec::new(),
            json,
        }
    }

    pub fn add_table(&mut self, caption: &str, table: Table) {
        self.tables.push((caption.to_string(), table));
    }

    pub fn render(&self) -> String {
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        for (caption, t) in &self.tables {
            out.push_str(&format!("\n-- {caption} --\n"));
            out.push_str(&t.render());
        }
        out
    }

    /// Write `<id>.json` and `<id>.txt` into `dir`.
    pub fn save(&self, dir: &str) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            format!("{dir}/{}.json", self.id),
            self.json.to_string_pretty(),
        )?;
        std::fs::write(format!("{dir}/{}.txt", self.id), self.render())?;
        Ok(())
    }
}

/// One evaluated algorithm: label + plan + Monte-Carlo results.
pub struct Evaluated {
    pub label: String,
    pub plan: Plan,
    pub results: McResults,
}

/// Build + evaluate one registry-resolved policy spec.
pub fn evaluate(
    s: &Scenario,
    spec: &PolicySpec,
    opts: &FigureOptions,
    keep_samples: bool,
) -> Evaluated {
    let plan = spec
        .build(s)
        .unwrap_or_else(|e| panic!("figure spec failed to resolve: {e}"));
    let results = sim::run(s, &plan, &opts.mc(keep_samples));
    Evaluated {
        label: plan.label.clone(),
        plan,
        results,
    }
}

/// The §V-B algorithm roster (Fig. 4/5/6/8 legends), by registry name.
/// `small_scale` adds the λ-sweep optimum (M = 2 only). `values`/`loads`
/// configure the proposed algorithms (Markov for the general case,
/// "exact" for computation-dominant scenarios like Fig. 8).
pub fn roster(small_scale: bool, values: ValueModel, loads: &str) -> Vec<PolicySpec> {
    let mut specs = vec![
        PolicySpec::new("uncoded", values, loads),
        PolicySpec::new("coded", values, loads),
        PolicySpec::new("dedi-simple", values, loads),
        PolicySpec::new("dedi-iter", values, loads),
        PolicySpec::new("dedi-iter", values, "sca"),
        PolicySpec::new("frac", values, loads),
        PolicySpec::new("frac", values, "sca"),
    ];
    if small_scale {
        specs.push(PolicySpec::new("optimal", values, "sca"));
    }
    specs
}

/// JSON record for one algorithm's MC outcome.
pub fn result_json(e: &Evaluated) -> Json {
    let mut j = Json::obj();
    j.set("label", Json::Str(e.label.clone()));
    j.set("mean_system_delay_ms", Json::Num(e.results.system.mean()));
    j.set("sem_ms", Json::Num(e.results.system.sem()));
    j.set("t_est_ms", Json::Num(e.plan.t_est()));
    j.set(
        "per_master_mean_ms",
        Json::from_f64_slice(
            &e.results
                .per_master
                .iter()
                .map(|s| s.mean())
                .collect::<Vec<_>>(),
        ),
    );
    j
}
