//! Fig. 5: CDF of the task completion delay (the P1 view of the P2
//! solutions) with the ρ_s = 0.95 readouts the paper quotes
//! (SCA-dedi 0.658 s < dedi 0.694 s < coded 0.957 s in 5(b)).

use super::common::{evaluate, Figure, FigureOptions};
use crate::assign::ValueModel;
use crate::config::{CommModel, Scenario};
use crate::policy::PolicySpec;
use crate::util::json::Json;
use crate::util::stats::Ecdf;
use crate::util::table::Table;

fn specs() -> Vec<PolicySpec> {
    let v = ValueModel::Markov;
    vec![
        PolicySpec::new("coded", v, "markov"),
        PolicySpec::new("dedi-iter", v, "markov"),
        PolicySpec::new("dedi-iter", v, "sca"),
        PolicySpec::new("frac", v, "sca"),
    ]
}

fn cdf_panel(fig: &mut Figure, tag: &str, s: &Scenario, opts: &FigureOptions) {
    let mut rows = Vec::new();
    let mut series = Vec::new();
    for spec in specs() {
        let e = evaluate(s, &spec, opts, true);
        let ecdf: Ecdf = e.results.system_ecdf().unwrap();
        rows.push((e.label.clone(), ecdf));
    }
    let mut t = Table::new(&["algorithm", "t @ ρ=0.5 (ms)", "t @ ρ=0.9", "t @ ρ=0.95", "t @ ρ=0.99"]);
    for (label, ecdf) in &rows {
        t.row_fmt(
            label,
            &[
                ecdf.inverse(0.5),
                ecdf.inverse(0.9),
                ecdf.inverse(0.95),
                ecdf.inverse(0.99),
            ],
            3,
        );
        let mut j = Json::obj();
        j.set("label", Json::Str(label.clone()));
        j.set("rho95_ms", Json::Num(ecdf.inverse(0.95)));
        j.set("cdf", Json::from_pairs(&ecdf.series(64)));
        series.push(j);
    }
    fig.add_table(&format!("({tag}) completion-delay quantiles"), t);
    fig.json.set(&format!("series_{tag}"), Json::Arr(series));
}

pub fn run(opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new("fig5", "CDF of task completion delay (ρ_s readouts)");
    let sa = Scenario::small_scale(opts.seed, 2.0, CommModel::Stochastic);
    let sb = Scenario::large_scale(opts.seed, 2.0, CommModel::Stochastic);
    cdf_panel(&mut fig, "a", &sa, opts);
    cdf_panel(&mut fig, "b", &sb, opts);
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rho95_ordering_matches_paper() {
        let fig = run(&FigureOptions {
            trials: 4_000,
            seed: 4,
            fit_samples: 1_000,
            threads: 0,
        });
        // Panel (b): SCA-dedi ≤ dedi ≤ coded at ρ_s = 0.95.
        let series = fig.json.get("series_b").unwrap().as_arr().unwrap();
        let rho = |label: &str| {
            series
                .iter()
                .find(|j| j.get("label").unwrap().as_str() == Some(label))
                .unwrap()
                .get("rho95_ms")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        let coded = rho("Coded [5]");
        let dedi = rho("Dedi, iter");
        let sca = rho("Dedi, iter + SCA");
        assert!(dedi < coded, "dedi {dedi} ≥ coded {coded}");
        assert!(sca <= dedi * 1.02, "sca {sca} > dedi {dedi}");
        // Paper: >30% reduction vs coded at ρ_s = 0.95.
        assert!(
            sca < coded * 0.85,
            "ρ95 reduction too small: {sca} vs {coded}"
        );
    }
}
