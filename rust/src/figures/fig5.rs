//! Fig. 5: CDF of the task completion delay (the P1 view of the P2
//! solutions) with the ρ_s = 0.95 readouts the paper quotes
//! (SCA-dedi 0.658 s < dedi 0.694 s < coded 0.957 s in 5(b)).
//!
//! Panels are the catalog sweeps "fig5a" (small scale) and "fig5b"
//! (large scale), samples kept for the CDFs.

use super::common::{sweep, Figure, FigureOptions};
use crate::util::json::Json;
use crate::util::stats::Ecdf;
use crate::util::table::Table;

fn cdf_panel(fig: &mut Figure, tag: &str, id: &str, opts: &FigureOptions) {
    let result = sweep(id, opts);
    // Consume the cells: the sample vectors move straight into the
    // ECDFs (no copy), which at CDF trial counts is the panel's largest
    // allocation.
    let rows: Vec<(String, Ecdf)> = result
        .cells
        .into_iter()
        .map(|c| {
            (
                c.outcome.label,
                Ecdf::new(c.outcome.samples.expect("samples kept")),
            )
        })
        .collect();
    let mut series = Vec::new();
    let mut t = Table::new(&["algorithm", "t @ ρ=0.5 (ms)", "t @ ρ=0.9", "t @ ρ=0.95", "t @ ρ=0.99"]);
    for (label, ecdf) in &rows {
        t.row_fmt(
            label,
            &[
                ecdf.inverse(0.5),
                ecdf.inverse(0.9),
                ecdf.inverse(0.95),
                ecdf.inverse(0.99),
            ],
            3,
        );
        let mut j = Json::obj();
        j.set("label", Json::Str(label.clone()));
        j.set("rho95_ms", Json::Num(ecdf.inverse(0.95)));
        j.set("cdf", Json::from_pairs(&ecdf.series(64)));
        series.push(j);
    }
    fig.add_table(&format!("({tag}) completion-delay quantiles"), t);
    fig.json.set(&format!("series_{tag}"), Json::Arr(series));
}

pub fn run(opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new("fig5", "CDF of task completion delay (ρ_s readouts)");
    cdf_panel(&mut fig, "a", "fig5a", opts);
    cdf_panel(&mut fig, "b", "fig5b", opts);
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    /// SCA-dedi may tie dedi at ρ95 (same assignment, nearby loads);
    /// allow a 2% band for the CRN-paired quantile noise at 4 000
    /// samples (quantile sem ≈ 1/(f(q)·√n) ≲ 1.5% here).
    const SCA_VS_DEDI_SLACK: f64 = 1.02;

    /// Paper: >30% ρ95 reduction vs the coded benchmark in 5(b). The
    /// 15% floor is half the reported effect — quantile noise at 4 000
    /// samples is ~1.5%, so a breach means a real regression.
    const SCA_VS_CODED_MAX_RATIO: f64 = 0.85;

    #[test]
    fn rho95_ordering_matches_paper() {
        // Seed + streams pinned ⇒ machine-independent quantiles; see the
        // fig2 test module note on the PR-1 flake risk.
        let fig = run(&FigureOptions {
            trials: 4_000,
            seed: 4,
            fit_samples: 1_000,
            threads: 1,
        });
        // Panel (b): SCA-dedi ≤ dedi ≤ coded at ρ_s = 0.95.
        let series = fig.json.get("series_b").unwrap().as_arr().unwrap();
        let rho = |label: &str| {
            series
                .iter()
                .find(|j| j.get("label").unwrap().as_str() == Some(label))
                .unwrap()
                .get("rho95_ms")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        let coded = rho("Coded [5]");
        let dedi = rho("Dedi, iter");
        let sca = rho("Dedi, iter + SCA");
        assert!(dedi < coded, "dedi {dedi} ≥ coded {coded}");
        assert!(sca <= dedi * SCA_VS_DEDI_SLACK, "sca {sca} > dedi {dedi}");
        assert!(
            sca < coded * SCA_VS_CODED_MAX_RATIO,
            "ρ95 reduction too small: {sca} vs {coded}"
        );
    }
}
