//! Ablation studies for the design choices DESIGN.md calls out, plus the
//! paper's named future-work extension. Driven by
//! `coded-coop ablation <id>`.
//!
//! | id | question |
//! |---|---|
//! | `redundancy` | how much coding overhead does the delay/robustness trade-off actually need? (Thm 1 fixes 2×, Thm 2 ~1.2–1.5×) |
//! | `multimsg` | the §VI future-work extension: chunked worker returns vs per-message overhead ([20]'s trade-off) |
//! | `straggler` | sensitivity of the Fig. 8 headline to the burst-throttling mixture (prob × slowdown grid) |
//! | `sca_step` | SCA step rule: paper's diminishing γ vs DCA full step (quality + iterations) |
//!
//! `redundancy` and `straggler` are plan→simulate grids and run as
//! catalog sweeps ("ablation_redundancy" / "ablation_straggler") on the
//! batched engine — the `overhead` axis and the zipped `(straggler_prob,
//! straggler_slow)` axis replace the hand-rolled loops. `multimsg` (its
//! own chunked-return engine) and `sca_step` (no simulation at all)
//! are not sweep cells and stay bespoke.

use super::common::{sweep, Figure, FigureOptions};
use crate::alloc::{markov, sca, EffLink};
use crate::assign::ValueModel;
use crate::config::{CommModel, Scenario};
use crate::plan;
use crate::policy::PolicySpec;
use crate::sim::multimsg;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Ecdf;
use crate::util::table::Table;

pub const ALL_IDS: &[&str] = &["redundancy", "multimsg", "straggler", "sca_step"];

pub fn run(id: &str, opts: &FigureOptions) -> anyhow::Result<Figure> {
    match id {
        "redundancy" => Ok(redundancy(opts)),
        "multimsg" => Ok(multimsg_ablation(opts)),
        "straggler" => Ok(straggler(opts)),
        "sca_step" => Ok(sca_step(opts)),
        other => anyhow::bail!("unknown ablation '{other}' (expected {ALL_IDS:?})"),
    }
}

fn base_plan(s: &Scenario) -> plan::Plan {
    PolicySpec::new("dedi-iter", ValueModel::Markov, "markov")
        .build(s)
        .expect("built-in policy resolves")
}

fn redundancy(opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new(
        "ablation_redundancy",
        "coding overhead β vs mean delay and ρ=0.95 tail (large scale)",
    );
    let result = sweep("ablation_redundancy", opts);
    let mut t = Table::new(&["overhead β", "mean delay (ms)", "ρ=0.95 (ms)"]);
    let mut arr = Vec::new();
    for c in result.cells {
        let beta = c.overhead.expect("redundancy sweep sets overhead");
        // Consuming iteration: the sample vector moves into the ECDF.
        let rho = Ecdf::new(c.outcome.samples.expect("samples kept")).inverse(0.95);
        t.row_fmt(&format!("{beta:.2}"), &[c.outcome.system.mean(), rho], 3);
        let mut j = Json::obj();
        j.set("beta", Json::Num(beta));
        j.set("mean_ms", Json::Num(c.outcome.system.mean()));
        j.set("rho95_ms", Json::Num(rho));
        arr.push(j);
    }
    fig.add_table(
        "β sweep (loads rescaled from the Theorem-1 plan; β=2 is Thm 1's own overhead)",
        t,
    );
    fig.json.set("series", Json::Arr(arr));
    fig
}

fn multimsg_ablation(opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new(
        "ablation_multimsg",
        "multi-message returns: chunks × per-message overhead (§VI future work)",
    );
    let s = Scenario::small_scale(opts.seed, 2.0, CommModel::Stochastic);
    let p = base_plan(&s);
    let overheads = [0.0, 10.0, 50.0, 200.0];
    let chunk_counts = [1usize, 2, 4, 8, 16];
    let mut header = vec!["chunks".to_string()];
    header.extend(overheads.iter().map(|o| format!("ovh={o} ms")));
    let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(&hdr);
    let mut arr = Vec::new();
    for &c in &chunk_counts {
        let mut row = Vec::new();
        for &o in &overheads {
            let r = multimsg::run(
                &s,
                &p,
                &multimsg::MultiMsgOptions {
                    chunks: c,
                    overhead_ms: o,
                    trials: opts.trials.min(30_000),
                    seed: opts.seed,
                },
            );
            row.push(r.mean());
        }
        let mut j = Json::obj();
        j.set("chunks", Json::Num(c as f64));
        j.set("mean_ms", Json::from_f64_slice(&row));
        arr.push(j);
        t.row_fmt(&format!("{c}"), &row, 1);
    }
    fig.add_table("mean system delay (ms), small scale, Dedi-iter plan", t);
    fig.json.set("series", Json::Arr(arr));
    fig
}

fn straggler(opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new(
        "ablation_straggler",
        "Fig. 8 headline sensitivity to the t2 burst-throttling mixture",
    );
    let result = sweep("ablation_straggler", opts);
    let mut t = Table::new(&[
        "prob × slowdown",
        "Uncoded (ms)",
        "Dedi, iter (ms)",
        "reduction",
    ]);
    let mut arr = Vec::new();
    // Grid order: one (prob, slowdown) point per chunk, policies
    // [uncoded, dedi-iter] innermost.
    for pair in result.cells.chunks(2) {
        let (unc, ded) = (&pair[0], &pair[1]);
        let prob = unc.axis("straggler_prob").expect("zipped axis");
        let slow = unc.axis("straggler_slow").expect("zipped axis");
        let (u_mean, d_mean) = (unc.outcome.system.mean(), ded.outcome.system.mean());
        let red = 100.0 * (1.0 - d_mean / u_mean);
        t.row_fmt(
            &format!("{prob:.2} × {slow:.0}"),
            &[u_mean, d_mean, red],
            1,
        );
        let mut j = Json::obj();
        j.set("prob", Json::Num(prob));
        j.set("slowdown", Json::Num(slow));
        j.set("reduction_pct", Json::Num(red));
        arr.push(j);
    }
    fig.add_table(
        "paper headline 82%; production mixture (0.02 × 20) marked in EXPERIMENTS.md",
        t,
    );
    fig.json.set("series", Json::Arr(arr));
    fig
}

fn sca_step(opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new(
        "ablation_sca_step",
        "SCA outer step: paper's diminishing γ (α=0.995) vs DCA full step",
    );
    let mut rng = Rng::new(opts.seed);
    let mut t = Table::new(&["N", "t* DCA (ms)", "t* diminishing (ms)", "rel gap"]);
    let mut arr = Vec::new();
    for n in [4usize, 8, 16, 50] {
        let links: Vec<EffLink> = (0..n)
            .map(|_| {
                let a = rng.range(0.05, 0.5);
                let u = 1.0 / a;
                EffLink::dedicated(&crate::model::params::LinkParams::new(2.0 * u, a, u))
            })
            .collect();
        let l_rows = 1e4;
        let thetas: Vec<f64> = links.iter().map(EffLink::theta).collect();
        let start = markov::allocate(&thetas, l_rows);
        let dca = sca::enhance(&links, l_rows, &start, &Default::default());
        let dim = sca::enhance(
            &links,
            l_rows,
            &start,
            &sca::ScaOptions {
                step_rule: sca::StepRule::Diminishing,
                ..Default::default()
            },
        );
        let gap = (dca.t_star - dim.t_star).abs() / dim.t_star;
        t.row_fmt(
            &format!("{n}"),
            &[dca.t_star, dim.t_star, gap],
            6,
        );
        let mut j = Json::obj();
        j.set("n", Json::Num(n as f64));
        j.set("gap", Json::Num(gap));
        arr.push(j);
    }
    fig.add_table("same stationary point (see §Perf for the 20× speed gap)", t);
    fig.json.set("series", Json::Arr(arr));
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Seed + streams pinned ⇒ machine-independent values; see the fig2
    /// test module note on the PR-1 flake risk.
    fn fast() -> FigureOptions {
        FigureOptions {
            trials: 1_500,
            seed: 13,
            fit_samples: 1_000,
            threads: 1,
        }
    }

    /// β=3 over-redundancy penalty: every node carries 1.5× the rows of
    /// the best-β plan, so its mean must exceed the sweep's best by well
    /// over the CRN-shared noise; 5% is ~¼ of the structural effect.
    const OVERRED_MIN_PENALTY: f64 = 1.05;

    /// DCA and diminishing step converge to the same stationary point;
    /// 1% covers the looser diminishing-step termination.
    const STEP_RULE_MAX_GAP: f64 = 1e-2;

    #[test]
    fn all_ablations_smoke() {
        for id in ALL_IDS {
            let fig = run(id, &fast()).unwrap();
            assert!(!fig.tables.is_empty(), "{id}");
        }
        assert!(run("nope", &fast()).is_err());
    }

    #[test]
    fn redundancy_tradeoff_shape() {
        // Too little redundancy hurts the tail; huge redundancy hurts the
        // mean (each node carries more rows). Mean at β=3 must exceed the
        // best mean in the sweep.
        let fig = redundancy(&fast());
        let series = fig.json.get("series").unwrap().as_arr().unwrap();
        let means: Vec<f64> = series
            .iter()
            .map(|j| j.get("mean_ms").unwrap().as_f64().unwrap())
            .collect();
        let best = means.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            means.last().unwrap() > &(best * OVERRED_MIN_PENALTY),
            "{means:?}"
        );
    }

    #[test]
    fn straggler_grid_shape() {
        let fig = straggler(&fast());
        let series = fig.json.get("series").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), 6);
        // the clean point (prob 0) reduces least; heavy throttling most
        let red = |i: usize| series[i].get("reduction_pct").unwrap().as_f64().unwrap();
        assert!(red(3) > red(0), "throttling should amplify the coding win");
    }

    #[test]
    fn sca_step_rules_agree_across_sizes() {
        let fig = sca_step(&fast());
        for j in fig.json.get("series").unwrap().as_arr().unwrap() {
            let gap = j.get("gap").unwrap().as_f64().unwrap();
            assert!(gap < STEP_RULE_MAX_GAP, "gap {gap}");
        }
    }
}
