//! Fig. 3: validation of the Markov-inequality approximation, large scale
//! (M = 4, N = 50, computation-dominant). Same driver as Fig. 2.

use super::common::{Figure, FigureOptions};
use super::fig2;
use crate::config::{CommModel, Scenario};

pub fn run(opts: &FigureOptions) -> Figure {
    let s = Scenario::large_scale(opts.seed, 2.0, CommModel::CompDominant);
    fig2::validation(
        "fig3",
        "Markov-approximation validation, 4 masters × 50 workers",
        &s,
        opts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn large_scale_enhanced_close_to_exact() {
        let fig = run(&FigureOptions {
            trials: 1_000,
            seed: 2,
            fit_samples: 1_000,
            threads: 0,
        });
        let arr = fig.json.get("results").unwrap().as_arr().unwrap();
        let mean = |i: usize| {
            arr[i]
                .get("mean_system_delay_ms")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        let (exact, enhanced) = (mean(0), mean(2));
        assert!(
            (enhanced - exact).abs() / exact < 0.05,
            "enhanced {enhanced} vs exact {exact}"
        );
        // Large scale: ~12 workers per master at L = 10⁴ rows lands in
        // the paper's few-hundred-ms range (Fig. 5b shows ~0.6 s tails).
        assert!(
            (50.0..1500.0).contains(&exact),
            "exact delay {exact} ms outside the paper's range"
        );
    }
}
