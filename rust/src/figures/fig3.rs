//! Fig. 3: validation of the Markov-inequality approximation, large scale
//! (M = 4, N = 50, computation-dominant). Same driver as Fig. 2, cells
//! declared under catalog id "fig3".

use super::common::{Figure, FigureOptions};
use super::fig2;

pub fn run(opts: &FigureOptions) -> Figure {
    fig2::validation(
        "fig3",
        "Markov-approximation validation, 4 masters × 50 workers",
        opts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// |enhanced − exact| / exact bound. 1 000 CRN trials at large scale:
    /// relative sem ≈ cv/√1000 ≈ 0.3/31.6 ≈ 1% per mean, the paired
    /// (shared-seed) difference tighter still; 5% ≈ 5σ unpaired.
    const ENHANCED_VS_EXACT_RTOL: f64 = 0.05;

    #[test]
    fn large_scale_enhanced_close_to_exact() {
        // Seed + streams pinned: the sampled values are machine-
        // independent, so this is an exact regression gate (see the
        // fig2 test module note on the PR-1 flake risk).
        let fig = run(&FigureOptions {
            trials: 1_000,
            seed: 2,
            fit_samples: 1_000,
            threads: 1,
        });
        let arr = fig.json.get("results").unwrap().as_arr().unwrap();
        let mean = |i: usize| {
            arr[i]
                .get("mean_system_delay_ms")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        let (exact, enhanced) = (mean(0), mean(2));
        assert!(
            (enhanced - exact).abs() / exact < ENHANCED_VS_EXACT_RTOL,
            "enhanced {enhanced} vs exact {exact}"
        );
        // Large scale: ~12 workers per master at L = 10⁴ rows lands in
        // the paper's few-hundred-ms range (Fig. 5b shows ~0.6 s tails).
        assert!(
            (50.0..1500.0).contains(&exact),
            "exact delay {exact} ms outside the paper's range"
        );
    }
}
