//! Fig. 2: validation of the Markov-inequality approximation, small scale
//! (M = 2, N = 5, computation-dominant).
//!
//! Three solutions, all with Algorithm-1 dedicated assignment:
//! * **Exact** — Theorem-2 values + Theorem-2 loads (optimal for P3);
//! * **Approx** — Theorem-1 (Markov) values + loads;
//! * **Approx, enhanced** — assignment from the approximation, loads
//!   re-solved with Theorem 2 (the §III-D enhancement specialized to the
//!   computation-dominant case, as the paper does for this figure).
//!
//! The cells are declared in [`crate::experiment::catalog`] (ids "fig2" /
//! "fig3") and run on the batched sweep engine.

use super::common::{result_json_cell, sweep, Figure, FigureOptions};
use crate::experiment::catalog;
use crate::policy::PolicySpec;
use crate::util::json::Json;
use crate::util::stats::Ecdf;
use crate::util::table::Table;

/// The three validation variants (registry-resolved; declared in the
/// sweep catalog).
pub fn variants() -> Vec<(&'static str, PolicySpec)> {
    catalog::validation_variants()
}

/// Shared driver for Figs. 2 and 3: run the catalog sweep of `id` and
/// format its three cells.
pub fn validation(id: &str, title: &str, opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new(id, title);
    let result = sweep(id, opts);
    let names: Vec<&'static str> = variants().into_iter().map(|(n, _)| n).collect();
    assert_eq!(result.cells.len(), names.len(), "{id}: unexpected grid");
    let n_masters = result.cells[0].outcome.per_master.len();

    // (a) average task completion delay per master + all-tasks max.
    let mut header: Vec<String> = vec!["solution".into()];
    header.extend((0..n_masters).map(|m| format!("master {} (ms)", m + 1)));
    header.push("all tasks (ms)".into());
    let hdr_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut ta = Table::new(&hdr_refs);
    let mut results = Vec::new();
    for (name, c) in names.iter().zip(&result.cells) {
        let mut vals: Vec<f64> = c.outcome.per_master.iter().map(|s| s.mean()).collect();
        vals.push(c.outcome.system.mean());
        ta.row_fmt(name, &vals, 3);
        let mut j = result_json_cell(c);
        j.set("name", Json::Str(name.to_string()));
        results.push(j);
    }
    fig.add_table("(a) average task completion delay", ta);

    // (b) CDF of the all-tasks completion delay.
    let mut tb = Table::new(&["P[T ≤ t]", "Exact (ms)", "Approx (ms)", "Approx, enhanced (ms)"]);
    // Last use of the cells: consume them so the sample vectors move
    // straight into the ECDFs (no copy).
    let ecdfs: Vec<Ecdf> = result
        .cells
        .into_iter()
        .map(|c| Ecdf::new(c.outcome.samples.expect("sweep keeps samples")))
        .collect();
    let mut series = Vec::new();
    for p in [0.05, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99] {
        let vals: Vec<f64> = ecdfs.iter().map(|e| e.inverse(p)).collect();
        tb.row_fmt(&format!("{p:.2}"), &vals, 3);
    }
    for (name, e) in names.iter().zip(&ecdfs) {
        let mut j = Json::obj();
        j.set("name", Json::Str(name.to_string()));
        j.set("cdf", Json::from_pairs(&e.series(64)));
        series.push(j);
    }
    fig.add_table("(b) CDF of task completion delay (quantiles)", tb);

    fig.json.set("results", Json::Arr(results));
    fig.json.set("cdf_series", Json::Arr(series));
    fig
}

pub fn run(opts: &FigureOptions) -> Figure {
    validation(
        "fig2",
        "Markov-approximation validation, 2 masters × 5 workers",
        opts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic test options. `threads` is PINNED (not 0 = "all
    /// cores"): the MC result depends bit-for-bit on how trials split
    /// across RNG streams, so an unpinned thread count made every
    /// statistical assertion here machine-dependent — the flake risk
    /// CHANGES.md PR 1 flagged. With seed and streams pinned, the
    /// sampled values are identical on every machine and the tolerances
    /// below are exact gates, not probabilistic ones.
    fn fast() -> FigureOptions {
        FigureOptions {
            trials: 2_000,
            seed: 1,
            fit_samples: 1_000,
            threads: 1,
        }
    }

    /// |enhanced − exact| / exact bound. Both variants share one MC seed
    /// (common random numbers), so the paired difference carries only
    /// the plan difference plus correlated noise. Each mean's relative
    /// sem at 2 000 trials is ≈ cv/√2000 ≈ 0.35/44.7 ≈ 0.8% (delay cv
    /// ≈ 0.35 on this scenario); 5% ≈ 6σ of even the UNpaired
    /// difference — headroom without admitting a real Exact/enhanced
    /// divergence (the paper's claim is that they coincide).
    const ENHANCED_VS_EXACT_RTOL: f64 = 0.05;

    /// Approx (Thm 1) may sit above Exact — the Markov bound is
    /// conservative — but within the paper's "acceptable gap": 2× is
    /// far above the observed ~1.1–1.3× and any 6σ noise band.
    const APPROX_VS_EXACT_FACTOR: f64 = 2.0;

    #[test]
    fn enhanced_tracks_exact() {
        // The paper's headline for Figs. 2–3: "Approx, enhanced" ≈ "Exact".
        let fig = run(&fast());
        let arr = fig.json.get("results").unwrap().as_arr().unwrap();
        let mean = |i: usize| {
            arr[i]
                .get("mean_system_delay_ms")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        let (exact, approx, enhanced) = (mean(0), mean(1), mean(2));
        assert!(
            (enhanced - exact).abs() / exact < ENHANCED_VS_EXACT_RTOL,
            "enhanced {enhanced} vs exact {exact}"
        );
        assert!(
            approx < APPROX_VS_EXACT_FACTOR * exact,
            "approx {approx} vs exact {exact}"
        );
    }

    #[test]
    fn tables_have_expected_shape() {
        let fig = run(&fast());
        assert_eq!(fig.tables.len(), 2);
        assert_eq!(fig.tables[0].1.n_rows(), 3); // three solutions
        assert_eq!(fig.tables[1].1.n_rows(), 8); // eight quantiles
    }
}
