//! Fig. 2: validation of the Markov-inequality approximation, small scale
//! (M = 2, N = 5, computation-dominant).
//!
//! Three solutions, all with Algorithm-1 dedicated assignment:
//! * **Exact** — Theorem-2 values + Theorem-2 loads (optimal for P3);
//! * **Approx** — Theorem-1 (Markov) values + loads;
//! * **Approx, enhanced** — assignment from the approximation, loads
//!   re-solved with Theorem 2 (the §III-D enhancement specialized to the
//!   computation-dominant case, as the paper does for this figure).

use super::common::{evaluate, Evaluated, Figure, FigureOptions};
use crate::assign::ValueModel;
use crate::config::{CommModel, Scenario};
use crate::policy::PolicySpec;
use crate::util::json::Json;
use crate::util::stats::Ecdf;
use crate::util::table::Table;

/// The three validation variants (registry-resolved).
pub fn variants() -> Vec<(&'static str, PolicySpec)> {
    vec![
        (
            "Exact (Thm 2)",
            PolicySpec::new("dedi-iter", ValueModel::Exact, "exact"),
        ),
        (
            "Approx (Thm 1)",
            PolicySpec::new("dedi-iter", ValueModel::Markov, "markov"),
        ),
        (
            "Approx, enhanced",
            PolicySpec::new("dedi-iter", ValueModel::Markov, "exact"),
        ),
    ]
}

/// Shared driver for Figs. 2 and 3.
pub fn validation(id: &str, title: &str, s: &Scenario, opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new(id, title);
    let evals: Vec<(&str, Evaluated)> = variants()
        .into_iter()
        .map(|(name, spec)| (name, evaluate(s, &spec, opts, true)))
        .collect();

    // (a) average task completion delay per master + all-tasks max.
    let mut header: Vec<String> = vec!["solution".into()];
    header.extend((0..s.n_masters()).map(|m| format!("master {} (ms)", m + 1)));
    header.push("all tasks (ms)".into());
    let hdr_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut ta = Table::new(&hdr_refs);
    let mut results = Vec::new();
    for (name, e) in &evals {
        let mut vals: Vec<f64> = e.results.per_master.iter().map(|s| s.mean()).collect();
        vals.push(e.results.system.mean());
        ta.row_fmt(name, &vals, 3);
        let mut j = super::common::result_json(e);
        j.set("name", Json::Str(name.to_string()));
        results.push(j);
    }
    fig.add_table("(a) average task completion delay", ta);

    // (b) CDF of the all-tasks completion delay.
    let mut tb = Table::new(&["P[T ≤ t]", "Exact (ms)", "Approx (ms)", "Approx, enhanced (ms)"]);
    let ecdfs: Vec<Ecdf> = evals
        .iter()
        .map(|(_, e)| e.results.system_ecdf().expect("samples kept"))
        .collect();
    let mut series = Vec::new();
    for p in [0.05, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99] {
        let vals: Vec<f64> = ecdfs.iter().map(|e| e.inverse(p)).collect();
        tb.row_fmt(&format!("{p:.2}"), &vals, 3);
    }
    for ((name, _), e) in evals.iter().zip(&ecdfs) {
        let mut j = Json::obj();
        j.set("name", Json::Str(name.to_string()));
        j.set("cdf", Json::from_pairs(&e.series(64)));
        series.push(j);
    }
    fig.add_table("(b) CDF of task completion delay (quantiles)", tb);

    fig.json.set("results", Json::Arr(results));
    fig.json.set("cdf_series", Json::Arr(series));
    fig
}

pub fn run(opts: &FigureOptions) -> Figure {
    let s = Scenario::small_scale(opts.seed, 2.0, CommModel::CompDominant);
    validation(
        "fig2",
        "Markov-approximation validation, 2 masters × 5 workers",
        &s,
        opts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> FigureOptions {
        FigureOptions {
            trials: 2_000,
            seed: 1,
            fit_samples: 1_000,
            threads: 0,
        }
    }

    #[test]
    fn enhanced_tracks_exact() {
        // The paper's headline for Figs. 2–3: "Approx, enhanced" ≈ "Exact".
        let fig = run(&fast());
        let arr = fig.json.get("results").unwrap().as_arr().unwrap();
        let mean = |i: usize| {
            arr[i]
                .get("mean_system_delay_ms")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        let (exact, approx, enhanced) = (mean(0), mean(1), mean(2));
        assert!(
            (enhanced - exact).abs() / exact < 0.05,
            "enhanced {enhanced} vs exact {exact}"
        );
        // Approx is within a reasonable factor (paper: "acceptable gap").
        assert!(approx < 2.0 * exact, "approx {approx} vs exact {exact}");
    }

    #[test]
    fn tables_have_expected_shape() {
        let fig = run(&fast());
        assert_eq!(fig.tables.len(), 2);
        assert_eq!(fig.tables[0].1.n_rows(), 3); // three solutions
        assert_eq!(fig.tables[1].1.n_rows(), 8); // eight quantiles
    }
}
