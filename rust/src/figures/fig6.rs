//! Fig. 6: impact of the communication rate — sweep γ/u while keeping u
//! fixed (large scale).
//!
//! (a) average task completion delay per algorithm;
//! (b) ratio of local load to total load `l_{m,0}/Σ_n l_{m,n}` — the
//!     benchmarks ignore communication so their ratio is flat; the
//!     proposed algorithms offload more as communication gets faster.
//!
//! The grid is the catalog sweep "fig6": a `gamma_ratio` axis rebinding
//! the scenario template's γ/u (same generation seed ⇒ identical
//! computation parameters, only γ varies) crossed with the 4-policy
//! roster.

use super::common::{sweep, Figure, FigureOptions};
use crate::experiment::catalog;
use crate::plan::Plan;
use crate::util::json::Json;
use crate::util::table::Table;

/// γ/u values swept (paper's x-axis; declared in the sweep catalog).
pub const RATIOS: &[f64] = catalog::FIG6_RATIOS;

/// Mean over masters of `l_{m,0} / Σ_n l_{m,n}`.
fn local_ratio(plan: &Plan) -> f64 {
    let per: Vec<f64> = plan
        .masters
        .iter()
        .map(|mp| {
            let local: f64 = mp
                .entries
                .iter()
                .filter(|e| e.node == 0)
                .map(|e| e.load)
                .sum();
            (local / mp.total_load()).max(0.0) // avoid `-0.0` for no-local plans
        })
        .collect();
    per.iter().sum::<f64>() / per.len() as f64
}

pub fn run(opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new(
        "fig6",
        "communication-rate sweep (γ/u), 4 masters × 50 workers",
    );
    let result = sweep("fig6", opts);
    let labels: Vec<String> = catalog::fig6_roster()
        .iter()
        .map(|sp| sp.label().expect("built-in roster resolves"))
        .collect();
    let n_pol = labels.len();
    assert_eq!(result.cells.len(), RATIOS.len() * n_pol, "unexpected grid");

    // Grid order: ratio outermost, policy innermost.
    let mut delay_rows: Vec<Vec<f64>> = vec![Vec::new(); n_pol];
    let mut ratio_rows: Vec<Vec<f64>> = vec![Vec::new(); n_pol];
    for (ci, c) in result.cells.iter().enumerate() {
        let pi = ci % n_pol;
        delay_rows[pi].push(c.outcome.system.mean());
        ratio_rows[pi].push(local_ratio(&c.plan));
    }

    let mut header = vec!["algorithm".to_string()];
    header.extend(RATIOS.iter().map(|r| format!("γ/u={r}")));
    let hdr: Vec<&str> = header.iter().map(String::as_str).collect();

    let mut ta = Table::new(&hdr);
    for (label, row) in labels.iter().zip(&delay_rows) {
        ta.row_fmt(label, row, 3);
    }
    fig.add_table("(a) average task completion delay (ms)", ta);

    let mut tb = Table::new(&hdr);
    for (label, row) in labels.iter().zip(&ratio_rows) {
        tb.row_fmt(label, row, 4);
    }
    fig.add_table("(b) local load / total load", tb);

    let mut arr = Vec::new();
    for (i, label) in labels.iter().enumerate() {
        let mut j = Json::obj();
        j.set("label", Json::Str(label.clone()));
        j.set("ratios", Json::from_f64_slice(RATIOS));
        j.set("mean_delay_ms", Json::from_f64_slice(&delay_rows[i]));
        j.set("local_load_ratio", Json::from_f64_slice(&ratio_rows[i]));
        arr.push(j);
    }
    fig.json.set("series", Json::Arr(arr));
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shapes_match_paper() {
        // Seed + streams pinned ⇒ machine-independent values; see the
        // fig2 test module note on the PR-1 flake risk. The assertions
        // below are orderings with CRN across cells (one shared MC
        // seed), so the compared means share their noise.
        let fig = run(&FigureOptions {
            trials: 1_500,
            seed: 6,
            fit_samples: 1_000,
            threads: 1,
        });
        let series = fig.json.get("series").unwrap().as_arr().unwrap();
        let by_label = |label: &str, key: &str| -> Vec<f64> {
            series
                .iter()
                .find(|j| j.get("label").unwrap().as_str() == Some(label))
                .unwrap()
                .get(key)
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap())
                .collect()
        };
        // (a) delay decreases as γ/u grows for the proposed algorithm.
        let dedi = by_label("Dedi, iter", "mean_delay_ms");
        assert!(dedi.first().unwrap() > dedi.last().unwrap());
        // Proposed beats benchmarks at every ratio.
        let unc = by_label("Uncoded", "mean_delay_ms");
        for (d, u) in dedi.iter().zip(&unc) {
            assert!(d < u, "dedi {d} ≥ uncoded {u}");
        }
        // (b) benchmark ratio flat; proposed ratio decreases with γ/u.
        let coded_ratio = by_label("Coded [5]", "local_load_ratio");
        let spread = coded_ratio.iter().fold(0.0f64, |a, &b| a.max(b))
            - coded_ratio.iter().fold(1.0f64, |a, &b| a.min(b));
        assert!(spread < 1e-9, "coded benchmark ratio should be flat");
        let dedi_ratio = by_label("Dedi, iter", "local_load_ratio");
        assert!(
            dedi_ratio.first().unwrap() > dedi_ratio.last().unwrap(),
            "local ratio should fall as comm speeds up: {dedi_ratio:?}"
        );
    }
}
