//! Fig. 4: average task completion delay of all algorithms vs the
//! benchmarks, with communication delay (γ = 2u).
//!
//! (a) small scale — includes the λ-sweep grid optimum;
//! (b) large scale — optimum omitted (like the paper: the search is only
//!     feasible at M = 2).
//!
//! Cells are declared in the sweep catalog (ids "fig4a" / "fig4b") and
//! run on the batched engine.

use super::common::{result_json_cell, sweep, Figure, FigureOptions};
use crate::util::json::Json;
use crate::util::table::Table;

fn delays(id: &str, title: &str, opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new(id, title);
    let result = sweep(id, opts);
    let mut t = Table::new(&["algorithm", "avg delay (ms)", "±sem", "planner t* (ms)"]);
    let mut results = Vec::new();
    let mut uncoded_mean = None;
    let mut coded_mean = None;
    for c in &result.cells {
        let mean = c.outcome.system.mean();
        match c.outcome.label.as_str() {
            "Uncoded" => uncoded_mean = Some(mean),
            "Coded [5]" => coded_mean = Some(mean),
            _ => {}
        }
        t.row_fmt(
            &c.outcome.label,
            &[mean, c.outcome.system.sem(), c.outcome.t_est_ms],
            3,
        );
        results.push(result_json_cell(c));
    }
    fig.add_table("average task completion delay", t);

    // Headline reductions (paper: up to 79–82% vs uncoded, ~30% vs coded).
    let best = results
        .iter()
        .map(|j| j.get("mean_system_delay_ms").unwrap().as_f64().unwrap())
        .fold(f64::INFINITY, f64::min);
    let mut hl = Table::new(&["reduction vs", "percent"]);
    if let Some(u) = uncoded_mean {
        hl.row_fmt("Uncoded", &[100.0 * (1.0 - best / u)], 1);
    }
    if let Some(c) = coded_mean {
        hl.row_fmt("Coded [5]", &[100.0 * (1.0 - best / c)], 1);
    }
    fig.add_table("best-algorithm delay reduction", hl);

    fig.json.set("results", Json::Arr(results));
    fig
}

pub fn run_small(opts: &FigureOptions) -> Figure {
    delays(
        "fig4a",
        "average delay, 2 masters × 5 workers (γ = 2u)",
        opts,
    )
}

pub fn run_large(opts: &FigureOptions) -> Figure {
    delays(
        "fig4b",
        "average delay, 4 masters × 50 workers (γ = 2u)",
        opts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Seed + streams pinned ⇒ machine-independent values; see the fig2
    /// test module note on the PR-1 flake risk.
    fn fast() -> FigureOptions {
        FigureOptions {
            trials: 3_000,
            seed: 3,
            fit_samples: 1_000,
            threads: 1,
        }
    }

    /// Required SCA improvement over the plain Markov allocation: the
    /// paper reports −8.85% at small scale; all cells share one MC seed
    /// (CRN), so the paired delta's noise is far below the per-mean
    /// rel. sem of ≈ 0.35/√3000 ≈ 0.6%. Requiring ≥ 3% keeps ~6%
    /// of slack for the plan-dependent part of the gap.
    const SCA_MIN_GAIN: f64 = 0.03;

    /// Frac + SCA vs the grid optimum: the paper calls it "close-to-
    /// optimal"; 5% ≈ 8× the CRN-paired noise at 3 000 trials.
    const FRAC_VS_OPTIMAL_RTOL: f64 = 0.05;

    /// Iterated vs simple greedy at large scale: iter ≤ simple up to a
    /// 2% band (they may tie; the band covers the paired noise).
    const ITER_VS_SIMPLE_SLACK: f64 = 1.02;

    fn mean_of(fig: &Figure, label: &str) -> f64 {
        fig.json
            .get("results")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .find(|j| j.get("label").unwrap().as_str() == Some(label))
            .unwrap_or_else(|| panic!("missing {label}"))
            .get("mean_system_delay_ms")
            .unwrap()
            .as_f64()
            .unwrap()
    }

    #[test]
    fn paper_ordering_small_scale() {
        // Fig. 4a shape: with only 2–3 workers per master the plain
        // Markov allocation is conservative, and the SCA-enhanced
        // variants carry the win (the paper's small-scale emphasis:
        // SCA −8.85% dedicated / −17.1% fractional, frac close to the
        // brute-force optimum).
        let fig = run_small(&fast());
        let uncoded = mean_of(&fig, "Uncoded");
        let coded = mean_of(&fig, "Coded [5]");
        let dedi = mean_of(&fig, "Dedi, iter");
        let dedi_sca = mean_of(&fig, "Dedi, iter + SCA");
        let frac_sca = mean_of(&fig, "Frac + SCA");
        let optimal_sca = mean_of(&fig, "Optimal + SCA");
        // SCA-enhanced proposed algorithms beat both benchmarks.
        assert!(dedi_sca < uncoded, "dedi+SCA {dedi_sca} ≥ uncoded {uncoded}");
        assert!(dedi_sca < coded, "dedi+SCA {dedi_sca} ≥ coded {coded}");
        assert!(frac_sca < uncoded && frac_sca < coded);
        // SCA materially helps at small scale (paper: 8.85%).
        assert!(
            dedi_sca < dedi * (1.0 - SCA_MIN_GAIN),
            "SCA gain too small: {dedi_sca} vs {dedi}"
        );
        // Fractional + SCA is close to the grid optimum.
        assert!(
            (frac_sca - optimal_sca).abs() / optimal_sca < FRAC_VS_OPTIMAL_RTOL,
            "frac+SCA {frac_sca} vs optimal {optimal_sca}"
        );
    }

    #[test]
    fn large_scale_iter_beats_simple() {
        let fig = run_large(&fast());
        let iter = mean_of(&fig, "Dedi, iter");
        let simple = mean_of(&fig, "Dedi, simple");
        assert!(
            iter <= simple * ITER_VS_SIMPLE_SLACK,
            "iter {iter} vs simple {simple}"
        );
    }
}
