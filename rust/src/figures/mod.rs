//! Figure-reproduction harness: regenerates every figure of §V.
//!
//! Each `figN` module produces a [`Figure`] — the same rows/series the
//! paper plots, as aligned text tables plus a JSON export. Driven by the
//! CLI (`coded-coop figure <id>`) and by `cargo bench --bench figures`.
//!
//! | id | paper | content |
//! |----|-------|---------|
//! | fig2 | Fig. 2(a,b) | Markov validation, M=2/N=5, avg + CDF |
//! | fig3 | Fig. 3(a,b) | Markov validation, M=4/N=50 |
//! | fig4a / fig4b | Fig. 4 | avg delay, all algorithms vs benchmarks |
//! | fig5 | Fig. 5(a,b) | delay CDFs + ρ_s = 0.95 readouts |
//! | fig6 | Fig. 6(a,b) | γ/u sweep: avg delay + local-load ratio |
//! | fig7 | Fig. 7(a,b) | trace sampling + shifted-exp fit |
//! | fig8 | Fig. 8 | EC2-fitted comp-dominant comparison |
//!
//! Every plan→simulate figure is a thin declaration over the experiment
//! layer: its cells live in [`crate::experiment::catalog`] as a
//! `SweepSpec` and run on the batched engine (`common::sweep`); only
//! fig7 (trace fitting) evaluates outside the sweep engine.

pub mod ablations;
pub mod common;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;

pub use common::{Figure, FigureOptions};

/// All figure ids in paper order.
pub const ALL_IDS: &[&str] = &[
    "fig2", "fig3", "fig4a", "fig4b", "fig5", "fig6", "fig7", "fig8",
];

/// Run one figure by id.
pub fn run(id: &str, opts: &FigureOptions) -> anyhow::Result<Figure> {
    match id {
        "fig2" => Ok(fig2::run(opts)),
        "fig3" => Ok(fig3::run(opts)),
        "fig4a" => Ok(fig4::run_small(opts)),
        "fig4b" => Ok(fig4::run_large(opts)),
        "fig5" => Ok(fig5::run(opts)),
        "fig6" => Ok(fig6::run(opts)),
        "fig7" => Ok(fig7::run(opts)),
        "fig8" => Ok(fig8::run(opts)),
        other => anyhow::bail!(
            "unknown figure '{other}' (expected one of {ALL_IDS:?} or 'all')"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke: every figure regenerates at tiny trial counts.
    #[test]
    fn all_figures_smoke() {
        let opts = FigureOptions {
            trials: 400,
            seed: 5,
            fit_samples: 2_000,
            ..Default::default()
        };
        for id in ALL_IDS {
            let fig = run(id, &opts).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert!(!fig.tables.is_empty(), "{id} produced no tables");
            let text = fig.render();
            assert!(text.contains(&fig.id), "{id} render misses id");
            // JSON export parses back.
            let js = fig.json.to_string_pretty();
            crate::util::json::parse(&js).expect("figure JSON must parse");
        }
    }

    #[test]
    fn unknown_id_errors() {
        assert!(run("fig99", &FigureOptions::default()).is_err());
    }
}
