//! Fig. 8: average task completion delay on the EC2-fitted scenario —
//! 4 t2.micro masters, 40 t2.micro + 10 c5.large workers, computation-
//! dominant (§V-C).
//!
//! The paper *plans* with the fitted shifted exponentials but *simulates*
//! with the measured traces. Our substitution (DESIGN.md §Substitutions)
//! therefore reports two panels:
//! * **fitted model** — delays drawn from the fitted distributions only;
//! * **measured-trace stand-in** — t2.micro delays drawn from the
//!   burst-throttling mixture (heavy straggler tail, as in real traces).
//!   This is the panel comparable to the paper's 82% / 30% headline: an
//!   uncoded scheme must wait for every worker, so it is almost surely
//!   hit by a throttled t2 instance, while the coded schemes ride over
//!   them.
//!
//! Proposed algorithms use the exact (Theorem-2) values and loads, as the
//! paper does for this comp-dominant evaluation.

use super::common::{evaluate, result_json, roster, Figure, FigureOptions};
use crate::assign::ValueModel;
use crate::config::Scenario;
use crate::util::json::Json;
use crate::util::table::Table;

fn panel(
    fig: &mut Figure,
    tag: &str,
    caption: &str,
    s: &Scenario,
    opts: &FigureOptions,
) -> Vec<Json> {
    let specs = roster(false, ValueModel::Exact, "exact");
    let mut t = Table::new(&["algorithm", "avg delay (ms)", "±sem", "planner t* (ms)"]);
    let mut results = Vec::new();
    for spec in &specs {
        let e = evaluate(s, spec, opts, false);
        t.row_fmt(
            &e.label,
            &[e.results.system.mean(), e.results.system.sem(), e.plan.t_est()],
            3,
        );
        results.push(result_json(&e));
    }
    fig.add_table(caption, t);

    let mean = |label: &str| -> Option<f64> {
        results
            .iter()
            .find(|j| j.get("label").unwrap().as_str() == Some(label))
            .map(|j| j.get("mean_system_delay_ms").unwrap().as_f64().unwrap())
    };
    let best = results
        .iter()
        .map(|j| j.get("mean_system_delay_ms").unwrap().as_f64().unwrap())
        .fold(f64::INFINITY, f64::min);
    let mut hl = Table::new(&["reduction vs", "percent"]);
    if let Some(u) = mean("Uncoded") {
        hl.row_fmt("Uncoded", &[100.0 * (1.0 - best / u)], 1);
    }
    if let Some(c) = mean("Coded [5]") {
        hl.row_fmt("Coded [5]", &[100.0 * (1.0 - best / c)], 1);
    }
    fig.add_table(
        &format!("({tag}) best-algorithm delay reduction"),
        hl,
    );
    results
}

pub fn run(opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new(
        "fig8",
        "EC2-fitted scenario: 4 masters, 40 t2.micro + 10 c5.large workers",
    );
    let fitted = panel(
        &mut fig,
        "fitted",
        "(fitted) delays from fitted shifted exponentials",
        &Scenario::ec2(40, 10, false),
        opts,
    );
    let measured = panel(
        &mut fig,
        "measured",
        "(measured) t2.micro burst-throttling mixture — paper headline: 82% / 30%",
        &Scenario::ec2(40, 10, true),
        opts,
    );
    fig.json.set("results_fitted", Json::Arr(fitted));
    fig.json.set("results_measured", Json::Arr(measured));
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_of(results: &[Json], label: &str) -> f64 {
        results
            .iter()
            .find(|j| j.get("label").unwrap().as_str() == Some(label))
            .unwrap()
            .get("mean_system_delay_ms")
            .unwrap()
            .as_f64()
            .unwrap()
    }

    #[test]
    fn ec2_headline_reductions() {
        let fig = run(&FigureOptions {
            trials: 4_000,
            seed: 8,
            fit_samples: 1_000,
            threads: 0,
        });
        let measured = fig.json.get("results_measured").unwrap().as_arr().unwrap();
        let uncoded = mean_of(measured, "Uncoded");
        let coded = mean_of(measured, "Coded [5]");
        let iter = mean_of(measured, "Dedi, iter");
        let simple = mean_of(measured, "Dedi, simple");
        let frac = mean_of(measured, "Frac");
        // Orderings: proposed ≤ both benchmarks; iter ≤ simple (identical
        // per-type workers can tie); frac ≈ iter.
        assert!(iter <= simple * 1.001, "iter {iter} > simple {simple}");
        assert!(iter < coded && iter < uncoded);
        assert!((frac - iter).abs() / iter < 0.1, "frac {frac} vs iter {iter}");
        // Headline magnitudes under the measured-trace stand-in
        // (paper: 82% vs uncoded, 30% vs coded).
        let best = frac.min(iter);
        let red_uncoded = 1.0 - best / uncoded;
        let red_coded = 1.0 - best / coded;
        assert!(
            red_uncoded > 0.6,
            "vs uncoded only {:.0}% (paper ~82%)",
            100.0 * red_uncoded
        );
        assert!(
            red_coded > 0.15,
            "vs coded only {:.0}% (paper ~30%)",
            100.0 * red_coded
        );
        // Fitted-only panel: same orderings, smaller margins.
        let fitted = fig.json.get("results_fitted").unwrap().as_arr().unwrap();
        assert!(mean_of(fitted, "Dedi, iter") < mean_of(fitted, "Uncoded"));
    }
}
