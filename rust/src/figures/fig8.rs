//! Fig. 8: average task completion delay on the EC2-fitted scenario —
//! 4 t2.micro masters, 40 t2.micro + 10 c5.large workers, computation-
//! dominant (§V-C).
//!
//! The paper *plans* with the fitted shifted exponentials but *simulates*
//! with the measured traces. Our substitution (DESIGN.md §Substitutions)
//! therefore reports two panels — the catalog sweeps "fig8_fitted" and
//! "fig8_measured":
//! * **fitted model** — delays drawn from the fitted distributions only;
//! * **measured-trace stand-in** — t2.micro delays drawn from the
//!   burst-throttling mixture (heavy straggler tail, as in real traces).
//!   This is the panel comparable to the paper's 82% / 30% headline: an
//!   uncoded scheme must wait for every worker, so it is almost surely
//!   hit by a throttled t2 instance, while the coded schemes ride over
//!   them.
//!
//! Proposed algorithms use the exact (Theorem-2) values and loads, as the
//! paper does for this comp-dominant evaluation.

use super::common::{result_json_cell, sweep, Figure, FigureOptions};
use crate::util::json::Json;
use crate::util::table::Table;

fn panel(
    fig: &mut Figure,
    tag: &str,
    caption: &str,
    id: &str,
    opts: &FigureOptions,
) -> Vec<Json> {
    let result = sweep(id, opts);
    let mut t = Table::new(&["algorithm", "avg delay (ms)", "±sem", "planner t* (ms)"]);
    let mut results = Vec::new();
    for c in &result.cells {
        t.row_fmt(
            &c.outcome.label,
            &[
                c.outcome.system.mean(),
                c.outcome.system.sem(),
                c.outcome.t_est_ms,
            ],
            3,
        );
        results.push(result_json_cell(c));
    }
    fig.add_table(caption, t);

    let mean = |label: &str| -> Option<f64> {
        results
            .iter()
            .find(|j| j.get("label").unwrap().as_str() == Some(label))
            .map(|j| j.get("mean_system_delay_ms").unwrap().as_f64().unwrap())
    };
    let best = results
        .iter()
        .map(|j| j.get("mean_system_delay_ms").unwrap().as_f64().unwrap())
        .fold(f64::INFINITY, f64::min);
    let mut hl = Table::new(&["reduction vs", "percent"]);
    if let Some(u) = mean("Uncoded") {
        hl.row_fmt("Uncoded", &[100.0 * (1.0 - best / u)], 1);
    }
    if let Some(c) = mean("Coded [5]") {
        hl.row_fmt("Coded [5]", &[100.0 * (1.0 - best / c)], 1);
    }
    fig.add_table(
        &format!("({tag}) best-algorithm delay reduction"),
        hl,
    );
    results
}

pub fn run(opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new(
        "fig8",
        "EC2-fitted scenario: 4 masters, 40 t2.micro + 10 c5.large workers",
    );
    let fitted = panel(
        &mut fig,
        "fitted",
        "(fitted) delays from fitted shifted exponentials",
        "fig8_fitted",
        opts,
    );
    let measured = panel(
        &mut fig,
        "measured",
        "(measured) t2.micro burst-throttling mixture — paper headline: 82% / 30%",
        "fig8_measured",
        opts,
    );
    fig.json.set("results_fitted", Json::Arr(fitted));
    fig.json.set("results_measured", Json::Arr(measured));
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Iter vs simple greedy: identical per-type workers can tie; the
    /// band only absorbs CRN-paired float noise.
    const ITER_VS_SIMPLE_SLACK: f64 = 1.001;

    /// Frac tracks the dedicated optimum on this homogeneous-per-type
    /// fleet; 10% is ≫ the paired noise at 4 000 trials.
    const FRAC_VS_ITER_RTOL: f64 = 0.1;

    /// Headline floors under the measured-trace stand-in. Paper: 82% vs
    /// uncoded, 30% vs coded. The floors sit at ~¾ and ~½ of those
    /// effects: the throttling mixture (0.02 × 20) reproduces the
    /// qualitative tail, not the exact trace, and the uncoded mean's cv
    /// is straggler-inflated (rel. sem ≈ 2–3% at 4 000 trials) — the
    /// floors stay > 10σ away from the observed reductions while still
    /// failing on any real regression of the coding win.
    const MIN_REDUCTION_VS_UNCODED: f64 = 0.6;
    const MIN_REDUCTION_VS_CODED: f64 = 0.15;

    fn mean_of(results: &[Json], label: &str) -> f64 {
        results
            .iter()
            .find(|j| j.get("label").unwrap().as_str() == Some(label))
            .unwrap()
            .get("mean_system_delay_ms")
            .unwrap()
            .as_f64()
            .unwrap()
    }

    #[test]
    fn ec2_headline_reductions() {
        // Seed + streams pinned ⇒ machine-independent values; see the
        // fig2 test module note on the PR-1 flake risk.
        let fig = run(&FigureOptions {
            trials: 4_000,
            seed: 8,
            fit_samples: 1_000,
            threads: 1,
        });
        let measured = fig.json.get("results_measured").unwrap().as_arr().unwrap();
        let uncoded = mean_of(measured, "Uncoded");
        let coded = mean_of(measured, "Coded [5]");
        let iter = mean_of(measured, "Dedi, iter");
        let simple = mean_of(measured, "Dedi, simple");
        let frac = mean_of(measured, "Frac");
        // Orderings: proposed ≤ both benchmarks; iter ≤ simple; frac ≈ iter.
        assert!(
            iter <= simple * ITER_VS_SIMPLE_SLACK,
            "iter {iter} > simple {simple}"
        );
        assert!(iter < coded && iter < uncoded);
        assert!(
            (frac - iter).abs() / iter < FRAC_VS_ITER_RTOL,
            "frac {frac} vs iter {iter}"
        );
        let best = frac.min(iter);
        let red_uncoded = 1.0 - best / uncoded;
        let red_coded = 1.0 - best / coded;
        assert!(
            red_uncoded > MIN_REDUCTION_VS_UNCODED,
            "vs uncoded only {:.0}% (paper ~82%)",
            100.0 * red_uncoded
        );
        assert!(
            red_coded > MIN_REDUCTION_VS_CODED,
            "vs coded only {:.0}% (paper ~30%)",
            100.0 * red_coded
        );
        // Fitted-only panel: same orderings, smaller margins.
        let fitted = fig.json.get("results_fitted").unwrap().as_arr().unwrap();
        assert!(mean_of(fitted, "Dedi, iter") < mean_of(fitted, "Uncoded"));
    }
}
