//! Fig. 7: per-row computation-delay traces on two instance types and
//! their shifted-exponential fits.
//!
//! The paper measures a 10⁶-dim dot product 10⁶ times on EC2 t2.micro /
//! c5.large and fits shifted exponentials. Offline substitution
//! (DESIGN.md §Substitutions): each instance profile *generates* a trace
//! with the paper's fitted parameters, and we re-run the full fitting
//! pipeline — sample → MLE fit → KS distance — validating that the
//! pipeline recovers the parameters and that the fit quality matches the
//! paper's "the fitting ... is accurate". (The e2e example additionally
//! measures REAL matvec delays through the PJRT runtime and fits those.)

use super::common::{Figure, FigureOptions};
use crate::traces::ec2::{InstanceType, C5_LARGE, T2_MICRO};
use crate::traces::fit::fit_shifted_exp;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Ecdf;
use crate::util::table::Table;

pub fn run(opts: &FigureOptions) -> Figure {
    let mut fig = Figure::new(
        "fig7",
        "measured delay traces + shifted-exponential fits",
    );
    let mut rng = Rng::new(opts.seed ^ 0xEC2);
    let mut t = Table::new(&[
        "instance", "true a (ms)", "fit a (ms)", "true u (1/ms)", "fit u (1/ms)",
        "KS", "samples",
    ]);
    let mut arr = Vec::new();
    for inst in [T2_MICRO, C5_LARGE] {
        let (row, j) = fit_one(&inst, opts.fit_samples, &mut rng);
        t.row_fmt(inst.name, &row, 4);
        arr.push(j);
    }
    fig.add_table("shifted-exponential fits", t);
    fig.json.set("fits", Json::Arr(arr));
    fig
}

fn fit_one(inst: &InstanceType, n: usize, rng: &mut Rng) -> (Vec<f64>, Json) {
    let trace = inst.sample_trace(n, rng);
    let fit = fit_shifted_exp(&trace)
        .expect("synthetic EC2 traces are non-degenerate by construction");
    let ecdf = Ecdf::new(trace);
    let mut j = Json::obj();
    j.set("instance", Json::Str(inst.name.into()));
    j.set("true_a", Json::Num(inst.a));
    j.set("true_u", Json::Num(inst.u));
    j.set("fit_a", Json::Num(fit.a));
    j.set("fit_u", Json::Num(fit.u));
    j.set("ks", Json::Num(fit.ks));
    j.set("empirical_cdf", Json::from_pairs(&ecdf.series(64)));
    (
        vec![inst.a, fit.a, inst.u, fit.u, fit.ks, n as f64],
        j,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_recover_paper_parameters() {
        let fig = run(&FigureOptions {
            trials: 10,
            seed: 7,
            fit_samples: 100_000,
            threads: 0,
        });
        let fits = fig.json.get("fits").unwrap().as_arr().unwrap();
        for f in fits {
            let ta = f.get("true_a").unwrap().as_f64().unwrap();
            let fa = f.get("fit_a").unwrap().as_f64().unwrap();
            let tu = f.get("true_u").unwrap().as_f64().unwrap();
            let fu = f.get("fit_u").unwrap().as_f64().unwrap();
            let ks = f.get("ks").unwrap().as_f64().unwrap();
            assert!((fa - ta).abs() / ta < 0.02, "a: {fa} vs {ta}");
            assert!((fu - tu).abs() / tu < 0.05, "u: {fu} vs {tu}");
            assert!(ks < 0.02, "fit should be accurate, ks={ks}");
        }
    }
}
