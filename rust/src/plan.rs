//! The planner: policy → (assignment, resource shares, loads) = [`Plan`].
//!
//! A [`Plan`] is the complete static decision the paper's algorithms
//! produce — everything the Monte-Carlo engine ([`crate::sim`]) or the
//! real coordinator ([`crate::coordinator`]) needs to run a deployment.

use crate::alloc::{self, comp_dominant, markov, sca, EffLink};
use crate::assign::{
    dedicated_iter, dedicated_simple, fractional, optimal, uniform, Dedicated,
    Fractional, ValueMatrix, ValueModel,
};
use crate::config::Scenario;
use crate::model::params::theta_fractional;

/// Assignment policy (§V legends).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Benchmark 1: uniform workers, equal split, no coding, no local.
    UncodedUniform,
    /// Benchmark 2: uniform workers, Theorem-2 loads ([5]).
    CodedUniform,
    /// Algorithm 2 dedicated assignment.
    DediSimple,
    /// Algorithm 1 dedicated assignment.
    DediIter,
    /// Algorithm 4 fractional assignment (from an Algorithm-1 start).
    Frac,
    /// λ-sweep grid optimum (M = 2 only; §V benchmark 3).
    FracOptimal,
}

/// Load-allocation method layered on the assignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoadMethod {
    /// Theorem 1 closed form on θ (the "Approx" of Figs. 2–3).
    Markov,
    /// Theorem 2 closed form on (a, u) — computation-dominant exact.
    Exact,
    /// Theorem 1 start + Algorithm 3 SCA enhancement.
    Sca,
}

/// Full planning specification.
#[derive(Clone, Copy, Debug)]
pub struct PlanSpec {
    pub policy: Policy,
    /// Node values driving the assignment search.
    pub values: ValueModel,
    pub loads: LoadMethod,
}

impl PlanSpec {
    pub fn label(&self) -> String {
        let base = match self.policy {
            Policy::UncodedUniform => return "Uncoded".to_string(),
            Policy::CodedUniform => return "Coded [5]".to_string(),
            Policy::DediSimple => "Dedi, simple",
            Policy::DediIter => "Dedi, iter",
            Policy::Frac => "Frac",
            Policy::FracOptimal => "Optimal",
        };
        match self.loads {
            LoadMethod::Sca => format!("{base} + SCA"),
            _ => base.to_string(),
        }
    }
}

/// One node's share of a master's plan.
#[derive(Clone, Copy, Debug)]
pub struct PlanEntry {
    /// Node id: 0 = the master's local processor, `n ≥ 1` = worker n.
    pub node: usize,
    /// Coded rows `l_{m,n}` (continuous; the coordinator rounds).
    pub load: f64,
    /// Compute share `k_{m,n}`.
    pub k: f64,
    /// Bandwidth share `b_{m,n}`.
    pub b: f64,
}

/// Per-master plan.
#[derive(Clone, Debug)]
pub struct MasterPlan {
    pub entries: Vec<PlanEntry>,
    /// Planner's predicted completion delay `t_m*` (ms).
    pub t_est: f64,
    pub l_rows: f64,
}

impl MasterPlan {
    pub fn total_load(&self) -> f64 {
        self.entries.iter().map(|e| e.load).sum()
    }
}

/// A complete deployment decision.
#[derive(Clone, Debug)]
pub struct Plan {
    pub label: String,
    /// Uncoded plans need ALL nodes to finish (no redundancy).
    pub uncoded: bool,
    pub masters: Vec<MasterPlan>,
}

impl Plan {
    /// Predicted system delay `max_m t_m*`.
    pub fn t_est(&self) -> f64 {
        self.masters.iter().map(|p| p.t_est).fold(0.0, f64::max)
    }
}

/// Build a plan for `spec` on `s`.
pub fn build(s: &Scenario, spec: &PlanSpec) -> Plan {
    match spec.policy {
        Policy::UncodedUniform => build_uncoded(s),
        Policy::CodedUniform => {
            let d = uniform::assign(s.n_masters(), s.n_workers());
            build_dedicated(s, &d, LoadMethod::Exact, "Coded [5]".into())
        }
        Policy::DediSimple => {
            let vm = ValueMatrix::new(s, spec.values);
            let d = dedicated_simple::assign(&vm);
            build_dedicated(s, &d, spec.loads, spec.label())
        }
        Policy::DediIter => {
            let vm = ValueMatrix::new(s, spec.values);
            let d = dedicated_iter::assign(&vm, &Default::default());
            build_dedicated(s, &d, spec.loads, spec.label())
        }
        Policy::Frac => {
            let vm = ValueMatrix::new(s, spec.values);
            let d = dedicated_iter::assign(&vm, &Default::default());
            let f = fractional::assign(s, &d, &Default::default());
            build_fractional(s, &f, spec.loads, spec.label())
        }
        Policy::FracOptimal => {
            let f = optimal::assign(s, &Default::default());
            build_fractional(s, &f, spec.loads, spec.label())
        }
    }
}

fn build_uncoded(s: &Scenario) -> Plan {
    let d = uniform::assign(s.n_masters(), s.n_workers());
    let masters = (0..s.n_masters())
        .map(|m| {
            let ws = d.workers_of(m);
            let share = s.l_rows(m) / ws.len() as f64;
            let entries: Vec<PlanEntry> = ws
                .iter()
                .map(|&w| PlanEntry {
                    node: w + 1,
                    load: share,
                    k: 1.0,
                    b: 1.0,
                })
                .collect();
            // Without redundancy the best estimate is the slowest mean.
            let t_est = entries
                .iter()
                .map(|e| {
                    share * EffLink::dedicated(&s.link(m, e.node)).theta()
                })
                .fold(0.0, f64::max);
            MasterPlan {
                entries,
                t_est,
                l_rows: s.l_rows(m),
            }
        })
        .collect();
    Plan {
        label: "Uncoded".into(),
        uncoded: true,
        masters,
    }
}

fn build_dedicated(
    s: &Scenario,
    d: &Dedicated,
    loads: LoadMethod,
    label: String,
) -> Plan {
    let masters = (0..s.n_masters())
        .map(|m| {
            // Node list: local first, then owned workers (node ids).
            let mut nodes = vec![0usize];
            nodes.extend(d.workers_of(m).iter().map(|&w| w + 1));
            let alloc = allocate(s, m, &nodes, |_| (1.0, 1.0), loads);
            MasterPlan {
                entries: nodes
                    .iter()
                    .zip(&alloc.loads)
                    .filter(|&(_, &l)| l > 0.0)
                    .map(|(&node, &load)| PlanEntry {
                        node,
                        load,
                        k: 1.0,
                        b: 1.0,
                    })
                    .collect(),
                t_est: alloc.t_star,
                l_rows: s.l_rows(m),
            }
        })
        .collect();
    Plan {
        label,
        uncoded: false,
        masters,
    }
}

fn build_fractional(
    s: &Scenario,
    f: &Fractional,
    loads: LoadMethod,
    label: String,
) -> Plan {
    let masters = (0..s.n_masters())
        .map(|m| {
            let mut nodes = vec![0usize];
            let mut shares = vec![(1.0, 1.0)];
            for w in 0..s.n_workers() {
                // A worker participates only with BOTH shares positive
                // (k, b, l all-zero-or-all-nonzero, §IV-A).
                if f.k[m][w] > 1e-12 && f.b[m][w] > 1e-12 {
                    nodes.push(w + 1);
                    shares.push((f.k[m][w], f.b[m][w]));
                }
            }
            let alloc = allocate(s, m, &nodes, |i| shares[i], loads);
            MasterPlan {
                entries: nodes
                    .iter()
                    .enumerate()
                    .zip(&alloc.loads)
                    .filter(|&(_, &l)| l > 0.0)
                    .map(|((i, &node), &load)| PlanEntry {
                        node,
                        load,
                        k: shares[i].0,
                        b: shares[i].1,
                    })
                    .collect(),
                t_est: alloc.t_star,
                l_rows: s.l_rows(m),
            }
        })
        .collect();
    Plan {
        label,
        uncoded: false,
        masters,
    }
}

/// Dispatch to the requested allocator over an explicit node list.
/// `share(i)` returns `(k, b)` for position `i` in `nodes`.
fn allocate(
    s: &Scenario,
    m: usize,
    nodes: &[usize],
    share: impl Fn(usize) -> (f64, f64),
    loads: LoadMethod,
) -> alloc::Allocation {
    let l_rows = s.l_rows(m);
    match loads {
        LoadMethod::Markov => {
            let thetas: Vec<f64> = nodes
                .iter()
                .enumerate()
                .map(|(i, &n)| {
                    let (k, b) = share(i);
                    theta_fractional(&s.link(m, n), k, b)
                })
                .collect();
            markov::allocate(&thetas, l_rows)
        }
        LoadMethod::Exact => {
            let params: Vec<comp_dominant::CompParams> = nodes
                .iter()
                .enumerate()
                .map(|(i, &n)| {
                    let (k, _) = share(i);
                    let p = s.link(m, n);
                    comp_dominant::CompParams {
                        a: p.a / k,
                        u: k * p.u,
                    }
                })
                .collect();
            comp_dominant::allocate(&params, l_rows)
        }
        LoadMethod::Sca => {
            let links: Vec<EffLink> = nodes
                .iter()
                .enumerate()
                .map(|(i, &n)| {
                    let (k, b) = share(i);
                    EffLink::fractional(&s.link(m, n), k, b)
                })
                .collect();
            sca::allocate(&links, l_rows, &Default::default())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CommModel, Scenario};

    fn spec(policy: Policy, loads: LoadMethod) -> PlanSpec {
        PlanSpec {
            policy,
            values: ValueModel::Markov,
            loads,
        }
    }

    #[test]
    fn uncoded_loads_sum_to_l_exactly() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::UncodedUniform, LoadMethod::Markov));
        assert!(p.uncoded);
        for mp in &p.masters {
            assert!((mp.total_load() - mp.l_rows).abs() < 1e-9);
            // no local node in the uncoded benchmark
            assert!(mp.entries.iter().all(|e| e.node >= 1));
        }
    }

    #[test]
    fn coded_plans_have_redundancy_and_local() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        for policy in [Policy::CodedUniform, Policy::DediSimple, Policy::DediIter] {
            let p = build(&s, &spec(policy, LoadMethod::Markov));
            assert!(!p.uncoded);
            for mp in &p.masters {
                assert!(
                    mp.total_load() > mp.l_rows,
                    "{policy:?}: no redundancy"
                );
                assert!(mp.entries.iter().any(|e| e.node == 0), "no local node");
            }
        }
    }

    #[test]
    fn dedicated_plans_partition_workers() {
        let s = Scenario::large_scale(2, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let mut seen = std::collections::HashSet::new();
        for mp in &p.masters {
            for e in &mp.entries {
                if e.node >= 1 {
                    assert!(seen.insert(e.node), "worker {} serves two masters", e.node);
                    assert_eq!(e.k, 1.0);
                    assert_eq!(e.b, 1.0);
                }
            }
        }
    }

    #[test]
    fn fractional_plan_respects_resource_constraints() {
        let s = Scenario::small_scale(3, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::Frac, LoadMethod::Markov));
        let mut ksum = vec![0.0; s.n_workers() + 1];
        let mut bsum = vec![0.0; s.n_workers() + 1];
        for mp in &p.masters {
            for e in &mp.entries {
                if e.node >= 1 {
                    ksum[e.node] += e.k;
                    bsum[e.node] += e.b;
                }
            }
        }
        for n in 1..=s.n_workers() {
            assert!(ksum[n] <= 1.0 + 1e-9, "Σk at worker {n} = {}", ksum[n]);
            assert!(bsum[n] <= 1.0 + 1e-9, "Σb at worker {n} = {}", bsum[n]);
        }
    }

    #[test]
    fn sca_improves_t_est() {
        let s = Scenario::small_scale(4, 2.0, CommModel::Stochastic);
        let base = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let enhanced = build(&s, &spec(Policy::DediIter, LoadMethod::Sca));
        assert!(
            enhanced.t_est() < base.t_est(),
            "SCA {} ≥ Markov {}",
            enhanced.t_est(),
            base.t_est()
        );
    }

    #[test]
    fn exact_loads_on_comp_dominant() {
        let s = Scenario::ec2(8, 2, false);
        let p = build(
            &s,
            &PlanSpec {
                policy: Policy::DediIter,
                values: ValueModel::Exact,
                loads: LoadMethod::Exact,
            },
        );
        for mp in &p.masters {
            let overhead = mp.total_load() / mp.l_rows;
            assert!(overhead > 1.0 && overhead < 2.0, "overhead {overhead}");
        }
    }

    #[test]
    fn labels() {
        assert_eq!(
            spec(Policy::DediIter, LoadMethod::Sca).label(),
            "Dedi, iter + SCA"
        );
        assert_eq!(spec(Policy::UncodedUniform, LoadMethod::Markov).label(), "Uncoded");
    }
}
