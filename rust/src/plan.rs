//! The planner: policy → (assignment, resource shares, loads) = [`Plan`].
//!
//! A [`Plan`] is the complete static decision the paper's algorithms
//! produce — everything the Monte-Carlo engine ([`crate::sim`]) or the
//! real coordinator ([`crate::coordinator`]) needs to run a deployment.
//!
//! Strategy dispatch is OPEN: [`build_with`] drives any
//! [`crate::policy::Assigner`] + [`crate::policy::LoadAllocator`] pair,
//! and [`build`] resolves the legacy [`PlanSpec`] enums through
//! [`crate::policy::registry`] — there is no policy `match` here, so new
//! strategies need zero edits to this module (see `DESIGN.md` §3).
//!
//! Plans serialize ([`Plan::to_json`] / [`Plan::from_json`], schema-
//! versioned): plan once, ship the JSON, execute many — the caching /
//! sharding story for serving planned deployments at scale (`coded-coop
//! plan export` / `plan run`).

use crate::assign::ValueModel;
use crate::config::Scenario;
use crate::policy::{Assigner, LoadAllocator, PolicySpec};
use crate::util::json::Json;

/// Assignment policy (§V legends).
///
/// Legacy closed enum, kept as a convenience for the built-in strategies;
/// the open, string-keyed surface is [`crate::policy::PolicySpec`] + the
/// registry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Benchmark 1: uniform workers, equal split, no coding, no local.
    UncodedUniform,
    /// Benchmark 2: uniform workers, Theorem-2 loads ([5]).
    CodedUniform,
    /// Algorithm 2 dedicated assignment.
    DediSimple,
    /// Algorithm 1 dedicated assignment.
    DediIter,
    /// Algorithm 4 fractional assignment (from an Algorithm-1 start).
    Frac,
    /// λ-sweep grid optimum (M = 2 only; §V benchmark 3).
    FracOptimal,
}

impl Policy {
    /// Registry key of this built-in policy.
    pub fn name(&self) -> &'static str {
        match self {
            Policy::UncodedUniform => "uncoded",
            Policy::CodedUniform => "coded",
            Policy::DediSimple => "dedi-simple",
            Policy::DediIter => "dedi-iter",
            Policy::Frac => "frac",
            Policy::FracOptimal => "optimal",
        }
    }

    /// Inverse of [`Policy::name`] (built-ins only).
    pub fn from_name(s: &str) -> Option<Policy> {
        Some(match s {
            "uncoded" => Policy::UncodedUniform,
            "coded" => Policy::CodedUniform,
            "dedi-simple" => Policy::DediSimple,
            "dedi-iter" => Policy::DediIter,
            "frac" => Policy::Frac,
            "optimal" => Policy::FracOptimal,
            _ => return None,
        })
    }
}

/// Load-allocation method layered on the assignment.
///
/// Legacy closed enum; the registry accepts arbitrary allocator names.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoadMethod {
    /// Theorem 1 closed form on θ (the "Approx" of Figs. 2–3).
    Markov,
    /// Theorem 2 closed form on (a, u) — computation-dominant exact.
    Exact,
    /// Theorem 1 start + Algorithm 3 SCA enhancement.
    Sca,
}

impl LoadMethod {
    /// Registry key of this built-in allocator.
    pub fn name(&self) -> &'static str {
        match self {
            LoadMethod::Markov => "markov",
            LoadMethod::Exact => "exact",
            LoadMethod::Sca => "sca",
        }
    }

    /// Inverse of [`LoadMethod::name`] (built-ins only).
    pub fn from_name(s: &str) -> Option<LoadMethod> {
        Some(match s {
            "markov" => LoadMethod::Markov,
            "exact" => LoadMethod::Exact,
            "sca" => LoadMethod::Sca,
            _ => return None,
        })
    }
}

/// Full planning specification over the built-in strategies.
///
/// Thin shim over [`PolicySpec`]: kept `Copy` and enum-typed so existing
/// examples and harness code keep compiling; new code (and anything that
/// must name runtime-registered strategies) should use [`PolicySpec`].
#[derive(Clone, Copy, Debug)]
pub struct PlanSpec {
    pub policy: Policy,
    /// Node values driving the assignment search.
    pub values: ValueModel,
    pub loads: LoadMethod,
}

impl PlanSpec {
    /// The open-world, registry-keyed equivalent of this spec.
    pub fn to_policy_spec(&self) -> PolicySpec {
        PolicySpec::new(self.policy.name(), self.values, self.loads.name())
    }

    /// Legend label ("Dedi, iter + SCA", …), as the resolved strategy
    /// reports it.
    pub fn label(&self) -> String {
        self.to_policy_spec()
            .label()
            .expect("built-in policies always resolve")
    }

    pub fn to_json(&self) -> Json {
        self.to_policy_spec().to_json()
    }

    /// Parse from JSON. Fails for registry names that are not built-ins —
    /// parse a [`PolicySpec`] instead for those.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let ps = PolicySpec::from_json(j)?;
        let policy = Policy::from_name(&ps.policy).ok_or_else(|| {
            anyhow::anyhow!("policy '{}' is not a built-in (use PolicySpec)", ps.policy)
        })?;
        let loads = LoadMethod::from_name(&ps.loads).ok_or_else(|| {
            anyhow::anyhow!(
                "load method '{}' is not a built-in (use PolicySpec)",
                ps.loads
            )
        })?;
        Ok(PlanSpec {
            policy,
            values: ps.values,
            loads,
        })
    }
}

/// One node's share of a master's plan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanEntry {
    /// Node id: 0 = the master's local processor, `n ≥ 1` = worker n.
    pub node: usize,
    /// Coded rows `l_{m,n}` (continuous; the coordinator rounds).
    pub load: f64,
    /// Compute share `k_{m,n}`.
    pub k: f64,
    /// Bandwidth share `b_{m,n}`.
    pub b: f64,
}

/// Per-master plan.
#[derive(Clone, Debug, PartialEq)]
pub struct MasterPlan {
    pub entries: Vec<PlanEntry>,
    /// Planner's predicted completion delay `t_m*` (ms).
    pub t_est: f64,
    pub l_rows: f64,
}

impl MasterPlan {
    pub fn total_load(&self) -> f64 {
        self.entries.iter().map(|e| e.load).sum()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("t_est", Json::Num(self.t_est));
        j.set("l_rows", Json::Num(self.l_rows));
        j.set(
            "entries",
            Json::Arr(
                self.entries
                    .iter()
                    .map(|e| {
                        let mut o = Json::obj();
                        o.set("node", Json::Num(e.node as f64));
                        o.set("load", Json::Num(e.load));
                        o.set("k", Json::Num(e.k));
                        o.set("b", Json::Num(e.b));
                        o
                    })
                    .collect(),
            ),
        );
        j
    }

    /// Parse + validate one master's plan. Malformed loads/shares (from
    /// hand-edited JSON) are rejected here so they can never reach the
    /// planner/simulator internals as NaNs or out-of-range fractions.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let num = |j: &Json, k: &str| -> anyhow::Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("master plan missing number '{k}'"))
        };
        let t_est = num(j, "t_est")?;
        let l_rows = num(j, "l_rows")?;
        anyhow::ensure!(
            l_rows.is_finite() && l_rows > 0.0,
            "l_rows must be positive, got {l_rows}"
        );
        anyhow::ensure!(
            t_est.is_finite() && t_est >= 0.0,
            "t_est must be finite and ≥ 0, got {t_est}"
        );
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("master plan missing 'entries'"))?
            .iter()
            .map(|e| {
                let node = e
                    .get("node")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("entry missing integer 'node'"))?;
                let load = num(e, "load")?;
                let k = num(e, "k")?;
                let b = num(e, "b")?;
                anyhow::ensure!(
                    load.is_finite() && load >= 0.0,
                    "node {node}: load must be finite and ≥ 0, got {load}"
                );
                // Tolerate float epsilon above 1 (grid arithmetic in some
                // assigners) by clamping back to 1 — downstream samplers
                // assert shares ≤ 1 exactly; reject anything materially
                // out of range.
                anyhow::ensure!(
                    k.is_finite() && k > 0.0 && k <= 1.0 + 1e-9,
                    "node {node}: compute share k={k} outside (0, 1]"
                );
                anyhow::ensure!(
                    b.is_finite() && b > 0.0 && b <= 1.0 + 1e-9,
                    "node {node}: bandwidth share b={b} outside (0, 1]"
                );
                Ok(PlanEntry {
                    node,
                    load,
                    k: k.min(1.0),
                    b: b.min(1.0),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(MasterPlan {
            entries,
            t_est,
            l_rows,
        })
    }
}

/// A complete deployment decision.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub label: String,
    /// Uncoded plans need ALL nodes to finish (no redundancy).
    pub uncoded: bool,
    pub masters: Vec<MasterPlan>,
}

impl Plan {
    /// Plan-document schema version ([`Plan::to_json`] stamps it;
    /// [`Plan::from_json`] rejects documents from a different major).
    pub const SCHEMA: u64 = 1;

    /// Predicted system delay `max_m t_m*`.
    pub fn t_est(&self) -> f64 {
        self.masters.iter().map(|p| p.t_est).fold(0.0, f64::max)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", Json::Num(Self::SCHEMA as f64));
        j.set("label", Json::Str(self.label.clone()));
        j.set("uncoded", Json::Bool(self.uncoded));
        j.set(
            "masters",
            Json::Arr(self.masters.iter().map(MasterPlan::to_json).collect()),
        );
        j
    }

    /// Parse + validate a serialized plan (schema-checked round-trip of
    /// [`Plan::to_json`]).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let schema = j
            .get("schema")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("plan document missing 'schema'"))?;
        anyhow::ensure!(
            schema as u64 == Self::SCHEMA,
            "unsupported plan schema {schema} (this build reads schema {})",
            Self::SCHEMA
        );
        let label = j
            .get("label")
            .and_then(Json::as_str)
            .unwrap_or("imported")
            .to_string();
        // `uncoded` flips the completion semantics (all-nodes vs any-L_m),
        // so a document that omits it is rejected rather than defaulted.
        let uncoded = j
            .get("uncoded")
            .and_then(Json::as_bool)
            .ok_or_else(|| anyhow::anyhow!("plan document missing boolean 'uncoded'"))?;
        let masters = j
            .get("masters")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("plan document missing 'masters'"))?
            .iter()
            .map(MasterPlan::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(!masters.is_empty(), "plan has no masters");
        Ok(Plan {
            label,
            uncoded,
            masters,
        })
    }

    /// Rescale every master's loads so the coding overhead `Σ_n l_{m,n} /
    /// L_m` becomes exactly `beta` (the redundancy ablation / `overhead`
    /// sweep axis). `t_est` is left untouched: it describes the original
    /// allocation, not the rescaled one. A `beta < 1` plan can never
    /// decode — [`Plan::validate`] rejects it before any engine runs it.
    pub fn with_overhead(&self, beta: f64) -> Plan {
        assert!(
            beta.is_finite() && beta > 0.0,
            "overhead must be positive and finite, got {beta}"
        );
        let mut out = self.clone();
        for mp in &mut out.masters {
            let cur = mp.total_load() / mp.l_rows;
            let f = beta / cur;
            for e in &mut mp.entries {
                e.load *= f;
            }
        }
        out
    }

    /// Cross-check a (possibly deserialized) plan against the scenario it
    /// is about to run on: master count and node ids must be in range,
    /// otherwise the engines would index out of bounds. Call this at the
    /// JSON boundary before handing a plan to an executor.
    pub fn validate(&self, s: &Scenario) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.masters.len() == s.n_masters(),
            "plan has {} masters but scenario '{}' has {}",
            self.masters.len(),
            s.name,
            s.n_masters()
        );
        for (m, mp) in self.masters.iter().enumerate() {
            for e in &mp.entries {
                anyhow::ensure!(
                    e.node <= s.n_workers(),
                    "master {m}: plan entry names node {} but scenario '{}' has workers 1..={}",
                    e.node,
                    s.name,
                    s.n_workers()
                );
            }
            // Every plan must distribute at least L_m rows: a coded plan
            // below L can never decode (infinite delay), an uncoded plan
            // below L would silently report an optimistic finite delay.
            anyhow::ensure!(
                mp.total_load() >= mp.l_rows * (1.0 - 1e-9),
                "master {m}: total load {} below L = {} — the task could never complete",
                mp.total_load(),
                mp.l_rows
            );
        }
        Ok(())
    }
}

/// Build a plan for the built-in `spec` on `s` (registry-routed; see
/// [`build_with`] for the open-world entry point).
pub fn build(s: &Scenario, spec: &PlanSpec) -> Plan {
    spec.to_policy_spec()
        .build(s)
        .expect("built-in policies always resolve")
}

/// Build a plan from any strategy pair: the single generic pipeline every
/// policy flows through (assign → per-master allocate → filter zero
/// loads).
pub fn build_with(
    s: &Scenario,
    assigner: &dyn Assigner,
    allocator: &dyn LoadAllocator,
    label: &str,
) -> Plan {
    let asn = assigner.assign(s);
    let uncoded = asn.uncoded();
    let masters = (0..s.n_masters())
        .map(|m| {
            let (nodes, shares) = asn.nodes_of(s, m);
            // Fail loudly at build time on malformed strategy output —
            // otherwise a registered assigner's bad share would only
            // surface as a deep sampler assert naming no policy.
            for (i, &(k, b)) in shares.iter().enumerate() {
                assert!(
                    k > 0.0 && k <= 1.0 + 1e-9 && b > 0.0 && b <= 1.0 + 1e-9,
                    "assignment for plan '{label}' produced share (k={k}, b={b}) \
                     outside (0, 1] for master {m}, node {}",
                    nodes[i]
                );
            }
            // Clamp the tolerated float epsilon back to 1 BEFORE the
            // allocator sees the shares — allocator internals (and the
            // delay samplers) assert shares ≤ 1 exactly.
            let shares: Vec<(f64, f64)> = shares
                .into_iter()
                .map(|(k, b)| (k.min(1.0), b.min(1.0)))
                .collect();
            let alloc = allocator.allocate(s, m, &nodes, &shares);
            // A wrong-length loads vector from a registered allocator must
            // also fail loudly, not silently truncate the plan.
            assert_eq!(
                alloc.loads.len(),
                nodes.len(),
                "allocator returned {} loads for {} serving nodes (master {m})",
                alloc.loads.len(),
                nodes.len()
            );
            MasterPlan {
                entries: nodes
                    .iter()
                    .enumerate()
                    .zip(&alloc.loads)
                    .filter(|&(_, &l)| l > 0.0)
                    .map(|((i, &node), &load)| PlanEntry {
                        node,
                        load,
                        k: shares[i].0,
                        b: shares[i].1,
                    })
                    .collect(),
                t_est: alloc.t_star,
                l_rows: s.l_rows(m),
            }
        })
        .collect();
    Plan {
        label: label.to_string(),
        uncoded,
        masters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CommModel, Scenario};

    fn spec(policy: Policy, loads: LoadMethod) -> PlanSpec {
        PlanSpec {
            policy,
            values: ValueModel::Markov,
            loads,
        }
    }

    #[test]
    fn uncoded_loads_sum_to_l_exactly() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::UncodedUniform, LoadMethod::Markov));
        assert!(p.uncoded);
        for mp in &p.masters {
            assert!((mp.total_load() - mp.l_rows).abs() < 1e-9);
            // no local node in the uncoded benchmark
            assert!(mp.entries.iter().all(|e| e.node >= 1));
        }
    }

    #[test]
    fn coded_plans_have_redundancy_and_local() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        for policy in [Policy::CodedUniform, Policy::DediSimple, Policy::DediIter] {
            let p = build(&s, &spec(policy, LoadMethod::Markov));
            assert!(!p.uncoded);
            for mp in &p.masters {
                assert!(
                    mp.total_load() > mp.l_rows,
                    "{policy:?}: no redundancy"
                );
                assert!(mp.entries.iter().any(|e| e.node == 0), "no local node");
            }
        }
    }

    #[test]
    fn dedicated_plans_partition_workers() {
        let s = Scenario::large_scale(2, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let mut seen = std::collections::HashSet::new();
        for mp in &p.masters {
            for e in &mp.entries {
                if e.node >= 1 {
                    assert!(seen.insert(e.node), "worker {} serves two masters", e.node);
                    assert_eq!(e.k, 1.0);
                    assert_eq!(e.b, 1.0);
                }
            }
        }
    }

    #[test]
    fn fractional_plan_respects_resource_constraints() {
        let s = Scenario::small_scale(3, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::Frac, LoadMethod::Markov));
        let mut ksum = vec![0.0; s.n_workers() + 1];
        let mut bsum = vec![0.0; s.n_workers() + 1];
        for mp in &p.masters {
            for e in &mp.entries {
                if e.node >= 1 {
                    ksum[e.node] += e.k;
                    bsum[e.node] += e.b;
                }
            }
        }
        for n in 1..=s.n_workers() {
            assert!(ksum[n] <= 1.0 + 1e-9, "Σk at worker {n} = {}", ksum[n]);
            assert!(bsum[n] <= 1.0 + 1e-9, "Σb at worker {n} = {}", bsum[n]);
        }
    }

    #[test]
    fn sca_improves_t_est() {
        let s = Scenario::small_scale(4, 2.0, CommModel::Stochastic);
        let base = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let enhanced = build(&s, &spec(Policy::DediIter, LoadMethod::Sca));
        assert!(
            enhanced.t_est() < base.t_est(),
            "SCA {} ≥ Markov {}",
            enhanced.t_est(),
            base.t_est()
        );
    }

    #[test]
    fn exact_loads_on_comp_dominant() {
        let s = Scenario::ec2(8, 2, false);
        let p = build(
            &s,
            &PlanSpec {
                policy: Policy::DediIter,
                values: ValueModel::Exact,
                loads: LoadMethod::Exact,
            },
        );
        for mp in &p.masters {
            let overhead = mp.total_load() / mp.l_rows;
            assert!(overhead > 1.0 && overhead < 2.0, "overhead {overhead}");
        }
    }

    #[test]
    fn labels() {
        assert_eq!(
            spec(Policy::DediIter, LoadMethod::Sca).label(),
            "Dedi, iter + SCA"
        );
        assert_eq!(spec(Policy::UncodedUniform, LoadMethod::Markov).label(), "Uncoded");
    }

    #[test]
    fn with_overhead_hits_target_exactly() {
        let s = Scenario::large_scale(5, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        for beta in [1.05, 1.5, 3.0] {
            let q = p.with_overhead(beta);
            for (mp, orig) in q.masters.iter().zip(&p.masters) {
                assert!(
                    (mp.total_load() / mp.l_rows - beta).abs() < 1e-9,
                    "beta {beta}"
                );
                // proportional rescale: per-node load ratios preserved
                for (e, o) in mp.entries.iter().zip(&orig.entries) {
                    assert_eq!(e.node, o.node);
                    assert!((e.load / o.load - mp.total_load() / orig.total_load()).abs() < 1e-9);
                }
                assert_eq!(mp.t_est, orig.t_est);
            }
            // sub-L overhead is constructible but rejected at validation
            assert!(p.with_overhead(0.5).validate(&s).is_err());
        }
    }

    #[test]
    fn plan_json_roundtrip_is_exact() {
        let s = Scenario::small_scale(6, 2.0, CommModel::Stochastic);
        for policy in [Policy::UncodedUniform, Policy::DediIter, Policy::Frac] {
            let p = build(&s, &spec(policy, LoadMethod::Markov));
            let text = p.to_json().to_string_pretty();
            let back = Plan::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, p, "{policy:?}");
            assert_eq!(back.t_est(), p.t_est());
        }
    }

    #[test]
    fn plan_from_json_rejects_malformed_documents() {
        let parse = |s: &str| crate::util::json::parse(s).unwrap();
        // Wrong schema version.
        assert!(Plan::from_json(&parse(r#"{"schema": 99, "masters": []}"#)).is_err());
        // No schema at all.
        assert!(Plan::from_json(&parse(r#"{"masters": []}"#)).is_err());
        // Out-of-range fractional share.
        let bad_share = r#"{"schema": 1, "label": "x", "uncoded": false,
            "masters": [{"t_est": 1.0, "l_rows": 10,
                         "entries": [{"node": 1, "load": 20, "k": 1.5, "b": 1.0}]}]}"#;
        let err = Plan::from_json(&parse(bad_share)).unwrap_err();
        assert!(err.to_string().contains("k=1.5"), "{err}");
        // Non-finite load text is not valid JSON; a negative load is.
        let bad_load = r#"{"schema": 1, "label": "x", "uncoded": false,
            "masters": [{"t_est": 1.0, "l_rows": 10,
                         "entries": [{"node": 1, "load": -3, "k": 1.0, "b": 1.0}]}]}"#;
        assert!(Plan::from_json(&parse(bad_load)).is_err());
    }

    #[test]
    fn validate_catches_scenario_mismatch() {
        let s = Scenario::small_scale(8, 2.0, CommModel::Stochastic); // M=2, N=5
        let mut p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        p.validate(&s).unwrap();
        // Out-of-range node id (worker 99 doesn't exist).
        p.masters[0].entries[0].node = 99;
        assert!(p.validate(&s).is_err());
        // Master-count mismatch.
        let q = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let bigger = Scenario::large_scale(8, 2.0, CommModel::Stochastic); // M=4
        assert!(q.validate(&bigger).is_err());
    }

    #[test]
    fn from_json_requires_uncoded_flag_and_clamps_epsilon_shares() {
        let parse = |s: &str| crate::util::json::parse(s).unwrap();
        // Missing `uncoded` is an error, not a default.
        let no_flag = r#"{"schema": 1, "label": "x",
            "masters": [{"t_est": 1.0, "l_rows": 10,
                         "entries": [{"node": 1, "load": 20, "k": 1.0, "b": 1.0}]}]}"#;
        assert!(Plan::from_json(&parse(no_flag)).is_err());
        // A share within float epsilon above 1 is clamped back to 1.0
        // (downstream samplers assert k, b ≤ 1 exactly).
        let eps = r#"{"schema": 1, "label": "x", "uncoded": false,
            "masters": [{"t_est": 1.0, "l_rows": 10,
                         "entries": [{"node": 1, "load": 20, "k": 1.0000000005, "b": 1.0}]}]}"#;
        let p = Plan::from_json(&parse(eps)).unwrap();
        assert_eq!(p.masters[0].entries[0].k, 1.0);
    }

    #[test]
    fn plan_spec_json_shim() {
        let sp = spec(Policy::Frac, LoadMethod::Sca);
        let back = PlanSpec::from_json(&sp.to_json()).unwrap();
        assert_eq!(back.policy, Policy::Frac);
        assert_eq!(back.loads, LoadMethod::Sca);
        assert_eq!(back.values, ValueModel::Markov);
    }
}
