//! Trial sampler + thread-parallel Monte-Carlo driver — **kernel v3**.
//!
//! Every figure, sweep cell and ablation bottoms out in this per-trial
//! loop, so it is the hottest path in the codebase. v2 is a
//! structure-of-arrays rework of the original AoS kernel (preserved
//! verbatim as [`oracle`] for parity tests and bench baselines):
//!
//! * **SoA compiled plans, family-tagged** — [`Compiled`] stores
//!   per-master flat columns (`comm_rate[]`, `shift[]`, `comp_rate[]`,
//!   `load[]`, straggler mixture) instead of `Vec<(LinkDelay, f64)>`,
//!   and the trial loop samples into reusable split key/payload buffers
//!   (`times: Vec<f64>`, `loads: Vec<f64>`) so the completion scan does
//!   branch-predictable plain-`f64` compares instead of tuple moves
//!   through a `partial_cmp` closure. Each link also carries a delay-
//!   family tag ([`crate::model::dist::DelayFamily`]): shifted-exp
//!   links keep the exact flat-column layout and arithmetic (pinned
//!   bit-for-bit against [`oracle`]), other families sample through
//!   their own scalar/vectorized fill paths.
//! * **Weighted-selection completion scan** — [`completion_scan`]
//!   replaces the full per-trial `sort_unstable` with a quickselect-style
//!   3-way partition that only ever sorts (and prefix-sums) the elements
//!   at or before the `Σ load ≥ L_m` crossing; the tail past the crossing
//!   is partitioned away untouched. Exactness note: the crossing test is
//!   a *sequential* floating-point prefix sum, so the scan accumulates in
//!   true sorted order (left partitions are resolved before the pivot
//!   block, the pivot block before the right) — bit-for-bit the same
//!   completion time as the legacy sort-then-scan, not merely the same in
//!   exact arithmetic.
//! * **Blocked sampling** (opt-in, [`SampleOrder::Blocked`]) — fills
//!   B-trial blocks column-per-link so per-link constants (rates, local /
//!   straggler branches) are hoisted out of the inner loop and the
//!   inverse-transform sampling runs as batched [`crate::util::rng::Rng::fill_exp`]
//!   column fills. **Bit contract:** blocked mode consumes the RNG in a
//!   different order than trial-major, so it produces *different bits
//!   from the same distribution* — statistically equivalent, never
//!   bit-equal. The default everywhere is [`SampleOrder::TrialMajor`],
//!   which reproduces the legacy kernel exactly.
//! * **Shared thread pool** — [`run`] submits shards to the process-wide
//!   [`crate::exec::pool`] instead of spawning fresh threads per call,
//!   and skips zero-trial trailing shards (`shard_sizes(4, 3) = [2,2,0]`)
//!   at submit time while preserving stream numbering.
//!
//! Kernel **v3** layers three things on top (PR 9):
//!
//! * **SIMD-chunked fills** — [`crate::util::rng::Rng::fill_f64`]/
//!   [`crate::util::rng::Rng::fill_exp`] and every
//!   [`crate::model::dist::DelayFamily::fill_block`] transform pass walk
//!   their columns in [`crate::util::rng::FILL_LANES`]-wide fixed-size
//!   chunks the autovectorizer can lower to SIMD lanes. Chunking changes
//!   no arithmetic and no draw order, so every existing bit contract
//!   survives.
//! * **[`SampleOrder::Chunked`] + ziggurat** — the blocked layout driven
//!   through the shared block core with thread-local scratch reuse
//!   across shards; bit-identical to [`SampleOrder::Blocked`] until
//!   [`McOptions::ziggurat`] swaps the exponential columns to the
//!   rejection sampler ([`crate::util::rng::Rng::fill_exp_zig`] — same
//!   law, variable RNG consumption, so distribution-equal only).
//! * **Arena-backed compile** — [`Compiled`] stores all masters' columns
//!   in one `ColumnArena` (a single allocation per column), and the
//!   batched engine's fused mode compiles a whole sweep grid into one
//!   arena, driving the same shard loops over per-cell column views.

use std::sync::Arc;

use crate::config::Scenario;
use crate::exec::pool;
use crate::model::dist::DelayFamily;
use crate::plan::Plan;
use crate::util::rng::Rng;
use crate::util::stats::{Ecdf, Summary};

/// Monte-Carlo options.
#[derive(Clone, Copy, Debug)]
pub struct McOptions {
    pub trials: usize,
    pub seed: u64,
    /// Keep raw per-trial system delays (needed for CDFs, Fig. 5).
    pub keep_samples: bool,
    /// RNG stream count (0 = all available cores). The split determines
    /// the sampled values bit-for-bit; actual parallelism comes from the
    /// shared process pool.
    pub threads: usize,
    /// Draw exponentials through the ziggurat rejection sampler
    /// (kernel v3). Only honored by [`SampleOrder::Chunked`] — the
    /// bit-exact orders ignore it (documented no-op), and chunked+zig
    /// is distribution-equal only.
    pub ziggurat: bool,
}

impl Default for McOptions {
    fn default() -> Self {
        Self {
            trials: 100_000,
            seed: 0x51D_E0,
            keep_samples: false,
            threads: 0,
            ziggurat: false,
        }
    }
}

/// RNG consumption order of the trial loop.
///
/// `TrialMajor` (default) draws link-by-link within each trial — the
/// legacy order, bit-for-bit reproducible across kernel versions.
/// `Blocked` fills B-trial blocks column-per-link: same delay
/// distribution, different bits (see the module docs' bit contract).
/// `Chunked` (kernel v3) is the blocked layout driven through the same
/// block core with thread-local scratch reuse across shards — bit-for-
/// bit identical to `Blocked` while `McOptions::ziggurat` is off, and
/// the only order that honors the ziggurat flag.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SampleOrder {
    #[default]
    TrialMajor,
    Blocked,
    Chunked,
}

impl SampleOrder {
    pub fn as_str(self) -> &'static str {
        match self {
            SampleOrder::TrialMajor => "trial_major",
            SampleOrder::Blocked => "blocked",
            SampleOrder::Chunked => "chunked",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "trial_major" | "trial-major" => Ok(SampleOrder::TrialMajor),
            "blocked" => Ok(SampleOrder::Blocked),
            "chunked" => Ok(SampleOrder::Chunked),
            other => anyhow::bail!("unknown sample order '{other}' (trial_major|blocked|chunked)"),
        }
    }
}

/// Aggregated Monte-Carlo results.
#[derive(Clone, Debug)]
pub struct McResults {
    /// Per-master completion-delay summaries.
    pub per_master: Vec<Summary>,
    /// System delay = max over masters, per trial.
    pub system: Summary,
    /// Raw system-delay samples (present iff `keep_samples`).
    pub samples: Option<Vec<f64>>,
    /// Raw per-master samples (present iff `keep_samples`).
    pub master_samples: Option<Vec<Vec<f64>>>,
}

impl McResults {
    /// ECDF of the system delay from a shared reference (one copy — the
    /// sorted vector must be owned). Prefer [`McResults::into_system_ecdf`]
    /// when the results are done: it moves the samples, zero copies.
    pub fn system_ecdf(&self) -> Option<Ecdf> {
        self.samples.as_deref().map(Ecdf::from_slice)
    }

    /// Consuming variant: moves the sample vector straight into the
    /// [`Ecdf`] — zero copies. Preferred when the results are done.
    pub fn into_system_ecdf(self) -> Option<Ecdf> {
        self.samples.map(Ecdf::new)
    }
}

// ----------------------------------------------------------------------
// Weighted-selection completion scan
// ----------------------------------------------------------------------

/// Below this range length the scan falls back to insertion sort — the
/// partition bookkeeping costs more than sorting outright.
const SCAN_SORT_CUTOFF: usize = 24;

/// Completion time of a coded master: the smallest sampled finish time
/// `t` at which the loads of all sub-tasks finished by `t` accumulate to
/// `l_rows` — evaluated with the exact floating-point semantics of
/// "sort by time, then `acc += load` in order until `acc ≥ l_rows`".
///
/// Both input slices are permuted in place (they are reusable per-trial
/// scratch). Returns `f64::INFINITY` when the total assigned load never
/// reaches `l_rows` (malformed plans: the task never completes).
///
/// Times must not be NaN (they are sums of finite sampled delays).
pub fn completion_scan(times: &mut [f64], loads: &mut [f64], l_rows: f64) -> f64 {
    debug_assert_eq!(times.len(), loads.len());
    let n = times.len();
    let mut acc = 0.0f64;
    scan_range(times, loads, 0, n, &mut acc, l_rows).unwrap_or(f64::INFINITY)
}

/// Resolve `[lo, hi)`: establish its elements in sorted position only as
/// far as the prefix sum needs, accumulating into `acc` in true sorted
/// order. `Some(t)` = crossing found at time `t`.
fn scan_range(
    times: &mut [f64],
    loads: &mut [f64],
    lo: usize,
    hi: usize,
    acc: &mut f64,
    target: f64,
) -> Option<f64> {
    if hi - lo <= SCAN_SORT_CUTOFF {
        insertion_sort_pair(times, loads, lo, hi);
        for i in lo..hi {
            *acc += loads[i];
            if *acc >= target {
                return Some(times[i]);
            }
        }
        return None;
    }
    let p = median3(times[lo], times[lo + (hi - lo) / 2], times[hi - 1]);
    let (lt, gt) = partition3(times, loads, lo, hi, p);
    // Everything < p, in sorted order, with exact sequential accumulation.
    if let Some(t) = scan_range(times, loads, lo, lt, acc, target) {
        return Some(t);
    }
    // The pivot block: every time equals p, so a crossing here is at p.
    for i in lt..gt {
        *acc += loads[i];
        if *acc >= target {
            return Some(times[i]);
        }
    }
    // Only now does the right side matter; the pivot guarantees progress
    // (the block is non-empty), so this terminates.
    scan_range(times, loads, gt, hi, acc, target)
}

#[inline]
fn median3(a: f64, b: f64, c: f64) -> f64 {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    if c < lo {
        lo
    } else if c > hi {
        hi
    } else {
        c
    }
}

/// Dutch-national-flag 3-way partition of `[lo, hi)` around the value
/// `p`, permuting `loads` alongside. Returns `(lt, gt)`:
/// `[lo, lt) < p`, `[lt, gt) == p`, `[gt, hi) > p`.
fn partition3(
    times: &mut [f64],
    loads: &mut [f64],
    lo: usize,
    hi: usize,
    p: f64,
) -> (usize, usize) {
    let (mut lt, mut i, mut gt) = (lo, lo, hi);
    while i < gt {
        let t = times[i];
        if t < p {
            times.swap(lt, i);
            loads.swap(lt, i);
            lt += 1;
            i += 1;
        } else if t > p {
            gt -= 1;
            times.swap(i, gt);
            loads.swap(i, gt);
        } else {
            i += 1;
        }
    }
    (lt, gt)
}

fn insertion_sort_pair(times: &mut [f64], loads: &mut [f64], lo: usize, hi: usize) {
    for i in (lo + 1)..hi {
        let (t, l) = (times[i], loads[i]);
        let mut j = i;
        while j > lo && times[j - 1] > t {
            times[j] = times[j - 1];
            loads[j] = loads[j - 1];
            j -= 1;
        }
        times[j] = t;
        loads[j] = l;
    }
}

// ----------------------------------------------------------------------
// SoA compiled plans
// ----------------------------------------------------------------------

/// Flat sampling columns for a set of compiled masters, family-tagged —
/// ONE allocation per column across all masters (kernel v3's fused
/// arena), instead of a `Vec` per master per column. A master is a
/// contiguous `[start, start + len)` slice of every column, described by
/// its [`MasterMeta`]; [`ColumnArena::master`] hands out the borrowed
/// [`MasterCols`] view the trial loops sample through.
///
/// `strag_prob < 0` encodes "no straggler mixture attached" — the
/// distinction matters beyond the probability value because an attached
/// mixture consumes one uniform draw per sample even when it does not
/// fire.
///
/// `fams[i] = None` marks the shifted-exponential fast path: the link
/// samples from the flat `shift[]`/`comp_rate[]` columns with the exact
/// pre-family arithmetic (pinned by the column-layout and oracle parity
/// tests). `Some(fam)` holds any other family, compiled to block scale
/// (`l/k`), with its own scalar and vectorized fill paths — `shift[i]`
/// and `comp_rate[i]` carry NaN poison for those links and are never
/// read.
#[derive(Default)]
pub(crate) struct ColumnArena {
    comm_rate: Vec<f64>, // ∞ = local link (no comm leg, no comm draw)
    shift: Vec<f64>,
    comp_rate: Vec<f64>,
    load: Vec<f64>,
    strag_prob: Vec<f64>,
    strag_slow: Vec<f64>,
    fams: Vec<Option<DelayFamily>>,
    /// Scenario node id per link (0 = master-local, `w ≥ 1` = worker w) —
    /// the serving layer's key into per-worker [`CapacityProfile`]s. Not
    /// read by the batch trial loops.
    nodes: Vec<usize>,
    meta: Vec<MasterMeta>,
}

/// Where one master's links live in the arena columns, plus its
/// completion parameters.
struct MasterMeta {
    start: usize,
    len: usize,
    l_rows: f64,
    uncoded: bool,
}

impl ColumnArena {
    /// Pre-size for `n_masters` masters totalling `n_links` links
    /// (grow-free pushes when the estimates are exact; still correct
    /// when they are not).
    pub(crate) fn with_capacity(n_masters: usize, n_links: usize) -> Self {
        ColumnArena {
            comm_rate: Vec::with_capacity(n_links),
            shift: Vec::with_capacity(n_links),
            comp_rate: Vec::with_capacity(n_links),
            load: Vec::with_capacity(n_links),
            strag_prob: Vec::with_capacity(n_links),
            strag_slow: Vec::with_capacity(n_links),
            fams: Vec::with_capacity(n_links),
            nodes: Vec::with_capacity(n_links),
            meta: Vec::with_capacity(n_masters),
        }
    }

    /// Compile master `m` of `(s, plan-master mp)` and append its links.
    /// Returns the arena index of the new master.
    pub(crate) fn push_master(
        &mut self,
        s: &Scenario,
        m: usize,
        mp: &crate::plan::MasterPlan,
        uncoded: bool,
    ) -> usize {
        let start = self.comm_rate.len();
        for e in &mp.entries {
            // One source of truth for the parameterization: compile
            // through the scenario's family-aware LinkDelay (eq. 3 for
            // shifted-exp links — the exact legacy arithmetic — or a
            // block-scaled family), then flatten.
            let d = s.link_delay(m, e.node, e.load, e.k, e.b);
            self.comm_rate.push(d.comm_rate());
            match d.comp() {
                DelayFamily::ShiftedExp { shift, rate } => {
                    self.shift.push(*shift);
                    self.comp_rate.push(*rate);
                    self.fams.push(None);
                }
                fam => {
                    // Poison the unused flat columns: the family arm
                    // never reads them.
                    self.shift.push(f64::NAN);
                    self.comp_rate.push(f64::NAN);
                    self.fams.push(Some(fam.clone()));
                }
            }
            self.load.push(e.load);
            self.nodes.push(e.node);
            match d.straggler() {
                Some(st) => {
                    self.strag_prob.push(st.prob);
                    self.strag_slow.push(st.slowdown);
                }
                None => {
                    self.strag_prob.push(-1.0);
                    self.strag_slow.push(1.0);
                }
            }
        }
        self.meta.push(MasterMeta {
            start,
            len: mp.entries.len(),
            l_rows: mp.l_rows,
            uncoded,
        });
        self.meta.len() - 1
    }

    pub(crate) fn n_masters(&self) -> usize {
        self.meta.len()
    }

    /// Borrowed per-master column view — the sampling surface of the
    /// trial loops.
    pub(crate) fn master(&self, m: usize) -> MasterCols<'_> {
        let meta = &self.meta[m];
        let r = meta.start..meta.start + meta.len;
        MasterCols {
            comm_rate: &self.comm_rate[r.clone()],
            shift: &self.shift[r.clone()],
            comp_rate: &self.comp_rate[r.clone()],
            load: &self.load[r.clone()],
            strag_prob: &self.strag_prob[r.clone()],
            strag_slow: &self.strag_slow[r.clone()],
            fams: &self.fams[r.clone()],
            nodes: &self.nodes[r],
            l_rows: meta.l_rows,
            uncoded: meta.uncoded,
        }
    }
}

/// One master's borrowed slice of the [`ColumnArena`] columns. All
/// sampling methods live here so the plain engine, the serving layer
/// and the fused batch grid drive the identical trial code.
pub(crate) struct MasterCols<'a> {
    comm_rate: &'a [f64],
    shift: &'a [f64],
    comp_rate: &'a [f64],
    load: &'a [f64],
    strag_prob: &'a [f64],
    strag_slow: &'a [f64],
    fams: &'a [Option<DelayFamily>],
    nodes: &'a [usize],
    l_rows: f64,
    uncoded: bool,
}

impl MasterCols<'_> {
    /// One delay draw for link `i` — the exact RNG consumption of
    /// `LinkDelay::sample`: comm leg (non-local only), straggler uniform
    /// (attached mixtures only), computation draw (family-specific; the
    /// shifted-exp arm is the legacy `shift + Exp(rate)`).
    #[inline]
    fn draw(&self, rng: &mut Rng, i: usize) -> f64 {
        let (comm, comp) = self.draw_parts(rng, i);
        comm + comp
    }

    /// [`MasterCols::draw`] split into its `(comm, computation)` legs
    /// (straggler factor already applied to the computation leg; the
    /// sum `comm + comp` is bit-for-bit the `draw` value). The warped
    /// sampler needs the legs separately: worker-capacity changes
    /// stretch computation, never the network transfer.
    #[inline]
    fn draw_parts(&self, rng: &mut Rng, i: usize) -> (f64, f64) {
        let comm = if self.comm_rate[i].is_finite() {
            rng.exp(self.comm_rate[i])
        } else {
            0.0
        };
        let factor = if self.strag_prob[i] >= 0.0 {
            if rng.f64() < self.strag_prob[i] {
                self.strag_slow[i]
            } else {
                1.0
            }
        } else {
            1.0
        };
        let comp = match &self.fams[i] {
            None => self.shift[i] + rng.exp(self.comp_rate[i]),
            Some(fam) => fam.sample(rng),
        };
        (comm, factor * comp)
    }

    /// Trial-major completion sample (bit-compatible with the legacy
    /// kernel: same draws, same completion arithmetic).
    fn sample_trial(&self, rng: &mut Rng, times: &mut Vec<f64>, loads: &mut Vec<f64>) -> f64 {
        let n = self.comm_rate.len();
        if self.uncoded {
            // Every sub-task must finish.
            let mut mx = 0.0f64;
            for i in 0..n {
                mx = f64::max(mx, self.draw(rng, i));
            }
            return mx;
        }
        times.clear();
        for i in 0..n {
            times.push(self.draw(rng, i));
        }
        loads.clear();
        loads.extend_from_slice(&self.load);
        completion_scan(times, loads, self.l_rows)
    }

    /// Blocked completion samples for `nb` trials: per link, fill one
    /// column of comm draws, straggler uniforms and computation draws,
    /// then scan each trial's gathered row. Different RNG order than
    /// [`MasterCols::sample_trial`] (see the module bit contract).
    /// `zig` routes every exponential column through the ziggurat
    /// ([`Rng::fill_exp_zig`]) — a further different-bits mode on top.
    #[allow(clippy::too_many_arguments)]
    fn sample_block(
        &self,
        rng: &mut Rng,
        nb: usize,
        cols: &mut [f64],
        comm_buf: &mut [f64],
        u_buf: &mut [f64],
        fam_buf: &mut [f64],
        times: &mut [f64],
        loads: &mut [f64],
        out: &mut [f64],
        zig: bool,
    ) {
        let n = self.comm_rate.len();
        debug_assert!(cols.len() >= n * nb || self.uncoded);
        if self.uncoded {
            // Running max over link columns; one column buffer suffices.
            out.fill(0.0);
            let col = &mut cols[..nb];
            for i in 0..n {
                self.fill_link_column(rng, i, col, comm_buf, u_buf, fam_buf, zig);
                for (o, &t) in out.iter_mut().zip(col.iter()) {
                    *o = f64::max(*o, t);
                }
            }
            return;
        }
        for i in 0..n {
            self.fill_link_column(
                rng,
                i,
                &mut cols[i * nb..(i + 1) * nb],
                comm_buf,
                u_buf,
                fam_buf,
                zig,
            );
        }
        for (t, o) in out.iter_mut().enumerate() {
            for i in 0..n {
                times[i] = cols[i * nb + t];
            }
            loads[..n].copy_from_slice(self.load);
            *o = completion_scan(&mut times[..n], &mut loads[..n], self.l_rows);
        }
    }

    /// Fill `col` with `col.len()` delay draws of link `i`. Leg order per
    /// column mirrors the per-trial leg order (comm, straggler uniform,
    /// computation), with the local / straggler / family branches
    /// hoisted out of the element loops. The shifted-exp arm's combine
    /// arithmetic is value-identical to the pre-family code (same adds
    /// in the same order); other families fill through their own
    /// vectorized [`DelayFamily::fill_block`] path (`fam_buf` is the
    /// bimodal arm's mixture-uniform scratch). `zig = true` swaps every
    /// exponential fill to [`Rng::fill_exp_zig`] (distribution-equal,
    /// different bits).
    #[allow(clippy::too_many_arguments)]
    fn fill_link_column(
        &self,
        rng: &mut Rng,
        i: usize,
        col: &mut [f64],
        comm_buf: &mut [f64],
        u_buf: &mut [f64],
        fam_buf: &mut [f64],
        zig: bool,
    ) {
        let nb = col.len();
        let local = !self.comm_rate[i].is_finite();
        let strag = self.strag_prob[i] >= 0.0;
        if !local {
            if zig {
                rng.fill_exp_zig(self.comm_rate[i], &mut comm_buf[..nb]);
            } else {
                rng.fill_exp(self.comm_rate[i], &mut comm_buf[..nb]);
            }
        }
        if strag {
            rng.fill_f64(&mut u_buf[..nb]);
        }
        match &self.fams[i] {
            None => {
                if zig {
                    rng.fill_exp_zig(self.comp_rate[i], col);
                } else {
                    rng.fill_exp(self.comp_rate[i], col);
                }
                let shift = self.shift[i];
                for c in col.iter_mut() {
                    *c = shift + *c;
                }
            }
            Some(fam) => fam.fill_block_opts(rng, col, &mut fam_buf[..nb], zig),
        }
        match (local, strag) {
            (true, false) => {}
            (false, false) => {
                for (c, &comm) in col.iter_mut().zip(comm_buf.iter()) {
                    *c = comm + *c;
                }
            }
            (true, true) => {
                let (p, s) = (self.strag_prob[i], self.strag_slow[i]);
                for (c, &u) in col.iter_mut().zip(u_buf.iter()) {
                    let f = if u < p { s } else { 1.0 };
                    *c = f * *c;
                }
            }
            (false, true) => {
                let (p, s) = (self.strag_prob[i], self.strag_slow[i]);
                for ((c, &comm), &u) in col.iter_mut().zip(comm_buf.iter()).zip(u_buf.iter()) {
                    let f = if u < p { s } else { 1.0 };
                    *c = comm + f * *c;
                }
            }
        }
    }
}

/// Precompiled `(scenario, plan)` sampling state, reusable across RNG
/// streams. Shared by [`run`] and the batched engine
/// ([`crate::exec::BatchRunner`]) so both sample the exact same way.
/// Since kernel v3 the columns live in one `ColumnArena` (a single
/// allocation per column across masters); the batched engine's fused
/// mode goes one step further and compiles a whole cell *grid* into one
/// arena through the same `ColumnArena::push_master` path.
pub struct Compiled {
    arena: ColumnArena,
    max_links: usize,
}

impl Compiled {
    pub fn new(s: &Scenario, plan: &Plan) -> Self {
        let n_links = plan.masters.iter().map(|mp| mp.entries.len()).sum();
        let mut arena = ColumnArena::with_capacity(plan.masters.len(), n_links);
        for (m, mp) in plan.masters.iter().enumerate() {
            arena.push_master(s, m, mp, plan.uncoded);
        }
        let max_links = (0..arena.n_masters())
            .map(|m| arena.meta[m].len)
            .max()
            .unwrap_or(0);
        Compiled { arena, max_links }
    }

    pub fn n_masters(&self) -> usize {
        self.arena.n_masters()
    }

    /// Link count of master `m`'s compiled plan.
    pub fn n_links(&self, m: usize) -> usize {
        self.arena.meta[m].len
    }

    /// Scenario node id of link `i` of master `m` (0 = master-local).
    pub fn node_of(&self, m: usize, i: usize) -> usize {
        self.arena.master(m).nodes[i]
    }

    /// Borrowed column view of master `m`.
    pub(crate) fn master(&self, m: usize) -> MasterCols<'_> {
        self.arena.master(m)
    }

    /// One completion sample of master `m` — exactly the per-master draw
    /// of the trial loop ([`run_shard`] consumes the RNG through this
    /// same code), exposed so the serving layer can sample jobs one at a
    /// time from its own stream. `times`/`loads` are reusable scratch.
    pub fn sample_master(
        &self,
        m: usize,
        rng: &mut Rng,
        times: &mut Vec<f64>,
        loads: &mut Vec<f64>,
    ) -> f64 {
        self.arena.master(m).sample_trial(rng, times, loads)
    }

    /// Time-varying-share completion sample: draws each link's delay
    /// exactly like [`Compiled::sample_master`] (identical RNG
    /// consumption, link order preserved), then warps each link's
    /// COMPUTATION leg through its node's [`CapacityProfile`] — the leg
    /// starts when the transfer lands (`t0 + comm`), and capacity
    /// changes stretch computation only, consistent with plan-time
    /// throttling scaling the fitted compute rate `u` and leaving the
    /// comm parameters alone (a transfer in flight completes; the
    /// worker's compute on it suspends or slows).
    ///
    /// `profiles` is indexed by scenario node id (index 0 — the
    /// master-local processor — is conventionally the constant profile;
    /// churn scripts only address shared workers). **Bit contract:**
    /// when every referenced profile is constant at and after `t0`, the
    /// warp is the exact identity and the legs recombine as `comm +
    /// comp` — bit-for-bit the [`Compiled::sample_master`] value; that
    /// is the constant-share/no-churn ≡ batch-engine guarantee the
    /// serving layer's parity tests pin.
    pub fn sample_master_warped(
        &self,
        m: usize,
        rng: &mut Rng,
        t0: f64,
        profiles: &[CapacityProfile],
        times: &mut Vec<f64>,
        loads: &mut Vec<f64>,
    ) -> f64 {
        let sim = self.arena.master(m);
        let n = sim.comm_rate.len();
        times.clear();
        for i in 0..n {
            let (comm, comp) = sim.draw_parts(rng, i);
            let node = sim.nodes[i];
            debug_assert!(node < profiles.len(), "no capacity profile for node {node}");
            times.push(match profiles.get(node) {
                Some(p) => comm + p.warp_scaled(t0, t0 + comm, comp),
                None => comm + comp,
            });
        }
        if sim.uncoded {
            // Every sub-task must finish — same fold as `sample_trial`.
            let mut mx = 0.0f64;
            for &t in times.iter() {
                mx = f64::max(mx, t);
            }
            return mx;
        }
        loads.clear();
        loads.extend_from_slice(&sim.load);
        completion_scan(times, loads, sim.l_rows)
    }
}

// ----------------------------------------------------------------------
// Time-varying shares (piecewise-constant capacity profiles)
// ----------------------------------------------------------------------

/// Piecewise-constant capacity of one node over absolute virtual time —
/// the engine's time-varying-share mode. Factors are RELATIVE to the
/// capacity the plan was compiled with: 1.0 = as planned, 0.5 = running
/// at half the planned rate (a mid-job throttle), 0.0 = away (a worker
/// that left; its in-flight work suspends and resumes on rejoin).
///
/// Before the first breakpoint the factor is 1.0; breakpoint `i` sets
/// the factor on `[times[i], times[i+1])` (left-closed).
///
/// A sub-task sampled with duration `d` at admission time `t0` (under
/// the factor in force at `t0`) completes after the smallest `T` with
/// `∫_{t0}^{t0+T} f(τ) dτ = d·f(t0)` — the standard processor-sharing
/// time change. When the factor never changes on `[t0, ∞)` the warp is
/// the exact identity (`T = d`, same bits), which keeps the
/// constant-share fast path bit-for-bit.
#[derive(Clone, Debug, Default)]
pub struct CapacityProfile {
    times: Vec<f64>,
    factors: Vec<f64>,
}

impl CapacityProfile {
    /// The always-at-planned-capacity profile (no breakpoints).
    pub fn constant() -> Self {
        Self::default()
    }

    /// Build from `(time, factor)` breakpoints. Times must be finite,
    /// non-negative and non-decreasing; factors finite and ≥ 0. Equal
    /// times are allowed — the later breakpoint wins.
    pub fn from_breakpoints(points: Vec<(f64, f64)>) -> anyhow::Result<Self> {
        let mut prev = 0.0f64;
        for &(t, f) in &points {
            anyhow::ensure!(
                t.is_finite() && t >= 0.0,
                "capacity breakpoint time {t} must be finite and ≥ 0"
            );
            anyhow::ensure!(
                t >= prev,
                "capacity breakpoints must be non-decreasing ({t} after {prev})"
            );
            anyhow::ensure!(
                f.is_finite() && f >= 0.0,
                "capacity factor {f} must be finite and ≥ 0"
            );
            prev = t;
        }
        let (times, factors) = points.into_iter().unzip();
        Ok(Self { times, factors })
    }

    /// `true` when the profile never deviates from planned capacity.
    pub fn is_constant(&self) -> bool {
        self.factors.iter().all(|&f| f == 1.0)
    }

    /// Capacity factor in force at absolute time `t`.
    pub fn factor_at(&self, t: f64) -> f64 {
        let idx = self.times.partition_point(|&bt| bt <= t);
        if idx == 0 {
            1.0
        } else {
            self.factors[idx - 1]
        }
    }

    /// Completion duration of a sub-task sampled with duration `d` at
    /// admission time `t0` (see the type docs for the time-change
    /// semantics). Returns `d` EXACTLY (no float round-trip) when the
    /// factor is constant from `t0` on; `∞` when capacity drops to zero
    /// forever before the work completes.
    pub fn warp(&self, t0: f64, d: f64) -> f64 {
        self.warp_scaled(t0, t0, d)
    }

    /// As [`CapacityProfile::warp`], but the work begins at `t_start ≥
    /// t_admit` while the duration `d` was sampled under the capacity
    /// in force at `t_admit` — the serving layer's computation legs
    /// start only when the transfer lands (`t_admit + comm`), yet their
    /// sampled duration reflects the plan compiled at admission.
    pub fn warp_scaled(&self, t_admit: f64, t_start: f64, d: f64) -> f64 {
        if self.times.is_empty() {
            return d;
        }
        let f_admit = self.factor_at(t_admit);
        // `d` encodes `d·f_admit` unit-capacity work. Admission at zero
        // capacity never happens through serving (absent workers are
        // not planned onto), but the API stays total: read `d` as
        // unit-capacity work then — zero capacity forever ⇒ ∞. This
        // case must bypass the constant-after fast path: a forever-zero
        // tail is "constant" yet must not return `d`.
        let need = if f_admit > 0.0 { d * f_admit } else { d };
        let idx = self.times.partition_point(|&bt| bt <= t_start);
        let f_start = if idx == 0 { 1.0 } else { self.factors[idx - 1] };
        // Exact-identity fast path: capacity stays at the admission
        // level from the work's start onward — bit-for-bit `d`.
        if f_admit > 0.0
            && f_start == f_admit
            && self.factors[idx..].iter().all(|&f| f == f_admit)
        {
            return d;
        }
        self.warp_from(t_start, need, idx, f_start)
    }

    /// Walk segments from `cur = t0` (current factor `f`, next
    /// breakpoint index `idx`) until `need` unit-capacity work is done.
    fn warp_from(&self, t0: f64, mut need: f64, mut idx: usize, mut f: f64) -> f64 {
        let mut cur = t0;
        loop {
            let end = self.times.get(idx).copied().unwrap_or(f64::INFINITY);
            if f > 0.0 {
                if end.is_infinite() || f * (end - cur) >= need {
                    return cur + need / f - t0;
                }
                need -= f * (end - cur);
            } else if end.is_infinite() {
                return f64::INFINITY;
            }
            cur = end;
            f = self.factors[idx];
            idx += 1;
        }
    }
}

// ----------------------------------------------------------------------
// Shard primitives
// ----------------------------------------------------------------------

/// The RNG-stream count [`run`] uses for a request: `threads` if nonzero,
/// else all cores, never more than `trials`. The split determines the
/// sampled values bit-for-bit, so anything that must reproduce a
/// `sim::run` result (the batched engine, golden-parity tests) goes
/// through this same function.
pub fn effective_streams(trials: usize, threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(trials.max(1))
    } else {
        threads
    }
}

/// Per-stream trial counts: ceil split of `trials` over `streams`
/// (trailing streams may receive 0 — kept for stream-id stability).
pub fn shard_sizes(trials: usize, streams: usize) -> Vec<usize> {
    let per = trials.div_ceil(streams);
    (0..streams)
        .map(|ti| per.min(trials.saturating_sub(ti * per)))
        .collect()
}

/// Output of one RNG stream's worth of trials.
pub struct ShardOut {
    pub per_master: Vec<Summary>,
    pub system: Summary,
    pub samples: Vec<f64>,
    pub master_samples: Vec<Vec<f64>>,
}

impl ShardOut {
    /// What a zero-trial shard produces — exactly `run_shard(.., 0, ..)`,
    /// so skipping empty shards at spawn time cannot change a merge.
    pub fn empty(m_cnt: usize, keep_samples: bool) -> Self {
        ShardOut {
            per_master: vec![Summary::new(); m_cnt],
            system: Summary::new(),
            samples: Vec::new(),
            master_samples: if keep_samples {
                vec![Vec::new(); m_cnt]
            } else {
                vec![]
            },
        }
    }
}

/// Run `trials` trials on RNG stream `stream` (1-based, exactly how
/// [`run`] numbers its threads) of the generator seeded by `seed`, in
/// the default trial-major order.
pub fn run_shard(
    c: &Compiled,
    seed: u64,
    stream: u64,
    trials: usize,
    keep_samples: bool,
) -> ShardOut {
    run_shard_ordered(c, seed, stream, trials, keep_samples, SampleOrder::TrialMajor)
}

/// [`run_shard`] with an explicit RNG consumption order.
pub fn run_shard_ordered(
    c: &Compiled,
    seed: u64,
    stream: u64,
    trials: usize,
    keep_samples: bool,
    order: SampleOrder,
) -> ShardOut {
    run_shard_opts(c, seed, stream, trials, keep_samples, order, false)
}

/// [`run_shard_ordered`] plus the kernel-v3 ziggurat flag (honored by
/// [`SampleOrder::Chunked`] only; a documented no-op for the bit-exact
/// orders).
pub fn run_shard_opts(
    c: &Compiled,
    seed: u64,
    stream: u64,
    trials: usize,
    keep_samples: bool,
    order: SampleOrder,
    ziggurat: bool,
) -> ShardOut {
    let views: Vec<MasterCols<'_>> = (0..c.n_masters()).map(|m| c.arena.master(m)).collect();
    run_shard_cols(
        &views,
        c.max_links,
        seed,
        stream,
        trials,
        keep_samples,
        order,
        ziggurat,
    )
}

/// Column-view shard entry point: the same trial loops, driven by any
/// set of [`MasterCols`] — a [`Compiled`] plan's own masters, or a
/// sub-range of the batched engine's fused grid arena. Everything above
/// ([`run_shard`] and friends) funnels here.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_shard_cols(
    masters: &[MasterCols<'_>],
    max_links: usize,
    seed: u64,
    stream: u64,
    trials: usize,
    keep_samples: bool,
    order: SampleOrder,
    ziggurat: bool,
) -> ShardOut {
    match order {
        SampleOrder::TrialMajor => {
            run_shard_trial_major(masters, max_links, seed, stream, trials, keep_samples)
        }
        // Blocked keeps its pre-v3 behavior exactly: fresh scratch per
        // shard, inverse-transform exponentials (the ziggurat flag is
        // ignored by the non-chunked orders).
        SampleOrder::Blocked => {
            let mut scratch = BlockScratch::default();
            run_shard_block_core(
                masters,
                max_links,
                seed,
                stream,
                trials,
                keep_samples,
                false,
                &mut scratch,
            )
        }
        // Chunked shares the identical block core (bit-for-bit Blocked
        // while ziggurat is off) and reuses thread-local scratch across
        // shards — buffer contents never leak into results (every read
        // range is written first), only the allocations are recycled.
        SampleOrder::Chunked => CHUNK_SCRATCH.with(|s| {
            let mut scratch = s.borrow_mut();
            run_shard_block_core(
                masters,
                max_links,
                seed,
                stream,
                trials,
                keep_samples,
                ziggurat,
                &mut scratch,
            )
        }),
    }
}

fn run_shard_trial_major(
    masters: &[MasterCols<'_>],
    max_links: usize,
    seed: u64,
    stream: u64,
    trials: usize,
    keep_samples: bool,
) -> ShardOut {
    let m_cnt = masters.len();
    let mut rng = Rng::new(seed).fork(stream);
    let mut per_master = vec![Summary::new(); m_cnt];
    let mut system = Summary::new();
    let mut samples = Vec::with_capacity(if keep_samples { trials } else { 0 });
    let mut master_samples = if keep_samples {
        vec![Vec::with_capacity(trials); m_cnt]
    } else {
        vec![]
    };
    let mut times: Vec<f64> = Vec::with_capacity(max_links);
    let mut loads: Vec<f64> = Vec::with_capacity(max_links);
    for _ in 0..trials {
        let mut sys = 0.0f64;
        for (m, sim) in masters.iter().enumerate() {
            let t = sim.sample_trial(&mut rng, &mut times, &mut loads);
            per_master[m].push(t);
            if keep_samples {
                master_samples[m].push(t);
            }
            sys = sys.max(t);
        }
        system.push(sys);
        if keep_samples {
            samples.push(sys);
        }
    }
    ShardOut {
        per_master,
        system,
        samples,
        master_samples,
    }
}

/// Trials per block in [`SampleOrder::Blocked`]/[`SampleOrder::Chunked`]:
/// big enough to amortize per-link constants and keep the `fill_exp`
/// columns in the vectorizable sweet spot, small enough that the
/// per-master column matrix (`max_links × BLOCK_TRIALS` doubles) stays
/// cache-resident.
const BLOCK_TRIALS: usize = 256;

/// Reusable buffers of the block sampler. Grow-only: a scratch that has
/// seen a big shard serves smaller ones without reallocating, which is
/// the point of the chunked order's thread-local reuse (the blocked
/// order builds a fresh one per shard — same values either way, since
/// every read range is overwritten before use).
#[derive(Default)]
struct BlockScratch {
    vals: Vec<f64>,
    cols: Vec<f64>,
    comm: Vec<f64>,
    u: Vec<f64>,
    fam: Vec<f64>,
    times: Vec<f64>,
    loads: Vec<f64>,
}

impl BlockScratch {
    fn ensure(&mut self, m_cnt: usize, max_links: usize, b: usize) {
        fn grow(v: &mut Vec<f64>, n: usize) {
            if v.len() < n {
                v.resize(n, 0.0);
            }
        }
        grow(&mut self.vals, m_cnt * b);
        grow(&mut self.cols, max_links.max(1) * b);
        grow(&mut self.comm, b);
        grow(&mut self.u, b);
        grow(&mut self.fam, b);
        grow(&mut self.times, max_links);
        grow(&mut self.loads, max_links);
    }
}

thread_local! {
    /// Per-thread scratch of [`SampleOrder::Chunked`] shards — each pool
    /// worker recycles its block buffers across every shard (and every
    /// sweep cell) it executes.
    static CHUNK_SCRATCH: std::cell::RefCell<BlockScratch> =
        std::cell::RefCell::new(BlockScratch::default());
}

#[allow(clippy::too_many_arguments)]
fn run_shard_block_core(
    masters: &[MasterCols<'_>],
    max_links: usize,
    seed: u64,
    stream: u64,
    trials: usize,
    keep_samples: bool,
    zig: bool,
    scratch: &mut BlockScratch,
) -> ShardOut {
    let m_cnt = masters.len();
    let mut rng = Rng::new(seed).fork(stream);
    let mut per_master = vec![Summary::new(); m_cnt];
    let mut system = Summary::new();
    let mut samples = Vec::with_capacity(if keep_samples { trials } else { 0 });
    let mut master_samples = if keep_samples {
        vec![Vec::with_capacity(trials); m_cnt]
    } else {
        vec![]
    };
    let b = BLOCK_TRIALS.min(trials.max(1));
    scratch.ensure(m_cnt, max_links, b);
    let BlockScratch {
        vals,
        cols,
        comm,
        u,
        fam,
        times,
        loads,
    } = scratch;
    let mut done = 0usize;
    while done < trials {
        let nb = b.min(trials - done);
        for (m, sim) in masters.iter().enumerate() {
            sim.sample_block(
                &mut rng,
                nb,
                cols,
                comm,
                u,
                fam,
                times,
                loads,
                &mut vals[m * b..m * b + nb],
                zig,
            );
        }
        // Same push/merge sequence per trial as trial-major, so summary
        // accumulation is structurally identical — only values differ.
        for t in 0..nb {
            let mut sys = 0.0f64;
            for (m, acc) in per_master.iter_mut().enumerate() {
                let v = vals[m * b + t];
                acc.push(v);
                if keep_samples {
                    master_samples[m].push(v);
                }
                sys = sys.max(v);
            }
            system.push(sys);
            if keep_samples {
                samples.push(sys);
            }
        }
        done += nb;
    }
    ShardOut {
        per_master,
        system,
        samples,
        master_samples,
    }
}

/// Merge shard outputs **in stream order** into aggregate results. The
/// order matters bit-for-bit: Welford merges and sample concatenation
/// happen exactly as [`run`] performs them.
pub fn merge_shards(m_cnt: usize, outs: Vec<ShardOut>, keep_samples: bool) -> McResults {
    let mut per_master = vec![Summary::new(); m_cnt];
    let mut system = Summary::new();
    let mut samples = Vec::new();
    let mut master_samples = vec![Vec::new(); m_cnt];
    for o in outs {
        for (acc, s) in per_master.iter_mut().zip(&o.per_master) {
            acc.merge(s);
        }
        system.merge(&o.system);
        samples.extend(o.samples);
        for (acc, v) in master_samples.iter_mut().zip(o.master_samples) {
            acc.extend(v);
        }
    }
    McResults {
        per_master,
        system,
        samples: keep_samples.then_some(samples),
        master_samples: keep_samples.then_some(master_samples),
    }
}

/// Run the Monte-Carlo evaluation of `plan` on `s` (trial-major order).
pub fn run(s: &Scenario, plan: &Plan, opts: &McOptions) -> McResults {
    run_ordered(s, plan, opts, SampleOrder::TrialMajor)
}

/// [`run`] with an explicit RNG consumption order. Shards execute on the
/// shared process pool ([`crate::exec::pool`]); zero-trial trailing
/// shards are never submitted (their merge contribution is the empty
/// [`ShardOut`], injected in stream order).
pub fn run_ordered(s: &Scenario, plan: &Plan, opts: &McOptions, order: SampleOrder) -> McResults {
    let compiled = Arc::new(Compiled::new(s, plan));
    let m_cnt = compiled.n_masters();
    let streams = effective_streams(opts.trials, opts.threads);
    let sizes = shard_sizes(opts.trials, streams);
    let (seed, keep, zig) = (opts.seed, opts.keep_samples, opts.ziggurat);
    let thunks: Vec<_> = sizes
        .iter()
        .enumerate()
        .filter(|&(_, &t)| t > 0)
        .map(|(ti, &t)| {
            let c = Arc::clone(&compiled);
            move || {
                (
                    ti,
                    run_shard_opts(&c, seed, ti as u64 + 1, t, keep, order, zig),
                )
            }
        })
        .collect();
    let mut slots: Vec<Option<ShardOut>> = sizes.iter().map(|_| None).collect();
    for (ti, out) in pool::run_all(thunks) {
        slots[ti] = Some(out);
    }
    let outs: Vec<ShardOut> = slots
        .into_iter()
        .map(|o| o.unwrap_or_else(|| ShardOut::empty(m_cnt, keep)))
        .collect();
    merge_shards(m_cnt, outs, keep)
}

// ----------------------------------------------------------------------
// Legacy kernel (parity oracle)
// ----------------------------------------------------------------------

/// The pre-v2 AoS kernel, preserved as a reference implementation.
///
/// Kept for two consumers only: the bit-for-bit parity tests (kernel v2
/// in trial-major order must reproduce it exactly) and the
/// `benches/engine.rs` old-vs-new trajectory rows. Not for production
/// paths — it re-sorts every trial and spawns threads per run.
///
/// The sampling/merging loops are verbatim legacy; the compile step now
/// routes through the family-aware [`Scenario::link_delay`] (identical
/// `LinkDelay` for shifted-exp links), so the oracle doubles as the
/// parity reference for every delay family — `LinkDelay::sample` and
/// the SoA kernel consume the RNG identically per link.
pub mod oracle {
    use super::{
        effective_streams, merge_shards, shard_sizes, McOptions, McResults, ShardOut,
    };
    use crate::config::Scenario;
    use crate::model::dist::LinkDelay;
    use crate::plan::Plan;
    use crate::util::rng::Rng;
    use crate::util::stats::Summary;

    struct MasterSim {
        links: Vec<(LinkDelay, f64)>,
        l_rows: f64,
        uncoded: bool,
    }

    impl MasterSim {
        fn sample(&self, rng: &mut Rng, scratch: &mut Vec<(f64, f64)>) -> f64 {
            if self.uncoded {
                return self
                    .links
                    .iter()
                    .map(|(d, _)| d.sample(rng))
                    .fold(0.0, f64::max);
            }
            scratch.clear();
            for (d, l) in &self.links {
                scratch.push((d.sample(rng), *l));
            }
            scratch.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let mut acc = 0.0;
            for &(t, l) in scratch.iter() {
                acc += l;
                if acc >= self.l_rows {
                    return t;
                }
            }
            f64::INFINITY
        }
    }

    /// AoS compiled state (the legacy `Compiled`).
    pub struct Compiled {
        sims: Vec<MasterSim>,
    }

    impl Compiled {
        pub fn new(s: &Scenario, plan: &Plan) -> Self {
            let sims = plan
                .masters
                .iter()
                .enumerate()
                .map(|(m, mp)| MasterSim {
                    links: mp
                        .entries
                        .iter()
                        .map(|e| {
                            (s.link_delay(m, e.node, e.load, e.k, e.b), e.load)
                        })
                        .collect(),
                    l_rows: mp.l_rows,
                    uncoded: plan.uncoded,
                })
                .collect();
            Compiled { sims }
        }

        pub fn n_masters(&self) -> usize {
            self.sims.len()
        }
    }

    /// The legacy shard loop, verbatim.
    pub fn run_shard(
        c: &Compiled,
        seed: u64,
        stream: u64,
        trials: usize,
        keep_samples: bool,
    ) -> ShardOut {
        let m_cnt = c.sims.len();
        let mut rng = Rng::new(seed).fork(stream);
        let mut per_master = vec![Summary::new(); m_cnt];
        let mut system = Summary::new();
        let mut samples = Vec::with_capacity(if keep_samples { trials } else { 0 });
        let mut master_samples = if keep_samples {
            vec![Vec::with_capacity(trials); m_cnt]
        } else {
            vec![]
        };
        let mut scratch = Vec::new();
        for _ in 0..trials {
            let mut sys = 0.0f64;
            for (m, sim) in c.sims.iter().enumerate() {
                let t = sim.sample(&mut rng, &mut scratch);
                per_master[m].push(t);
                if keep_samples {
                    master_samples[m].push(t);
                }
                sys = sys.max(t);
            }
            system.push(sys);
            if keep_samples {
                samples.push(sys);
            }
        }
        ShardOut {
            per_master,
            system,
            samples,
            master_samples,
        }
    }

    /// The legacy driver, verbatim: spawn one scoped thread per shard
    /// (including zero-trial shards), join in stream order, merge.
    pub fn run(s: &Scenario, plan: &Plan, opts: &McOptions) -> McResults {
        let compiled = Compiled::new(s, plan);
        let streams = effective_streams(opts.trials, opts.threads);
        let sizes = shard_sizes(opts.trials, streams);
        let outs: Vec<ShardOut> = std::thread::scope(|scope| {
            let c = &compiled;
            let handles: Vec<_> = sizes
                .iter()
                .enumerate()
                .map(|(ti, &trials)| {
                    scope.spawn(move || {
                        run_shard(c, opts.seed, ti as u64 + 1, trials, opts.keep_samples)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        merge_shards(compiled.n_masters(), outs, opts.keep_samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::{CommModel, Scenario};
    use crate::plan::{build, LoadMethod, PlanSpec, Policy};
    use crate::util::prop::{check, Config};

    fn mc(trials: usize, keep: bool) -> McOptions {
        McOptions {
            trials,
            seed: 99,
            keep_samples: keep,
            threads: 0,
            ziggurat: false,
        }
    }

    fn spec(policy: Policy, loads: LoadMethod) -> PlanSpec {
        PlanSpec {
            policy,
            values: ValueModel::Markov,
            loads,
        }
    }

    #[test]
    fn coded_completion_below_uncoded() {
        // The headline ordering of Fig. 4.
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        let unc = build(&s, &spec(Policy::UncodedUniform, LoadMethod::Markov));
        let ded = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let r_unc = run(&s, &unc, &mc(20_000, false));
        let r_ded = run(&s, &ded, &mc(20_000, false));
        assert!(
            r_ded.system.mean() < r_unc.system.mean(),
            "dedi {} ≥ uncoded {}",
            r_ded.system.mean(),
            r_unc.system.mean()
        );
    }

    #[test]
    fn empirical_mean_close_to_planner_estimate() {
        // The Markov t* is an upper-bound-flavored estimate; the empirical
        // mean system delay should be the same order (within 2×).
        let s = Scenario::small_scale(2, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let r = run(&s, &p, &mc(20_000, false));
        let est = p.t_est();
        let got = r.system.mean();
        assert!(got < 2.0 * est && got > 0.2 * est, "est {est} vs emp {got}");
    }

    #[test]
    fn deterministic_given_seed_and_threads() {
        let s = Scenario::small_scale(3, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediSimple, LoadMethod::Markov));
        let o = McOptions {
            trials: 5_000,
            seed: 7,
            keep_samples: false,
            threads: 2,
            ziggurat: false,
        };
        let a = run(&s, &p, &o);
        let b = run(&s, &p, &o);
        assert_eq!(a.system.mean(), b.system.mean());
        assert_eq!(a.system.count(), 5_000);
    }

    #[test]
    fn system_is_max_of_masters() {
        let s = Scenario::small_scale(4, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let r = run(&s, &p, &mc(2_000, true));
        let samples = r.samples.unwrap();
        let ms = r.master_samples.unwrap();
        for (i, &sys) in samples.iter().enumerate() {
            let mx = ms.iter().map(|v| v[i]).fold(0.0, f64::max);
            assert!((sys - mx).abs() < 1e-12);
        }
    }

    #[test]
    fn cdf_available_when_requested() {
        let s = Scenario::small_scale(5, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let r = run(&s, &p, &mc(5_000, true));
        let ecdf = r.system_ecdf().unwrap();
        assert_eq!(ecdf.len(), 5_000);
        // ρ_s = 0.95 readout exists and exceeds the median.
        assert!(ecdf.inverse(0.95) >= ecdf.inverse(0.5));
    }

    #[test]
    fn into_system_ecdf_consumes_without_changing_values() {
        let s = Scenario::small_scale(5, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let r = run(&s, &p, &mc(1_000, true));
        let borrowed = r.system_ecdf().unwrap();
        let owned = r.into_system_ecdf().unwrap();
        assert_eq!(borrowed.len(), owned.len());
        assert_eq!(borrowed.inverse(0.5), owned.inverse(0.5));
        assert_eq!(borrowed.inverse(0.95), owned.inverse(0.95));
    }

    #[test]
    fn comp_dominant_sampling_has_no_comm_leg() {
        // In comp-dominant mode the minimum possible delay is the pure
        // shift; with comm it would be strictly larger on average.
        let sd = Scenario::small_scale(6, 0.25, CommModel::Stochastic);
        let sc = Scenario::small_scale(6, 0.25, CommModel::CompDominant);
        let pd = build(&sd, &spec(Policy::DediIter, LoadMethod::Markov));
        let pc = build(&sc, &spec(Policy::DediIter, LoadMethod::Markov));
        let rd = run(&sd, &pd, &mc(10_000, false));
        let rc = run(&sc, &pc, &mc(10_000, false));
        assert!(rc.system.mean() < rd.system.mean());
    }

    #[test]
    fn shard_split_matches_legacy_formula() {
        // These drove the pre-refactor per-run thread split; the batched
        // engine reproduces `run` bit-for-bit only if they stay put.
        assert_eq!(shard_sizes(5, 3), vec![2, 2, 1]);
        assert_eq!(shard_sizes(4, 3), vec![2, 2, 0]);
        assert_eq!(shard_sizes(6, 2), vec![3, 3]);
        assert_eq!(effective_streams(10, 4), 4);
        assert!(effective_streams(2, 0) <= 2);
        assert_eq!(effective_streams(0, 0), 1);
        // Zero-trial trailing shards are skipped at submit time; with
        // the skip in place the run must still match the legacy driver
        // (which spawns them) bit-for-bit, stream ids intact.
        let s = Scenario::small_scale(8, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let o = McOptions {
            trials: 4, // → [2, 2, 0] at 3 streams
            seed: 13,
            keep_samples: true,
            threads: 3,
            ziggurat: false,
        };
        let skipping = run(&s, &p, &o);
        let legacy = oracle::run(&s, &p, &o);
        assert_eq!(skipping.system.count(), 4);
        assert_eq!(skipping.system.mean(), legacy.system.mean());
        assert_eq!(skipping.samples.unwrap(), legacy.samples.unwrap());
    }

    #[test]
    fn shards_recompose_run_exactly() {
        let s = Scenario::small_scale(9, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let o = McOptions {
            trials: 3_000,
            seed: 21,
            keep_samples: true,
            threads: 3,
            ziggurat: false,
        };
        let direct = run(&s, &p, &o);
        let c = Compiled::new(&s, &p);
        let outs: Vec<ShardOut> = shard_sizes(o.trials, 3)
            .iter()
            .enumerate()
            .map(|(ti, &t)| run_shard(&c, o.seed, ti as u64 + 1, t, true))
            .collect();
        let merged = merge_shards(c.n_masters(), outs, true);
        assert_eq!(merged.system.mean(), direct.system.mean());
        assert_eq!(merged.system.count(), direct.system.count());
        assert_eq!(merged.samples.unwrap(), direct.samples.unwrap());
    }

    #[test]
    fn single_thread_matches_multi_thread_statistically() {
        let s = Scenario::small_scale(7, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let r1 = run(
            &s,
            &p,
            &McOptions {
                trials: 30_000,
                seed: 11,
                keep_samples: false,
                threads: 1,
                ziggurat: false,
            },
        );
        let r8 = run(
            &s,
            &p,
            &McOptions {
                trials: 30_000,
                seed: 12,
                keep_samples: false,
                threads: 8,
                ziggurat: false,
            },
        );
        let (m1, m8) = (r1.system.mean(), r8.system.mean());
        assert!((m1 - m8).abs() / m1 < 0.05, "{m1} vs {m8}");
    }

    // ------------------------------------------------------------------
    // Kernel v2 specifics
    // ------------------------------------------------------------------

    fn assert_bitwise_equal(a: &McResults, b: &McResults, ctx: &str) {
        assert_eq!(a.system.mean(), b.system.mean(), "{ctx}: system mean");
        assert_eq!(a.system.sem(), b.system.sem(), "{ctx}: system sem");
        assert_eq!(a.system.count(), b.system.count(), "{ctx}: count");
        assert_eq!(a.system.min(), b.system.min(), "{ctx}: min");
        assert_eq!(a.system.max(), b.system.max(), "{ctx}: max");
        for (m, (x, y)) in a.per_master.iter().zip(&b.per_master).enumerate() {
            assert_eq!(x.mean(), y.mean(), "{ctx}: master {m} mean");
            assert_eq!(x.sem(), y.sem(), "{ctx}: master {m} sem");
        }
        assert_eq!(a.samples, b.samples, "{ctx}: samples");
        assert_eq!(a.master_samples, b.master_samples, "{ctx}: master samples");
    }

    #[test]
    fn v2_trial_major_matches_legacy_oracle_bit_for_bit() {
        // The acceptance bar of the kernel rewrite: identical draws,
        // identical completion times, identical merges — across coded /
        // uncoded plans, comm models, straggler mixtures, and the
        // >cutoff link counts that exercise the quickselect path.
        let cases: Vec<(&str, Scenario, PlanSpec)> = vec![
            (
                "small/dedi-iter",
                Scenario::small_scale(31, 2.0, CommModel::Stochastic),
                spec(Policy::DediIter, LoadMethod::Markov),
            ),
            (
                "small/uncoded",
                Scenario::small_scale(32, 2.0, CommModel::Stochastic),
                spec(Policy::UncodedUniform, LoadMethod::Markov),
            ),
            (
                "small-comp-dominant/frac",
                Scenario::small_scale(33, 2.0, CommModel::CompDominant),
                spec(Policy::Frac, LoadMethod::Markov),
            ),
            (
                "large/dedi-iter", // 50 workers: selection scan beyond the sort cutoff
                Scenario::large_scale(34, 2.0, CommModel::Stochastic),
                spec(Policy::DediIter, LoadMethod::Markov),
            ),
            (
                "ec2-stragglers/dedi-simple", // straggler uniforms consume RNG draws
                Scenario::ec2(6, 2, true),
                spec(Policy::DediSimple, LoadMethod::Markov),
            ),
        ];
        for (ctx, s, ps) in cases {
            let p = build(&s, &ps);
            let o = McOptions {
                trials: if ctx.starts_with("large") { 500 } else { 2_000 },
                seed: 4242,
                keep_samples: true,
                threads: 2,
                ziggurat: false,
            };
            let v2 = run(&s, &p, &o);
            let legacy = oracle::run(&s, &p, &o);
            assert_bitwise_equal(&v2, &legacy, ctx);
        }
    }

    fn family_scenarios() -> Vec<(&'static str, Scenario)> {
        use crate::config::Transform;
        use crate::model::dist::{FamilyKind, TraceDist};
        let base = |seed| Scenario::small_scale(seed, 2.0, CommModel::Stochastic);
        let mut trace_s = base(44);
        let mut rng = Rng::new(909);
        let samples: Vec<f64> = (0..300)
            .map(|_| 0.2 + rng.exp(4.0) * if rng.f64() < 0.04 { 15.0 } else { 1.0 })
            .collect();
        let id = trace_s.add_trace(TraceDist::from_samples("syn", samples).unwrap());
        let trace_s = trace_s.transformed(&[Transform::Family(FamilyKind::Trace { id })]);
        vec![
            (
                "weibull",
                base(41).transformed(&[Transform::Family(FamilyKind::Weibull {
                    shape: 0.6,
                })]),
            ),
            (
                "pareto",
                base(42).transformed(&[Transform::Family(FamilyKind::Pareto {
                    alpha: 2.5,
                })]),
            ),
            (
                "bimodal",
                base(43).transformed(&[Transform::Family(FamilyKind::Bimodal {
                    prob: 0.1,
                    slow: 10.0,
                })]),
            ),
            ("trace", trace_s),
        ]
    }

    #[test]
    fn family_kernels_match_oracle_bit_for_bit() {
        // Every non-shifted family flows through the same compile entry
        // (`Scenario::link_delay`) in both kernels, and the SoA draw
        // consumes the RNG exactly like `LinkDelay::sample` — so the
        // oracle stays the parity reference family-generically.
        for (ctx, s) in family_scenarios() {
            let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
            let o = McOptions {
                trials: 2_000,
                seed: 777,
                keep_samples: true,
                threads: 2,
                ziggurat: false,
            };
            let v2 = run(&s, &p, &o);
            let legacy = oracle::run(&s, &p, &o);
            assert_bitwise_equal(&v2, &legacy, ctx);
            assert!(v2.system.mean().is_finite(), "{ctx}");
        }
    }

    #[test]
    fn shifted_exp_compiles_to_legacy_column_layout() {
        // The acceptance pin of the family refactor: a pure shifted-exp
        // scenario must compile to the exact pre-refactor SoA columns —
        // all links on the flat-column fast path (no family tags), with
        // the eq.-(3) values LinkDelay::new produces.
        use crate::model::dist::LinkDelay;
        for s in [
            Scenario::small_scale(31, 2.0, CommModel::Stochastic),
            Scenario::ec2(6, 2, true),
        ] {
            let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
            let c = Compiled::new(&s, &p);
            for (m, mp) in p.masters.iter().enumerate() {
                let soa = c.master(m);
                assert!(
                    soa.fams.iter().all(Option::is_none),
                    "master {m}: shifted-exp link left the fast path"
                );
                for (i, e) in mp.entries.iter().enumerate() {
                    let d = LinkDelay::new(&s.link(m, e.node), e.load, e.k, e.b);
                    assert_eq!(soa.comm_rate[i], d.comm_rate(), "m{m} link {i} comm");
                    assert_eq!(soa.shift[i], d.shift(), "m{m} link {i} shift");
                    assert_eq!(soa.comp_rate[i], d.comp_rate(), "m{m} link {i} rate");
                    assert_eq!(soa.load[i], e.load, "m{m} link {i} load");
                    match d.straggler() {
                        Some(st) => {
                            assert_eq!(soa.strag_prob[i], st.prob);
                            assert_eq!(soa.strag_slow[i], st.slowdown);
                        }
                        None => assert!(soa.strag_prob[i] < 0.0),
                    }
                }
            }
        }
    }

    #[test]
    fn family_blocked_statistically_equivalent_to_trial_major() {
        // The blocked fill paths of the new families obey the same
        // different-bits/same-distribution contract as the shifted-exp
        // kernel (tolerances sized as in the shifted-exp test below).
        for (ctx, s) in family_scenarios() {
            let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
            let o = McOptions {
                trials: 40_000,
                seed: 31337,
                keep_samples: true,
                threads: 2,
                ziggurat: false,
            };
            let tm = run_ordered(&s, &p, &o, SampleOrder::TrialMajor);
            let bl = run_ordered(&s, &p, &o, SampleOrder::Blocked);
            let (m1, m2) = (tm.system.mean(), bl.system.mean());
            let sem = (tm.system.sem().powi(2) + bl.system.sem().powi(2)).sqrt();
            assert!(
                (m1 - m2).abs() < 6.0 * sem,
                "{ctx}: mean {m1} vs {m2} (6σ = {})",
                6.0 * sem
            );
            let d = tm
                .system_ecdf()
                .unwrap()
                .sup_distance(&bl.system_ecdf().unwrap());
            assert!(d < 0.025, "{ctx}: ECDF sup distance {d}");
        }
    }

    #[test]
    fn chunked_is_bit_identical_to_blocked_without_ziggurat() {
        // SampleOrder::Chunked drives the same block core as Blocked —
        // while the ziggurat flag is off the two must agree to the last
        // bit, on shifted-exp and on every delay family (this is the
        // strong pin that the thread-local scratch reuse changes no
        // values).
        let mut cases = family_scenarios();
        cases.push((
            "shifted-exp",
            Scenario::small_scale(31, 2.0, CommModel::Stochastic),
        ));
        for (ctx, s) in cases {
            let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
            let o = McOptions {
                trials: 3_000, // tail block below BLOCK_TRIALS covered
                seed: 909,
                keep_samples: true,
                threads: 2,
                ziggurat: false,
            };
            let bl = run_ordered(&s, &p, &o, SampleOrder::Blocked);
            let ch = run_ordered(&s, &p, &o, SampleOrder::Chunked);
            assert_bitwise_equal(&ch, &bl, ctx);
        }
    }

    #[test]
    fn ziggurat_chunked_statistically_equivalent_to_trial_major() {
        // Chunked + ziggurat swaps every exponential column to the
        // rejection sampler: different bits by construction, same law.
        // Tolerances mirror the blocked-vs-trial-major contract test.
        let mut cases = family_scenarios();
        cases.push((
            "shifted-exp",
            Scenario::small_scale(31, 2.0, CommModel::Stochastic),
        ));
        for (ctx, s) in cases {
            let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
            let o = McOptions {
                trials: 40_000,
                seed: 65521,
                keep_samples: true,
                threads: 2,
                ziggurat: true,
            };
            let tm = run_ordered(&s, &p, &o, SampleOrder::TrialMajor);
            let zg = run_ordered(&s, &p, &o, SampleOrder::Chunked);
            let (m1, m2) = (tm.system.mean(), zg.system.mean());
            let sem = (tm.system.sem().powi(2) + zg.system.sem().powi(2)).sqrt();
            assert!(
                (m1 - m2).abs() < 6.0 * sem,
                "{ctx}: mean {m1} vs {m2} (6σ = {})",
                6.0 * sem
            );
            let rel_var =
                (tm.system.var() - zg.system.var()).abs() / tm.system.var().max(1e-12);
            assert!(rel_var < 0.1, "{ctx}: variance off by {rel_var}");
            let d = tm
                .system_ecdf()
                .unwrap()
                .sup_distance(&zg.system_ecdf().unwrap());
            assert!(d < 0.025, "{ctx}: ECDF sup distance {d}");
        }
    }

    #[test]
    fn ziggurat_flag_is_a_no_op_for_bit_exact_orders() {
        // TrialMajor and Blocked document the ziggurat flag as ignored:
        // flipping it must not change a bit.
        let s = Scenario::small_scale(31, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let mut o = McOptions {
            trials: 2_000,
            seed: 4711,
            keep_samples: true,
            threads: 2,
            ziggurat: false,
        };
        for order in [SampleOrder::TrialMajor, SampleOrder::Blocked] {
            o.ziggurat = false;
            let off = run_ordered(&s, &p, &o, order);
            o.ziggurat = true;
            let on = run_ordered(&s, &p, &o, order);
            assert_bitwise_equal(&on, &off, order.as_str());
        }
    }

    #[test]
    fn sample_order_chunked_parses_and_prints() {
        assert_eq!(
            SampleOrder::parse("chunked").unwrap(),
            SampleOrder::Chunked
        );
        assert_eq!(SampleOrder::Chunked.as_str(), "chunked");
    }

    #[test]
    fn completion_scan_matches_sort_oracle_property() {
        // Random loads/times on an exact-arithmetic grid (quarters: every
        // partial sum is exact, so the crossing is order-independent and
        // the comparison is meaningful to the last bit), with heavy
        // duplicate pressure, Σl < L infinity cases and single-link
        // edges.
        check(
            Config::default().cases(300),
            "selection scan == sort-then-scan",
            |g| {
                let n = g.usize_range(1, 257);
                let times: Vec<f64> = (0..n)
                    .map(|_| g.rng().index(64) as f64 * 0.25)
                    .collect();
                let loads: Vec<f64> =
                    (0..n).map(|_| (1 + g.rng().index(8)) as f64 * 0.25).collect();
                let total_units: usize = loads.iter().map(|&l| (l * 4.0) as usize).sum();
                // Sometimes beyond the total: the task never completes.
                let target = (1 + g.rng().index(total_units + total_units / 4 + 1)) as f64 * 0.25;

                let mut pairs: Vec<(f64, f64)> =
                    times.iter().copied().zip(loads.iter().copied()).collect();
                pairs.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
                let mut acc = 0.0;
                let mut want = f64::INFINITY;
                for &(t, l) in &pairs {
                    acc += l;
                    if acc >= target {
                        want = t;
                        break;
                    }
                }

                let mut ts = times.clone();
                let mut ls = loads.clone();
                let got = completion_scan(&mut ts, &mut ls, target);
                assert_eq!(got, want, "n={n} target={target}");
                // The scan permutes, never loses: same multisets.
                let mut st = times;
                let mut sl = loads;
                st.sort_unstable_by(f64::total_cmp);
                sl.sort_unstable_by(f64::total_cmp);
                ts.sort_unstable_by(f64::total_cmp);
                ls.sort_unstable_by(f64::total_cmp);
                assert_eq!(ts, st);
                assert_eq!(ls, sl);
            },
        );
    }

    #[test]
    fn completion_scan_edge_cases() {
        // Empty: nothing ever accumulates.
        assert_eq!(completion_scan(&mut [], &mut [], 1.0), f64::INFINITY);
        // Single link, reached and not reached.
        assert_eq!(completion_scan(&mut [3.5], &mut [2.0], 2.0), 3.5);
        assert_eq!(completion_scan(&mut [3.5], &mut [1.0], 2.0), f64::INFINITY);
        // All-duplicate times: crossing lands inside the tie block.
        let mut t = vec![1.25; 100];
        let mut l = vec![0.5; 100];
        assert_eq!(completion_scan(&mut t, &mut l, 10.0), 1.25);
    }

    // ------------------------------------------------------------------
    // Time-varying shares
    // ------------------------------------------------------------------

    #[test]
    fn capacity_profile_warp_arithmetic() {
        // Throttle to half speed at t = 5.
        let p = CapacityProfile::from_breakpoints(vec![(5.0, 0.5)]).unwrap();
        assert_eq!(p.factor_at(0.0), 1.0);
        assert_eq!(p.factor_at(5.0), 0.5);
        // Completes before the throttle: untouched (exact identity).
        assert_eq!(p.warp(0.0, 4.0), 4.0);
        // 5 units at full speed, remaining 3 at half: 5 + 6 = 11.
        assert_eq!(p.warp(0.0, 8.0), 11.0);
        // Admitted inside the throttled regime with no further change:
        // exact identity (the job was sampled at the throttled rate).
        assert_eq!(p.warp(6.0, 30.0), 30.0);

        // Pause [5, 9), then resume.
        let pause = CapacityProfile::from_breakpoints(vec![(5.0, 0.0), (9.0, 1.0)]).unwrap();
        assert_eq!(pause.warp(0.0, 8.0), 12.0); // 5 done, 4 paused, 3 after
        assert_eq!(pause.warp(0.0, 5.0), 5.0);  // exactly at the pause edge
        // Leave forever: work in flight never completes.
        let gone = CapacityProfile::from_breakpoints(vec![(5.0, 0.0)]).unwrap();
        assert_eq!(gone.warp(0.0, 4.0), 4.0);
        assert_eq!(gone.warp(0.0, 8.0), f64::INFINITY);
        // Admitted AFTER capacity dropped to zero forever: also ∞ (the
        // forever-zero tail must not hit the constant-after identity).
        assert_eq!(gone.warp(6.0, 8.0), f64::INFINITY);
        // Admitted during a pause that later lifts: waits, then runs.
        let pause2 = CapacityProfile::from_breakpoints(vec![(5.0, 0.0), (9.0, 1.0)]).unwrap();
        assert_eq!(pause2.warp(6.0, 8.0), 11.0); // wait to 9, then 8 work

        // Speed-up relative to admission-time capacity: admitted at 10
        // under a 0.5 throttle that lifts at 20 — the remaining work
        // runs twice as fast, so 30 sampled ms finish in 20.
        let lift =
            CapacityProfile::from_breakpoints(vec![(0.0, 0.5), (20.0, 1.0)]).unwrap();
        assert_eq!(lift.warp(10.0, 30.0), 20.0);

        // Two-time warp: admitted at full rate (t = 0), work starting at
        // t = 6 after the 0.5 throttle landed — the whole leg runs at
        // half the sampled speed.
        assert_eq!(p.warp_scaled(0.0, 6.0, 4.0), 8.0);
        // Admitted under the throttle with no further change: identity.
        assert_eq!(p.warp_scaled(6.0, 7.0, 4.0), 4.0);
        // Admitted at full rate, work starts inside a forever-pause: ∞.
        assert_eq!(gone.warp_scaled(0.0, 6.0, 1.0), f64::INFINITY);

        // Constant profiles are the identity and report as such.
        assert!(CapacityProfile::constant().is_constant());
        assert_eq!(CapacityProfile::constant().warp(3.0, 7.25), 7.25);
        // Malformed breakpoints are graceful errors.
        assert!(CapacityProfile::from_breakpoints(vec![(5.0, -1.0)]).is_err());
        assert!(CapacityProfile::from_breakpoints(vec![(5.0, 1.0), (3.0, 1.0)]).is_err());
        assert!(CapacityProfile::from_breakpoints(vec![(f64::NAN, 1.0)]).is_err());
    }

    #[test]
    fn sample_master_matches_trial_loop_bit_for_bit() {
        // The serving layer's per-job draw must be exactly the batch
        // kernel's per-master draw: same stream, same order, same bits.
        for (ctx, s, ps) in [
            (
                "small/dedi-iter",
                Scenario::small_scale(61, 2.0, CommModel::Stochastic),
                spec(Policy::DediIter, LoadMethod::Markov),
            ),
            (
                "small/uncoded",
                Scenario::small_scale(62, 2.0, CommModel::Stochastic),
                spec(Policy::UncodedUniform, LoadMethod::Markov),
            ),
            (
                "ec2-stragglers/dedi-simple",
                Scenario::ec2(6, 2, true),
                spec(Policy::DediSimple, LoadMethod::Markov),
            ),
        ] {
            let p = build(&s, &ps);
            let c = Compiled::new(&s, &p);
            let trials = 200;
            let direct = run_shard(&c, 99, 1, trials, true);
            let mut rng = Rng::new(99).fork(1);
            let (mut times, mut loads) = (Vec::new(), Vec::new());
            let trivial = vec![CapacityProfile::constant(); s.n_workers() + 1];
            for t in 0..trials {
                for m in 0..c.n_masters() {
                    // Alternate the plain and the trivially-warped entry
                    // points: both must reproduce the trial loop.
                    let v = if (t + m) % 2 == 0 {
                        c.sample_master(m, &mut rng, &mut times, &mut loads)
                    } else {
                        c.sample_master_warped(m, &mut rng, 0.0, &trivial, &mut times, &mut loads)
                    };
                    assert_eq!(
                        v, direct.master_samples[m][t],
                        "{ctx}: trial {t} master {m}"
                    );
                }
            }
        }
    }

    #[test]
    fn warped_sampling_stretches_and_starves() {
        // A non-trivial profile on every worker must stretch completion;
        // workers gone forever starve coded masters whose remaining
        // finite links cannot reach L.
        let s = Scenario::small_scale(63, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let c = Compiled::new(&s, &p);
        let n = s.n_workers();
        let (mut times, mut loads) = (Vec::new(), Vec::new());
        let trivial = vec![CapacityProfile::constant(); n + 1];
        // Workers leave (capacity 0) just after admission and never
        // return; the draw itself happens at full capacity.
        let mut gone = vec![CapacityProfile::constant()];
        for _ in 0..n {
            gone.push(CapacityProfile::from_breakpoints(vec![(1e-9, 0.0)]).unwrap());
        }
        let mut stretched = 0usize;
        for seed in 0..50u64 {
            let mut r1 = Rng::new(seed).fork(1);
            let mut r2 = Rng::new(seed).fork(1);
            let base = c.sample_master(0, &mut r1, &mut times, &mut loads);
            // Throttle applied mid-stream (breakpoint after t0 = 0):
            let thr = vec![CapacityProfile::constant()]
                .into_iter()
                .chain((0..n).map(|_| {
                    CapacityProfile::from_breakpoints(vec![(1e-6, 0.01)]).unwrap()
                }))
                .collect::<Vec<_>>();
            let warped =
                c.sample_master_warped(0, &mut r2, 0.0, &thr, &mut times, &mut loads);
            assert!(warped >= base, "seed {seed}: warp sped a job up");
            if warped > base {
                stretched += 1;
            }
        }
        assert!(stretched > 40, "throttling almost never stretched ({stretched}/50)");
        // Workers leaving forever right after admission: the local link
        // alone carries less than L, so the job can never complete.
        let mut rng = Rng::new(7).fork(1);
        let v = c.sample_master_warped(0, &mut rng, 0.0, &gone, &mut times, &mut loads);
        assert!(v.is_infinite(), "coded job completed without its workers");
        // Sanity: trivial profiles at the same stream stay finite.
        let mut rng = Rng::new(7).fork(1);
        let v = c.sample_master_warped(0, &mut rng, 0.0, &trivial, &mut times, &mut loads);
        assert!(v.is_finite());
    }

    #[test]
    fn blocked_mode_is_deterministic_and_well_formed() {
        let s = Scenario::small_scale(12, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let o = McOptions {
            trials: 3_000, // not a multiple of BLOCK_TRIALS: tail block covered
            seed: 5,
            keep_samples: true,
            threads: 2,
            ziggurat: false,
        };
        let a = run_ordered(&s, &p, &o, SampleOrder::Blocked);
        let b = run_ordered(&s, &p, &o, SampleOrder::Blocked);
        assert_eq!(a.system.count(), 3_000);
        assert_eq!(a.system.mean(), b.system.mean());
        assert_eq!(a.samples, b.samples);
        // System is still the max over masters, per trial.
        let samples = a.samples.unwrap();
        let ms = a.master_samples.unwrap();
        for (i, &sys) in samples.iter().enumerate() {
            let mx = ms.iter().map(|v| v[i]).fold(0.0, f64::max);
            assert!((sys - mx).abs() < 1e-12);
        }
    }

    #[test]
    fn blocked_mode_statistically_equivalent_to_trial_major() {
        // The different-bits/same-distribution contract: compare the two
        // orders on the same seed. Tolerances are sized from the MC
        // noise at 40 000 trials (mean: 6× the rss SEM ≈ 6σ of the
        // paired difference; ECDF sup distance: ~3.5× the two-sample
        // KS scale sqrt(2/n) ≈ 0.007).
        for (label, s, ps) in [
            (
                "small/dedi-iter",
                Scenario::small_scale(14, 2.0, CommModel::Stochastic),
                spec(Policy::DediIter, LoadMethod::Markov),
            ),
            (
                "ec2-stragglers/dedi-simple",
                Scenario::ec2(6, 2, true),
                spec(Policy::DediSimple, LoadMethod::Markov),
            ),
        ] {
            let p = build(&s, &ps);
            let o = McOptions {
                trials: 40_000,
                seed: 2024,
                keep_samples: true,
                threads: 2,
                ziggurat: false,
            };
            let tm = run_ordered(&s, &p, &o, SampleOrder::TrialMajor);
            let bl = run_ordered(&s, &p, &o, SampleOrder::Blocked);
            let (m1, m2) = (tm.system.mean(), bl.system.mean());
            let sem = (tm.system.sem().powi(2) + bl.system.sem().powi(2)).sqrt();
            assert!(
                (m1 - m2).abs() < 6.0 * sem,
                "{label}: mean {m1} vs {m2} (6σ = {})",
                6.0 * sem
            );
            let (v1, v2) = (tm.system.var(), bl.system.var());
            assert!(
                (v1 - v2).abs() / v1 < 0.10,
                "{label}: variance {v1} vs {v2}"
            );
            let d = tm
                .system_ecdf()
                .unwrap()
                .sup_distance(&bl.system_ecdf().unwrap());
            assert!(d < 0.025, "{label}: ECDF sup distance {d}");
        }
    }
}
