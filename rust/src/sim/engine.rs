//! Trial sampler + thread-parallel Monte-Carlo driver.

use crate::config::Scenario;
use crate::model::dist::LinkDelay;
use crate::plan::Plan;
use crate::util::rng::Rng;
use crate::util::stats::{Ecdf, Summary};

/// Monte-Carlo options.
#[derive(Clone, Copy, Debug)]
pub struct McOptions {
    pub trials: usize,
    pub seed: u64,
    /// Keep raw per-trial system delays (needed for CDFs, Fig. 5).
    pub keep_samples: bool,
    /// Worker threads (0 = all available cores).
    pub threads: usize,
}

impl Default for McOptions {
    fn default() -> Self {
        Self {
            trials: 100_000,
            seed: 0x51D_E0,
            keep_samples: false,
            threads: 0,
        }
    }
}

/// Aggregated Monte-Carlo results.
#[derive(Clone, Debug)]
pub struct McResults {
    /// Per-master completion-delay summaries.
    pub per_master: Vec<Summary>,
    /// System delay = max over masters, per trial.
    pub system: Summary,
    /// Raw system-delay samples (present iff `keep_samples`).
    pub samples: Option<Vec<f64>>,
    /// Raw per-master samples (present iff `keep_samples`).
    pub master_samples: Option<Vec<Vec<f64>>>,
}

impl McResults {
    pub fn system_ecdf(&self) -> Option<Ecdf> {
        self.samples.clone().map(Ecdf::new)
    }
}

/// Precompiled sampling state for one master: `(delay dist, load)` pairs.
struct MasterSim {
    links: Vec<(LinkDelay, f64)>,
    l_rows: f64,
    uncoded: bool,
}

impl MasterSim {
    /// Sample one completion time.
    ///
    /// Coded: sort finish times, accumulate loads until `L_m` rows have
    /// arrived — that arrival instant is the completion (the master then
    /// cancels the rest). Uncoded: every sub-task must finish.
    fn sample(&self, rng: &mut Rng, scratch: &mut Vec<(f64, f64)>) -> f64 {
        if self.uncoded {
            return self
                .links
                .iter()
                .map(|(d, _)| d.sample(rng))
                .fold(0.0, f64::max);
        }
        scratch.clear();
        for (d, l) in &self.links {
            scratch.push((d.sample(rng), *l));
        }
        // §Perf item 2: unstable sort — no allocation, ~6% engine gain.
        scratch.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut acc = 0.0;
        for &(t, l) in scratch.iter() {
            acc += l;
            if acc >= self.l_rows {
                return t;
            }
        }
        // Total assigned < L_m can only happen for malformed plans; the
        // task never completes.
        f64::INFINITY
    }
}

/// Precompiled `(scenario, plan)` sampling state, reusable across RNG
/// streams. Shared by [`run`] and the batched engine
/// ([`crate::exec::BatchRunner`]) so both sample the exact same way.
pub struct Compiled {
    sims: Vec<MasterSim>,
}

impl Compiled {
    pub fn new(s: &Scenario, plan: &Plan) -> Self {
        let sims = plan
            .masters
            .iter()
            .enumerate()
            .map(|(m, mp)| MasterSim {
                links: mp
                    .entries
                    .iter()
                    .map(|e| {
                        let p = s.link(m, e.node);
                        (LinkDelay::new(&p, e.load, e.k, e.b), e.load)
                    })
                    .collect(),
                l_rows: mp.l_rows,
                uncoded: plan.uncoded,
            })
            .collect();
        Compiled { sims }
    }

    pub fn n_masters(&self) -> usize {
        self.sims.len()
    }
}

/// The RNG-stream count [`run`] uses for a request: `threads` if nonzero,
/// else all cores, never more than `trials`. The split determines the
/// sampled values bit-for-bit, so anything that must reproduce a
/// `sim::run` result (the batched engine, golden-parity tests) goes
/// through this same function.
pub fn effective_streams(trials: usize, threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(trials.max(1))
    } else {
        threads
    }
}

/// Per-stream trial counts: ceil split of `trials` over `streams`
/// (trailing streams may receive 0 — kept for stream-id stability).
pub fn shard_sizes(trials: usize, streams: usize) -> Vec<usize> {
    let per = trials.div_ceil(streams);
    (0..streams)
        .map(|ti| per.min(trials.saturating_sub(ti * per)))
        .collect()
}

/// Output of one RNG stream's worth of trials.
pub struct ShardOut {
    pub per_master: Vec<Summary>,
    pub system: Summary,
    pub samples: Vec<f64>,
    pub master_samples: Vec<Vec<f64>>,
}

/// Run `trials` trials on RNG stream `stream` (1-based, exactly how
/// [`run`] numbers its threads) of the generator seeded by `seed`.
pub fn run_shard(
    c: &Compiled,
    seed: u64,
    stream: u64,
    trials: usize,
    keep_samples: bool,
) -> ShardOut {
    let m_cnt = c.sims.len();
    let mut rng = Rng::new(seed).fork(stream);
    let mut per_master = vec![Summary::new(); m_cnt];
    let mut system = Summary::new();
    let mut samples = Vec::with_capacity(if keep_samples { trials } else { 0 });
    let mut master_samples = if keep_samples {
        vec![Vec::with_capacity(trials); m_cnt]
    } else {
        vec![]
    };
    let mut scratch = Vec::new();
    for _ in 0..trials {
        let mut sys = 0.0f64;
        for (m, sim) in c.sims.iter().enumerate() {
            let t = sim.sample(&mut rng, &mut scratch);
            per_master[m].push(t);
            if keep_samples {
                master_samples[m].push(t);
            }
            sys = sys.max(t);
        }
        system.push(sys);
        if keep_samples {
            samples.push(sys);
        }
    }
    ShardOut {
        per_master,
        system,
        samples,
        master_samples,
    }
}

/// Merge shard outputs **in stream order** into aggregate results. The
/// order matters bit-for-bit: Welford merges and sample concatenation
/// happen exactly as [`run`] performs them.
pub fn merge_shards(m_cnt: usize, outs: Vec<ShardOut>, keep_samples: bool) -> McResults {
    let mut per_master = vec![Summary::new(); m_cnt];
    let mut system = Summary::new();
    let mut samples = Vec::new();
    let mut master_samples = vec![Vec::new(); m_cnt];
    for o in outs {
        for (acc, s) in per_master.iter_mut().zip(&o.per_master) {
            acc.merge(s);
        }
        system.merge(&o.system);
        samples.extend(o.samples);
        for (acc, v) in master_samples.iter_mut().zip(o.master_samples) {
            acc.extend(v);
        }
    }
    McResults {
        per_master,
        system,
        samples: keep_samples.then_some(samples),
        master_samples: keep_samples.then_some(master_samples),
    }
}

/// Run the Monte-Carlo evaluation of `plan` on `s`.
pub fn run(s: &Scenario, plan: &Plan, opts: &McOptions) -> McResults {
    let compiled = Compiled::new(s, plan);
    let streams = effective_streams(opts.trials, opts.threads);
    let sizes = shard_sizes(opts.trials, streams);
    let outs: Vec<ShardOut> = std::thread::scope(|scope| {
        let c = &compiled;
        let handles: Vec<_> = sizes
            .iter()
            .enumerate()
            .map(|(ti, &trials)| {
                scope.spawn(move || {
                    run_shard(c, opts.seed, ti as u64 + 1, trials, opts.keep_samples)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    merge_shards(compiled.n_masters(), outs, opts.keep_samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::{CommModel, Scenario};
    use crate::plan::{build, LoadMethod, PlanSpec, Policy};

    fn mc(trials: usize, keep: bool) -> McOptions {
        McOptions {
            trials,
            seed: 99,
            keep_samples: keep,
            threads: 0,
        }
    }

    fn spec(policy: Policy, loads: LoadMethod) -> PlanSpec {
        PlanSpec {
            policy,
            values: ValueModel::Markov,
            loads,
        }
    }

    #[test]
    fn coded_completion_below_uncoded() {
        // The headline ordering of Fig. 4.
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        let unc = build(&s, &spec(Policy::UncodedUniform, LoadMethod::Markov));
        let ded = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let r_unc = run(&s, &unc, &mc(20_000, false));
        let r_ded = run(&s, &ded, &mc(20_000, false));
        assert!(
            r_ded.system.mean() < r_unc.system.mean(),
            "dedi {} ≥ uncoded {}",
            r_ded.system.mean(),
            r_unc.system.mean()
        );
    }

    #[test]
    fn empirical_mean_close_to_planner_estimate() {
        // The Markov t* is an upper-bound-flavored estimate; the empirical
        // mean system delay should be the same order (within 2×).
        let s = Scenario::small_scale(2, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let r = run(&s, &p, &mc(20_000, false));
        let est = p.t_est();
        let got = r.system.mean();
        assert!(got < 2.0 * est && got > 0.2 * est, "est {est} vs emp {got}");
    }

    #[test]
    fn deterministic_given_seed_and_threads() {
        let s = Scenario::small_scale(3, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediSimple, LoadMethod::Markov));
        let o = McOptions {
            trials: 5_000,
            seed: 7,
            keep_samples: false,
            threads: 2,
        };
        let a = run(&s, &p, &o);
        let b = run(&s, &p, &o);
        assert_eq!(a.system.mean(), b.system.mean());
        assert_eq!(a.system.count(), 5_000);
    }

    #[test]
    fn system_is_max_of_masters() {
        let s = Scenario::small_scale(4, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let r = run(&s, &p, &mc(2_000, true));
        let samples = r.samples.unwrap();
        let ms = r.master_samples.unwrap();
        for (i, &sys) in samples.iter().enumerate() {
            let mx = ms.iter().map(|v| v[i]).fold(0.0, f64::max);
            assert!((sys - mx).abs() < 1e-12);
        }
    }

    #[test]
    fn cdf_available_when_requested() {
        let s = Scenario::small_scale(5, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let r = run(&s, &p, &mc(5_000, true));
        let ecdf = r.system_ecdf().unwrap();
        assert_eq!(ecdf.len(), 5_000);
        // ρ_s = 0.95 readout exists and exceeds the median.
        assert!(ecdf.inverse(0.95) >= ecdf.inverse(0.5));
    }

    #[test]
    fn comp_dominant_sampling_has_no_comm_leg() {
        // In comp-dominant mode the minimum possible delay is the pure
        // shift; with comm it would be strictly larger on average.
        let sd = Scenario::small_scale(6, 0.25, CommModel::Stochastic);
        let sc = Scenario::small_scale(6, 0.25, CommModel::CompDominant);
        let pd = build(&sd, &spec(Policy::DediIter, LoadMethod::Markov));
        let pc = build(&sc, &spec(Policy::DediIter, LoadMethod::Markov));
        let rd = run(&sd, &pd, &mc(10_000, false));
        let rc = run(&sc, &pc, &mc(10_000, false));
        assert!(rc.system.mean() < rd.system.mean());
    }

    #[test]
    fn shard_split_matches_legacy_formula() {
        // These drove the pre-refactor per-run thread split; the batched
        // engine reproduces `run` bit-for-bit only if they stay put.
        assert_eq!(shard_sizes(5, 3), vec![2, 2, 1]);
        assert_eq!(shard_sizes(4, 3), vec![2, 2, 0]);
        assert_eq!(shard_sizes(6, 2), vec![3, 3]);
        assert_eq!(effective_streams(10, 4), 4);
        assert!(effective_streams(2, 0) <= 2);
        assert_eq!(effective_streams(0, 0), 1);
    }

    #[test]
    fn shards_recompose_run_exactly() {
        let s = Scenario::small_scale(9, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let o = McOptions {
            trials: 3_000,
            seed: 21,
            keep_samples: true,
            threads: 3,
        };
        let direct = run(&s, &p, &o);
        let c = Compiled::new(&s, &p);
        let outs: Vec<ShardOut> = shard_sizes(o.trials, 3)
            .iter()
            .enumerate()
            .map(|(ti, &t)| run_shard(&c, o.seed, ti as u64 + 1, t, true))
            .collect();
        let merged = merge_shards(c.n_masters(), outs, true);
        assert_eq!(merged.system.mean(), direct.system.mean());
        assert_eq!(merged.system.count(), direct.system.count());
        assert_eq!(merged.samples.unwrap(), direct.samples.unwrap());
    }

    #[test]
    fn single_thread_matches_multi_thread_statistically() {
        let s = Scenario::small_scale(7, 2.0, CommModel::Stochastic);
        let p = build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let r1 = run(
            &s,
            &p,
            &McOptions {
                trials: 30_000,
                seed: 11,
                keep_samples: false,
                threads: 1,
            },
        );
        let r8 = run(
            &s,
            &p,
            &McOptions {
                trials: 30_000,
                seed: 12,
                keep_samples: false,
                threads: 8,
            },
        );
        let (m1, m8) = (r1.system.mean(), r8.system.mean());
        assert!((m1 - m8).abs() / m1 < 0.05, "{m1} vs {m8}");
    }
}
