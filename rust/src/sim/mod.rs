//! Monte-Carlo completion-delay engine (§V's evaluation methodology).
//!
//! The paper evaluates every plan by sampling the per-link delays
//! `T_{m,n}` and computing each master's completion time — the first
//! instant the accumulated coded rows reach `L_m` (or, uncoded, the
//! slowest sub-task). [`engine`] runs trials thread-parallel and returns
//! mean/CDF statistics for each master and for the system maximum.

pub mod engine;
pub mod multimsg;

pub use engine::{run, McOptions, McResults};
