//! Monte-Carlo completion-delay engine (§V's evaluation methodology).
//!
//! The paper evaluates every plan by sampling the per-link delays
//! `T_{m,n}` and computing each master's completion time — the first
//! instant the accumulated coded rows reach `L_m` (or, uncoded, the
//! slowest sub-task). [`engine`] runs trials thread-parallel and returns
//! mean/CDF statistics for each master and for the system maximum.
//!
//! The kernel is the v2 structure-of-arrays engine (see [`engine`]):
//! SoA compiled plans, a weighted-selection completion scan, an opt-in
//! blocked sampling order ([`SampleOrder`]) and shards executed on the
//! shared process pool. The pre-v2 kernel survives as
//! [`engine::oracle`] for parity tests and bench baselines.

pub mod engine;
pub mod multimsg;

pub use engine::{run, run_ordered, CapacityProfile, McOptions, McResults, SampleOrder};
