//! Multi-message communication extension (§VI future work; cf. [19], [20]).
//!
//! In the base model a worker's entire block `l_{m,n}` arrives at once
//! (eq. 3); with multi-message communication the worker streams its block
//! back in `c` chunks, so a straggler that finishes only part of its
//! block still contributes rows. Per [20] each extra message carries a
//! transmission overhead, giving the communication–computation trade-off
//! this module quantifies (ablation `multimsg`).
//!
//! Chunk model (consistent with eqs. 1–2):
//! * the input block is shipped ONCE: comm leg `Exp(bγ/l)` as before;
//! * computation proceeds chunk by chunk: chunk `j` of size `l/c`
//!   completes at `comm + Σ_{i≤j} [a·(l/c)/k + Exp(k·u/(l/c))]`
//!   (the sum of per-chunk shifted exponentials equals the full-block
//!   delay in distribution — chunking adds no compute penalty);
//! * each return message adds a fixed `overhead_ms` (the [20] cost), so
//!   chunk `j` is *available* at `t_j + j·overhead_ms`.

use super::engine::completion_scan;
use crate::config::Scenario;
use crate::model::dist::{DelayFamily, FamilyKind};
use crate::plan::Plan;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Multi-message options.
#[derive(Clone, Copy, Debug)]
pub struct MultiMsgOptions {
    /// Chunks per worker block (1 = the paper's base model).
    pub chunks: usize,
    /// Per-message transmission overhead (ms), the [20] cost.
    pub overhead_ms: f64,
    pub trials: usize,
    pub seed: u64,
}

impl Default for MultiMsgOptions {
    fn default() -> Self {
        Self {
            chunks: 4,
            overhead_ms: 0.0,
            trials: 20_000,
            seed: 0xC4_15,
        }
    }
}

/// Per-master chunk-event sampling state, SoA like the main kernel
/// ([`crate::sim::engine`]): per-link flat columns plus a precomputed
/// per-event load template (chunk loads are trial-invariant, so each
/// trial just memcpys the template into the scan's payload buffer).
/// Chunk computation delays sample through the per-link
/// [`DelayFamily`] (compiled at chunk scale `lc/k`); shifted-exp links
/// compile to the exact pre-family `a·lc/k` / `k·u/lc` parameters and
/// draw in the same RNG order, so their values are unchanged.
struct MasterSim {
    comm_rate: Vec<f64>, // ∞ ⇒ no comm leg
    chunk_comp: Vec<DelayFamily>,
    chunks: usize,
    /// Event loads in link-major emission order (`links × chunks`).
    load_template: Vec<f64>,
    l_rows: f64,
}

fn compile(s: &Scenario, plan: &Plan, chunks: usize) -> Vec<MasterSim> {
    assert!(chunks >= 1);
    plan.masters
        .iter()
        .enumerate()
        .map(|(m, mp)| {
            let n = mp.entries.len();
            let mut sim = MasterSim {
                comm_rate: Vec::with_capacity(n),
                chunk_comp: Vec::with_capacity(n),
                chunks,
                load_template: Vec::with_capacity(n * chunks),
                l_rows: mp.l_rows,
            };
            for e in &mp.entries {
                let p = s.link(m, e.node);
                let lc = e.load / chunks as f64;
                sim.comm_rate.push(if p.is_local() {
                    f64::INFINITY
                } else {
                    e.b * p.gamma / e.load
                });
                sim.chunk_comp.push(match p.family {
                    // Legacy chunk parameterization, expression-exact.
                    FamilyKind::ShiftedExp => DelayFamily::ShiftedExp {
                        shift: p.a * lc / e.k,
                        rate: e.k * p.u / lc,
                    },
                    kind => kind.resolve(p.a, p.u, &s.traces).scaled(lc / e.k),
                });
                for _ in 0..chunks {
                    sim.load_template.push(lc);
                }
            }
            sim
        })
        .collect()
}

impl MasterSim {
    /// Sample one completion: emit every link's chunk-availability times
    /// (same RNG draw order as the pre-SoA sampler), then resolve the
    /// `Σ load ≥ L_m` crossing with the shared weighted-selection scan
    /// instead of a full event sort.
    fn sample(
        &self,
        rng: &mut Rng,
        overhead: f64,
        times: &mut Vec<f64>,
        loads: &mut Vec<f64>,
    ) -> f64 {
        times.clear();
        for (&cr, comp) in self.comm_rate.iter().zip(&self.chunk_comp) {
            let comm = if cr.is_infinite() { 0.0 } else { rng.exp(cr) };
            let mut t = comm;
            for j in 1..=self.chunks {
                t += comp.sample(rng);
                times.push(t + j as f64 * overhead);
            }
        }
        loads.clear();
        loads.extend_from_slice(&self.load_template);
        completion_scan(times, loads, self.l_rows)
    }
}

/// Per-master + system mean completion delay under chunked returns.
pub fn run(s: &Scenario, plan: &Plan, opts: &MultiMsgOptions) -> Summary {
    let sims = compile(s, plan, opts.chunks);
    let mut rng = Rng::new(opts.seed);
    let mut system = Summary::new();
    let mut times = Vec::new();
    let mut loads = Vec::new();
    for _ in 0..opts.trials {
        let mut sys: f64 = 0.0;
        for sim in &sims {
            sys = sys.max(sim.sample(&mut rng, opts.overhead_ms, &mut times, &mut loads));
        }
        system.push(sys);
    }
    system
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::{CommModel, Scenario};
    use crate::plan::{build, LoadMethod, PlanSpec, Policy};
    use crate::sim::{self, McOptions};

    fn setup() -> (Scenario, Plan) {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        let p = build(
            &s,
            &PlanSpec {
                policy: Policy::DediIter,
                values: ValueModel::Markov,
                loads: LoadMethod::Markov,
            },
        );
        (s, p)
    }

    #[test]
    fn single_chunk_matches_base_engine() {
        // c = 1 with zero overhead IS the base model; means must agree
        // statistically with the main MC engine.
        let (s, p) = setup();
        let multi = run(
            &s,
            &p,
            &MultiMsgOptions {
                chunks: 1,
                overhead_ms: 0.0,
                trials: 30_000,
                seed: 5,
            },
        );
        let base = sim::run(
            &s,
            &p,
            &McOptions {
                trials: 30_000,
                seed: 6,
                keep_samples: false,
                threads: 1,
                ziggurat: false,
            },
        );
        let (a, b) = (multi.mean(), base.system.mean());
        assert!((a - b).abs() / b < 0.03, "{a} vs {b}");
    }

    #[test]
    fn more_chunks_reduce_delay_without_overhead() {
        // Partial results from stragglers can only help (free chunking).
        let (s, p) = setup();
        let opts = |c| MultiMsgOptions {
            chunks: c,
            overhead_ms: 0.0,
            trials: 20_000,
            seed: 7,
        };
        let c1 = run(&s, &p, &opts(1)).mean();
        let c4 = run(&s, &p, &opts(4)).mean();
        let c16 = run(&s, &p, &opts(16)).mean();
        assert!(c4 < c1, "c=4 {c4} ≥ c=1 {c1}");
        assert!(c16 <= c4 * 1.01, "c=16 {c16} ≫ c=4 {c4}");
    }

    #[test]
    fn overhead_creates_tradeoff() {
        // With a heavy per-message cost, many chunks must eventually lose
        // — the [20] communication–computation trade-off.
        let (s, p) = setup();
        let opts = |c, o| MultiMsgOptions {
            chunks: c,
            overhead_ms: o,
            trials: 15_000,
            seed: 8,
        };
        let heavy = 500.0; // ms per message, deliberately punishing
        let c1 = run(&s, &p, &opts(1, heavy)).mean();
        let c16 = run(&s, &p, &opts(16, heavy)).mean();
        assert!(c16 > c1, "chunking should lose under heavy overhead");
    }

    #[test]
    fn family_links_sample_through_chunk_interface() {
        // A heavy-tail scenario flows through the same chunk engine;
        // free chunking still helps (partial results from stragglers),
        // and more so than under the light tail.
        use crate::config::Transform;
        use crate::model::dist::FamilyKind;
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic)
            .transformed(&[Transform::Family(FamilyKind::Pareto { alpha: 2.2 })]);
        let p = build(
            &s,
            &PlanSpec {
                policy: Policy::DediIter,
                values: ValueModel::Markov,
                loads: LoadMethod::Markov,
            },
        );
        let opts = |c| MultiMsgOptions {
            chunks: c,
            overhead_ms: 0.0,
            trials: 20_000,
            seed: 11,
        };
        let c1 = run(&s, &p, &opts(1)).mean();
        let c8 = run(&s, &p, &opts(8)).mean();
        assert!(c1.is_finite() && c8.is_finite());
        assert!(c8 < c1, "free chunking should help: c8 {c8} ≥ c1 {c1}");
    }

    #[test]
    fn chunked_total_compute_is_distribution_preserving() {
        // Mean completion with c chunks at a SINGLE node ≈ mean of the
        // base model plus nothing: Σ of c shifted-exps has the same mean
        // as the single-block delay.
        use crate::model::params::LinkParams;
        let p = LinkParams::new(1e12, 0.2, 5.0);
        let mut rng = Rng::new(9);
        let l = 100.0;
        let c = 8usize;
        let lc = l / c as f64;
        let mut mean_sum = 0.0;
        let n = 50_000;
        for _ in 0..n {
            let mut t = 0.0;
            for _ in 0..c {
                t += p.a * lc + rng.exp(p.u / lc);
            }
            mean_sum += t;
        }
        let want = p.a * l + l / p.u; // E of single block
        let got = mean_sum / n as f64;
        assert!((got - want).abs() / want < 0.01, "{got} vs {want}");
    }
}
