//! Coding substrate: real-valued systematic MDS code + dense linear
//! algebra for the decoder.
//!
//! The paper encodes `A_m` row-wise with an MDS code over the reals and
//! recovers `A_m x_m` from **any** `L_m` coded inner products. We use a
//! systematic generator `G = [I; P]` with Gaussian parity `P` (any `L`
//! rows are invertible w.p. 1 — the standard real-field MDS construction,
//! same as [5]); decode is an `L×L` LU solve on the received-row
//! sub-generator, implemented in [`gauss`] because jax lowers
//! `linalg.solve` to a LAPACK custom-call that the text-HLO PJRT path
//! cannot execute.

pub mod gauss;
pub mod mds;

pub use gauss::Matrix;
pub use mds::MdsCode;
