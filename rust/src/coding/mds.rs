//! Real-valued systematic MDS code (§II): `Ã_m = G·A_m`, recover from any
//! `L_m` coded inner products.
//!
//! Generator `G = [I; P]` with i.i.d. Gaussian parity `P/√L`: every `L×L`
//! row sub-matrix is invertible with probability 1, giving the MDS
//! property over ℝ (construction of [5]). The encode matmul itself runs
//! through the AOT Pallas artifact in the coordinator ([`crate::runtime`]);
//! this module owns generator construction, the decode solve, and a native
//! encode used by tests and as runtime fallback for off-bucket shapes.

use super::gauss::{Lu, Matrix};
use crate::util::rng::Rng;

/// A systematic (l_coded, l) MDS code over ℝ.
#[derive(Clone, Debug)]
pub struct MdsCode {
    l: usize,
    l_coded: usize,
    g: Matrix,
}

impl MdsCode {
    /// Build a systematic generator with Gaussian parity rows.
    pub fn new(l: usize, l_coded: usize, rng: &mut Rng) -> Self {
        assert!(l > 0, "data length must be positive");
        assert!(
            l_coded >= l,
            "coded length {l_coded} must be ≥ data length {l}"
        );
        let scale = 1.0 / (l as f64).sqrt();
        let mut g = Matrix::zeros(l_coded, l);
        for i in 0..l {
            g[(i, i)] = 1.0;
        }
        for i in l..l_coded {
            for j in 0..l {
                g[(i, j)] = rng.normal() * scale;
            }
        }
        Self { l, l_coded, g }
    }

    pub fn data_len(&self) -> usize {
        self.l
    }

    pub fn coded_len(&self) -> usize {
        self.l_coded
    }

    /// Redundancy ratio `L̃/L`.
    pub fn overhead(&self) -> f64 {
        self.l_coded as f64 / self.l as f64
    }

    /// The full generator (shipped to the encode artifact as an input).
    pub fn generator(&self) -> &Matrix {
        &self.g
    }

    /// Rows `[from, to)` of the generator — the coded rows assigned to one
    /// worker.
    pub fn generator_slice(&self, from: usize, to: usize) -> Matrix {
        assert!(from <= to && to <= self.l_coded);
        self.g.select_rows(&(from..to).collect::<Vec<_>>())
    }

    /// Native encode: `Ã = G·A` (tests + off-bucket runtime fallback; the
    /// hot path uses the Pallas `mds_encode` artifact).
    pub fn encode(&self, a: &Matrix) -> Matrix {
        assert_eq!(a.rows(), self.l, "data must have {} rows", self.l);
        self.g.matmul(a)
    }

    /// Decode `z = A·x` from ≥ `L` received coded products.
    ///
    /// `received`: (coded-row index, value) pairs in arrival order. Uses
    /// the first `L` of them (the paper's master stops at `L_m` results).
    /// Returns `None` if fewer than `L` arrived or the sub-generator is
    /// singular (probability-zero for Gaussian parity).
    pub fn decode(&self, received: &[(usize, f64)]) -> Option<Vec<f64>> {
        if received.len() < self.l {
            return None;
        }
        let take = &received[..self.l];
        let idx: Vec<usize> = take.iter().map(|&(i, _)| i).collect();
        debug_assert!(idx.iter().all(|&i| i < self.l_coded));

        // Fast path: if the first L arrivals are exactly the systematic
        // rows, the values ARE the answer (common when no parity needed).
        if idx.iter().enumerate().all(|(pos, &i)| i == pos) {
            return Some(take.iter().map(|&(_, v)| v).collect());
        }

        let g_sub = self.g.select_rows(&idx);
        let b: Vec<f64> = take.iter().map(|&(_, v)| v).collect();
        Lu::new(&g_sub).solve(&b)
    }

    /// Multi-column decode for batched mat-vec (Remark 2): each received
    /// entry carries `batch` values.
    pub fn decode_batch(
        &self,
        received: &[(usize, Vec<f64>)],
        batch: usize,
    ) -> Option<Matrix> {
        if received.len() < self.l {
            return None;
        }
        let take = &received[..self.l];
        let idx: Vec<usize> = take.iter().map(|&(i, _)| i).collect();
        let g_sub = self.g.select_rows(&idx);
        let mut rhs = Matrix::zeros(self.l, batch);
        for (r, (_, vals)) in take.iter().enumerate() {
            assert_eq!(vals.len(), batch);
            rhs.row_mut(r).copy_from_slice(vals);
        }
        Lu::new(&g_sub).solve_matrix(&rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_data(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.normal()).collect(),
        )
    }

    #[test]
    fn generator_is_systematic() {
        let mut rng = Rng::new(1);
        let code = MdsCode::new(8, 12, &mut rng);
        for i in 0..8 {
            for j in 0..8 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert_eq!(code.generator()[(i, j)], want);
            }
        }
        assert!((code.overhead() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn encode_then_systematic_decode() {
        let mut rng = Rng::new(2);
        let code = MdsCode::new(16, 24, &mut rng);
        let a = random_data(&mut rng, 16, 4);
        let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        let coded = code.encode(&a);
        let y = coded.matvec(&x); // all 24 coded products
        let truth = a.matvec(&x);

        // First 16 arrivals are systematic rows: fast path.
        let rx: Vec<(usize, f64)> = (0..16).map(|i| (i, y[i])).collect();
        let z = code.decode(&rx).unwrap();
        for i in 0..16 {
            assert!((z[i] - truth[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn decode_from_any_subset() {
        let mut rng = Rng::new(3);
        let code = MdsCode::new(12, 20, &mut rng);
        let a = random_data(&mut rng, 12, 3);
        let x: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
        let y = code.encode(&a).matvec(&x);
        let truth = a.matvec(&x);

        for trial in 0..20 {
            let mut order: Vec<usize> = (0..20).collect();
            let mut r = Rng::new(100 + trial);
            r.shuffle(&mut order);
            let rx: Vec<(usize, f64)> =
                order[..12].iter().map(|&i| (i, y[i])).collect();
            let z = code.decode(&rx).expect("any 12 rows decode");
            for i in 0..12 {
                assert!(
                    (z[i] - truth[i]).abs() < 1e-6,
                    "trial {trial} row {i}: {} vs {}",
                    z[i],
                    truth[i]
                );
            }
        }
    }

    #[test]
    fn decode_insufficient_returns_none() {
        let mut rng = Rng::new(4);
        let code = MdsCode::new(10, 15, &mut rng);
        let rx: Vec<(usize, f64)> = (0..9).map(|i| (i, 1.0)).collect();
        assert!(code.decode(&rx).is_none());
    }

    #[test]
    fn decode_uses_first_l_arrivals() {
        // Extra arrivals beyond L are ignored (cancellation semantics).
        let mut rng = Rng::new(5);
        let code = MdsCode::new(6, 10, &mut rng);
        let a = random_data(&mut rng, 6, 1);
        let x = vec![1.0];
        let y = code.encode(&a).matvec(&x);
        let mut rx: Vec<(usize, f64)> = (2..10).map(|i| (i, y[i])).collect();
        rx.push((0, 999.0)); // late arrival with a corrupt value: ignored
        let z = code.decode(&rx).unwrap();
        let truth = a.matvec(&x);
        for i in 0..6 {
            assert!((z[i] - truth[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn decode_batch_matches_columnwise() {
        let mut rng = Rng::new(6);
        let code = MdsCode::new(8, 13, &mut rng);
        let a = random_data(&mut rng, 8, 2);
        let xs = random_data(&mut rng, 2, 4); // batch of 4 vectors
        let coded = code.encode(&a);
        let y = coded.matmul(&xs); // 13 x 4
        let truth = a.matmul(&xs);

        let mut order: Vec<usize> = (0..13).collect();
        rng.shuffle(&mut order);
        let rx: Vec<(usize, Vec<f64>)> = order[..8]
            .iter()
            .map(|&i| (i, y.row(i).to_vec()))
            .collect();
        let z = code.decode_batch(&rx, 4).unwrap();
        for i in 0..8 {
            for j in 0..4 {
                assert!((z[(i, j)] - truth[(i, j)]).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn generator_slice_matches_rows() {
        let mut rng = Rng::new(7);
        let code = MdsCode::new(4, 8, &mut rng);
        let s = code.generator_slice(2, 5);
        assert_eq!(s.rows(), 3);
        for r in 0..3 {
            assert_eq!(s.row(r), code.generator().row(r + 2));
        }
    }

    #[test]
    #[should_panic(expected = "must be ≥")]
    fn rejects_undersized_code() {
        MdsCode::new(10, 9, &mut Rng::new(0));
    }
}
