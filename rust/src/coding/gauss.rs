//! Dense f64 linear algebra: row-major [`Matrix`], matmul, LU with partial
//! pivoting, solve and inverse. Sized for the decoder's `L×L` systems and
//! the tests' oracles — not a BLAS replacement.

use std::fmt;

/// Row-major dense matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, Vec::len);
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        Self {
            rows: r,
            cols: c,
            data: rows.concat(),
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Select a subset of rows (the decoder's `G_S`).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (dst, &src) in idx.iter().enumerate() {
            out.row_mut(dst).copy_from_slice(self.row(src));
        }
        out
    }

    /// Vertical stack.
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix::from_vec(self.rows + other.rows, self.cols, data)
    }

    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(
            self.cols, other.rows,
            "matmul: {}x{} · {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        let mut out = Matrix::zeros(self.rows, other.cols);
        // ikj loop order: streams `other` rows, decent cache behavior.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += aik * b;
                }
            }
        }
        out
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Max-abs element (for residual checks).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, &x| m.max(x.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// LU factorization with partial pivoting: `P·A = L·U`.
pub struct Lu {
    lu: Matrix,
    /// Row permutation: `perm[i]` = original row in position i.
    perm: Vec<usize>,
    singular: bool,
}

impl Lu {
    pub fn new(a: &Matrix) -> Self {
        assert_eq!(a.rows, a.cols, "LU needs a square matrix");
        let n = a.rows;
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut singular = false;

        for col in 0..n {
            // Pivot: largest |value| in this column at/below the diagonal.
            let mut piv = col;
            let mut best = lu[(col, col)].abs();
            for r in col + 1..n {
                let v = lu[(r, col)].abs();
                if v > best {
                    best = v;
                    piv = r;
                }
            }
            if best < 1e-12 {
                singular = true;
                continue;
            }
            if piv != col {
                perm.swap(piv, col);
                for j in 0..n {
                    let tmp = lu[(col, j)];
                    lu[(col, j)] = lu[(piv, j)];
                    lu[(piv, j)] = tmp;
                }
            }
            let d = lu[(col, col)];
            // §Perf item 4: slice-based elimination — split the buffer at
            // the pivot row so the inner update is a bounds-check-free
            // zip over contiguous slices (vectorizable).
            let (top, bottom) = lu.data.split_at_mut((col + 1) * n);
            let pivot_tail = &top[col * n + col + 1..(col + 1) * n];
            for r in 0..n - col - 1 {
                let row = &mut bottom[r * n..(r + 1) * n];
                let f = row[col] / d;
                row[col] = f;
                for (x, &p) in row[col + 1..].iter_mut().zip(pivot_tail) {
                    *x -= f * p;
                }
            }
        }
        Self { lu, perm, singular }
    }

    pub fn is_singular(&self) -> bool {
        self.singular
    }

    /// Solve `A x = b` for one right-hand side.
    pub fn solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        if self.singular {
            return None;
        }
        let n = self.lu.rows;
        assert_eq!(b.len(), n);
        // Apply permutation, forward substitution (L has unit diagonal).
        let mut y: Vec<f64> = (0..n).map(|i| b[self.perm[i]]).collect();
        for i in 0..n {
            for j in 0..i {
                y[i] -= self.lu[(i, j)] * y[j];
            }
        }
        // Back substitution.
        for i in (0..n).rev() {
            for j in i + 1..n {
                let yj = y[j];
                y[i] -= self.lu[(i, j)] * yj;
            }
            y[i] /= self.lu[(i, i)];
        }
        Some(y)
    }

    /// Solve with a matrix right-hand side (column-wise).
    pub fn solve_matrix(&self, b: &Matrix) -> Option<Matrix> {
        let n = self.lu.rows;
        assert_eq!(b.rows, n);
        let mut out = Matrix::zeros(n, b.cols);
        let mut col = vec![0.0; n];
        for c in 0..b.cols {
            for r in 0..n {
                col[r] = b[(r, c)];
            }
            let x = self.solve(&col)?;
            for r in 0..n {
                out[(r, c)] = x[r];
            }
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let data = (0..r * c).map(|_| rng.normal()).collect();
        Matrix::from_vec(r, c, data)
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = random_matrix(&mut rng, 4, 4);
        let i = Matrix::identity(4);
        assert_eq!(a.matmul(&i).data(), a.data());
        assert_eq!(i.matmul(&a).data(), a.data());
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(2);
        let a = random_matrix(&mut rng, 5, 7);
        let x: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        let via_mm = a.matmul(&Matrix::from_vec(7, 1, x.clone()));
        let via_mv = a.matvec(&x);
        for i in 0..5 {
            assert!((via_mm[(i, 0)] - via_mv[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn lu_solves_random_systems() {
        let mut rng = Rng::new(3);
        for n in [1, 2, 5, 20, 64] {
            let a = random_matrix(&mut rng, n, n);
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x_true);
            let x = Lu::new(&a).solve(&b).expect("nonsingular");
            for i in 0..n {
                assert!(
                    (x[i] - x_true[i]).abs() < 1e-8 * (1.0 + x_true[i].abs()),
                    "n={n} i={i}: {} vs {}",
                    x[i],
                    x_true[i]
                );
            }
        }
    }

    #[test]
    fn lu_detects_singularity() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        let lu = Lu::new(&a);
        assert!(lu.is_singular());
        assert!(lu.solve(&[1.0, 1.0]).is_none());
    }

    #[test]
    fn lu_requires_pivoting_case() {
        // Zero on the diagonal: fails without partial pivoting.
        let a = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let x = Lu::new(&a).solve(&[3.0, 7.0]).unwrap();
        assert!((x[0] - 7.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn solve_matrix_multi_rhs() {
        let mut rng = Rng::new(4);
        let a = random_matrix(&mut rng, 6, 6);
        let xs = random_matrix(&mut rng, 6, 3);
        let b = a.matmul(&xs);
        let got = Lu::new(&a).solve_matrix(&b).unwrap();
        for i in 0..6 {
            for j in 0..3 {
                assert!((got[(i, j)] - xs[(i, j)]).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn select_rows_and_vstack() {
        let a = Matrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]);
        let s = a.select_rows(&[2, 0]);
        assert_eq!(s.data(), &[3.0, 1.0]);
        let v = s.vstack(&a);
        assert_eq!(v.rows(), 5);
        assert_eq!(v.data(), &[3.0, 1.0, 1.0, 2.0, 3.0]);
    }
}
